"""Dynamic workload adaptation (the paper's Fig. 8 scenario).

MnasNet + InceptionV4 under step-changing request rates; the online
controller re-estimates rates in a sliding window and re-plans every 30 s.

    PYTHONPATH=src python examples/dynamic_adaptation.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.paper_models import paper_profile
from repro.core.allocator import edge_tpu_compiler_plan
from repro.core.planner import TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.controller import run_adaptive
from repro.serving.simulator import simulate
from repro.serving.workload import RatePhase, dynamic_trace


def main() -> None:
    hw = EDGE_TPU_PLATFORM
    profiles = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
    phases = [
        RatePhase(0.0, 300.0, (5.0, 1.0)),
        RatePhase(300.0, 600.0, (5.0, 3.0)),
        RatePhase(600.0, 900.0, (5.0, 5.0)),
    ]
    trace = dynamic_trace(phases, seed=0)
    res = run_adaptive(
        profiles, trace, hw, hw.cpu.n_cores,
        replan_period=30.0, window=30.0, initial_rates=(5.0, 1.0),
    )
    print(f"adaptive: mean latency {res.sim.overall_mean()*1e3:.1f} ms, "
          f"{len(res.plans)} plans, "
          f"max allocator time {max(res.plan_compute_seconds)*1e3:.2f} ms")
    changes = [
        (t, p.partition, p.cores)
        for t, p in zip(res.replan_times, res.plans)
    ]
    seen = None
    for t, part, cores in changes:
        if (part, cores) != seen:
            print(f"  t={t:6.0f}s plan: partition={list(part)} cores={list(cores)}")
            seen = (part, cores)

    ts = [TenantSpec(p, 3.0) for p in profiles]
    static = simulate(ts, edge_tpu_compiler_plan(ts), hw, trace)
    print(f"static compiler baseline: {static.overall_mean()*1e3:.1f} ms "
          f"(adaptive is {100*(1-res.sim.overall_mean()/static.overall_mean()):.1f}% lower)")


if __name__ == "__main__":
    main()
