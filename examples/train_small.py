"""Train a reduced-config model with the full training substrate
(AdamW + WSD schedule + microbatching + checkpointing + data pipeline).

    PYTHONPATH=src python examples/train_small.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import batches_for_arch
from repro.models.transformer import init_params
from repro.training.checkpoint import restore, save
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.schedule import wsd_schedule
from repro.training.train_loop import TrainConfig, make_train_step


def main() -> None:
    cfg = get_arch("minicpm-2b").reduced()   # WSD is MiniCPM's signature
    steps = 60
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=3e-3), n_microbatches=2
    )
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = adamw_init(params, tcfg.optimizer)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    losses = []
    for step, batch in zip(range(steps), batches_for_arch(cfg, 8, 64)):
        scale = wsd_schedule(step, total_steps=steps)
        params, opt, m = step_fn(params, opt, batch, scale)
        losses.append(float(m["loss"]))
        if step % 10 == 0:
            print(f"step {step:3d} loss {losses[-1]:.4f} lr x{float(scale):.3f}")
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training must reduce loss"

    path = "/tmp/repro_ckpt_minicpm"
    save(path, params, {"arch": cfg.name})
    params2 = restore(path, params)
    import numpy as np
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("checkpoint round-trip OK")


if __name__ == "__main__":
    main()
