"""Fleet serving: 8 tenants planned across a 4-device heterogeneous fleet.

The two-level planner (``fleet_hill_climb``) places each tenant on a
device, hill-climbs every device's local partition/core plan, and the
fleet simulator replays one Poisson trace split across the devices.  The
same mix is also round-robin-placed for contrast, and the adaptive fleet
controller then runs a two-phase dynamic trace where a sustained rate
skew triggers a placement re-plan.

    PYTHONPATH=src python examples/fleet_serve.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.paper_models import paper_profile
from repro.core.fleet import (
    DeviceSpec,
    fleet_hill_climb,
    round_robin_fleet_plan,
)
from repro.core.planner import TenantSpec
from repro.serving.fleet import run_adaptive_fleet, simulate_fleet
from repro.serving.workload import RatePhase, dynamic_trace, poisson_trace


def main() -> None:
    # Four device classes: an overclocked full-spec box, the reference
    # 8 MB Edge TPU, and two cut-down devices (less SRAM, slower swap
    # path, fewer host cores, slower TPU/CPU).
    fleet = [
        DeviceSpec("fast", 8 << 20, 400e6, 4, tpu_speed=1.2),
        DeviceSpec("ref", 8 << 20, 400e6, 4),
        DeviceSpec("small", 4 << 20, 200e6, 2, tpu_speed=0.6, cpu_speed=0.7),
        DeviceSpec("tiny", 2 << 20, 100e6, 2, tpu_speed=0.4, cpu_speed=0.5),
    ]
    names = [
        "squeezenet", "mobilenetv2", "efficientnet", "mnasnet",
        "gpunet", "densenet201", "resnet50v2", "xception",
    ]
    tenants = [
        TenantSpec(paper_profile(n), 2.0 + 0.5 * i)
        for i, n in enumerate(names)
    ]
    rates = [t.rate for t in tenants]

    fleet_plan, obj = fleet_hill_climb(tenants, fleet)
    rr_plan, _ = round_robin_fleet_plan(tenants, fleet)
    print("placement (planned):")
    for i, t in enumerate(tenants):
        d = fleet_plan.placement[i][0]
        plan = fleet_plan.device_plans[d]
        print(f"  {names[i]:>13} -> {fleet[d].name:<5} "
              f"p={plan.partition[i]} cores={plan.cores[i]}")

    trace = poisson_trace(rates, 200.0, seed=5)
    res = simulate_fleet(tenants, fleet_plan, fleet, trace)
    res_rr = simulate_fleet(tenants, rr_plan, fleet, trace)
    mean = res.request_weighted_mean(rates)
    mean_rr = res_rr.request_weighted_mean(rates)
    print(f"planned placement:     mean latency {mean*1e3:7.1f} ms "
          f"(per-TPU util {res.tpu_utilization:.2f})")
    print(f"round-robin placement: mean latency {mean_rr*1e3:7.1f} ms "
          f"(per-TPU util {res_rr.tpu_utilization:.2f})")
    print(f"placement win: {100*(1 - mean/mean_rr):.1f}% lower mean latency")

    # Dynamic phase: traffic migrates onto the two heaviest models; the
    # controller's warm re-plans absorb small drift, and the sustained
    # offered-load skew trips the placement re-plan gate.
    base = tuple(1.0 for _ in tenants)
    skew = tuple(8.0 if i >= 6 else 0.3 for i in range(len(tenants)))
    dyn = dynamic_trace(
        [RatePhase(0.0, 80.0, base), RatePhase(80.0, 240.0, skew)], seed=13
    )
    ares = run_adaptive_fleet(
        [t.profile for t in tenants], dyn, fleet,
        replan_period=20.0, imbalance_threshold=0.15, imbalance_patience=2,
    )
    print(f"adaptive fleet: {len(ares.replan_times)} re-plan boundaries, "
          f"placement re-planned at t={ares.placement_replan_times}, "
          f"mean latency {ares.sim.overall_mean()*1e3:.1f} ms")


if __name__ == "__main__":
    main()
