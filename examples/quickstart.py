"""Quickstart: SwapLess in 60 seconds.

Plans collaborative TPU-CPU execution for a single memory-oversized model
(InceptionV4, 43.2 MB vs 8 MB SRAM), compares against the default Edge TPU
compiler behaviour, and validates the analytic prediction with the
discrete-event simulator.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.paper_models import paper_profile
from repro.core import latency
from repro.core.allocator import edge_tpu_compiler_plan, hill_climb
from repro.core.planner import TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace


def main() -> None:
    hw = EDGE_TPU_PLATFORM
    rate = 4.0  # requests/s
    tenants = [TenantSpec(paper_profile("inceptionv4"), rate)]

    # Default: everything on the TPU -> intra-model swapping every request.
    base = edge_tpu_compiler_plan(tenants)
    base_pred = latency.predict(tenants, base, hw)
    print(f"[compiler]  full-TPU      predicted {base_pred.latencies[0]*1e3:7.1f} ms")

    # SwapLess: Algorithm 1 picks the partition point + CPU cores.
    plan, _ = hill_climb(tenants, hw, hw.cpu.n_cores)
    pred = latency.predict(tenants, plan, hw)
    p = plan.partition[0]
    print(
        f"[swapless]  prefix={p}/11 cores={plan.cores[0]} "
        f"predicted {pred.latencies[0]*1e3:7.1f} ms "
        f"(-{100*(1-pred.latencies[0]/base_pred.latencies[0]):.1f}%)"
    )

    # Validate against the simulator (plays the role of the paper's testbed).
    reqs = poisson_trace([rate], duration=1000.0, seed=0)
    for name, pl in [("compiler", base), ("swapless", plan)]:
        sim = simulate(tenants, pl, hw, reqs)
        print(f"[{name:>8s}]  simulated     observed {sim.mean_latency(0)*1e3:7.1f} ms")


if __name__ == "__main__":
    main()
