"""End-to-end driver: multi-tenant collaborative serving with batched
requests through the real execution engine.

Three co-located CNNs (combined footprint >> 8 MB SRAM) are planned by
SwapLess, then actual JAX inference requests flow through the global
accelerator worker + per-model CPU pools.  The analytic model, the DES, and
the real engine all run on the same plan.

    PYTHONPATH=src python examples/multi_tenant_serve.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.paper_models import paper_profile
from repro.core import latency
from repro.core.allocator import edge_tpu_compiler_plan, swapless_plan
from repro.core.planner import TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.models.cnn import PAPER_CNN_SPECS, build_executable
from repro.serving.engine import ServingEngine
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace

NAMES = ["densenet201", "resnet50v2", "gpunet"]
RATES = [1.2, 1.2, 2.0]
K_MAX = 4


def main() -> None:
    hw = EDGE_TPU_PLATFORM
    tenants = [TenantSpec(paper_profile(n), r) for n, r in zip(NAMES, RATES)]

    plan = swapless_plan(tenants, hw, K_MAX)
    base = edge_tpu_compiler_plan(tenants)
    pred = latency.predict(tenants, plan, hw)
    print("plan:", dict(zip(NAMES, zip(plan.partition, plan.cores))))
    print("alphas:", [f"{a:.2f}" for a in pred.alphas])

    reqs = poisson_trace(RATES, duration=1500.0, seed=1)
    sim = simulate(tenants, plan, hw, reqs)
    simb = simulate(tenants, base, hw, reqs)
    print(
        f"DES mean latency: swapless {sim.overall_mean()*1e3:.1f} ms vs "
        f"compiler {simb.overall_mean()*1e3:.1f} ms "
        f"(-{100*(1 - sim.overall_mean()/simb.overall_mean()):.1f}%)"
    )

    # Batched requests through the real engine.
    models = [build_executable(PAPER_CNN_SPECS[n], seed=i) for i, n in enumerate(NAMES)]
    eng = ServingEngine(models, plan, k_max=K_MAX)
    try:
        n_req = 8
        for i, m in enumerate(models):
            for s in range(n_req):
                eng.submit(i, m.make_input(s))
        done = eng.drain(timeout=180.0)
        print(f"real engine: {len(done)}/{len(NAMES)*n_req} requests completed")
        for i, n in enumerate(NAMES):
            outs = [c for c in done if c.model_idx == i]
            ok = all(np.isfinite(np.asarray(c.output)).all() for c in outs)
            print(f"  {n:<14} n={len(outs)} outputs_finite={ok}")
    finally:
        eng.shutdown()


if __name__ == "__main__":
    main()
