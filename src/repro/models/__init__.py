from repro.models.transformer import (
    backbone,
    count_params,
    decode_step,
    forward_loss,
    init_decode_caches,
    init_params,
)

__all__ = [
    "backbone",
    "count_params",
    "decode_step",
    "forward_loss",
    "init_decode_caches",
    "init_params",
]
