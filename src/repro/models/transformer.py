"""Unified decoder model covering all assigned architecture families.

Design:

* **Scan-over-layers** with stacked weights for training/prefill -- HLO size
  is O(1) in depth, so 64-layer models compile quickly even under the
  512-device dry-run.  The scan unit is a *layer group* of
  ``cfg.group_size`` layers (``moe_period`` for MoE archs so dense/MoE
  layers can alternate with heterogeneous params).
* **Per-layer local/global attention** is handled inside one homogeneous
  scan via a traced per-layer window value (0 = global), so gemma3's 5:1
  pattern, llama4's chunked-local pattern, and hymba's 3 full-attention
  layers all share one code path.
* **Decode** uses an unrolled Python loop over layers with per-layer caches:
  full-attention layers keep O(S) KV caches; sliding-window layers keep
  O(window) ring buffers; SSM/RWKV layers carry O(1) state.  This is what
  makes long_500k decoding feasible for sub-quadratic archs.
* Blocks are pre-norm residual; the final projection unembeds to the vocab.

Modality frontends (vision/audio) are *stubs by assignment*: ``input_specs``
provides precomputed patch/frame embeddings; a learned linear projector
maps them into d_model (the only trained frontend piece).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Params,
    mlp_forward,
    mlp_init,
    mlp_param_count,
    rms_norm,
)
from repro.models.sharding_utils import constrain

DEFAULT_DTYPE = jnp.bfloat16


# ==========================================================================
# Parameter construction
# ==========================================================================
def _layer_init(cfg: ArchConfig, key: jax.Array, layer_idx: int, dtype) -> Params:
    """Parameters for one layer (within a group)."""
    if cfg.block == "rwkv6":
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "rwkv": rwkv_mod.rwkv_init(
                k1, cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.decay_rank, dtype
            ),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
        }
    p: Params = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    k_attn, k_mix, k_ssm = jax.random.split(key, 3)
    p["attn"] = attn_mod.attn_init(
        k_attn,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.resolved_head_dim,
        cfg.qkv_bias,
        dtype,
    )
    if cfg.block == "hymba":
        p["ssm"] = ssm_mod.ssm_init(
            k_ssm, cfg.d_model, cfg.ssm_inner, cfg.ssm_state, dtype
        )
    if cfg.layer_is_moe(layer_idx):
        p["moe"] = moe_mod.moe_init(
            k_mix, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype
        )
    else:
        p["mlp"] = mlp_init(k_mix, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def init_params(cfg: ArchConfig, key: jax.Array, dtype=DEFAULT_DTYPE) -> Params:
    keys = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(cfg.d_model)
    params: Params = {
        "embed": (
            jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * scale
        ).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": (
            jax.random.normal(keys[1], (cfg.d_model, cfg.vocab_size)) * scale
        ).astype(dtype),
    }
    if cfg.frontend != "none":
        params["frontend_proj"] = (
            jax.random.normal(keys[2], (cfg.frontend_dim, cfg.d_model)) * scale
        ).astype(dtype)

    # Stacked layer-group params: leaf shape (n_groups, ...).
    g = cfg.group_size
    layer_keys = jax.random.split(keys[3], cfg.n_layers).reshape(
        cfg.n_groups, g, 2
    )

    def group_params(gkeys):
        return [
            _layer_init(cfg, gkeys[j], j, dtype) for j in range(g)
        ]

    # vmap the init over groups so leaves stack along axis 0.  Positions j
    # within a group have identical structure across groups (layer_is_moe
    # depends only on j mod group_size).
    params["groups"] = jax.vmap(group_params)(layer_keys)
    return params


def layer_window_values(cfg: ArchConfig) -> np.ndarray:
    """Per-layer traced window (0 = global/full attention)."""
    vals = []
    for i in range(cfg.n_layers):
        if cfg.attn_kind == "none":
            vals.append(0)
        elif cfg.layer_is_global(i):
            vals.append(0)
        else:
            vals.append(cfg.window)
    return np.asarray(vals, np.int32).reshape(cfg.n_groups, cfg.group_size)


# ==========================================================================
# Forward (training / prefill): scan over layer groups
# ==========================================================================
def _batch_token(cfg: ArchConfig) -> str:
    return "batch_full" if cfg.parallelism == "fsdp" else "batch"


def _transformer_layer(
    cfg: ArchConfig,
    p: Params,
    h: jax.Array,
    window: jax.Array,
    positions: jax.Array,
    is_moe_layer: bool,
) -> tuple[jax.Array, jax.Array]:
    """Pre-norm residual block; returns (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    if cfg.block == "rwkv6":
        B = h.shape[0]
        state = (
            jnp.zeros((B, cfg.d_model), h.dtype),
            jnp.zeros(
                (B, cfg.n_heads, cfg.resolved_head_dim, cfg.resolved_head_dim),
                jnp.float32,
            ),
        )
        y, _ = rwkv_mod.time_mix(
            x, p["rwkv"], state, n_heads=cfg.n_heads, eps=cfg.norm_eps,
            chunked=cfg.use_chunked_scan,
        )
        h = h + y
        x2 = rms_norm(h, p["ln2"], cfg.norm_eps)
        y2, _ = rwkv_mod.channel_mix(
            x2, p["rwkv"], jnp.zeros((h.shape[0], cfg.d_model), h.dtype)
        )
        return h + y2, aux

    y = attn_mod.attn_forward(
        x,
        p["attn"],
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta,
        window=window,
        positions=positions,
    )
    if cfg.block == "hymba":
        # Hymba: attention heads and SSM heads run in PARALLEL on the same
        # normed input; outputs are averaged (arXiv:2411.13676 Sec. 2).
        y_ssm, _ = ssm_mod.ssm_forward(
            x, p["ssm"], chunked=cfg.use_chunked_scan
        )
        y = 0.5 * (y + y_ssm)
    h = h + y
    x2 = rms_norm(h, p["ln2"], cfg.norm_eps)
    if is_moe_layer:
        out = moe_mod.moe_ffn(
            x2, p["moe"], k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
            weight_gather=cfg.moe_weight_gather,
        )
        y2 = out.y
        aux = aux + out.aux_loss
    else:
        y2 = mlp_forward(x2, p["mlp"], cfg.mlp)
    return constrain(h + y2, _batch_token(cfg), None, None), aux


def backbone(
    cfg: ArchConfig,
    params: Params,
    h: jax.Array,
    positions: jax.Array | None = None,
    *,
    remat: bool = True,
    remat_policy: str = "nothing",
) -> tuple[jax.Array, jax.Array]:
    """Run all layers; returns (hidden_states, total_aux_loss)."""
    S = h.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    windows = jnp.asarray(layer_window_values(cfg))  # (G, group)

    def group_fn(carry, xs):
        h, aux = carry
        gp, win = xs
        for j in range(cfg.group_size):
            pj = jax.tree.map(lambda a: a, gp[j])
            h, a = _transformer_layer(
                cfg, pj, h, win[j], positions, cfg.layer_is_moe(j)
            )
            aux = aux + a
        return (h, aux), None

    if remat:
        policy = (
            jax.checkpoint_policies.dots_saveable
            if remat_policy == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        group_fn = jax.checkpoint(group_fn, policy=policy)
    (h, aux), _ = jax.lax.scan(
        group_fn,
        (h, jnp.zeros((), jnp.float32)),
        (params["groups"], windows),
    )
    return h, aux


# ==========================================================================
# Inputs / embeddings
# ==========================================================================
def embed_inputs(
    cfg: ArchConfig, params: Params, batch: dict[str, jax.Array]
) -> tuple[jax.Array, jax.Array | None]:
    """Returns (h (B,S,D), loss_mask or None).

    * text archs: batch["tokens"] (B, S) int32.
    * vlm: frontend patch embeddings are prepended to token embeddings;
      patch positions are masked out of the loss.
    * audio: batch["frame_embeds"] (B, S, frontend_dim) projected to d_model;
      labels are EnCodec codes in batch["labels"].
    """
    if cfg.frontend == "vision":
        tok = params["embed"][batch["tokens"]]
        patches = batch["patch_embeds"] @ params["frontend_proj"]
        h = jnp.concatenate([patches.astype(tok.dtype), tok], axis=1)
        B, P = patches.shape[0], patches.shape[1]
        S_text = tok.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((B, P), jnp.float32), jnp.ones((B, S_text), jnp.float32)],
            axis=1,
        )
        return constrain(h, _batch_token(cfg), None, None), mask
    if cfg.frontend == "audio":
        h = batch["frame_embeds"] @ params["frontend_proj"]
        return constrain(h, _batch_token(cfg), None, None), None
    return constrain(params["embed"][batch["tokens"]], _batch_token(cfg), None, None), None


def unembed(cfg: ArchConfig, params: Params, h: jax.Array) -> jax.Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    if cfg.parallelism == "fsdp":
        return constrain(logits, "batch_full", None, None)
    return constrain(logits, "batch", None, "model")


def forward_loss(
    cfg: ArchConfig,
    params: Params,
    batch: dict[str, jax.Array],
    *,
    remat: bool = True,
    remat_policy: str = "nothing",
    aux_weight: float = 0.01,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Next-token cross-entropy (+ MoE aux) for one microbatch."""
    h, loss_mask = embed_inputs(cfg, params, batch)
    h, aux = backbone(cfg, params, h, remat=remat, remat_policy=remat_policy)
    logits = unembed(cfg, params, h)                       # (B, S, V)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        # Align: prepend ignore labels for patch positions.
        B, P = h.shape[0], cfg.n_patches
        labels = jnp.concatenate(
            [jnp.zeros((B, P), labels.dtype), labels], axis=1
        )
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if loss_mask is not None:
        nll = nll * loss_mask
        denom = jnp.maximum(loss_mask.sum(), 1.0)
    else:
        denom = np.prod(nll.shape)
    ce = nll.sum() / denom
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ==========================================================================
# Decode path (unrolled, per-layer heterogeneous caches)
# ==========================================================================
def init_decode_caches(
    cfg: ArchConfig, batch: int, max_len: int, dtype=DEFAULT_DTYPE
) -> list[Any]:
    """Per-layer cache pytrees sized by the layer's attention kind."""
    caches: list[Any] = []
    hd = cfg.resolved_head_dim
    for i in range(cfg.n_layers):
        if cfg.block == "rwkv6":
            caches.append(
                rwkv_mod.rwkv_state_init(batch, cfg.d_model, cfg.n_heads, dtype)
            )
            continue
        size = max_len if cfg.layer_is_global(i) else min(cfg.window, max_len)
        c: dict[str, Any] = {
            "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        }
        if cfg.block == "hymba":
            c["ssm"] = ssm_mod.ssm_state_init(batch, cfg.ssm_inner, cfg.ssm_state)
            c["ssm_prev"] = jnp.zeros((batch, cfg.d_model), dtype)
        caches.append(c)
    return caches


def _layer_params_at(params: Params, layer_idx: int, cfg: ArchConfig) -> Params:
    g, j = divmod(layer_idx, cfg.group_size)
    return jax.tree.map(lambda a: a[g], params["groups"][j])


def decode_step(
    cfg: ArchConfig,
    params: Params,
    caches: list[Any],
    tokens: jax.Array,        # (B, 1) int32 (or (B,1,frontend_dim) for audio)
    cur_len: jax.Array,       # scalar int32: number of tokens already cached
) -> tuple[jax.Array, list[Any]]:
    """One-token serve step: returns (logits (B,1,V), new caches)."""
    if cfg.frontend == "audio":
        h = tokens @ params["frontend_proj"]
    else:
        h = params["embed"][tokens]
    new_caches: list[Any] = []
    for i in range(cfg.n_layers):
        p = _layer_params_at(params, i, cfg)
        cache = caches[i]
        x = rms_norm(h, p["ln1"], cfg.norm_eps)
        if cfg.block == "rwkv6":
            y, (tm_shift, wkv) = rwkv_mod.time_mix(
                x,
                p["rwkv"],
                (cache["tm_shift"], cache["wkv"]),
                n_heads=cfg.n_heads,
                eps=cfg.norm_eps,
            )
            h = h + y
            x2 = rms_norm(h, p["ln2"], cfg.norm_eps)
            y2, cm_shift = rwkv_mod.channel_mix(x2, p["rwkv"], cache["cm_shift"])
            h = h + y2
            new_caches.append(
                {"tm_shift": tm_shift, "wkv": wkv, "cm_shift": cm_shift}
            )
            continue

        is_global = cfg.layer_is_global(i)
        if is_global:
            y, k_c, v_c = attn_mod.attn_decode_step(
                x, p["attn"], cache["k"], cache["v"], cur_len,
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim,
                rope_theta=cfg.rope_theta,
                window=0,
            )
        else:
            y, k_c, v_c = attn_mod.attn_decode_step_ring(
                x, p["attn"], cache["k"], cache["v"], cur_len,
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim,
                rope_theta=cfg.rope_theta,
            )
        new_cache = {"k": k_c, "v": v_c}
        if cfg.block == "hymba":
            y_ssm, ssm_state = ssm_mod.ssm_forward(x, p["ssm"], cache["ssm"])
            y = 0.5 * (y + y_ssm)
            new_cache["ssm"] = ssm_state
            new_cache["ssm_prev"] = cache["ssm_prev"]
        h = h + y
        x2 = rms_norm(h, p["ln2"], cfg.norm_eps)
        if cfg.layer_is_moe(i):
            out = moe_mod.moe_ffn(
                x2, p["moe"], k=cfg.experts_per_token,
                capacity_factor=cfg.capacity_factor,
                weight_gather=cfg.moe_weight_gather,
            )
            y2 = out.y
        else:
            y2 = mlp_forward(x2, p["mlp"], cfg.mlp)
        h = constrain(h + y2, _batch_token(cfg), None, None)
        new_caches.append(new_cache)
    return unembed(cfg, params, h), new_caches


def prefill_step(
    cfg: ArchConfig,
    params: Params,
    batch: dict[str, jax.Array],
    max_len: int,
) -> tuple[jax.Array, list[Any]]:
    """Process a full prompt; returns (last-token logits, decode caches).

    Layers run in an unrolled Python loop (like decode) so heterogeneous
    per-layer cache shapes are possible: full layers keep the whole context,
    sliding-window layers keep only the trailing ``window`` tokens, SSM/RWKV
    layers keep O(1) state.
    """
    h, _ = embed_inputs(cfg, params, batch)
    B, S, _ = h.shape
    positions = jnp.arange(S)
    caches: list[Any] = []
    for i in range(cfg.n_layers):
        p = _layer_params_at(params, i, cfg)
        x = rms_norm(h, p["ln1"], cfg.norm_eps)
        if cfg.block == "rwkv6":
            zero = (
                jnp.zeros((B, cfg.d_model), h.dtype),
                jnp.zeros(
                    (B, cfg.n_heads, cfg.resolved_head_dim, cfg.resolved_head_dim),
                    jnp.float32,
                ),
            )
            y, (tm_shift, wkv) = rwkv_mod.time_mix(
                x, p["rwkv"], zero, n_heads=cfg.n_heads, eps=cfg.norm_eps,
                chunked=cfg.use_chunked_scan,
            )
            h = h + y
            x2 = rms_norm(h, p["ln2"], cfg.norm_eps)
            y2, cm_shift = rwkv_mod.channel_mix(
                x2, p["rwkv"], jnp.zeros((B, cfg.d_model), h.dtype)
            )
            h = h + y2
            caches.append({"tm_shift": tm_shift, "wkv": wkv, "cm_shift": cm_shift})
            continue

        is_global = cfg.layer_is_global(i)
        window = 0 if is_global else cfg.window
        y, k_kv, v_kv = attn_mod.attn_forward(
            x,
            p["attn"],
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            window=window,
            positions=positions,
            return_kv=True,
        )
        cache_size = max_len if is_global else min(cfg.window, max_len)
        hd = cfg.resolved_head_dim
        k_c = jnp.zeros((B, cache_size, cfg.n_kv_heads, hd), h.dtype)
        v_c = jnp.zeros((B, cache_size, cfg.n_kv_heads, hd), h.dtype)
        if is_global:
            k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k_kv, 0, axis=1)
            v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v_kv, 0, axis=1)
        else:
            # Seed the ring buffer with the last `cache_size` tokens, laid
            # out so slot (t % W) holds token t -- matching decode's ring.
            W = cache_size
            tail_k = k_kv[:, -W:]
            tail_v = v_kv[:, -W:]
            start = S - W if S >= W else 0
            idx = (start + jnp.arange(min(W, S))) % W
            k_c = k_c.at[:, idx].set(tail_k[:, : len(idx)] if S >= W else tail_k)
            v_c = v_c.at[:, idx].set(tail_v[:, : len(idx)] if S >= W else tail_v)
        new_cache: dict[str, Any] = {"k": k_c, "v": v_c}
        if cfg.block == "hymba":
            y_ssm, ssm_state = ssm_mod.ssm_forward(
                x, p["ssm"], chunked=cfg.use_chunked_scan
            )
            y = 0.5 * (y + y_ssm)
            new_cache["ssm"] = ssm_state
            new_cache["ssm_prev"] = x[:, -1, :]
        h = h + y
        x2 = rms_norm(h, p["ln2"], cfg.norm_eps)
        if cfg.layer_is_moe(i):
            y2 = moe_mod.moe_ffn(
                x2, p["moe"], k=cfg.experts_per_token,
                capacity_factor=cfg.capacity_factor,
                weight_gather=cfg.moe_weight_gather,
            ).y
        else:
            y2 = mlp_forward(x2, p["mlp"], cfg.mlp)
        h = constrain(h + y2, _batch_token(cfg), None, None)
        caches.append(new_cache)
    logits = unembed(cfg, params, h[:, -1:, :])
    return logits, caches


# ==========================================================================
# Parameter accounting (for roofline MODEL_FLOPS)
# ==========================================================================
def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    total = cfg.vocab_size * cfg.d_model * 2           # embed + lm_head
    total += cfg.d_model                               # final norm
    if cfg.frontend != "none":
        total += cfg.frontend_dim * cfg.d_model
    for i in range(cfg.n_layers):
        total += 2 * cfg.d_model                       # ln1, ln2
        if cfg.block == "rwkv6":
            total += rwkv_mod.rwkv_param_count(
                cfg.d_model, cfg.d_ff, cfg.decay_rank
            )
            continue
        total += attn_mod.attn_param_count(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, cfg.qkv_bias,
        )
        if cfg.block == "hymba":
            total += ssm_mod.ssm_param_count(
                cfg.d_model, cfg.ssm_inner, cfg.ssm_state
            )
        if cfg.layer_is_moe(i):
            if active_only:
                total += cfg.d_model * cfg.n_experts
                total += (
                    cfg.experts_per_token * 3 * cfg.d_model * cfg.d_ff
                )
            else:
                total += moe_mod.moe_param_count(
                    cfg.d_model, cfg.d_ff, cfg.n_experts
                )
        else:
            total += mlp_param_count(cfg.d_model, cfg.d_ff, cfg.mlp)
    return total
