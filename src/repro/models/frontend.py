"""Modality-frontend stubs + batch/spec builders for every arch x shape.

Per the assignment carve-out, the vision encoder (ViT/SigLIP) and the audio
codec (EnCodec/mel+conv) are NOT implemented; ``input_specs`` provides
precomputed patch/frame embeddings of the right shape, and concrete batches
for smoke tests are drawn from a PRNG.  The learned projector that maps
frontend features into d_model lives in the transformer params.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

EMBED_DTYPE = jnp.bfloat16


def train_input_specs(
    cfg: ArchConfig, batch: int, seq_len: int
) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a train/prefill step (no allocation)."""
    if cfg.frontend == "vision":
        s_text = seq_len - cfg.n_patches
        return {
            "tokens": jax.ShapeDtypeStruct((batch, s_text), jnp.int32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (batch, cfg.n_patches, cfg.frontend_dim), EMBED_DTYPE
            ),
            "labels": jax.ShapeDtypeStruct((batch, s_text), jnp.int32),
        }
    if cfg.frontend == "audio":
        return {
            "frame_embeds": jax.ShapeDtypeStruct(
                (batch, seq_len, cfg.frontend_dim), EMBED_DTYPE
            ),
            "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }


def decode_token_specs(cfg: ArchConfig, batch: int) -> jax.ShapeDtypeStruct:
    if cfg.frontend == "audio":
        return jax.ShapeDtypeStruct((batch, 1, cfg.frontend_dim), EMBED_DTYPE)
    return jax.ShapeDtypeStruct((batch, 1), jnp.int32)


def make_train_batch(
    cfg: ArchConfig, batch: int, seq_len: int, seed: int = 0
) -> dict[str, Any]:
    """Concrete random batch matching train_input_specs (smoke tests)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.frontend == "vision":
        s_text = seq_len - cfg.n_patches
        return {
            "tokens": jax.random.randint(k1, (batch, s_text), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(
                k2, (batch, cfg.n_patches, cfg.frontend_dim), EMBED_DTYPE
            ),
            "labels": jax.random.randint(k3, (batch, s_text), 0, cfg.vocab_size),
        }
    if cfg.frontend == "audio":
        return {
            "frame_embeds": jax.random.normal(
                k1, (batch, seq_len, cfg.frontend_dim), EMBED_DTYPE
            ),
            "labels": jax.random.randint(k2, (batch, seq_len), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(k1, (batch, seq_len), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (batch, seq_len), 0, cfg.vocab_size),
    }


def make_decode_token(cfg: ArchConfig, batch: int, seed: int = 0) -> Any:
    key = jax.random.PRNGKey(seed)
    if cfg.frontend == "audio":
        return jax.random.normal(key, (batch, 1, cfg.frontend_dim), EMBED_DTYPE)
    return jax.random.randint(key, (batch, 1), 0, cfg.vocab_size)
