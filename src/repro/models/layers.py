"""Shared neural-net building blocks (pure JAX, functional).

All modules are plain functions over parameter pytrees so that layer stacks
can be ``lax.scan``-ed with stacked weights (HLO size O(1) in depth) and
partitioned at block boundaries by the SwapLess planner.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


# --------------------------------------------------------------------------
# Normalization
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dtype)


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), jnp.float32)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
NEG_INF = -1e30


def causal_window_mask(
    q_pos: jax.Array, kv_pos: jax.Array, window: jax.Array | int
) -> jax.Array:
    """(Q, K) boolean mask: causal, optionally restricted to a local window.

    ``window`` <= 0 means unrestricted (global/full attention); a traced
    value is allowed so one scanned layer stack can mix local/global layers
    via a per-layer flag.
    """
    q = q_pos[:, None]
    k = kv_pos[None, :]
    causal = k <= q
    window = jnp.asarray(window)
    in_window = jnp.where(window > 0, q - k < window, True)
    return causal & in_window


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd) for GQA."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, kv, n_rep, hd)
    ).reshape(b, s, kv * n_rep, hd)


def attention_plain(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array,
    scale: float,
) -> jax.Array:
    """Reference attention.  q:(B,Sq,H,hd) k,v:(B,Sk,H,hd) mask:(Sq,Sk)."""
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    window: jax.Array | int,
    scale: float,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp.

    Scans over query chunks; inside each, scans over KV chunks maintaining
    (m, l, acc) running statistics.  Never materializes the (Sq, Sk) score
    matrix -- required to even *lower* prefill_32k within HBM.  This is also
    the numerical oracle for the Pallas flash kernel.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0
    nq, nk = Sq // q_chunk, Sk // kv_chunk

    qs = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    qpos = q_positions.reshape(nq, q_chunk)
    ks = k.reshape(B, nk, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kpos = kv_positions.reshape(nk, kv_chunk)

    def q_block(carry, q_item):
        qb, qp = q_item  # (B,qc,H,hd), (qc,)

        def kv_block(state, kv_item):
            m, l, acc = state
            kb, vb, kp = kv_item
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qb, kb, preferred_element_type=jnp.float32
            ) * scale
            mask = causal_window_mask(qp, kp, window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (ks, vs, kpos))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return carry, out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,qc,H,hd)

    _, outs = jax.lax.scan(q_block, None, (qs, qpos))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------
def mlp_forward(x: jax.Array, p: Params, kind: str) -> jax.Array:
    """kind: swiglu | gelu | relu2 (Nemotron squared-ReLU)."""
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
        return h @ p["w_out"]
    if kind == "gelu":
        return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]
    if kind == "relu2":
        return jnp.square(jax.nn.relu(x @ p["w_in"])) @ p["w_out"]
    raise ValueError(f"unknown mlp kind {kind}")


def mlp_init(key: jax.Array, d_model: int, d_ff: int, kind: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d_model)
    scale_out = 1.0 / np.sqrt(d_ff)
    p: Params = {
        "w_in": (jax.random.normal(k1, (d_model, d_ff)) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d_model)) * scale_out).astype(dtype),
    }
    if kind == "swiglu":
        p["w_gate"] = (
            jax.random.normal(k3, (d_model, d_ff)) * scale_in
        ).astype(dtype)
    return p


def mlp_param_count(d_model: int, d_ff: int, kind: str) -> int:
    n = 2 * d_model * d_ff
    if kind == "swiglu":
        n += d_model * d_ff
    return n
