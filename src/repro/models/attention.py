"""GQA attention block: projections + RoPE + masked attention + KV cache.

Supports per-layer local/global switching via a traced ``window`` value so a
single scanned layer stack can interleave sliding-window and full-attention
layers (gemma3 5:1, llama4 iRoPE-style, hymba SWA).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (
    Params,
    apply_rope,
    attention_chunked,
    attention_plain,
    causal_window_mask,
    repeat_kv,
)

CHUNKED_SEQ_THRESHOLD = 2048  # use online-softmax path at/above this length


def attn_init(
    key: jax.Array,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    qkv_bias: bool,
    dtype,
) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d_model)
    so = 1.0 / np.sqrt(n_heads * head_dim)
    p: Params = {
        "wq": (jax.random.normal(kq, (d_model, n_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d_model, n_kv_heads * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d_model, n_kv_heads * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (n_heads * head_dim, d_model)) * so).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def attn_param_count(
    d_model: int, n_heads: int, n_kv_heads: int, head_dim: int, qkv_bias: bool
) -> int:
    n = d_model * head_dim * (2 * n_heads + 2 * n_kv_heads)
    if qkv_bias:
        n += head_dim * (n_heads + 2 * n_kv_heads)
    return n


def _project_qkv(x, p, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return (
        q.reshape(B, S, n_heads, head_dim),
        k.reshape(B, S, n_kv_heads, head_dim),
        v.reshape(B, S, n_kv_heads, head_dim),
    )


def attn_forward(
    x: jax.Array,
    p: Params,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window: jax.Array | int,
    positions: jax.Array | None = None,
    return_kv: bool = False,
):
    """Full-sequence (training / prefill) attention.  x: (B, S, D).

    ``return_kv=True`` additionally returns the post-RoPE (k, v) in
    (B, S, KV, hd) layout for KV-cache construction during prefill.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k_kv, v_kv = _project_qkv(x, p, n_heads, n_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k_kv = apply_rope(k_kv, positions, rope_theta)
    k = repeat_kv(k_kv, n_heads // n_kv_heads)
    v = repeat_kv(v_kv, n_heads // n_kv_heads)
    scale = 1.0 / np.sqrt(head_dim)
    pos1d = positions if positions.ndim == 1 else positions[0]
    if S >= CHUNKED_SEQ_THRESHOLD:
        out = attention_chunked(q, k, v, pos1d, pos1d, window, scale)
    else:
        mask = causal_window_mask(pos1d, pos1d, window)
        out = attention_plain(q, k, v, mask, scale)
    out = out.reshape(B, S, n_heads * head_dim) @ p["wo"]
    if return_kv:
        return out, k_kv, v_kv
    return out


def _gqa_cache_attention(
    q: jax.Array,          # (B, 1, H, hd)
    k_cache: jax.Array,    # (B, S, KV, hd)
    v_cache: jax.Array,    # (B, S, KV, hd)
    mask: jax.Array,       # (S,) bool
    scale: float,
) -> jax.Array:
    """Decode attention against a (possibly seq-sharded) cache.

    Grouped einsums instead of ``repeat_kv``: broadcasting query heads over
    their KV group never reshapes the cache, so a cache whose sequence dim is
    sharded over 'model' STAYS sharded -- GSPMD reduces the softmax stats and
    the weighted-V contraction with tiny all-reduces instead of all-gathering
    the multi-GB cache (distributed flash-decode; EXPERIMENTS.md §Perf C).
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale                                           # (B, KV, G, 1, S)
    s = jnp.where(mask[None, None, None, None, :], s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p_ = jnp.exp(s - m)
    denom = p_.sum(axis=-1, keepdims=True)
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", p_.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    ) / denom.reshape(B, 1, KV, G, 1)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attn_decode_step(
    x: jax.Array,
    p: Params,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window: jax.Array | int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode with a KV cache.

    x: (B, 1, D); k_cache/v_cache: (B, S_max, KV, hd); cur_len: scalar count
    of valid cache entries.  Returns (out, new_k_cache, new_v_cache).
    """
    B, _, _ = x.shape
    S_max = k_cache.shape[1]
    pos = jnp.full((B, 1), cur_len, jnp.int32)
    q, k_new, v_new = _project_qkv(x, p, n_heads, n_kv_heads, head_dim)
    q = apply_rope(q, pos, rope_theta)
    k_new = apply_rope(k_new, pos, rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, cur_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, cur_len, axis=1)

    scale = 1.0 / np.sqrt(head_dim)
    kv_pos = jnp.arange(S_max)
    window = jnp.asarray(window)
    valid = kv_pos <= cur_len
    in_window = jnp.where(window > 0, cur_len - kv_pos < window, True)
    mask = valid & in_window                                   # (S_max,)
    out = _gqa_cache_attention(q, k_cache, v_cache, mask, scale)
    out = out.reshape(B, 1, n_heads * head_dim) @ p["wo"]
    return out, k_cache, v_cache


def attn_decode_step_ring(
    x: jax.Array,
    p: Params,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a *ring-buffered* sliding-window cache.

    The cache holds only the last ``W`` tokens (W = cache size); slot
    ``cur_len % W`` is overwritten each step.  RoPE is applied with absolute
    positions at insertion, so attention logits need no per-slot position
    bookkeeping -- only an occupancy mask.  This is what makes long_500k
    decode memory O(window) instead of O(seq) for local layers.
    """
    B, _, _ = x.shape
    W = k_cache.shape[1]
    pos = jnp.full((B, 1), cur_len, jnp.int32)
    q, k_new, v_new = _project_qkv(x, p, n_heads, n_kv_heads, head_dim)
    q = apply_rope(q, pos, rope_theta)
    k_new = apply_rope(k_new, pos, rope_theta)
    slot = jnp.mod(cur_len, W)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot, axis=1)

    scale = 1.0 / np.sqrt(head_dim)
    occupied = jnp.arange(W) <= cur_len  # ring fully valid once len >= W
    out = _gqa_cache_attention(q, k_cache, v_cache, occupied, scale)
    out = out.reshape(B, 1, n_heads * head_dim) @ p["wo"]
    return out, k_cache, v_cache
