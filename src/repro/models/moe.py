"""Mixture-of-Experts FFN with GShard-style capacity-bounded dense dispatch.

TPU adaptation note (DESIGN.md Sec. 2): CUDA MoE stacks use ragged
gather/scatter + NCCL all-to-all.  The TPU-native formulation keeps dispatch
as dense one-hot einsums (MXU-friendly, statically shaped) with a capacity
bound; expert weights shard over the mesh ('data' on the expert axis when
divisible, 'model' on d_ff), and GSPMD lowers the dispatch einsums into
all-to-all/all-gather collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Params
from repro.models.sharding_utils import constrain


def moe_init(
    key: jax.Array,
    d_model: int,
    d_ff: int,
    n_experts: int,
    dtype,
) -> Params:
    kr, kg, ki, ko = jax.random.split(key, 4)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "router": (jax.random.normal(kr, (d_model, n_experts)) * s_in).astype(
            jnp.float32
        ),
        "w_gate": (
            jax.random.normal(kg, (n_experts, d_model, d_ff)) * s_in
        ).astype(dtype),
        "w_in": (
            jax.random.normal(ki, (n_experts, d_model, d_ff)) * s_in
        ).astype(dtype),
        "w_out": (
            jax.random.normal(ko, (n_experts, d_ff, d_model)) * s_out
        ).astype(dtype),
    }


def moe_param_count(d_model: int, d_ff: int, n_experts: int) -> int:
    return d_model * n_experts + n_experts * 3 * d_model * d_ff


def expert_capacity(
    n_tokens: int, n_experts: int, k: int, capacity_factor: float
) -> int:
    cap = int(np.ceil(n_tokens * k * capacity_factor / n_experts))
    return max(8, int(np.ceil(cap / 8)) * 8)  # pad for tiling friendliness


@dataclasses.dataclass
class MoEOutput:
    y: jax.Array
    aux_loss: jax.Array          # load-balance loss (Shazeer-style)
    router_entropy: jax.Array


def _moe_groups(
    xg: jax.Array,           # (G, gs, D) token groups
    p: Params,
    k: int,
    C: int,
    weight_gather: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Capacity-bounded dispatch within each group.

    Returns (y (G, gs, D), frac_tokens (E,), frac_probs (E,)).  The dispatch
    tensor is (G, gs, E, C) -- bounded by the group size, not the global
    token count, which is what keeps 32k-sequence prefill lowerable.
    """
    G, gs, D = xg.shape
    E = p["router"].shape[-1]

    logits = xg.astype(jnp.float32) @ p["router"]              # (G, gs, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (G, gs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    assign = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)    # (G, gs, k, E)
    gates_te = jnp.einsum("gtk,gtke->gte", gate_vals, assign)
    mask_te = assign.sum(2)                                    # (G, gs, E)
    pos_te = jnp.cumsum(mask_te, axis=1) - mask_te             # within-group
    keep = mask_te * (pos_te < C)
    pos_cl = jnp.minimum(pos_te, C - 1).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(pos_cl, C, dtype=xg.dtype)        # (G, gs, E, C)
    dispatch = slot_oh * keep[..., None].astype(xg.dtype)
    combine = dispatch * gates_te[..., None].astype(xg.dtype)

    xe = jnp.einsum("gtd,gtec->gecd", xg, dispatch)            # (G, E, C, D)
    w_gate, w_in, w_out = p["w_gate"], p["w_in"], p["w_out"]
    if weight_gather:
        # Expert-parallel compute layout (grok-style E-over-'model'):
        #  * expert weights: keep E sharded, all-gather the intra-expert
        #    shards at use (~MB slices) instead of all-reducing the ~GB
        #    (G,E,C,F) partial sums a sharded-D contraction would produce;
        #  * dispatched tokens: shard the capacity dim over 'data' so the
        #    FFN flops stay 256-way sharded (E x C) with no partial sums.
        w_gate = constrain(w_gate, "model", None, None)
        w_in = constrain(w_in, "model", None, None)
        w_out = constrain(w_out, "model", None, None)
        xe = constrain(xe, None, "model", "data", None)
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", xe, w_gate)
    ) * jnp.einsum("gecd,edf->gecf", xe, w_in)
    ye = jnp.einsum("gecf,efd->gecd", h, w_out)
    if weight_gather:
        ye = constrain(ye, None, "model", "data", None)
    y = jnp.einsum("gecd,gtec->gtd", ye, combine)
    return y, mask_te.mean((0, 1)), probs.mean((0, 1))


def moe_ffn(
    x: jax.Array,
    p: Params,
    *,
    k: int,
    capacity_factor: float = 1.25,
    group_size: int = 1024,
    scan_group_chunk: int = 64,
    weight_gather: bool = False,
) -> MoEOutput:
    """x: (B, S, D) -> (B, S, D) via grouped top-k capacity dispatch.

    Tokens are split into groups of ``group_size`` (GShard-style) with
    per-group capacity; when there are many groups (long prefill) the groups
    are processed ``scan_group_chunk`` at a time under lax.map to bound live
    memory.
    """
    B, S, D = x.shape
    E = p["router"].shape[-1]
    T = B * S
    gs = min(group_size, T)
    while T % gs:
        gs //= 2
    gs = max(gs, 1)
    G = T // gs
    C = expert_capacity(gs, E, k, capacity_factor)
    xg = x.reshape(G, gs, D)

    if G > scan_group_chunk and G % scan_group_chunk == 0:
        n_chunks = G // scan_group_chunk
        xc = xg.reshape(n_chunks, scan_group_chunk, gs, D)
        y, ft, fp = jax.lax.map(
            lambda xi: _moe_groups(xi, p, k, C, weight_gather), xc
        )
        y = y.reshape(G, gs, D)
        frac_tokens, frac_probs = ft.mean(0), fp.mean(0)
    else:
        y, frac_tokens, frac_probs = _moe_groups(xg, p, k, C, weight_gather)

    aux = E * jnp.sum(frac_tokens * frac_probs)
    entropy = -jnp.sum(frac_probs * jnp.log(frac_probs + 1e-9))
    return MoEOutput(
        y=y.reshape(B, S, D).astype(x.dtype),
        aux_loss=aux,
        router_entropy=entropy,
    )
