"""RWKV6 ("Finch") block: attention-free time-mix with data-dependent decay.

The WKV6 recurrence per head (state S in R^{hd x hd}):

    out_t = r_t^T (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T

with w_t = exp(-exp(w0 + lora(x_t))) the *data-dependent* decay -- the
paper's headline feature (arXiv:2404.05892).  Token-shift interpolation uses
static mu parameters (the low-rank data-dependent shift of full RWKV6 is
orthogonal to the systems behaviour studied here; noted in DESIGN.md).

TPU adaptation: the CUDA WKV kernel is re-expressed as (a) a lax.scan
recurrence (HLO = one While op, O(1) program size in T) for the reference
path and (b) a chunked formulation (kernels/rwkv*) that turns the inner work
into MXU matmuls -- within-chunk parallel, cross-chunk sequential carry.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Params, rms_norm


def rwkv_init(
    key: jax.Array, d_model: int, d_ff: int, n_heads: int, decay_rank: int, dtype
) -> Params:
    head_dim = d_model // n_heads
    keys = jax.random.split(key, 10)
    s = 1.0 / np.sqrt(d_model)
    return {
        # time-mix
        "mu": jnp.full((5, d_model), 0.5, dtype),  # r,k,v,w,g lerp coeffs
        "w0": jnp.full((n_heads, head_dim), -2.0, jnp.float32),
        "w_lora_a": (jax.random.normal(keys[0], (d_model, decay_rank)) * s).astype(dtype),
        "w_lora_b": (
            jax.random.normal(keys[1], (decay_rank, d_model)) / np.sqrt(decay_rank)
        ).astype(dtype),
        "u": jnp.zeros((n_heads, head_dim), jnp.float32),
        "wr": (jax.random.normal(keys[2], (d_model, d_model)) * s).astype(dtype),
        "wk": (jax.random.normal(keys[3], (d_model, d_model)) * s).astype(dtype),
        "wv": (jax.random.normal(keys[4], (d_model, d_model)) * s).astype(dtype),
        "wg": (jax.random.normal(keys[5], (d_model, d_model)) * s).astype(dtype),
        "wo": (jax.random.normal(keys[6], (d_model, d_model)) * s).astype(dtype),
        "ln_x": jnp.zeros((d_model,), dtype),
        # channel-mix (squared-relu, RWKV convention)
        "mu_c": jnp.full((2, d_model), 0.5, dtype),
        "ck": (jax.random.normal(keys[7], (d_model, d_ff)) * s).astype(dtype),
        "cv": (
            jax.random.normal(keys[8], (d_ff, d_model)) / np.sqrt(d_ff)
        ).astype(dtype),
        "cr": (jax.random.normal(keys[9], (d_model, d_model)) * s).astype(dtype),
    }


def rwkv_param_count(d_model: int, d_ff: int, decay_rank: int) -> int:
    return (
        5 * d_model
        + 2 * d_model                      # w0, u
        + 2 * d_model * decay_rank
        + 5 * d_model * d_model            # wr wk wv wg wo
        + d_model                          # ln_x
        + 2 * d_model
        + d_model * d_ff * 2
        + d_model * d_model                # cr
    )


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """shift(x)[t] = x[t-1]; position 0 sees ``prev`` (decode carry)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _decays(xw: jax.Array, p: Params, n_heads: int, head_dim: int) -> jax.Array:
    """Data-dependent per-channel decay w_t in (0, 1)."""
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    B, S, D = lora.shape
    w = p["w0"][None, None] + lora.reshape(B, S, n_heads, head_dim).astype(
        jnp.float32
    )
    return jnp.exp(-jnp.exp(w))


def wkv_scan(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Reference WKV6 recurrence via lax.scan over time.

    r,k,v,w: (B, S, H, hd); u: (H, hd); state: (B, H, hd, hd).
    Returns (out (B,S,H,hd) float32, final state).
    """
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S_new = w_t[..., :, None] * S + kv
        return S_new, out

    seq = tuple(
        jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w)
    )
    state, outs = jax.lax.scan(step, state.astype(jnp.float32), seq)
    return jnp.moveaxis(outs, 0, 1), state


def wkv_chunked(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array,
    *,
    chunk: int = 32,
) -> tuple[jax.Array, jax.Array]:
    """Chunked closed-form WKV6 (the Pallas kernel's math in pure jnp).

    Within a chunk of L tokens all work is matmuls (MXU-friendly) and the
    sequential carry is one (B,H,hd,hd) state per chunk instead of per
    token -- this is the §Perf fix for the memory-bound WKV scan (the
    per-timestep lax.scan reads+writes the full state T times).

    r,k,v,w: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) float32.
    Returns (out (B,S,H,hd) float32, final state).
    """
    B, S, H, hd = r.shape
    L = min(chunk, S)
    if S % L:
        return wkv_scan(r, k, v, w, u, state)  # fallback for ragged tails
    n_chunks = S // L

    def to_chunks(a):
        return (
            a.astype(jnp.float32)
            .reshape(B, n_chunks, L, H, hd)
            .transpose(1, 0, 3, 2, 4)          # (C, B, H, L, hd)
        )

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    mask = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)

    def one_chunk(S0, inp):
        r_, k_, v_, w_ = inp                   # (B,H,L,hd)
        logw = jnp.log(jnp.maximum(w_, 1e-12))
        lc_incl = jnp.cumsum(logw, axis=2)
        lc_excl = lc_incl - logw
        r_t = r_ * jnp.exp(lc_excl)
        k_t = k_ * jnp.exp(-lc_incl)
        a = jnp.einsum("bhld,bhmd->bhlm", r_t, k_t) * mask[None, None]
        diag = jnp.einsum("bhld,bhld->bhl", r_, u[None, :, None, :] * k_)
        out = (
            jnp.einsum("bhlm,bhmd->bhld", a, v_)
            + diag[..., None] * v_
            + jnp.einsum("bhlk,bhkv->bhlv", r_t, S0)
        )
        c_last = jnp.exp(lc_incl[:, :, -1, :])              # (B,H,hd)
        kv = jnp.einsum("bhlk,bhlv->bhkv", k_t, v_)
        S_new = c_last[..., None] * (S0 + kv)
        return S_new, out

    state, outs = jax.lax.scan(
        one_chunk, state.astype(jnp.float32), (rc, kc, vc, wc)
    )
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    # Cast at the boundary: keeps downstream matmuls (and their fwd/bwd
    # all-reduces) in the model dtype -- f32 stays internal to the chunk.
    return out.astype(r.dtype), state


def time_mix(
    x: jax.Array,
    p: Params,
    state: tuple[jax.Array, jax.Array],
    *,
    n_heads: int,
    eps: float,
    chunked: bool = False,
    chunk: int = 128,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """RWKV6 attention replacement.  x: (B,S,D).

    state = (shift_prev (B,D), wkv_state (B,H,hd,hd)); pass zeros for
    training/prefill from scratch.  ``chunked`` selects the closed-form
    chunked WKV (the optimized path; identical math, §Perf).
    """
    B, S, D = x.shape
    head_dim = D // n_heads
    shift_prev, wkv_state = state
    xs = _token_shift(x, shift_prev)
    mu = p["mu"]
    xr = x + (xs - x) * mu[0]
    xk = x + (xs - x) * mu[1]
    xv = x + (xs - x) * mu[2]
    xw = x + (xs - x) * mu[3]
    xg = x + (xs - x) * mu[4]

    r = (xr @ p["wr"]).reshape(B, S, n_heads, head_dim)
    k = (xk @ p["wk"]).reshape(B, S, n_heads, head_dim)
    v = (xv @ p["wv"]).reshape(B, S, n_heads, head_dim)
    g = jax.nn.silu(xg @ p["wg"])
    w = _decays(xw, p, n_heads, head_dim)

    if chunked and S > 1:
        out, wkv_state = wkv_chunked(r, k, v, w, p["u"], wkv_state, chunk=chunk)
    else:
        out, wkv_state = wkv_scan(r, k, v, w, p["u"], wkv_state)
    out = out.reshape(B, S, D).astype(x.dtype)
    out = rms_norm(out, p["ln_x"], eps) * g
    return out @ p["wo"], (x[:, -1, :], wkv_state)


def channel_mix(
    x: jax.Array, p: Params, prev: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """RWKV squared-ReLU channel mix with token shift."""
    xs = _token_shift(x, prev)
    mu = p["mu_c"]
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return jax.nn.sigmoid(xr @ p["cr"]) * (kk @ p["cv"]), x[:, -1, :]


def rwkv_state_init(
    batch: int, d_model: int, n_heads: int, dtype=jnp.float32
) -> dict[str, jax.Array]:
    head_dim = d_model // n_heads
    return {
        "tm_shift": jnp.zeros((batch, d_model), dtype),
        "wkv": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
        "cm_shift": jnp.zeros((batch, d_model), dtype),
    }
