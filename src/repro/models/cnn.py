"""Partitionable convolutional model families (the paper's Table II models).

These are real, runnable JAX conv nets used by the serving-engine
integration path and examples: each model is a chain of *stages* (the
paper's partition points) so the SwapLess planner can split them between
the accelerator worker and CPU pools.  Channel widths are chosen per family
so stage weight footprints follow the back-loaded distribution used by the
synthetic profiles.  (Latency *validation* uses the calibrated profiles +
DES; these nets prove the execution plumbing with real tensors.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import ExecutableModel


@dataclasses.dataclass(frozen=True)
class CNNSpec:
    name: str
    stage_channels: tuple[int, ...]   # output channels per stage
    in_size: int = 64                 # input spatial resolution
    in_channels: int = 3
    kernel: int = 3


def _conv(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def init_cnn(spec: CNNSpec, key: jax.Array, dtype=jnp.float32) -> list[dict]:
    """One params dict per stage: conv + pointwise conv."""
    params = []
    c_in = spec.in_channels
    for i, c_out in enumerate(spec.stage_channels):
        key, k1, k2 = jax.random.split(key, 3)
        fan = spec.kernel * spec.kernel * c_in
        params.append(
            {
                "conv": (
                    jax.random.normal(k1, (spec.kernel, spec.kernel, c_in, c_out))
                    / np.sqrt(fan)
                ).astype(dtype),
                "pw": (
                    jax.random.normal(k2, (1, 1, c_out, c_out)) / np.sqrt(c_out)
                ).astype(dtype),
            }
        )
        c_in = c_out
    return params


def stage_fn(p: dict, downsample: bool) -> Callable[[jax.Array], jax.Array]:
    def fn(x: jax.Array) -> jax.Array:
        y = jax.nn.relu(_conv(x, p["conv"], stride=2 if downsample else 1))
        return jax.nn.relu(_conv(y, p["pw"]))
    return fn


def build_executable(
    spec: CNNSpec, seed: int = 0, jit_stages: bool = True
) -> ExecutableModel:
    params = init_cnn(spec, jax.random.PRNGKey(seed))
    segs = []
    for i, p in enumerate(params):
        fn = stage_fn(p, downsample=(i % 2 == 0))
        segs.append(jax.jit(fn) if jit_stages else fn)

    def make_input(seed2: int) -> jax.Array:
        return jax.random.normal(
            jax.random.PRNGKey(seed2),
            (1, spec.in_size, spec.in_size, spec.in_channels),
        )

    return ExecutableModel(name=spec.name, segments=tuple(segs), make_input=make_input)


# Reduced-scale counterparts of the paper's models (stage count == Table II
# partition points; widths grow with depth like the real families).
PAPER_CNN_SPECS: dict[str, CNNSpec] = {
    "squeezenet": CNNSpec("squeezenet", (16, 32)),
    "mobilenetv2": CNNSpec("mobilenetv2", (8, 16, 24, 32, 48)),
    "efficientnet": CNNSpec("efficientnet", (8, 16, 24, 32, 48, 64)),
    "mnasnet": CNNSpec("mnasnet", (8, 16, 16, 24, 32, 48, 64)),
    "gpunet": CNNSpec("gpunet", (16, 32, 48, 64, 96)),
    "densenet201": CNNSpec("densenet201", (16, 24, 32, 48, 64, 96, 128)),
    "resnet50v2": CNNSpec("resnet50v2", (16, 24, 32, 48, 64, 96, 128, 160)),
    "xception": CNNSpec("xception", (8, 16, 24, 32, 48, 64, 96, 128, 160, 192, 224)),
    "inceptionv4": CNNSpec("inceptionv4", (8, 16, 24, 32, 48, 64, 96, 128, 160, 192, 256)),
}
