"""Activation sharding constraints inside model code.

Without internal constraints GSPMD's propagation can legally pick
pathological layouts -- e.g. all-gathering the batch after the (vocab-
sharded) embedding gather and running pure tensor-parallel over all chips
(observed on qwen train_4k; see EXPERIMENTS.md §Dry-run).  ``constrain``
pins activations to batch-sharded layouts whenever a mesh context is
active, and is a no-op under single-device tests.

Spec tokens: 'batch' expands to the mesh's batch axes (('pod','data') on
the multi-pod mesh), 'model' passes through, None replicates.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _active_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def constrain(x: jax.Array, *spec_tokens) -> jax.Array:
    """with_sharding_constraint(x, P(...)) resolved against the active mesh."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    resolved = []
    for tok in spec_tokens:
        if tok == "batch":
            axes = tuple(a for a in ("pod", "data") if a in names)
            resolved.append(axes if axes else None)
        elif tok == "batch_full":
            # FSDP: batch spans every mesh axis.
            resolved.append(tuple(mesh.axis_names))
        elif tok is None:
            resolved.append(None)
        elif isinstance(tok, str):
            resolved.append(tok if tok in names else None)
        else:
            resolved.append(tok)
    return jax.lax.with_sharding_constraint(x, P(*resolved))
