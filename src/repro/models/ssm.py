"""Selective state-space mixer (Mamba/SSD-style), used by Hymba's parallel
SSM heads (arXiv:2411.13676).

Per head with state size N:

    h_t = exp(-softplus(dt_t) * A) * h_{t-1} + (dt_t * B_t) x_t^T
    y_t = C_t^T h_t + D * x_t

with B_t, C_t, dt_t data-dependent projections of the input (selective
scan).  Expressed as lax.scan over time (single While op in HLO).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Params


def ssm_init(
    key: jax.Array, d_model: int, d_inner: int, state: int, dtype
) -> Params:
    keys = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(d_model)
    return {
        "w_in": (jax.random.normal(keys[0], (d_model, d_inner)) * s).astype(dtype),
        "w_gate": (jax.random.normal(keys[1], (d_model, d_inner)) * s).astype(dtype),
        "w_B": (jax.random.normal(keys[2], (d_model, state)) * s).astype(dtype),
        "w_C": (jax.random.normal(keys[3], (d_model, state)) * s).astype(dtype),
        "w_dt": (jax.random.normal(keys[4], (d_model, d_inner)) * s).astype(dtype),
        "A_log": jnp.zeros((d_inner,), jnp.float32),      # A = exp(A_log) > 0
        "D": jnp.ones((d_inner,), jnp.float32),
        "w_out": (
            jax.random.normal(keys[5], (d_inner, d_model)) / np.sqrt(d_inner)
        ).astype(dtype),
    }


def ssm_param_count(d_model: int, d_inner: int, state: int) -> int:
    return (
        3 * d_model * d_inner
        + 2 * d_model * state
        + 2 * d_inner
        + d_inner * d_model
    )


def selective_scan(
    x: jax.Array,      # (B, S, d_inner)
    B_t: jax.Array,    # (B, S, N)
    C_t: jax.Array,    # (B, S, N)
    dt: jax.Array,     # (B, S, d_inner) pre-softplus
    A: jax.Array,      # (d_inner,)
    h0: jax.Array,     # (B, d_inner, N)
) -> tuple[jax.Array, jax.Array]:
    """Sequential selective scan; returns (y (B,S,d_inner), h_final)."""
    dt = jax.nn.softplus(dt.astype(jnp.float32))

    def step(h, inp):
        x_t, b_t, c_t, dt_t = inp
        decay = jnp.exp(-dt_t * A[None, :])               # (B, d_inner)
        h = h * decay[..., None] + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    seq = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B_t.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C_t.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt, 1, 0),
    )
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), seq)
    return jnp.moveaxis(ys, 0, 1), h


def selective_scan_chunked(
    x: jax.Array,      # (B, S, d_inner)
    B_t: jax.Array,    # (B, S, N)
    C_t: jax.Array,    # (B, S, N)
    dt: jax.Array,     # (B, S, d_inner) pre-softplus
    A: jax.Array,      # (d_inner,)
    h0: jax.Array,     # (B, d_inner, N)
    *,
    chunk: int = 32,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD closed form (within-chunk matmuls, per-chunk carry).

    y_t = ca_t * ((M^T . mask) @ (u'/ca))_t + ca_t * (h0 C_t)
    h_L = ca_L * (h0 + sum_s (u'_s/ca_s) B_s^T)     with ca = cumprod(a)

    The (L,L) mixing matrix M_st = B_s . C_t is d-independent -- all inner
    work becomes MXU matmuls (the §Perf fix for the memory-bound
    per-timestep scan; same class as the chunked WKV).
    """
    Bb, S, d_inner = x.shape
    L = min(chunk, S)
    if S % L:
        return selective_scan(x, B_t, C_t, dt, A, h0)
    n_chunks = S // L

    def chunks(a):
        return jnp.moveaxis(
            a.astype(jnp.float32).reshape(Bb, n_chunks, L, -1), 1, 0
        )                                              # (C, B, L, F)

    xc, bc, cc, dc = map(chunks, (x, B_t, C_t, dt))
    mask = jnp.tril(jnp.ones((L, L), jnp.float32))     # diagonal included

    def one_chunk(h, inp):
        x_, b_, c_, dt_ = inp                          # (B, L, *)
        dt_ = jax.nn.softplus(dt_)
        loga = -dt_ * A[None, None, :]                 # (B, L, d)
        lca = jnp.cumsum(loga, axis=1)                 # inclusive cumlog
        ca = jnp.exp(lca)
        up = dt_ * x_                                  # u'_s
        ut = up * jnp.exp(-lca)                        # u'_s / ca_s
        m = jnp.einsum("bsn,btn->bst", b_, c_) * mask.T[None]   # s<=t
        y_intra = ca * jnp.einsum("bst,bsd->btd", m, ut)
        y_carry = ca * jnp.einsum("bdn,btn->btd", h, c_)
        y = y_intra + y_carry
        h_new = ca[:, -1, :, None] * (
            h + jnp.einsum("btd,btn->bdn", ut, b_)
        )
        return h_new, y

    h, ys = jax.lax.scan(one_chunk, h0.astype(jnp.float32), (xc, bc, cc, dc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, d_inner)
    return y, h


def ssm_forward(
    x: jax.Array,
    p: Params,
    h0: jax.Array | None = None,
    *,
    chunked: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (B, S, D); returns (y, final_state)."""
    B, S, D = x.shape
    d_inner = p["w_in"].shape[-1]
    N = p["w_B"].shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, d_inner, N), jnp.float32)
    u = x @ p["w_in"]
    z = jax.nn.silu(x @ p["w_gate"])
    B_t = x @ p["w_B"]
    C_t = x @ p["w_C"]
    dt = x @ p["w_dt"]
    A = jnp.exp(p["A_log"])
    if chunked and S > 1:
        y, h = selective_scan_chunked(u, B_t, C_t, dt, A, h0)
    else:
        y, h = selective_scan(u, B_t, C_t, dt, A, h0)
    y = (y + p["D"][None, None] * u.astype(jnp.float32)).astype(x.dtype)
    return (y * z) @ p["w_out"], h


def ssm_state_init(batch: int, d_inner: int, state: int) -> jax.Array:
    return jnp.zeros((batch, d_inner, state), jnp.float32)
