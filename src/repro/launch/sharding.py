"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Baseline placement (the §Perf pass iterates on this):

* batch axes -> ('pod','data') [multi-pod] or ('data',);
* attention / MLP / RWKV / SSM matrices: column-shard the wide output dim on
  'model', row-shard the contraction dim of output projections on 'model';
* embeddings / lm_head: vocab on 'model';
* MoE expert tensors: expert axis on 'data' when divisible (expert
  parallelism -- llama4's 128 experts / 16), otherwise shard d_model on
  'data' and d_ff on 'model' (grok's 8 experts);
* layer-stacked leaves keep their leading (n_groups,) axis unsharded;
* KV caches: batch on the batch axes, everything else replicated;
* optimizer moments follow their parameter's spec.

Rules are name-based over tree paths, so they apply to any arch config.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import batch_axes


def _data_size(mesh: Mesh) -> int:
    return mesh.shape["data"]


def batch_axes_for(cfg: ArchConfig, mesh: Mesh) -> tuple[str, ...]:
    """FSDP shards the batch over every mesh axis; zero3/TP over pod/data."""
    if cfg.parallelism == "fsdp":
        return tuple(mesh.axis_names)
    return batch_axes(mesh)


def _fsdp_spec(path: str, leaf, mesh: Mesh) -> P:
    """ZeRO-3: shard each tensor's largest dim over ALL mesh axes."""
    stacked = "groups" in path
    shape = leaf.shape
    start = 1 if stacked else 0
    if leaf.ndim - start < 1:
        return P(*([None] * leaf.ndim))
    all_axes = tuple(mesh.axis_names)
    extent = 1
    for a in all_axes:
        extent *= mesh.shape[a]
    # Pick the largest divisible dim (prefer later dims on ties -- weight
    # matrices put d_model/d_ff there).
    best = None
    for i in range(start, leaf.ndim):
        if shape[i] % extent == 0 and (best is None or shape[i] >= shape[best]):
            best = i
    spec = [None] * leaf.ndim
    if best is not None:
        spec[best] = all_axes
    return P(*spec)


def _spec_for_param(path: str, leaf, cfg: ArchConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (path = jax keystr)."""
    stacked = "groups" in path          # leading (n_groups,) axis
    lead: tuple = (None,) if stacked else ()

    def p(*axes):
        return P(*lead, *axes)

    nd = leaf.ndim - (1 if stacked else 0)

    # --- top-level ---------------------------------------------------------
    if "embed" in path:
        return P("model", None)
    if "lm_head" in path:
        return P(None, "model")
    if "frontend_proj" in path:
        return P(None, "model")
    if "final_norm" in path:
        return P(None)

    # --- MoE ---------------------------------------------------------------
    if "moe" in path:
        if "router" in path:
            return p(None, None)
        E = cfg.n_experts
        model_size = mesh.shape["model"]
        if E % _data_size(mesh) == 0:
            # Expert parallel over 'data' + d_ff over 'model' (llama4: 128e).
            if "w_out" in path:  # (E, F, D)
                return p("data", "model", None)
            return p("data", None, "model")
        if E % model_size == 0:
            # Expert parallel over 'model' + d_ff over 'data' -- reachable by
            # refactoring the logical mesh (grok: 8e on a 32x8 mesh).  The
            # contraction dim stays unsharded so the expert matmuls produce
            # no partial sums (no (G,E,C,F) all-reduce).
            if "w_out" in path:
                return p("model", "data", None)
            return p("model", None, "data")
        # Tensor-parallel fallback: shard inside each expert.
        if "w_out" in path:
            return p(None, "model", "data")
        return p(None, "data", "model")

    # --- attention -----------------------------------------------------------
    if "attn" in path:
        if path.endswith("['wo']"):
            return p("model", None)
        if "wq" in path or "wk" in path or "wv" in path:
            return p(None, "model")
        if "bq" in path or "bk" in path or "bv" in path:
            return p("model")
        return p(*([None] * nd))

    # --- RWKV ----------------------------------------------------------------
    if "rwkv" in path:
        if any(k in path for k in ("['wr']", "['wk']", "['wv']", "['wg']", "['ck']")):
            return p(None, "model")
        if "['wo']" in path or "['cv']" in path:
            return p("model", None)
        if "['cr']" in path:
            return p(None, "model")
        if "w_lora_a" in path:
            return p(None, None)
        if "w_lora_b" in path:
            return p(None, "model")
        return p(*([None] * nd))

    # --- SSM (hymba) -----------------------------------------------------------
    if "ssm" in path:
        if any(k in path for k in ("w_in", "w_gate", "w_dt")):
            return p(None, "model")
        if "w_out" in path:
            return p("model", None)
        return p(*([None] * nd))

    # --- dense MLP ---------------------------------------------------------------
    if "mlp" in path:
        if "w_out" in path:
            return p("model", None)
        return p(None, "model")

    # --- norms & anything else: replicate -------------------------------------
    return p(*([None] * nd))


def _sanitize(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec axes whose mesh extent doesn't divide the dim (jax requires
    divisible input shardings; e.g. hymba's vocab of 32001)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        out.append(entry if shape[i] % extent == 0 else None)
    return P(*out)


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_like: Any) -> Any:
    """NamedSharding pytree matching ``params_like`` (arrays or SDS)."""

    def assign(path, leaf):
        ks = jax.tree_util.keystr(path)
        if cfg.parallelism in ("fsdp", "zero3"):
            spec = _fsdp_spec(ks, leaf, mesh)
        else:
            spec = _spec_for_param(ks, leaf, cfg, mesh)
        return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, params_like)


def opt_state_shardings(cfg: ArchConfig, mesh: Mesh, opt_like: Any) -> Any:
    """Moments follow their parameter's sharding; step is replicated."""

    def assign(path, leaf):
        ks = jax.tree_util.keystr(path)
        if "step" in ks:
            return NamedSharding(mesh, P())
        # strip the leading ['m'] / ['v'] container key
        if cfg.parallelism in ("fsdp", "zero3"):
            spec = _fsdp_spec(ks, leaf, mesh)
        else:
            spec = _spec_for_param(ks, leaf, cfg, mesh)
        return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, opt_like)


def _best_batch_axes(
    preferred: tuple[str, ...], batch_dim: int, mesh: Mesh
) -> tuple[str, ...] | None:
    """Longest divisible suffix fallback: full axes, then drop leading axes
    until the batch dim divides (e.g. global_batch=32 on a 2x32x8 mesh:
    ('pod','data')=64 fails -> ('data',)=32 works).  Prevents the sanitizer
    from silently replicating the whole batch."""
    for start in range(len(preferred)):
        cand = preferred[start:]
        extent = 1
        for a in cand:
            extent *= mesh.shape[a]
        if extent and batch_dim % extent == 0:
            return cand
    return None


def batch_shardings(cfg: ArchConfig, mesh: Mesh, batch_like: Any) -> Any:
    axes = batch_axes_for(cfg, mesh)

    def assign(path, leaf):
        best = _best_batch_axes(axes, leaf.shape[0], mesh)
        rest = (None,) * (leaf.ndim - 1)
        spec = P(best, *rest) if best else P(None, *rest)
        return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, batch_like)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, caches_like: Any) -> Any:
    """Decode caches.

    KV caches (B, S, KV, hd): batch over the batch axes when divisible, and
    the *sequence* dim over 'model' when divisible -- KV-head counts rarely
    divide the model axis (grok kv=8 vs model=16), but the 32k/500k sequence
    always does, and seq-sharding is what keeps a 1 TB cache at ~4 GB/chip.
    Attention over a seq-sharded cache costs an all-gather of per-position
    logits (small at decode).  SSM/RWKV states: batch only.
    """
    axes = batch_axes_for(cfg, mesh)
    model_size = mesh.shape["model"]

    def assign(path, leaf):
        key = jax.tree_util.keystr(path)
        b = leaf.shape[0]
        batch_spec = _best_batch_axes(axes, b, mesh)
        is_kv = key.endswith("['k']") or key.endswith("['v']")
        if is_kv and leaf.ndim == 4:
            s = leaf.shape[1]
            seq_spec = "model" if s % model_size == 0 else None
            return NamedSharding(
                mesh,
                _sanitize(P(batch_spec, seq_spec, None, None), leaf.shape, mesh),
            )
        rest = (None,) * (leaf.ndim - 1)
        return NamedSharding(
            mesh, _sanitize(P(batch_spec, *rest), leaf.shape, mesh)
        )

    return jax.tree_util.tree_map_with_path(assign, caches_like)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
