"""Step builders: (step_fn, abstract inputs, in/out shardings) per
(arch x input-shape), shared by the dry-run, the roofline analysis, and the
real launchers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.launch import sharding as shd
from repro.launch.mesh import batch_axes
from repro.models.frontend import decode_token_specs, train_input_specs
from repro.models.transformer import (
    decode_step,
    init_decode_caches,
    init_params,
    prefill_step,
)
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, make_train_step

BIG_MODEL_PARAMS = 50e9  # above this, keep Adam moments in bf16


@dataclasses.dataclass
class StepBundle:
    """Everything needed to jit/lower one step."""

    fn: Callable
    args: tuple            # abstract (ShapeDtypeStruct) arguments
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    description: str = ""


def _abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def _n_batch_shards(mesh: Mesh, cfg: ArchConfig | None = None) -> int:
    from repro.launch.sharding import batch_axes_for

    axes = batch_axes_for(cfg, mesh) if cfg is not None else batch_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def train_config_for(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> TrainConfig:
    n_shards = _n_batch_shards(mesh, cfg)
    n_micro = max(shape.global_batch // n_shards, 1)
    moments = (
        jnp.bfloat16 if cfg.param_count() > BIG_MODEL_PARAMS else jnp.float32
    )
    return TrainConfig(
        optimizer=AdamWConfig(moments_dtype=moments),
        n_microbatches=n_micro,
        remat=True,
        remat_policy=cfg.remat_policy,
    )


def build_train(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> StepBundle:
    tcfg = train_config_for(cfg, shape, mesh)
    step = make_train_step(cfg, tcfg)

    params_sds = _abstract_params(cfg)
    from repro.training.optimizer import adamw_init

    opt_sds = jax.eval_shape(partial(adamw_init, cfg=tcfg.optimizer), params_sds)
    batch_sds = train_input_specs(cfg, shape.global_batch, shape.seq_len)

    p_shard = shd.param_shardings(cfg, mesh, params_sds)
    o_shard = shd.opt_state_shardings(cfg, mesh, opt_sds)
    b_shard = shd.batch_shardings(cfg, mesh, batch_sds)
    metrics_shard = {
        "loss": shd.replicated(mesh),
        "grad_norm": shd.replicated(mesh),
    }
    return StepBundle(
        fn=step,
        args=(params_sds, opt_sds, batch_sds),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metrics_shard),
        donate_argnums=(0, 1),
        description=f"train_step[{cfg.name} x {shape.name}] "
        f"(micro={tcfg.n_microbatches})",
    )


def build_prefill(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> StepBundle:
    params_sds = _abstract_params(cfg)
    batch_sds = train_input_specs(cfg, shape.global_batch, shape.seq_len)
    batch_sds.pop("labels")

    def fn(params, batch):
        return prefill_step(cfg, params, batch, max_len=shape.seq_len)

    caches_sds = jax.eval_shape(
        lambda: init_decode_caches(cfg, shape.global_batch, shape.seq_len)
    )
    p_shard = shd.param_shardings(cfg, mesh, params_sds)
    b_shard = shd.batch_shardings(cfg, mesh, batch_sds)
    c_shard = shd.cache_shardings(cfg, mesh, caches_sds)
    logits_shape = (shape.global_batch, 1, cfg.vocab_size)
    from repro.launch.sharding import batch_axes_for
    vocab_ax = None if cfg.parallelism == "fsdp" else "model"
    logits_shard = NamedSharding(
        mesh,
        shd._sanitize(
            P(batch_axes_for(cfg, mesh), None, vocab_ax), logits_shape, mesh
        ),
    )
    return StepBundle(
        fn=fn,
        args=(params_sds, batch_sds),
        in_shardings=(p_shard, b_shard),
        out_shardings=(logits_shard, c_shard),
        description=f"prefill_step[{cfg.name} x {shape.name}]",
    )


def build_decode(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> StepBundle:
    params_sds = _abstract_params(cfg)
    caches_sds = jax.eval_shape(
        lambda: init_decode_caches(cfg, shape.global_batch, shape.seq_len)
    )
    tok_sds = decode_token_specs(cfg, shape.global_batch)
    len_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, caches, tokens, cur_len):
        return decode_step(cfg, params, caches, tokens, cur_len)

    p_shard = shd.param_shardings(cfg, mesh, params_sds)
    c_shard = shd.cache_shardings(cfg, mesh, caches_sds)
    from repro.launch.sharding import batch_axes_for
    b = shape.global_batch
    baxes = batch_axes_for(cfg, mesh)
    t_spec = (
        P(baxes, None)
        if b % _n_batch_shards(mesh, cfg) == 0
        else P(None, None)
    )
    if cfg.frontend == "audio":
        t_spec = P(*t_spec, None)
    t_shard = NamedSharding(mesh, t_spec)
    l_shard = shd.replicated(mesh)
    vocab_ax = None if cfg.parallelism == "fsdp" else "model"
    logits_spec = (
        P(baxes, None, vocab_ax)
        if b % _n_batch_shards(mesh, cfg) == 0
        else P(None, None, vocab_ax)
    )
    logits_spec = shd._sanitize(logits_spec, (b, 1, cfg.vocab_size), mesh)
    return StepBundle(
        fn=fn,
        args=(params_sds, caches_sds, tok_sds, len_sds),
        in_shardings=(p_shard, c_shard, t_shard, l_shard),
        out_shardings=(NamedSharding(mesh, logits_spec), c_shard),
        donate_argnums=(1,),
        description=f"decode_step[{cfg.name} x {shape.name}]",
    )


def build_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> StepBundle:
    if shape.kind == "train":
        return build_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    if shape.kind == "decode":
        return build_decode(cfg, shape, mesh)
    raise ValueError(shape.kind)
