"""Multi-tenant serving launcher -- the paper's technique as a first-class
feature of the framework.

Co-locates several models behind one accelerator worker with bounded fast
memory.  The SwapLess planner (analytic queueing model + hill-climbing) picks
each model's accelerator prefix / host suffix split and host core allocation;
requests then flow through the real execution engine (JAX compute) while the
calibrated platform model predicts the latency the same plan would see on the
edge testbed.

    PYTHONPATH=src python -m repro.launch.serve \
        --models inceptionv4,mnasnet --rates 2.0,5.0 --duration 30
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.paper_models import paper_profile
from repro.core import latency
from repro.core.allocator import (
    edge_tpu_compiler_plan,
    swapless_plan,
)
from repro.core.planner import TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.models.cnn import PAPER_CNN_SPECS, build_executable
from repro.serving.engine import ServingEngine
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="inceptionv4,mnasnet")
    ap.add_argument("--rates", default="2.0,5.0")
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--requests", type=int, default=20,
                    help="real-execution requests per model")
    ap.add_argument("--k-max", type=int, default=4)
    args = ap.parse_args()

    names = args.models.split(",")
    rates = [float(r) for r in args.rates.split(",")]
    hw = EDGE_TPU_PLATFORM
    tenants = [TenantSpec(paper_profile(n), r) for n, r in zip(names, rates)]

    # --- plan ---------------------------------------------------------------
    plan = swapless_plan(tenants, hw, args.k_max)
    baseline = edge_tpu_compiler_plan(tenants)
    pred = latency.predict(tenants, plan, hw)
    pred_base = latency.predict(tenants, baseline, hw)
    print("SwapLess plan:")
    for t, p, k, a in zip(tenants, plan.partition, plan.cores, pred.alphas):
        P = t.profile.num_partition_points
        print(
            f"  {t.profile.name:<14} prefix={p}/{P} cores={k} alpha={a:.2f} "
            f"predicted={pred.latencies[names.index(t.profile.name)]*1e3:.1f}ms"
        )
    print(
        f"predicted mean latency: swapless={pred.mean_latency(tenants)*1e3:.1f}ms "
        f"vs compiler={pred_base.mean_latency(tenants)*1e3:.1f}ms"
    )

    # --- DES over the full duration ------------------------------------------
    reqs = poisson_trace(rates, args.duration, seed=0)
    sim = simulate(tenants, plan, hw, reqs)
    sim_base = simulate(tenants, baseline, hw, reqs)
    print(
        f"simulated mean latency ({len(reqs)} requests): "
        f"swapless={sim.overall_mean()*1e3:.1f}ms "
        f"compiler={sim_base.overall_mean()*1e3:.1f}ms "
        f"(-{100*(1-sim.overall_mean()/max(sim_base.overall_mean(),1e-12)):.1f}%)"
    )

    # --- real execution through the engine ------------------------------------
    models = [build_executable(PAPER_CNN_SPECS[n], seed=i) for i, n in enumerate(names)]
    eng = ServingEngine(models, plan, k_max=args.k_max)
    try:
        for i, m in enumerate(models):
            for s in range(args.requests):
                eng.submit(i, m.make_input(s))
        done = eng.drain(timeout=120.0)
        by_model: dict[int, list[float]] = {}
        for c in done:
            by_model.setdefault(c.model_idx, []).append(c.latency)
        print(f"real execution: {len(done)} requests completed")
        for i, name in enumerate(names):
            ls = np.array(by_model.get(i, [0.0]))
            print(
                f"  {name:<14} n={len(ls)} mean={ls.mean()*1e3:.2f}ms "
                f"p95={np.percentile(ls, 95)*1e3:.2f}ms"
            )
    finally:
        eng.shutdown()


if __name__ == "__main__":
    main()
