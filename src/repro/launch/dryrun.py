import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes and capture memory/cost/collective statistics.

MUST be run as a standalone process (the XLA flag above is set before any
jax import and locks the device count).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results (memory_analysis, cost_analysis, collective bytes parsed from the
compiled HLO) are appended as JSON lines under experiments/dryrun/.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.roofline.analysis import analyze_compiled

# §Perf knobs applied under --opt.  Per-arch overrides come from the
# hillclimb iterations in EXPERIMENTS.md §Perf.
OPT_DEFAULT = dict(use_chunked_scan=True)
OPT_OVERRIDES: dict[str, dict] = {
    # 7.5B params: weight all-gather (ZeRO-3) is ~50x cheaper than
    # tensor-parallel activation all-reduce at batch 1/chip.
    "rwkv6-7b": dict(use_chunked_scan=True, parallelism="fsdp"),
    # d_inner=3200 is not 256-divisible, so ZeRO sharding degenerates for
    # half the tensors; TP + chunked SSD is the best fitting config.
    "hymba-1.5b": dict(use_chunked_scan=True),
    # 8 experts cannot map onto a 16-wide axis; refactor the logical mesh to
    # 32x8 so experts are expert-parallel on 'model' (d_model over 'data').
    "grok-1-314b": dict(use_chunked_scan=True,
                         mesh=(32, 8), capacity_factor=1.0),
}


def run_one(
    arch_name: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    optimized: bool = False,
    out_dir: str = "experiments/dryrun",
    verbose: bool = True,
) -> dict:
    import dataclasses as _dc

    cfg = ARCHS[arch_name]
    mesh_shape: tuple | None = None
    if optimized:
        ov = dict(OPT_OVERRIDES.get(arch_name, OPT_DEFAULT))
        mesh_shape = ov.pop("mesh", None)
        cfg = _dc.replace(cfg, **ov)
    shape = INPUT_SHAPES[shape_name]
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    record: dict = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_tag,
        "variant": "optimized" if optimized else "baseline",
        "status": "",
    }
    if not cfg.supports_shape(shape_name):
        record["status"] = "skipped"
        record["reason"] = (
            "full-attention arch: long_500k decode requires sub-quadratic "
            "attention (see DESIGN.md Sec. 4)"
        )
        _append(out_dir, record)
        if verbose:
            print(f"[skip] {arch_name} x {shape_name}: full attention")
        return record

    if mesh_shape is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    else:
        if multi_pod:
            mesh = jax.make_mesh((2, *mesh_shape), ("pod", "data", "model"))
        else:
            mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        record["mesh_factorization"] = list(mesh_shape)
    bundle = build_step(cfg, shape, mesh)
    t0 = time.time()
    try:
        with mesh:
            jitted = jax.jit(
                bundle.fn,
                in_shardings=bundle.in_shardings,
                out_shardings=bundle.out_shardings,
                donate_argnums=bundle.donate_argnums,
            )
            lowered = jitted.lower(*bundle.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        record["status"] = "ok"
        record["lower_s"] = round(t_lower, 1)
        record["compile_s"] = round(t_compile, 1)
        record["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        if record["memory"]["peak_bytes"] is None:
            # The CPU backend's memory analysis reports component sizes but
            # no peak; approximate it as args + outputs + temps (an upper
            # bound on simultaneously-live buffers) and say so.
            parts = [
                record["memory"][key]
                for key in ("argument_bytes", "output_bytes", "temp_bytes")
            ]
            if all(p is not None for p in parts):
                record["memory"]["peak_bytes"] = sum(parts)
                record["memory"]["peak_is_estimate"] = True
        record.update(analyze_compiled(cfg, shape, mesh, compiled))
        if verbose:
            gb = (record["memory"]["peak_bytes"] or 0) / 2**30
            print(
                f"[ok]   {arch_name} x {shape_name} ({mesh_tag}): "
                f"peak={gb:.2f} GiB/device, "
                f"compute={record['roofline']['compute_s']:.4f}s "
                f"memory={record['roofline']['memory_s']:.4f}s "
                f"collective={record['roofline']['collective_s']:.4f}s "
                f"-> {record['roofline']['bottleneck']} "
                f"[lower {record['lower_s']}s compile {record['compile_s']}s]"
            )
    except Exception as e:  # noqa: BLE001 -- record the failure, keep sweeping
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch_name} x {shape_name}: {record['error']}")
    _append(out_dir, record)
    return record


def _append(out_dir: str, record: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    suffix = "_opt" if record.get("variant") == "optimized" else ""
    fname = os.path.join(out_dir, f"dryrun_{record['mesh']}{suffix}.jsonl")
    with open(fname, "a") as f:
        f.write(json.dumps(record) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one architecture id")
    ap.add_argument("--shape", default=None, help="one input-shape id")
    ap.add_argument("--all", action="store_true", help="sweep all pairs")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--opt", action="store_true", help="apply §Perf knobs")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        f"dry-run expects 512 forced host devices, got {jax.device_count()}"
    )

    pairs: list[tuple[str, str]]
    if args.all:
        pairs = [(a, s) for a in ARCHS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        pairs = [(args.arch, args.shape)]

    n_ok = n_skip = n_fail = 0
    for a, s in pairs:
        rec = run_one(
            a, s,
            multi_pod=args.multi_pod,
            optimized=args.opt,
            out_dir=args.out_dir,
        )
        n_ok += rec["status"] == "ok"
        n_skip += rec["status"] == "skipped"
        n_fail += rec["status"] == "error"
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
