"""Training launcher.

On real TPU pods this drives the full configs over the production mesh; on
this CPU container use ``--reduced`` (smoke-scale variants).  Example:

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 50 --batch 8 --seq 128 --log-every 10
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import batches_for_arch
from repro.models.transformer import init_params
from repro.training.checkpoint import save
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.schedule import cosine_schedule, wsd_schedule
from repro.training.train_loop import TrainConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", choices=["wsd", "cosine"], default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # MiniCPM trains with WSD (its signature contribution); others cosine.
    sched_name = args.schedule or ("wsd" if "minicpm" in cfg.name else "cosine")
    sched = wsd_schedule if sched_name == "wsd" else cosine_schedule

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        n_microbatches=args.microbatches,
    )
    params = init_params(cfg, jax.random.PRNGKey(args.seed), dtype=jnp.float32)
    opt_state = adamw_init(params, tcfg.optimizer)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M schedule={sched_name}")

    data = batches_for_arch(cfg, args.batch, args.seq, seed=args.seed)
    t0 = time.time()
    first = last = None
    for step, batch in zip(range(args.steps), data):
        lr_scale = sched(step, total_steps=args.steps)
        params, opt_state, metrics = step_fn(params, opt_state, batch, lr_scale)
        loss = float(metrics["loss"])
        if first is None:
            first = loss
        last = loss
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(
                f"step {step:5d} loss {loss:8.4f} gnorm "
                f"{float(metrics['grad_norm']):8.3f} lr x{float(lr_scale):.3f} "
                f"({dt:.1f}s)"
            )
    print(f"loss: {first:.4f} -> {last:.4f}")
    if args.checkpoint:
        save(args.checkpoint, params, {"arch": cfg.name, "steps": args.steps})
        print(f"checkpoint saved to {args.checkpoint}")


if __name__ == "__main__":
    main()
