"""Production mesh construction (TPU v5e pods).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before calling it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over real local devices (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the global batch shards over."""
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)
