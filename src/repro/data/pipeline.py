"""Synthetic token data pipeline: deterministic, shardable, host-side.

Generates Zipf-distributed token streams with local n-gram structure (so a
model can actually reduce loss on it), batched for the training loop and
sharded across the data axis with jax.device_put when a mesh is active.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig
from repro.models.frontend import make_train_batch


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.3


class SyntheticTokens:
    """Infinite iterator of {tokens, labels} numpy batches."""

    def __init__(self, dcfg: DataConfig):
        self.cfg = dcfg
        self.rng = np.random.default_rng(dcfg.seed)
        # Second-order structure: a random bigram transition "template".
        self._shift = self.rng.integers(1, dcfg.vocab_size, size=64)

    def _sample_stream(self, n: int) -> np.ndarray:
        c = self.cfg
        z = self.rng.zipf(c.zipf_a, size=n).astype(np.int64)
        base = np.clip(z, 1, c.vocab_size - 1)
        # Half the positions continue a deterministic bigram pattern --
        # learnable structure for the loss-goes-down tests/examples.
        out = base.copy()
        mask = self.rng.random(n) < 0.5
        prev = np.roll(out, 1)
        out[mask] = (prev[mask] + self._shift[prev[mask] % 64]) % c.vocab_size
        return out.astype(np.int32)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        c = self.cfg
        while True:
            flat = self._sample_stream(c.batch_size * (c.seq_len + 1))
            arr = flat.reshape(c.batch_size, c.seq_len + 1)
            yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def batches_for_arch(
    cfg: ArchConfig, batch_size: int, seq_len: int, seed: int = 0
) -> Iterator[dict]:
    """Arch-aware batches (handles vlm/audio stub inputs)."""
    if cfg.frontend == "none":
        yield from SyntheticTokens(
            DataConfig(batch_size, seq_len, cfg.vocab_size, seed)
        )
    else:
        i = 0
        while True:
            yield make_train_batch(cfg, batch_size, seq_len, seed=seed + i)
            i += 1
