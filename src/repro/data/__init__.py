from repro.data.pipeline import DataConfig, SyntheticTokens, batches_for_arch

__all__ = ["DataConfig", "SyntheticTokens", "batches_for_arch"]
