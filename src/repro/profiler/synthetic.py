"""Synthetic per-segment profile generation for the paper's CNN families.

Without the physical Coral testbed, per-segment service times are derived
from a calibrated hardware model (repro/hw/specs.py) plus per-model shape
parameters:

* FLOPs are front-loaded across segments (early conv stages dominate
  compute), decaying geometrically with ``flops_decay``.
* Weights are back-loaded (late stages have wide channels), growing
  geometrically with ``weight_growth`` -- this is why offloading *trailing*
  layers relieves most memory pressure, the paper's central lever.
* Activation boundary sizes shrink with depth (spatial downsampling).
* The TPU-over-CPU speedup per segment decays geometrically from
  ``speedup_front`` to ``speedup_back`` -- a direct encoding of the paper's
  Fig. 3 observation that CPU and TPU converge in trailing segments.

CPU 1-core time of a segment is flops / cpu.ops_per_core; TPU time is the
CPU time divided by the segment's speedup.  All knobs live in the per-model
spec table (repro/configs/paper_models.py) and are calibrated so the derived
swap-overhead fractions land in the ranges the paper reports (Figs. 1-2).
"""
from __future__ import annotations

import dataclasses

from repro.core.planner import ModelProfile, Segment
from repro.hw.specs import Platform


@dataclasses.dataclass(frozen=True)
class SyntheticModelSpec:
    """Shape parameters for one paper model (Table II row + Fig. 3 curve)."""

    name: str
    size_mb: float
    gflops: float
    partition_points: int
    speedup_front: float = 80.0
    speedup_back: float = 1.1
    flops_decay: float = 0.70      # per-segment geometric decay of FLOPs
    weight_growth: float = 1.60    # per-segment geometric growth of weights
    input_kb: float = 150.0        # e.g. 224x224x3 int8
    final_out_kb: float = 4.0      # logits-ish boundary at the last cut


def _geometric_fractions(n: int, ratio: float) -> list[float]:
    vals = [ratio**i for i in range(n)]
    tot = sum(vals)
    return [v / tot for v in vals]


def build_profile(spec: SyntheticModelSpec, platform: Platform) -> ModelProfile:
    n = spec.partition_points
    flops_fracs = _geometric_fractions(n, spec.flops_decay)
    weight_fracs = _geometric_fractions(n, spec.weight_growth)
    total_flops = spec.gflops * 1e9
    total_bytes = int(spec.size_mb * 1e6)

    # Boundary activation sizes decay from input size to final_out_kb.
    in_b = spec.input_kb * 1e3
    out_b = spec.final_out_kb * 1e3
    if n > 1:
        act_ratio = (out_b / in_b) ** (1.0 / n)
    else:
        act_ratio = out_b / in_b

    # Per-segment TPU speedup decays geometrically front -> back.
    if n > 1:
        sp_ratio = (spec.speedup_back / spec.speedup_front) ** (1.0 / (n - 1))
    else:
        sp_ratio = 1.0

    segments: list[Segment] = []
    for i in range(n):
        flops = total_flops * flops_fracs[i]
        wbytes = int(round(total_bytes * weight_fracs[i]))
        cpu_1core = flops / platform.cpu.ops_per_core
        speedup = spec.speedup_front * sp_ratio**i
        tpu = cpu_1core / speedup
        boundary = int(in_b * act_ratio ** (i + 1))
        segments.append(
            Segment(
                name=f"{spec.name}/seg{i}",
                flops=flops,
                weight_bytes=wbytes,
                out_bytes=boundary,
                tpu_time=tpu,
                cpu_time_1core=cpu_1core,
                cpu_parallel_frac=platform.cpu.parallel_frac,
            )
        )
    # Fix rounding drift so the profile's total footprint matches Table II.
    drift = total_bytes - sum(s.weight_bytes for s in segments)
    if drift != 0:
        last = segments[-1]
        segments[-1] = dataclasses.replace(
            last, weight_bytes=last.weight_bytes + drift
        )
    return ModelProfile(
        name=spec.name,
        segments=tuple(segments),
        input_bytes=int(in_b),
    )
