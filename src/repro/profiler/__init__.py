from repro.profiler.synthetic import SyntheticModelSpec, build_profile

__all__ = ["SyntheticModelSpec", "build_profile"]
