"""Minimal sharded-pytree checkpointing via npz (no external deps)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf) for path, leaf in flat}


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten(tree)
    np.savez(path, **{k: v for k, v in arrays.items()})
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = jax.tree_util.keystr(p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def load_metadata(path: str) -> dict:
    with open(path + ".meta.json") as f:
        return json.load(f)
