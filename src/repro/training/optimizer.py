"""AdamW optimizer (from scratch -- no optax dependency).

Moments can be kept in bf16 for very large models (llama4/grok at 256 chips
would not fit f32 moments in HBM; DESIGN.md Sec. 7) -- the update math still
runs in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moments_dtype: Any = jnp.float32


def adamw_init(params: Any, cfg: AdamWConfig) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moments_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(
    grads: Any,
    state: dict[str, Any],
    params: Any,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
) -> tuple[Any, dict[str, Any]]:
    """Returns (new_params, new_state)."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(cfg.moments_dtype), v32.astype(cfg.moments_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([n[0] for n in new])
    new_m = treedef.unflatten([n[1] for n in new])
    new_v = treedef.unflatten([n[2] for n in new])
    return new_params, {"step": step, "m": new_m, "v": new_v}
