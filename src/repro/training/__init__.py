from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.schedule import cosine_schedule, wsd_schedule
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step

__all__ = [
    "AdamWConfig",
    "TrainConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "init_train_state",
    "make_train_step",
    "wsd_schedule",
]
