"""Learning-rate schedules, including MiniCPM's WSD (warmup-stable-decay,
arXiv:2404.06395) and cosine."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(
    step,
    *,
    total_steps: int,
    warmup_frac: float = 0.01,
    decay_frac: float = 0.1,
    final_scale: float = 0.1,
):
    """MiniCPM WSD: linear warmup -> flat -> sharp exponential-style decay.

    Returns a multiplicative scale in (0, 1]."""
    t = jnp.asarray(step, jnp.float32)
    warm = max(int(total_steps * warmup_frac), 1)
    decay_start = int(total_steps * (1.0 - decay_frac))
    warm_scale = t / warm
    decay_t = (t - decay_start) / max(total_steps - decay_start, 1)
    decay_scale = final_scale ** jnp.clip(decay_t, 0.0, 1.0)
    return jnp.where(
        t < warm, warm_scale, jnp.where(t < decay_start, 1.0, decay_scale)
    )


def cosine_schedule(
    step, *, total_steps: int, warmup_frac: float = 0.01, final_scale: float = 0.1
):
    t = jnp.asarray(step, jnp.float32)
    warm = max(int(total_steps * warmup_frac), 1)
    prog = jnp.clip((t - warm) / max(total_steps - warm, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(
        t < warm, t / warm, final_scale + (1.0 - final_scale) * cos
    )
