"""Training step with gradient-accumulation microbatching.

The global batch is split into ``n_microbatches`` slices scanned
sequentially; per-slice gradients accumulate in param dtype (bf16 for the
very large models -- documented memory trade-off).  This is also what keeps
train_4k's logits (global_batch x seq x vocab) from ever materializing.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import forward_loss
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    n_microbatches: int = 1
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs:
                                    # avoids re-running fwd all-reduces in bwd)
    aux_weight: float = 0.01


def _split_micro(batch: dict[str, jax.Array], n: int) -> dict[str, jax.Array]:
    def reshape(a):
        return a.reshape(n, a.shape[0] // n, *a.shape[1:])

    return jax.tree.map(reshape, batch)


def make_train_step(
    cfg: ArchConfig, tcfg: TrainConfig
) -> Callable[..., tuple[Any, Any, dict[str, jax.Array]]]:
    """Returns train_step(params, opt_state, batch, lr_scale)."""

    def loss_fn(params, mb):
        loss, metrics = forward_loss(
            cfg, params, mb, remat=tcfg.remat, aux_weight=tcfg.aux_weight,
            remat_policy=tcfg.remat_policy,
        )
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, lr_scale=1.0):
        n = tcfg.n_microbatches
        if n > 1:
            micro = _split_micro(batch, n)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), metrics["ce"]

            g0 = jax.tree.map(jnp.zeros_like, params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_fn, (g0, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss_sum / n
        else:
            (loss, _), grads = grad_fn(params, batch)
        new_params, new_opt = adamw_update(
            grads, opt_state, params, tcfg.optimizer, lr_scale
        )
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_train_state(
    cfg: ArchConfig, tcfg: TrainConfig, params: Any
) -> dict[str, Any]:
    return adamw_init(params, tcfg.optimizer)
