from repro.roofline.analysis import analyze_compiled, model_flops
from repro.roofline.hlo_parse import count_collective_ops, parse_collective_bytes

__all__ = [
    "analyze_compiled",
    "count_collective_ops",
    "model_flops",
    "parse_collective_bytes",
]
