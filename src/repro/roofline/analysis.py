"""Three-term roofline analysis from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        (197e12 bf16)
    memory term     = HLO_bytes_per_device / HBM_bw             (819e9 B/s)
    collective term = collective_bytes_per_device / link_bw     (~50e9 B/s)

``compiled.cost_analysis()`` runs on the SPMD-partitioned per-device module,
so its FLOPs/bytes are already per-chip; collective bytes come from the HLO
parser (repro/roofline/hlo_parse.py) with while-loop multiplicities.

MODEL_FLOPS uses 6*N*D (train) / 2*N*D (inference) with N the *active*
parameter count for MoE; the ratio MODEL_FLOPS / (HLO_FLOPs * chips) shows
how much compiled compute is "useful" (catches remat recompute, capacity
overhead, dispatch waste).
"""
from __future__ import annotations

from typing import Any

from repro.configs.base import ArchConfig, InputShape
from repro.hw.specs import TPU_V5E
from repro.roofline.hlo_parse import parse_hlo_costs


def _cost_dict(compiled) -> dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze_compiled(
    cfg: ArchConfig,
    shape: InputShape,
    mesh,
    compiled,
    *,
    chip=TPU_V5E,
) -> dict[str, Any]:
    n_chips = mesh.devices.size
    cost = _cost_dict(compiled)
    static_flops = float(cost.get("flops", 0.0))
    static_bytes = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    parsed = parse_hlo_costs(hlo)
    flops_dev = max(parsed.flops, static_flops)
    bytes_dev = max(parsed.bytes_accessed, static_bytes)

    compute_s = flops_dev / chip.peak_flops_bf16
    memory_s = bytes_dev / chip.hbm_bw
    collective_s = parsed.collective_bytes["total"] / chip.ici_link_bw

    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    bottleneck = max(terms, key=terms.get).replace("_s", "")

    mf = model_flops(cfg, shape)
    hlo_total_flops = flops_dev * n_chips
    useful = mf / hlo_total_flops if hlo_total_flops > 0 else 0.0

    return {
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "bottleneck": bottleneck,
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "static_flops_per_device": static_flops,   # cost_analysis (loop
            "static_bytes_per_device": static_bytes,   # bodies counted once)
            "collective_bytes_per_device": parsed.collective_bytes["total"],
            "collective_breakdown": {
                k: v for k, v in parsed.collective_bytes.items() if k != "total"
            },
            "collective_op_counts": parsed.collective_ops,
            "model_flops": mf,
            "useful_flops_ratio": useful,
            "n_chips": int(n_chips),
        }
    }
