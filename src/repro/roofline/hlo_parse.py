"""Loop-aware cost accounting over compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` visits each while-loop body ONCE, so scanned
programs (layer stacks, microbatch accumulation, chunked attention) are
under-counted by the loop trip counts; and it reports no collective traffic
at all.  This parser fixes both:

* Computations are extracted from the HLO text with a per-instruction
  symbol table (name -> shape) so operand shapes can be resolved.
* Execution multiplicity per computation is propagated through the call
  graph: while bodies/conditions multiply by the loop's exact
  ``known_trip_count`` backend annotation (present for all lax.scan loops),
  fusion/call/to_apply edges inherit the caller's multiplicity.
* FLOPs: 2 * prod(result dims) * prod(lhs contracting dims) per dot,
  times multiplicity.  (Elementwise flops are excluded -- matmul-dominated
  models; the analysis reports cost_analysis' static number alongside.)
* Bytes: operand + result bytes per instruction, skipping the *insides* of
  fusion computations (fused ops don't touch HBM; the fusion instruction
  itself accounts for its operands/result), times multiplicity.  Sliced
  access is charged at slice size, not buffer size: dynamic-slice charges
  its result, dynamic-update-slice charges its update, and a fusion operand
  whose only internal uses are dynamic-slices/gathers is charged at the
  sliced sizes (scan bodies slice one layer's weights out of the stacked
  (n_groups, ...) buffers -- charging the full stack every iteration would
  overstate traffic ~500x).
* Collectives: result bytes of all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute, times multiplicity.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^((?:\([^=]*\))|(?:[\w\[\]\{\},\/\* ]+?))\s+([\w\-]+)\(")


def _shapes_in(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dtype, dims in _shapes_in(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str       # result type portion
    op: str             # op name (add, dot, fusion, while, ...)
    rest: str           # full text after '='


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]


def _parse_computations(hlo_text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry_name = ""
    current: Computation | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?(%?[\w\.\-]+)", stripped)
            name = m.group(1) if m else "?"
            current = Computation(name=name, instrs=[])
            comps[name] = current
            if stripped.startswith("ENTRY"):
                entry_name = name
            continue
        if stripped == "}":
            current = None
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            # parameter declarations inside header already handled; also
            # lines like "%param = s32[] parameter(0)" DO match _INSTR_RE.
            continue
        name, rest = im.group(1), im.group(2)
        # Split result type from op: the op name is the token right before
        # the first '(' that isn't part of a tuple type.
        op = ""
        type_str = rest
        om = re.search(r"([\w\-]+)\(", rest)
        if om:
            op = om.group(1)
            type_str = rest[: om.start()]
        current.instrs.append(Instr(name=name, type_str=type_str, op=op, rest=rest))
    return comps, entry_name


def _trip_count(rest: str) -> float:
    m = re.search(r'known_trip_count":\{"n":"(\d+)"', rest)
    if m:
        return float(m.group(1))
    return 1.0


def _callees(instr: Instr) -> list[tuple[str, float]]:
    """(callee computation, multiplier) edges for one instruction."""
    out: list[tuple[str, float]] = []
    if instr.op == "while":
        trip = _trip_count(instr.rest)
        for key in ("condition", "body"):
            m = re.search(rf"{key}=(%?[\w\.\-]+)", instr.rest)
            if m:
                out.append((m.group(1), trip))
        return out
    m = re.search(r"calls=(%?[\w\.\-]+)", instr.rest)
    if m:
        out.append((m.group(1), 1.0))
    m = re.search(r"to_apply=(%?[\w\.\-]+)", instr.rest)
    if m:
        out.append((m.group(1), 1.0))
    for m in re.finditer(r"branch_computations=\{([^}]*)\}", instr.rest):
        for name in m.group(1).split(","):
            out.append((name.strip(), 1.0))
    return out


@dataclasses.dataclass
class HloCosts:
    flops: float                       # loop-aware dot flops (per device)
    bytes_accessed: float              # loop-aware HBM bytes (per device)
    collective_bytes: dict[str, float]
    collective_ops: dict[str, int]
    trip_counted_whiles: int


def parse_hlo_costs(hlo_text: str) -> HloCosts:
    comps, entry = _parse_computations(hlo_text)

    # Symbol tables per computation: name -> result type string.
    symtab: dict[str, dict[str, str]] = {}
    for cname, comp in comps.items():
        tab = {}
        for ins in comp.instrs:
            tab[ins.name] = ins.type_str
        symtab[cname] = tab

    # Fusion-target computations (their internals don't touch HBM).
    fused_targets: set[str] = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                m = re.search(r"calls=(%?[\w\.\-]+)", ins.rest)
                if m:
                    fused_targets.add(m.group(1))

    # Multiplicities via BFS from entry.
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    n_whiles = 0
    frontier = [entry]
    seen_edges = set()
    while frontier:
        cname = frontier.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.op == "while":
                n_whiles += 1
            for callee, k in _callees(ins):
                edge = (cname, ins.name, callee)
                if edge in seen_edges:
                    continue
                seen_edges.add(edge)
                mult[callee] += mult[cname] * k
                frontier.append(callee)

    flops = 0.0
    bytes_acc = 0.0
    coll_bytes = {k: 0.0 for k in _COLLECTIVE_KINDS}
    coll_ops = {k: 0 for k in _COLLECTIVE_KINDS}

    def operand_names(rest: str, op: str) -> list[str]:
        m = re.search(rf"{op}\(([^)]*)\)", rest)
        if not m:
            return []
        return re.findall(r"%[\w\.\-]+", m.group(1))

    # For fusion computations: effective bytes per parameter index.  If a
    # fused parameter is only consumed through dynamic-slice/gather, the
    # fusion reads only the slices, not the whole buffer.
    _PASSTHROUGH = ("bitcast", "reshape", "copy", "convert", "transpose")
    _SLICE_OPS = ("dynamic-slice", "gather", "slice")

    def fused_param_bytes(comp: Computation) -> dict[int, float]:
        tab = {i.name: i for i in comp.instrs}
        uses_of: dict[str, list[Instr]] = defaultdict(list)
        for ins in comp.instrs:
            for opn in re.findall(r"%[\w\.\-]+", ins.rest):
                if opn in tab and opn != ins.name:
                    uses_of[opn].append(ins)
        param_idx: dict[str, int] = {}
        for ins in comp.instrs:
            if ins.op == "parameter":
                pm = re.search(r"parameter\((\d+)\)", ins.rest)
                if pm:
                    param_idx[ins.name] = int(pm.group(1))

        def effective(pname: str) -> float:
            """Slice-size bytes if all terminal uses slice; else full size."""
            full = float(_bytes_of(tab[pname].type_str))
            total = 0.0
            frontier = [pname]
            visited = set()
            while frontier:
                n = frontier.pop()
                if n in visited:
                    continue
                visited.add(n)
                for u in uses_of.get(n, []):
                    if u.op in _SLICE_OPS:
                        total += _bytes_of(u.type_str)
                    elif u.op in _PASSTHROUGH:
                        frontier.append(u.name)
                    else:
                        return full       # consumed whole somewhere
            return min(total, full) if total > 0 else full

        return {idx: effective(p) for p, idx in param_idx.items()}

    fused_pb: dict[str, dict[int, float]] = {
        name: fused_param_bytes(comps[name])
        for name in fused_targets
        if name in comps
    }
    # Fusion output: if the root is a dynamic-update-slice, the write is the
    # update slice, not the full carry buffer.
    fused_out_bytes: dict[str, float] = {}
    for name in fused_targets:
        comp = comps.get(name)
        if comp is None or not comp.instrs:
            continue
        root = comp.instrs[-1]
        if root.op == "dynamic-update-slice":
            ops = operand_names(root.rest, root.op)
            if len(ops) >= 2 and ops[1] in symtab[name]:
                fused_out_bytes[name] = float(_bytes_of(symtab[name][ops[1]]))

    _SKIP_BYTES_OPS = (
        "parameter", "constant", "tuple", "get-tuple-element",
        "bitcast", "while", "conditional", "call", "custom-call",
        "after-all", "partition-id", "replica-id",
    )

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0.0:
            continue
        in_fusion = cname in fused_targets
        tab = symtab[cname]
        for ins in comp.instrs:
            # --- FLOPs: dots (also inside fusions -- they do real math).
            if ins.op == "dot":
                res_dims = 1
                for _, dims in _shapes_in(ins.type_str):
                    for d in dims:
                        res_dims *= d
                ops = operand_names(ins.rest, "dot")
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
                contract = 1
                if ops and cdims:
                    lhs_type = tab.get(ops[0], "")
                    shapes = _shapes_in(lhs_type)
                    if shapes:
                        _, lhs_dims = shapes[0]
                        for ci in cdims.group(1).split(","):
                            if ci and int(ci) < len(lhs_dims):
                                contract *= lhs_dims[int(ci)]
                flops += 2.0 * res_dims * contract * m

            # --- collectives.
            base_op = ins.op.replace("-start", "").replace("-done", "")
            if base_op in _COLLECTIVE_KINDS and not ins.op.endswith("-done"):
                coll_bytes[base_op] += _bytes_of(ins.type_str) * m
                coll_ops[base_op] += 1

            # --- bytes: skip fusion internals; count real instructions.
            if in_fusion or ins.op in _SKIP_BYTES_OPS:
                continue
            if ins.op == "dynamic-slice" or ins.op == "gather":
                b = 2.0 * _bytes_of(ins.type_str)          # read slice + write
            elif ins.op == "dynamic-update-slice":
                ops = operand_names(ins.rest, ins.op)
                upd = (
                    _bytes_of(tab[ops[1]])
                    if len(ops) >= 2 and ops[1] in tab
                    else _bytes_of(ins.type_str)
                )
                b = 2.0 * upd                                # read + write slice
            elif ins.op == "fusion":
                cm = re.search(r"calls=(%?[\w\.\-]+)", ins.rest)
                callee = cm.group(1) if cm else ""
                pb = fused_pb.get(callee, {})
                ops = operand_names(ins.rest, "fusion")
                b = fused_out_bytes.get(callee, float(_bytes_of(ins.type_str)))
                for i_op, opn in enumerate(ops):
                    if opn in tab:
                        b += pb.get(i_op, float(_bytes_of(tab[opn])))
            else:
                b = float(_bytes_of(ins.type_str))
                for opn in re.findall(r"%[\w\.\-]+", ins.rest):
                    if opn in tab:
                        b += _bytes_of(tab[opn])
            bytes_acc += b * m

    return HloCosts(
        flops=flops,
        bytes_accessed=bytes_acc,
        collective_bytes={**coll_bytes, "total": sum(coll_bytes.values())},
        collective_ops=coll_ops,
        trip_counted_whiles=n_whiles,
    )


# Back-compat helpers --------------------------------------------------------
def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    return parse_hlo_costs(hlo_text).collective_bytes


def count_collective_ops(hlo_text: str) -> dict[str, int]:
    return parse_hlo_costs(hlo_text).collective_ops
