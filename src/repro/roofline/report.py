"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONL.

    PYTHONPATH=src python -m repro.roofline.report experiments/dryrun/dryrun_16x16.jsonl
"""
from __future__ import annotations

import json
import sys


def load(path: str) -> list[dict]:
    recs = [json.loads(l) for l in open(path)]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return recs


def markdown_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | peak GiB/dev | compute s | memory s | collective s "
        "| bottleneck | MODEL_FLOPS | useful ratio | one-line diagnosis |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | — "
                f"| {r['reason'][:60]} |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR | — | — "
                f"| {r.get('error','')[:60]} |"
            )
            continue
        ro = r["roofline"]
        peak = (r["memory"]["peak_bytes"] or 0) / 2**30
        diag = _diagnose(ro)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {peak:.2f} "
            f"| {ro['compute_s']:.4f} | {ro['memory_s']:.4f} "
            f"| {ro['collective_s']:.4f} | {ro['bottleneck']} "
            f"| {ro['model_flops']:.2e} | {ro['useful_flops_ratio']:.3f} "
            f"| {diag} |"
        )
    return "\n".join(lines)


def _diagnose(ro: dict) -> str:
    """One sentence on what would move the dominant term down."""
    b = ro["bottleneck"]
    if b == "compute":
        if ro["useful_flops_ratio"] < 0.5:
            return "compute-bound with low useful ratio: cut remat/capacity waste"
        return "compute-bound near useful flops: increase per-chip batch or quantize"
    if b == "memory":
        ratio = ro["memory_s"] / max(ro["compute_s"], 1e-12)
        if ratio > 20:
            return (
                "HBM traffic >> flops: fuse attention/scan intermediates "
                "(Pallas flash/WKV kernels), larger chunk sizes"
            )
        return "memory-bound: improve fusion, bf16 intermediates, bigger tiles"
    cb = ro.get("collective_breakdown", {})
    if cb:
        top = max((k for k in cb), key=lambda k: cb[k])
        return (
            f"collective-bound (mostly {top}): reshard to cut {top}, "
            "overlap collectives with compute, or batch them"
        )
    return "collective-bound: reshard or overlap"


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun/dryrun_16x16.jsonl"
    print(markdown_table(load(path)))


if __name__ == "__main__":
    main()
