"""Pluggable TPU service disciplines for the serving simulators.

Every layer of the repro used to hardwire a single global FCFS queue in
front of the TPU, which forfeits the paper's biggest latency lever after
partitioning itself: with the inter-model swap-in (Eq. 2's ``T_load``)
charged on every tenant switch, *service order* decides how often the
switch happens.  Serving same-tenant requests back-to-back amortizes one
swap-in over the whole run -- the scheduling/placement-order effect that
prior edge multi-tenancy work (Subedi et al.; Villarrubia et al.) treats
as a first-class design axis.

This module is the one implementation of queue mechanics both simulators
share:

* the event-heap DES (``repro.serving.des``) calls ``pop`` from its
  TPU-completion handler (the only point where the baseline popped its
  global FIFO deque);
* the sequential stepper (``repro.serving.simulator``) drives the same
  objects from its deferred-TPU decision loop.

The *selection* of a discipline is data, not code: ``DisciplineSpec``
(``repro.core.planner``) rides on the ``Plan``, so the planner co-optimizes
it with (P, K) and ``set_plan`` can change it mid-flight.  ``fcfs`` is the
permanent reference -- both simulators keep their native bitwise-pinned
FCFS hot paths and only instantiate these objects for non-default specs
(``make_discipline`` returns ``None`` for plain FCFS).

Contract every discipline obeys (relied on by tests/test_scheduling.py):

* **per-tenant FIFO**: within one tenant, jobs are served strictly in
  enqueue order -- a discipline chooses *which tenant* goes next, never
  reorders inside a tenant;
* **work-conserving**: ``pop`` returns a job whenever one is queued;
* **bounded unfairness** (swap_batch): between two services of the global
  FCFS head's tenant, at most ``batch_cap - 1`` same-tenant services are
  inserted, so no tenant starves.

Jobs are opaque tuples whose field 0 is the model index (the shared
``_J_MODEL`` layout of both simulators); disciplines read nothing else.
"""
from __future__ import annotations

import collections
import itertools
import math

from repro.core.planner import DisciplineSpec, FCFS  # noqa: F401  (re-export)

__all__ = [
    "FCFS",
    "DisciplineSpec",
    "Discipline",
    "FcfsDiscipline",
    "SwapBatchDiscipline",
    "PriorityDiscipline",
    "WeightedFairDiscipline",
    "make_discipline",
]


class Discipline:
    """Base of every TPU queue discipline: per-tenant FIFO deques of
    ``(seq, enqueue_time, job)`` rows plus a global arrival sequence.

    Subclasses override ``_choose`` to pick the tenant served next;
    ``push``/``pop``/``drain_rows`` and the per-tenant FIFO invariant are
    shared.  ``pop`` receives the simulated time plus the server's current
    same-tenant run state (last model begun and the length of its
    consecutive run) so run-extending disciplines can amortize swaps.
    """

    def __init__(self, spec: DisciplineSpec, n_models: int):
        if spec.weights is not None and len(spec.weights) != n_models:
            # validate_plan checks this too, but the simulators construct
            # disciplines without it -- fail at build time, not with an
            # IndexError deep inside the first contended pop.
            raise ValueError(
                f"discipline weights length {len(spec.weights)} != "
                f"{n_models} models"
            )
        self.spec = spec
        self.n = n_models
        self._queues: list[collections.deque] = [
            collections.deque() for _ in range(n_models)
        ]
        self._seq = itertools.count()
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def push(self, job: tuple, enqueue_time: float) -> None:
        """Enqueue one job; callers push in nondecreasing enqueue time."""
        self._queues[job[0]].append((next(self._seq), enqueue_time, job))
        self._len += 1

    def _head_model(self) -> int:
        """Tenant holding the globally earliest-enqueued job (FCFS head)."""
        best, best_seq = -1, math.inf
        for i, q in enumerate(self._queues):
            if q and q[0][0] < best_seq:
                best, best_seq = i, q[0][0]
        return best

    def _choose(self, now: float, run_model: int | None, run_len: int) -> int:
        raise NotImplementedError

    def pop(self, now: float, run_model: int | None, run_len: int):
        """Job served next, or ``None`` when nothing is queued."""
        if not self._len:
            return None
        i = self._choose(now, run_model, run_len)
        _, _, job = self._queues[i].popleft()
        self._len -= 1
        self._served(i, job)
        return job

    def _served(self, model_idx: int, job: tuple) -> None:
        """Post-pop bookkeeping hook (weighted-fair service accounting)."""

    def drain_rows(self) -> list[tuple[int, float, tuple]]:
        """Remove and return every queued ``(seq, enqueue_time, job)`` row in
        global enqueue order -- the migration path when ``set_plan`` switches
        disciplines mid-flight (relative order is preserved)."""
        rows = sorted(
            row for q in self._queues for row in q
        )
        for q in self._queues:
            q.clear()
        self._len = 0
        return rows


class FcfsDiscipline(Discipline):
    """Global FCFS through the shared interface.

    The simulators never run plain FCFS through this object (their native
    deque hot paths stay bitwise-pinned); it exists as the reference the
    other disciplines are tested against and as the drain-out queue after
    a mid-flight switch *back* to FCFS.
    """

    def _choose(self, now: float, run_model: int | None, run_len: int) -> int:
        return self._head_model()


class SwapBatchDiscipline(Discipline):
    """Swap-amortizing batching: keep serving the resident tenant.

    On each completion the server extends the current same-tenant run --
    popping that tenant's earliest queued job, whose weights are already
    resident so the service pays no ``T_load`` -- until one of three
    fairness triggers ends the run and FCFS order resumes at the global
    head:

    * the run reaches ``batch_cap`` consecutive services,
    * the tenant has nothing queued,
    * the globally oldest queued job has waited more than ``staleness``
      seconds (``inf`` by default: the cap alone bounds unfairness).
    """

    def _choose(self, now: float, run_model: int | None, run_len: int) -> int:
        head = self._head_model()
        if (
            run_model is not None
            and run_model != head
            and run_len < self.spec.batch_cap
            and self._queues[run_model]
        ):
            head_q = self._queues[head]
            if now - head_q[0][1] <= self.spec.staleness:
                return run_model
        return head


class PriorityDiscipline(Discipline):
    """Strict non-preemptive priority: highest ``weights[i]`` first, global
    FCFS order among tenants of equal weight.  Unweighted tenants default
    to priority 0; a starving low-priority tenant is the discipline working
    as specified, not a bug -- the planner's co-optimization only commits
    it when the predicted objective still wins."""

    def _choose(self, now: float, run_model: int | None, run_len: int) -> int:
        w = self.spec.weights
        best, best_key = -1, None
        for i, q in enumerate(self._queues):
            if not q:
                continue
            key = (-(w[i] if w is not None else 0.0), q[0][0])
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best


class WeightedFairDiscipline(Discipline):
    """Weighted fair queueing over accumulated TPU service time.

    The nonempty tenant with the smallest ``served_time / weight`` goes
    next (ties: global FCFS order), which converges to weight-proportional
    TPU shares under backlog.  The simulator charges realized service via
    ``charge`` when it begins the job (the miss-dependent swap cost is only
    known there); the single-server loop pops at most one job per
    completion, so the charge always lands before the next ``pop``.
    """

    def __init__(self, spec: DisciplineSpec, n_models: int):
        super().__init__(spec, n_models)
        self._served_time = [0.0] * n_models

    def _choose(self, now: float, run_model: int | None, run_len: int) -> int:
        w = self.spec.weights
        best, best_key = -1, None
        for i, q in enumerate(self._queues):
            if not q:
                continue
            wi = w[i] if w is not None else 1.0
            credit = self._served_time[i] / wi if wi > 0 else math.inf
            key = (credit, q[0][0])
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    def charge(self, model_idx: int, service: float) -> None:
        """Record realized TPU service time for fairness accounting."""
        self._served_time[model_idx] += service


def make_discipline(spec: DisciplineSpec, n_models: int) -> Discipline | None:
    """Instantiate the queue mechanics for a spec.

    Returns ``None`` for plain FCFS (including ``swap_batch`` with
    ``batch_cap == 1``, which cannot batch): the simulators keep their
    native bitwise-pinned FCFS paths and only pay the discipline
    indirection when a spec actually changes service order.
    """
    if spec.kind == "fcfs" or (spec.kind == "swap_batch" and not spec.batches):
        return None
    if spec.kind == "swap_batch":
        return SwapBatchDiscipline(spec, n_models)
    if spec.kind == "priority":
        return PriorityDiscipline(spec, n_models)
    if spec.kind == "weighted_fair":
        return WeightedFairDiscipline(spec, n_models)
    raise ValueError(f"unknown discipline kind {spec.kind!r}")
