"""Sequential per-request simulator of the SwapLess runtime.

Plays the role of the physical testbed in the paper's evaluation: the
analytic model *predicts* latency, the simulator *observes* it.  The
simulated system matches Section IV's runtime:

* a single global TPU worker with an FCFS queue (M/G/1 discipline),
* per-model CPU pools with ``k_i`` single-request workers (M/D/k),
* an explicit SRAM cache with model-granularity LRU eviction
  (ground truth for the paper's conservative alpha approximation),
* intra-model swap streaming folded into TPU service time,
* input/boundary transfer latencies that do not occupy either server
  (matching the additive d/B terms of Eq. 4).

``RuntimeSimulator`` is a *stepper*: it walks the trace in arrival order
and resolves each request's full timeline with ``max(t, server_free)``
recurrences.  Two execution paths share that definition:

* the scalar ``step``/``offer`` path, one pure-Python iteration per
  request -- the seed semantics and the differential reference;
* ``run_trace``, a vectorized fast path over a columnar ``Trace`` that
  resolves a whole constant-plan segment at once with the Lindley
  recurrence identity ``end = cumsum(s) + maximum.accumulate(arrival -
  shifted cumsum(s))`` plus a cheap exact sequential replay for SRAM miss
  accounting.  ``simulate()`` and ``run_adaptive()`` dispatch to it
  automatically between re-plan boundaries.  It is a *replay* of the
  scalar semantics, not a new model: every quantity matches the scalar
  path to float round-off (integer observables exactly), enforced by
  ``tests/test_sim_fastpath.py``.

The stepper shares structure with the analytic recurrences, so the
independent event-driven backend (``repro.serving.des``) is the ground
truth the model is validated against; both implement the same driver
surface (``offer`` / ``advance_to`` / ``set_plan`` / ``drain`` /
``result``) over the shared ``Request`` trace and ``SimResult`` record,
and ``simulate(..., backend=...)`` / ``run_adaptive(..., backend=...)``
pick between them.
"""
from __future__ import annotations

import heapq
import itertools
import math
from typing import Sequence

import numpy as np

from repro.core.planner import (
    ModelProfile,
    Plan,
    route_tables,
    TenantSpec,
)
from repro.hw.specs import Platform
from repro.serving.cache import SramCache
from repro.serving.faults import FaultStats, as_view
from repro.serving.result import SimResult
from repro.serving.scheduling import (
    FcfsDiscipline,
    WeightedFairDiscipline,
    make_discipline,
)
from repro.serving.workload import Request, Trace

__all__ = ["RuntimeSimulator", "SimResult", "simulate", "make_backend"]


def _lindley_guess(enqueue: np.ndarray, service: np.ndarray, free0: float) -> np.ndarray:
    """Completion times of a single FCFS server via the Lindley identity.

    Unrolls ``end[j] = max(enqueue[j], end[j-1]) + service[j]`` (with the
    server initially free at ``free0``) into

        end = cumsum(service)
              + maximum.accumulate(enqueue - shifted_cumsum(service))

    where the initial free time folds into position 0 of the accumulate.
    Associativity differs from the scalar recurrence, so this agrees with
    it only to round-off -- it is the *guess* that classifies busy-period
    boundaries for the bit-exact ``_server_ends`` below.
    """
    cu = np.cumsum(service)
    shifted = np.empty_like(cu)
    shifted[0] = 0.0
    shifted[1:] = cu[:-1]
    d = enqueue - shifted
    if d[0] < free0:
        d[0] = free0
    return cu + np.maximum.accumulate(d)


def _segmented_ends(
    enqueue: np.ndarray,
    service: np.ndarray,
    free0: float,
    resets: np.ndarray,
) -> np.ndarray:
    """Server completion times given busy-period boundaries ``resets``.

    ``resets[j]`` asserts job ``j`` found the server idle (``enqueue[j] >=
    end[j-1]``), so its busy period restarts from ``enqueue[j]`` exactly and
    every later end in the period is the *left-to-right* float sum
    ``fl(...fl(fl(root + s_r) + s_r+1)... )`` -- the very association the
    scalar recurrence produces.  Busy periods are mutually independent, so
    they all resolve in parallel: segments are bucketed by power-of-two
    length and each bucket is one padded 2-D ``cumsum`` along rows (NumPy's
    ``accumulate`` is sequential, giving the exact association per row).
    Bitwise equal to the scalar stepper iff ``resets`` is classified as the
    scalar run would.
    """
    n = enqueue.size
    starts = np.flatnonzero(resets)
    roots = enqueue[starts].copy()
    if starts[0] == 0 and roots[0] < free0:
        roots[0] = free0  # max(enqueue[0], free0): selection, no arithmetic
    seg_len = np.empty(starts.size, dtype=np.int64)
    seg_len[:-1] = starts[1:] - starts[:-1]
    seg_len[-1] = n - starts[-1]
    ends = np.empty(n)
    bexp = np.ceil(np.log2(seg_len)).astype(np.int64)
    for b in range(int(bexp.max()) + 1):
        sel = np.flatnonzero(bexp == b)
        if not sel.size:
            continue
        r, l = starts[sel], seg_len[sel]
        if b == 0:
            ends[r] = roots[sel] + service[r]
            continue
        if b == 1:
            # Length-2 segments, the bulk at moderate load: two adds.
            e0 = roots[sel] + service[r]
            ends[r] = e0
            ends[r + 1] = e0 + service[r + 1]
            continue
        w = 1 << b
        cols = np.arange(w)
        idx = r[:, None] + cols[None, :]
        valid = cols[None, :] < l[:, None]
        mat = np.zeros((r.size, w + 1))
        mat[:, 0] = roots[sel]
        mat[:, 1:] = np.where(valid, service[np.where(valid, idx, 0)], 0.0)
        cs = np.cumsum(mat, axis=1)
        ends[idx[valid]] = cs[:, 1:][valid]
    return ends


def _server_ends(enqueue: np.ndarray, service: np.ndarray, free0: float) -> np.ndarray:
    """Completion times of a single FCFS server, vectorized *and* bit-exact.

    The scalar recurrence ``end[j] = max(enqueue[j], end[j-1]) + service[j]``
    only couples jobs within a busy period; across an idle gap the clock
    restarts from the enqueue time exactly.  So: guess the ends with the
    Lindley identity, classify busy-period boundaries from the guess,
    recompute each period with the scalar association (``_segmented_ends``),
    and re-check the classification against the recomputed ends.  A
    consistent fixpoint satisfies the scalar recurrence elementwise and is
    therefore *bitwise* the scalar result.  Misclassifications only occur
    where the guess's round-off straddles a near-tie, so the loop almost
    always exits on the first pass; a pathological non-converging tie chain
    falls back to the plain sequential recurrence.
    """
    ends = _lindley_guess(enqueue, service, free0)
    resets = np.empty(enqueue.size, dtype=bool)
    for _ in range(8):
        resets[0] = True
        np.greater_equal(enqueue[1:], ends[:-1], out=resets[1:])
        if resets.all():
            # Fully idle server (zero queueing): end = enqueue + service
            # elementwise, trivially consistent.
            ends = enqueue + service
            if enqueue[0] < free0:
                ends[0] = free0 + service[0]
            if np.array_equal(enqueue[1:] >= ends[:-1], resets[1:]):
                return ends
            continue
        ends = _segmented_ends(enqueue, service, free0, resets)
        if np.array_equal(enqueue[1:] >= ends[:-1], resets[1:]):
            return ends
    out = np.empty(enqueue.size)
    free = free0
    for j, (e, s) in enumerate(zip(enqueue.tolist(), service.tolist())):
        free = (e if e > free else free) + s
        out[j] = free
    return out


# Deferred-TPU job tuple of the discipline path (same field layout as the
# DES ``_J_*`` map; the two simulators never exchange jobs, but one layout
# keeps the mechanics recognizably parallel).
_DJ_MODEL = 0
_DJ_ARR = 1
_DJ_RECORD = 2
_DJ_TPU_S = 3
_DJ_CPU_S = 4
_DJ_OUT_X = 5
_DJ_PBYTES = 6
_DJ_TLOAD = 7
_DJ_SUFFIX = 8


class RuntimeSimulator:
    """Steppable two-stage (TPU -> CPU) system over profiled tenants.

    The TPU queue runs under ``plan.discipline``: with the default FCFS the
    seed scalar ``step`` path resolves each request fully at arrival (queue
    order == arrival order, so no queue state is needed); any other
    discipline defers TPU service decisions through a pending queue
    (``repro.serving.scheduling``) that is drained as the offered clock
    advances -- see ``_advance_tpu``.
    """

    def __init__(
        self,
        profiles: Sequence[ModelProfile],
        plan: Plan,
        platform: Platform,
        *,
        faults=None,
    ):
        self.profiles = list(profiles)
        self.platform = platform
        self.n = len(self.profiles)
        self.cache = SramCache(platform.sram_bytes)
        self.tpu_free = 0.0
        self.tpu_busy = 0.0
        self.last_completion = 0.0
        self.latencies: list[list[float]] = [[] for _ in range(self.n)]
        self.arrivals: list[list[float]] = [[] for _ in range(self.n)]
        self.misses = [0] * self.n
        self.tpu_requests = [0] * self.n
        self._plan: Plan | None = None
        self._cpu_pools: list[list[float]] = [[0.0] for _ in range(self.n)]
        # Non-FCFS discipline state (all dormant under the default FCFS,
        # whose scalar/vectorized paths stay bitwise-pinned):
        self._disc = None                     # scheduling.Discipline | None
        self._wf: WeightedFairDiscipline | None = None
        self._tpu_arrivals: list[tuple] = []  # (enqueue_t, seq, job) heap
        self._arr_seq = itertools.count()
        self._run_model: int | None = None
        self._run_len = 0
        # Fault injection (serving.faults): a trivial view (no windows)
        # normalizes to None so an empty schedule takes the exact pre-fault
        # code paths, and faults=None stays bitwise the pre-fault simulator.
        fv = as_view(faults)
        self._faults = fv if fv is not None and fv.has_faults else None
        self._fault_lost = [0] * self.n
        self._fault_requeued = [0] * self.n
        self.set_plan(plan, now=0.0)

    # -- plan management ----------------------------------------------------
    def set_plan(self, plan: Plan, now: float) -> None:
        """Switch to a new (P, K) configuration at time ``now``.

        CPU pools are resized preserving the most-loaded workers' busy
        horizons (a worker mid-request finishes its request).  The paper
        preloads candidate partitions so switching cost is negligible; we
        model it as free.
        """
        if len(plan.partition) != self.n:
            raise ValueError("plan size mismatch")
        old = self._plan
        if self._disc is not None:
            # Resolve TPU decisions up to the switch instant so queued work
            # bound under the old plan is ordered before the change.
            self._advance_tpu(now)
        if old is None or plan.discipline != old.discipline:
            if self._disc is None:
                # FCFS -> non-FCFS (or the initial install): the scalar path
                # leaves nothing pending, so no migration is needed.
                self._disc = make_discipline(plan.discipline, self.n)
            else:
                # Between discipline objects, queued jobs migrate in global
                # enqueue order.  A switch back to FCFS keeps the deferred
                # machinery (as an FcfsDiscipline) -- the scalar fast path
                # cannot absorb already-queued jobs, and mixed-discipline
                # runs are outside the bitwise-pinned FCFS contract anyway.
                new = make_discipline(plan.discipline, self.n) or FcfsDiscipline(
                    plan.discipline, self.n
                )
                for _, t, job in self._disc.drain_rows():
                    new.push(job, t)
                self._disc = new
            self._wf = (
                self._disc
                if isinstance(self._disc, WeightedFairDiscipline)
                else None
            )
        if self._disc is not None and self._faults is not None:
            # Fault gates are defined on the scalar FCFS recurrence (and
            # mirrored by the DES); composing them with deferred-discipline
            # service orders is unspecified, so refuse loudly.
            raise ValueError(
                "fault injection supports the FCFS discipline only"
            )
        self._plan = plan
        self._derive(plan)
        new_pools: list[list[float]] = []
        for i, k in enumerate(plan.cores):
            size = max(k, 1)
            prev = self._cpu_pools[i] if old is not None else [now]
            busy = sorted(prev, reverse=True)[:size]
            while len(busy) < size:
                busy.append(now)
            heapq.heapify(busy)
            new_pools.append(busy)
        self._cpu_pools = new_pools

    def _derive(self, plan: Plan) -> None:
        pf = self.profiles
        p = plan.partition
        rt = route_tables(pf, plan, self.platform)
        self._prefix_bytes = rt.prefix_bytes
        self._s_tpu = rt.s_tpu
        self._t_load = rt.t_load
        self._s_cpu = rt.s_cpu
        self._in_xfer = rt.in_xfer
        self._out_xfer = rt.out_xfer
        # Columnar mirrors of the per-model tables for the vectorized path
        # (same float values -- np.array of python floats is exact).
        self._part_arr = np.array(p, dtype=np.int64)
        self._points_arr = np.array(
            [f.num_partition_points for f in pf], dtype=np.int64
        )
        self._s_tpu_arr = np.array(self._s_tpu)
        self._t_load_arr = np.array(self._t_load)
        self._in_xfer_arr = np.array(self._in_xfer)
        self._out_xfer_arr = np.array(self._out_xfer)
        # Boundary transfer charged only on split routes (0 < p < P); a
        # masked copy lets the fast path add it unconditionally (x + 0.0
        # is exact) instead of scattering through boolean masks.
        self._out_eff_arr = np.where(
            (self._part_arr > 0) & (self._part_arr < self._points_arr),
            self._out_xfer_arr,
            0.0,
        )
        self._want = [
            min(b, self.cache.capacity) for b in self._prefix_bytes
        ]

    @property
    def plan(self) -> Plan:
        assert self._plan is not None
        return self._plan

    # -- event processing ---------------------------------------------------
    def step(self, req: Request, *, record: bool = True) -> float:
        """Process one request; returns its end-to-end latency (s).

        FCFS only: the scalar recurrence resolves each request fully at
        arrival, which is exactly the property non-FCFS disciplines give
        up.  Under a non-default ``plan.discipline`` drive the simulator
        through ``offer``/``advance_to``/``drain`` instead.
        """
        if self._disc is not None:
            raise ValueError(
                "step() resolves a request at arrival; non-FCFS disciplines "
                "defer service order -- drive via offer()/advance_to()/drain()"
            )
        if self._faults is not None:
            return self._step_faulted(req, record)
        i = req.model_idx
        p = self.plan.partition[i]
        P_i = self.profiles[i].num_partition_points
        t = req.arrival
        if p > 0:
            t += self._in_xfer[i]
            start = max(t, self.tpu_free)
            miss = self.cache.access(i, self._prefix_bytes[i], start)
            service = self._s_tpu[i] * req.service_scale + (
                self._t_load[i] if miss else 0.0
            )
            self.tpu_free = start + service
            self.tpu_busy += service
            t = self.tpu_free
            if record:
                self.tpu_requests[i] += 1
                if miss:
                    self.misses[i] += 1
            if p < P_i:
                t += self._out_xfer[i]
        if p < P_i:
            pool = self._cpu_pools[i]
            free = heapq.heappop(pool)
            start = max(t, free)
            end = start + self._s_cpu[i] * req.service_scale
            heapq.heappush(pool, end)
            t = end
        self.last_completion = max(self.last_completion, t)
        lat = t - req.arrival
        if record:
            self.latencies[i].append(lat)
            self.arrivals[i].append(req.arrival)
        return lat

    def _step_faulted(self, req: Request, record: bool) -> float:
        """Scalar ``step`` with the device-fault gates applied.

        The fault semantics live in ``serving.faults``: the dropout gate
        fires at the arrival instant and again at each service start
        (requeue defers to the recovery instant; lost drops and counts,
        leaving server state untouched); speed factors bind at the instant
        each service or transfer begins.  The DES applies the same gates at
        the same instants with the same float ops, so DES == stepper stays
        elementwise under any schedule (``tests/test_faults.py``).  Returns
        ``nan`` for a lost request.
        """
        fv = self._faults
        i = req.model_idx
        p = self.plan.partition[i]
        P_i = self.profiles[i].num_partition_points
        t = req.arrival
        if fv.is_down(t):
            if fv.lost:
                if record:
                    self._fault_lost[i] += 1
                return math.nan
            t = fv.down_until(t)
            if record:
                self._fault_requeued[i] += 1
        if p > 0:
            t += self._in_xfer[i] / fv.swap_factor(t)
            start = max(t, self.tpu_free)
            if fv.is_down(start):
                if fv.lost:
                    if record:
                        self._fault_lost[i] += 1
                    return math.nan
                start = fv.down_until(start)
                if record:
                    self._fault_requeued[i] += 1
            miss = self.cache.access(i, self._prefix_bytes[i], start)
            service = self._s_tpu[i] * req.service_scale / fv.tpu_factor(start)
            if miss:
                service += self._t_load[i] / fv.swap_factor(start)
            self.tpu_free = start + service
            self.tpu_busy += service
            t = self.tpu_free
            if record:
                self.tpu_requests[i] += 1
                if miss:
                    self.misses[i] += 1
            if p < P_i:
                t += self._out_xfer[i] / fv.swap_factor(self.tpu_free)
        if p < P_i:
            pool = self._cpu_pools[i]
            free = heapq.heappop(pool)
            start = max(t, free)
            if fv.is_down(start):
                if fv.lost:
                    heapq.heappush(pool, free)
                    if record:
                        self._fault_lost[i] += 1
                    return math.nan
                start = fv.down_until(start)
                if record:
                    self._fault_requeued[i] += 1
            end = start + self._s_cpu[i] * req.service_scale / fv.cpu_factor(start)
            heapq.heappush(pool, end)
            t = end
        self.last_completion = max(self.last_completion, t)
        lat = t - req.arrival
        if record:
            self.latencies[i].append(lat)
            self.arrivals[i].append(req.arrival)
        return lat

    # -- deferred TPU machinery (non-FCFS disciplines) -----------------------
    def _offer_deferred(self, req: Request, record: bool) -> None:
        """Discipline-path ``offer``: bind the route at arrival, defer the
        TPU service decision to ``_advance_tpu``.

        Full-CPU routes resolve immediately (they never touch the TPU and
        per-model pools see them in arrival order either way); TPU-bound
        jobs enter a future-enqueue heap keyed by ``arrival + input_xfer``
        so the discipline queue receives them in enqueue-time order exactly
        as the DES's enqueue events fire.
        """
        i = req.model_idx
        p = self.plan.partition[i]
        suffix = p < self.profiles[i].num_partition_points
        if p > 0:
            enq = req.arrival + self._in_xfer[i]
            # Advance only to the *arrival*: it lower-bounds every future
            # enqueue (offers come in arrival order and input transfers are
            # non-negative), so no decision is finalized before a job the
            # DES would already have queued.  Advancing to this job's own
            # enqueue time would over-run it whenever another model's
            # smaller input transfer lands an enqueue inside (arrival, enq].
            self._advance_tpu(req.arrival)
            job = (
                i,
                req.arrival,
                record,
                self._s_tpu[i] * req.service_scale,
                self._s_cpu[i] * req.service_scale,
                self._out_xfer[i] if suffix else 0.0,
                self._prefix_bytes[i],
                self._t_load[i],
                suffix,
            )
            heapq.heappush(self._tpu_arrivals, (enq, next(self._arr_seq), job))
            return
        self._advance_tpu(req.arrival)
        pool = self._cpu_pools[i]
        free = heapq.heappop(pool)
        start = max(req.arrival, free)
        end = start + self._s_cpu[i] * req.service_scale
        heapq.heappush(pool, end)
        self.last_completion = max(self.last_completion, end)
        if record:
            self.latencies[i].append(end - req.arrival)
            self.arrivals[i].append(req.arrival)

    def _advance_tpu(self, until: float) -> None:
        """Resolve every TPU service decision at or before time ``until``.

        Replays the DES event interleaving with two pending structures: the
        future-enqueue heap (jobs still in input transfer) and the
        discipline queue (jobs waiting for the server).  The server is busy
        exactly through ``tpu_free`` whenever the discipline queue is
        nonempty -- jobs only queue behind a busy server -- so the next
        decision is either ingesting the earliest future enqueue (when it
        lands at or before the completion) or letting the discipline pick
        at the completion instant.  Exact ties between an enqueue and a
        completion resolve enqueue-first here, where the DES orders them by
        event sequence; like FCFS multi-tenant tie order, that difference
        is legitimate between the two backends (ROADMAP "DES is ground
        truth").
        """
        disc = self._disc
        heap = self._tpu_arrivals
        while True:
            next_enq = heap[0][0] if heap else math.inf
            if len(disc):
                if next_enq <= self.tpu_free:
                    if next_enq > until:
                        return
                    enq_t, _, job = heapq.heappop(heap)
                    disc.push(job, enq_t)
                    continue
                if self.tpu_free > until:
                    return
                job = disc.pop(self.tpu_free, self._run_model, self._run_len)
                self._begin_tpu_job(job, self.tpu_free)
                continue
            if not heap or next_enq > until:
                return
            enq_t, _, job = heapq.heappop(heap)
            if enq_t >= self.tpu_free:
                # Idle server: work-conserving start, no discipline choice.
                self._begin_tpu_job(job, enq_t)
            else:
                disc.push(job, enq_t)

    def _begin_tpu_job(self, job: tuple, start: float) -> None:
        """Serve one TPU job at ``start`` and resolve its full timeline
        (same per-request float ops as the scalar ``step`` TPU/CPU path)."""
        i = job[_DJ_MODEL]
        if i == self._run_model:
            self._run_len += 1
        else:
            self._run_model = i
            self._run_len = 1
        miss = self.cache.access(i, job[_DJ_PBYTES], start)
        service = job[_DJ_TPU_S] + (job[_DJ_TLOAD] if miss else 0.0)
        self.tpu_free = start + service
        self.tpu_busy += service
        if self._wf is not None:
            self._wf.charge(i, service)
        if job[_DJ_RECORD]:
            self.tpu_requests[i] += 1
            if miss:
                self.misses[i] += 1
        t = self.tpu_free
        if job[_DJ_SUFFIX]:
            t += job[_DJ_OUT_X]
            pool = self._cpu_pools[i]
            free = heapq.heappop(pool)
            start_c = max(t, free)
            end = start_c + job[_DJ_CPU_S]
            heapq.heappush(pool, end)
            t = end
        self.last_completion = max(self.last_completion, t)
        if job[_DJ_RECORD]:
            self.latencies[i].append(t - job[_DJ_ARR])
            self.arrivals[i].append(job[_DJ_ARR])

    # -- vectorized fast path -----------------------------------------------
    def _lindley(
        self, enqueue: np.ndarray, service: np.ndarray, free0: float
    ) -> np.ndarray:
        """Single-server FCFS completion times for one constant-plan span.

        The one recurrence hook backends may re-implement: the NumPy
        stepper uses the exact ``_server_ends`` fixpoint (bitwise-pinned
        reference); ``serving.jax_stepper.JaxStepper`` overrides it with
        a jitted float32 max-plus scan under the statistical-equivalence
        contract.  Everything else in ``run_trace`` is shared.
        """
        return _server_ends(enqueue, service, free0)

    def _replay_lru(
        self, tm: np.ndarray, first: np.ndarray, last: np.ndarray
    ) -> tuple[np.ndarray, list[tuple[int, int]]]:
        """Exact SRAM miss accounting for a TPU access sequence.

        Misses depend only on the *order* of accesses (LRU recency order
        equals processing order: TPU starts are strictly increasing), never
        on the clock, so they resolve before the Lindley pass.  ``first`` /
        ``last`` map each model to its first/last position in ``tm`` (-1
        when absent).  Returns the per-access miss flags plus the final
        ``(model, bytes)`` residency in recency order; the caller stamps
        ``last_used`` from the computed start times and restores the cache.

        Two regimes:
        * *no possible eviction* (worst-case residency fits capacity): a
          model can miss only on its first access -- fully vectorized;
        * otherwise an O(#tenant-switches) run-compressed LRU replay
          (within a run of one model only the first access can miss).
        """
        cap = self.cache.capacity
        want = self._want
        old_state = self.cache.state()
        old_bytes = {m: b for m, b, _ in old_state}
        miss = np.zeros(tm.size, dtype=bool)

        first_l = first.tolist()
        accessed = [g for g, f in enumerate(first_l) if f >= 0]
        grow = sum(max(0, want[g] - old_bytes.get(g, 0)) for g in accessed)
        if self.cache.used + grow <= cap:
            # No eviction can occur: first-touch misses only.
            miss[[f for g, f in enumerate(first_l)
                  if f >= 0 and old_bytes.get(g, -1) < want[g]]] = True
            # Recency: untouched entries keep their order, accessed models
            # move to the back ordered by last occurrence.
            by_last = sorted((last[g], g) for g in accessed)
            accessed_set = set(accessed)
            order = [
                (g, b) for g, b, _ in old_state if g not in accessed_set
            ] + [
                (g, max(old_bytes.get(g, 0), want[g])) for _, g in by_last
            ]
            return miss, order

        # General LRU replay over tenant-switch points.
        runs = np.flatnonzero(
            np.concatenate(([True], tm[1:] != tm[:-1]))
        )
        od: dict[int, int] = {m: b for m, b, _ in old_state}
        od_get, od_pop = od.get, od.pop
        used = self.cache.used
        miss_at: list[int] = []
        append = miss_at.append
        for pos, g in zip(runs.tolist(), tm[runs].tolist()):
            w = want[g]
            b = od_get(g)
            if b is not None and b >= w:
                del od[g]          # move-to-end: dict keeps insertion order
                od[g] = b
                continue
            append(pos)
            if b is not None:
                del od[g]
                used -= b
            while used + w > cap and od:
                used -= od_pop(next(iter(od)))
            od[g] = w
            used += w
        miss[miss_at] = True
        return miss, list(od.items())

    def run_trace(self, trace: Trace, *, record_from: float = 0.0) -> None:
        """Resolve a whole arrival-sorted, constant-plan trace segment.

        Semantically identical to ``for r in trace: self.offer(r,
        record=r.arrival >= record_from)`` -- same state evolution, same
        recorded observations -- but vectorized: the TPU stage is one
        exact Lindley pass over the merged trace (``_server_ends``), SRAM
        misses replay exactly from access order alone, and each CPU pool
        resolves per model (the same exact Lindley for one core; the scalar
        heap recurrence, op-for-op, for multi-core pools, whose service
        order depends on the heap state).  Every float observable is
        *bitwise* identical to the scalar path except the aggregate
        ``tpu_busy`` (pairwise vs sequential summation, equal to round-off).
        """
        n_req = len(trace)
        if n_req == 0:
            return
        if not trace.is_sorted:
            # Same misuse the scalar driver surfaces per request; an
            # unsorted trace would silently corrupt the Lindley order and
            # the searchsorted warmup boundary.  O(1) for generator traces.
            raise ValueError("run_trace requires an arrival-sorted Trace")
        if self._disc is not None or self._faults is not None:
            # Non-FCFS disciplines defer service decisions, which the
            # Lindley identity (strict FCFS order) cannot express; a fault
            # schedule makes service times depend on each request's start
            # instant, which the identity likewise cannot see.  Both fall
            # back transparently to the scalar reference loop -- same
            # observables, scalar speed.  Default FCFS with faults=None
            # keeps the vectorized path below.
            for r in trace:
                self.offer(r, record=r.arrival >= record_from)
            return
        m = trace.model_idx
        arr = trace.arrival
        sc = trace.service_scale
        unit = trace.scale_is_unit
        has_tpu = self._part_arr > 0
        has_cpu = self._part_arr < self._points_arr
        # Arrival-sorted segment: the record predicate (arrival >=
        # record_from) is a suffix starting at k0 -- no boolean mask needed.
        k0 = int(np.searchsorted(arr, record_from, side="left"))

        all_tpu = bool(has_tpu.all())
        any_cpu = bool(has_cpu.any())
        if all_tpu:
            ti, tm, arr_t = None, m, arr
            kt = k0
        else:
            ti = np.flatnonzero(has_tpu[m])
            tm, arr_t = m[ti], arr[ti]
            kt = int(np.searchsorted(ti, k0, side="left"))

        if all_tpu and not any_cpu:
            completion = None  # pure-TPU segment: completion == ends
        else:
            completion = arr.copy()  # p==0 models enqueue to CPU at arrival

        if tm.size:
            enq = arr_t + self._in_xfer_arr[tm]
            # First/last occurrence per model via scatter (last write wins):
            # O(n), no sort.
            last = np.full(self.n, -1, dtype=np.int64)
            last[tm] = np.arange(tm.size)
            first = np.full(self.n, -1, dtype=np.int64)
            first[tm[::-1]] = np.arange(tm.size - 1, -1, -1)
            miss, residency = self._replay_lru(tm, first, last)
            any_miss = bool(miss.any())
            if unit:
                service = self._s_tpu_arr[tm]  # fancy index -> fresh array
            elif ti is None:
                service = self._s_tpu_arr[tm] * sc
            else:
                service = self._s_tpu_arr[tm] * sc[ti]
            if any_miss:
                mi = np.flatnonzero(miss)
                service[mi] += self._t_load_arr[tm[mi]]
            free0 = self.tpu_free
            ends = self._lindley(enq, service, free0)
            # Cache handoff: each accessed model's last_used is the start of
            # its last access; untouched residents keep their old stamps.
            old_stamp = {g: lu for g, _, lu in self.cache.state()}
            last_l = last.tolist()
            rows = []
            for g, b in residency:
                j = last_l[g]
                if j >= 0:
                    prev = ends[j - 1] if j else free0
                    e = enq[j]
                    stamp = float(e if e >= prev else prev)
                else:
                    stamp = old_stamp.get(g, 0.0)
                rows.append((g, b, stamp))
            self.cache.restore(rows)
            self.tpu_free = float(ends[-1])
            self.tpu_busy += float(service.sum())
            rec_tm = tm[kt:]
            for i, c in enumerate(np.bincount(rec_tm, minlength=self.n)):
                self.tpu_requests[i] += int(c)
            if any_miss:
                for i, c in enumerate(
                    np.bincount(rec_tm[miss[kt:]], minlength=self.n)
                ):
                    self.misses[i] += int(c)
            if completion is None:
                completion = ends
            elif ti is None:
                completion = ends + self._out_eff_arr[tm]
            else:
                completion[ti] = ends + self._out_eff_arr[tm]

        if any_cpu:
            for i in np.flatnonzero(has_cpu).tolist():
                sel = np.flatnonzero(m == i)
                if sel.size == 0:
                    continue
                t_in = completion[sel]
                svc = (
                    np.full(sel.size, self._s_cpu[i])
                    if unit
                    else self._s_cpu[i] * sc[sel]
                )
                pool = self._cpu_pools[i]
                if len(pool) == 1:
                    ends_c = self._lindley(t_in, svc, pool[0])
                    pool[0] = float(ends_c[-1])
                else:
                    # Multi-server FCFS: replay the scalar heap ops exactly.
                    ends_l: list[float] = []
                    push, pop = heapq.heappush, heapq.heappop
                    for t, s in zip(t_in.tolist(), svc.tolist()):
                        free = pop(pool)
                        end = (t if t > free else free) + s
                        push(pool, end)
                        ends_l.append(end)
                    ends_c = np.array(ends_l)
                completion[sel] = ends_c

        self.last_completion = max(
            self.last_completion, float(completion.max())
        )
        # Record columnar chunks; result() flattens them (tolist-ing a
        # million floats into Python lists would dominate the whole pass).
        if k0 < n_req:
            lat_r = completion[k0:] - arr[k0:]
            arr_r = arr[k0:]
            if self.n == 1:
                self.latencies[0].append(lat_r)
                self.arrivals[0].append(arr_r)
            else:
                m_r = m[k0:]
                for i in range(self.n):
                    keep = m_r == i
                    if keep.any():
                        self.latencies[i].append(lat_r[keep])
                        self.arrivals[i].append(arr_r[keep])

    # -- shared driver surface (see repro.serving.des) -----------------------
    def offer(self, req: Request, *, record: bool = True) -> None:
        """Driver-contract entry: requests must be offered in arrival order.

        Under FCFS this is an alias of ``step`` (each request resolves
        fully on arrival); under a non-FCFS discipline the TPU decision is
        deferred to the pending-queue machinery.
        """
        if self._disc is None:
            self.step(req, record=record)
        else:
            self._offer_deferred(req, record)

    def advance_to(self, t: float) -> None:
        """Resolve deferred TPU decisions up to ``t`` (no-op under FCFS,
        where the stepper has no pending events between requests)."""
        if self._disc is not None:
            self._advance_tpu(t)

    def drain(self) -> float:
        """Run any deferred TPU work dry; reports the last completion
        (under FCFS nothing is ever in flight between steps)."""
        if self._disc is not None:
            self._advance_tpu(math.inf)
        return self.last_completion

    def result(self, duration: float) -> SimResult:
        return SimResult(
            latencies=[_flat(ls) for ls in self.latencies],
            arrivals=[_flat(a) for a in self.arrivals],
            tpu_busy=self.tpu_busy,
            duration=duration,
            misses=self.misses,
            tpu_requests=self.tpu_requests,
            fault=self._fault_stats(),
        )

    def _fault_stats(self) -> "FaultStats | None":
        if self._faults is None:
            return None
        return FaultStats(
            lost=list(self._fault_lost),
            requeued=list(self._fault_requeued),
            down_windows=self._faults.down_windows,
            degraded_windows=self._faults.degraded_windows,
        )


def _flat(parts: list):
    """Flatten mixed scalar/chunk observation storage.

    The scalar path appends floats, ``run_trace`` appends NumPy chunks;
    pure-scalar lists pass through untouched (the seed's live-list
    behavior), anything chunked concatenates to one float64 array.
    """
    if not any(isinstance(p, np.ndarray) for p in parts):
        return parts
    return np.concatenate(
        [p if isinstance(p, np.ndarray) else np.array([p]) for p in parts]
    )


def _stepper_factory(profiles, plan, platform, faults=None):
    return RuntimeSimulator(profiles, plan, platform, faults=faults)


def _jax_factory(profiles, plan, platform, faults=None):
    # Local import: the default backends must not pay jax's import
    # (or its compilation cache) unless the caller opted in.
    from repro.serving.jax_stepper import JaxStepper

    return JaxStepper(profiles, plan, platform, faults=faults)


def _des_factory(profiles, plan, platform, faults=None):
    # Local import: des.py imports the shared result/workload modules
    # only, so the dependency stays one-way at module-load time.
    from repro.serving.des import DiscreteEventSimulator

    return DiscreteEventSimulator(profiles, plan, platform, faults=faults)


# Name -> lazy constructor.  The registry is the single source of truth for
# what `backend=` accepts everywhere (simulate / run_adaptive / the fleet
# layer); the error path lists its keys so a typo names every valid choice.
_BACKENDS = {
    "stepper": _stepper_factory,
    "des": _des_factory,
    "jax": _jax_factory,
}


def make_backend(
    backend: str,
    profiles: Sequence[ModelProfile],
    plan: Plan,
    platform: Platform,
    *,
    faults=None,
):
    """Instantiate a serving-simulation backend by name.

    ``"stepper"`` is the sequential ``RuntimeSimulator``; ``"des"`` the
    event-driven ``DiscreteEventSimulator`` (the validation ground truth);
    ``"jax"`` the ``JaxStepper`` -- the stepper with its Lindley
    recurrences evaluated on-device (float32, statistically equivalent,
    opt-in: nothing imports jax unless asked for).
    """
    try:
        factory = _BACKENDS[backend]
    except KeyError:
        valid = ", ".join(repr(k) for k in _BACKENDS)
        raise ValueError(
            f"unknown backend {backend!r}: valid backends are {valid}"
        ) from None
    return factory(profiles, plan, platform, faults=faults)


def ensure_sorted(requests: "Trace | Sequence[Request]"):
    """The trace in arrival order, skipping the copy when already sorted.

    Every generator returns sorted traces (``Trace`` carries the flag, so
    the check is O(1)); ``Request`` sequences are verified linearly --
    cheaper than the unconditional ``sorted()`` copy either way.
    """
    if isinstance(requests, Trace):
        return requests.sorted_by_arrival()
    if all(a.arrival <= b.arrival for a, b in zip(requests, requests[1:])):
        return requests
    return sorted(requests, key=lambda r: r.arrival)


def sorted_trace_and_horizon(requests: "Trace | Sequence[Request]"):
    """``(arrival-sorted trace, last arrival time)`` -- the shared preamble
    of ``simulate`` and ``run_adaptive`` (the horizon anchors the warmup
    cutoff and the minimum reported duration; 0.0 for an empty trace)."""
    reqs = ensure_sorted(requests)
    if not len(reqs):
        return reqs, 0.0
    if isinstance(reqs, Trace):
        return reqs, float(reqs.arrival[-1])
    return reqs, reqs[-1].arrival


def simulate(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    requests: "Trace | Sequence[Request]",
    *,
    warmup_frac: float = 0.05,
    backend: str = "stepper",
    vectorize: bool = True,
    faults=None,
) -> SimResult:
    """Run a static-plan simulation over a request trace.

    ``warmup_frac``: leading fraction of the trace excluded from statistics
    (cold-start cache fills; the paper measures steady state).
    ``backend``: ``"stepper"`` (default) or ``"des"`` -- same contract,
    independent mechanics.
    ``vectorize``: with a columnar ``Trace``, resolve the whole trace through
    the fast driver -- the vectorized ``run_trace`` on the stepper, the
    inlined columnar ``offer_trace`` on the DES (default); ``False`` forces
    the scalar per-request reference path.
    ``faults``: optional ``serving.faults`` schedule/view injected into the
    backend (dropout / throttle / swap degradation; forces the scalar path);
    the ``None`` default is bitwise the pre-fault simulator.
    """
    sim = make_backend(
        backend, [t.profile for t in tenants], plan, platform, faults=faults
    )
    reqs, horizon = sorted_trace_and_horizon(requests)
    warmup_t = horizon * warmup_frac
    if vectorize and isinstance(reqs, Trace):
        if backend in ("stepper", "jax"):
            sim.run_trace(reqs, record_from=warmup_t)
        else:
            sim.offer_trace(reqs, record_from=warmup_t)
    else:
        for req in reqs:
            sim.offer(req, record=req.arrival >= warmup_t)
    # Duration runs to the last completion, not the last arrival: under
    # backlog the servers keep draining after arrivals stop, and clipping
    # the horizon at the last arrival let tpu_utilization exceed 1.0.
    return sim.result(max(horizon, sim.drain()))
