"""Sequential per-request simulator of the SwapLess runtime.

Plays the role of the physical testbed in the paper's evaluation: the
analytic model *predicts* latency, the simulator *observes* it.  The
simulated system matches Section IV's runtime:

* a single global TPU worker with an FCFS queue (M/G/1 discipline),
* per-model CPU pools with ``k_i`` single-request workers (M/D/k),
* an explicit SRAM cache with model-granularity LRU eviction
  (ground truth for the paper's conservative alpha approximation),
* intra-model swap streaming folded into TPU service time,
* input/boundary transfer latencies that do not occupy either server
  (matching the additive d/B terms of Eq. 4).

``RuntimeSimulator`` is a *stepper*: it walks the trace in arrival order
and resolves each request's full timeline with ``max(t, server_free)``
recurrences.  That shares structure with the analytic recurrences, so the
independent event-driven backend (``repro.serving.des``) is the ground
truth the model is validated against; both implement the same driver
surface (``offer`` / ``advance_to`` / ``set_plan`` / ``drain`` /
``result``) over the shared ``Request`` trace and ``SimResult`` record,
and ``simulate(..., backend=...)`` / ``run_adaptive(..., backend=...)``
pick between them.
"""
from __future__ import annotations

import heapq
from typing import Sequence

from repro.core.planner import (
    ModelProfile,
    Plan,
    load_time,
    prefix_service_time,
    TenantSpec,
)
from repro.hw.specs import Platform
from repro.serving.cache import SramCache
from repro.serving.result import SimResult
from repro.serving.workload import Request

__all__ = ["RuntimeSimulator", "SimResult", "simulate", "make_backend"]


class RuntimeSimulator:
    """Steppable two-stage (TPU -> CPU) FCFS system over profiled tenants."""

    def __init__(
        self,
        profiles: Sequence[ModelProfile],
        plan: Plan,
        platform: Platform,
    ):
        self.profiles = list(profiles)
        self.platform = platform
        self.n = len(self.profiles)
        self.cache = SramCache(platform.sram_bytes)
        self.tpu_free = 0.0
        self.tpu_busy = 0.0
        self.last_completion = 0.0
        self.latencies: list[list[float]] = [[] for _ in range(self.n)]
        self.arrivals: list[list[float]] = [[] for _ in range(self.n)]
        self.misses = [0] * self.n
        self.tpu_requests = [0] * self.n
        self._plan: Plan | None = None
        self._cpu_pools: list[list[float]] = [[0.0] for _ in range(self.n)]
        self.set_plan(plan, now=0.0)

    # -- plan management ----------------------------------------------------
    def set_plan(self, plan: Plan, now: float) -> None:
        """Switch to a new (P, K) configuration at time ``now``.

        CPU pools are resized preserving the most-loaded workers' busy
        horizons (a worker mid-request finishes its request).  The paper
        preloads candidate partitions so switching cost is negligible; we
        model it as free.
        """
        if len(plan.partition) != self.n:
            raise ValueError("plan size mismatch")
        old = self._plan
        self._plan = plan
        self._derive(plan)
        new_pools: list[list[float]] = []
        for i, k in enumerate(plan.cores):
            size = max(k, 1)
            prev = self._cpu_pools[i] if old is not None else [now]
            busy = sorted(prev, reverse=True)[:size]
            while len(busy) < size:
                busy.append(now)
            heapq.heapify(busy)
            new_pools.append(busy)
        self._cpu_pools = new_pools

    def _derive(self, plan: Plan) -> None:
        pf, pl = self.profiles, self.platform
        p = plan.partition
        self._prefix_bytes = [f.prefix_weight_bytes(q) for f, q in zip(pf, p)]
        self._s_tpu = [prefix_service_time(f, q, pl) for f, q in zip(pf, p)]
        self._t_load = [load_time(f, q, pl) for f, q in zip(pf, p)]
        self._s_cpu = [
            f.suffix_cpu_time(q, 1) if q < f.num_partition_points else 0.0
            for f, q in zip(pf, p)
        ]
        self._in_xfer = [f.input_bytes / pl.swap_bw for f in pf]
        self._out_xfer = [f.boundary_bytes(q) / pl.swap_bw for f, q in zip(pf, p)]

    @property
    def plan(self) -> Plan:
        assert self._plan is not None
        return self._plan

    # -- event processing ---------------------------------------------------
    def step(self, req: Request, *, record: bool = True) -> float:
        """Process one request; returns its end-to-end latency (s)."""
        i = req.model_idx
        p = self.plan.partition[i]
        P_i = self.profiles[i].num_partition_points
        t = req.arrival
        if p > 0:
            t += self._in_xfer[i]
            start = max(t, self.tpu_free)
            miss = self.cache.access(i, self._prefix_bytes[i], start)
            service = self._s_tpu[i] * req.service_scale + (
                self._t_load[i] if miss else 0.0
            )
            self.tpu_free = start + service
            self.tpu_busy += service
            t = self.tpu_free
            if record:
                self.tpu_requests[i] += 1
                if miss:
                    self.misses[i] += 1
            if p < P_i:
                t += self._out_xfer[i]
        if p < P_i:
            pool = self._cpu_pools[i]
            free = heapq.heappop(pool)
            start = max(t, free)
            end = start + self._s_cpu[i] * req.service_scale
            heapq.heappush(pool, end)
            t = end
        self.last_completion = max(self.last_completion, t)
        lat = t - req.arrival
        if record:
            self.latencies[i].append(lat)
            self.arrivals[i].append(req.arrival)
        return lat

    # -- shared driver surface (see repro.serving.des) -----------------------
    def offer(self, req: Request, *, record: bool = True) -> None:
        """Driver-contract alias of ``step``: requests must be offered in
        arrival order (the stepper resolves each fully on arrival)."""
        self.step(req, record=record)

    def advance_to(self, t: float) -> None:
        """No-op: the stepper has no pending events between requests."""

    def drain(self) -> float:
        """Nothing is ever in flight between steps; reports the horizon."""
        return self.last_completion

    def result(self, duration: float) -> SimResult:
        return SimResult(
            latencies=self.latencies,
            arrivals=self.arrivals,
            tpu_busy=self.tpu_busy,
            duration=duration,
            misses=self.misses,
            tpu_requests=self.tpu_requests,
        )


def make_backend(
    backend: str,
    profiles: Sequence[ModelProfile],
    plan: Plan,
    platform: Platform,
):
    """Instantiate a serving-simulation backend by name.

    ``"stepper"`` is the sequential ``RuntimeSimulator``; ``"des"`` the
    event-driven ``DiscreteEventSimulator`` (the validation ground truth).
    """
    if backend == "stepper":
        return RuntimeSimulator(profiles, plan, platform)
    if backend == "des":
        # Local import: des.py imports the shared result/workload modules
        # only, so the dependency stays one-way at module-load time.
        from repro.serving.des import DiscreteEventSimulator

        return DiscreteEventSimulator(profiles, plan, platform)
    raise ValueError(f"unknown backend {backend!r} (want 'stepper' or 'des')")


def simulate(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    requests: Sequence[Request],
    *,
    warmup_frac: float = 0.05,
    backend: str = "stepper",
) -> SimResult:
    """Run a static-plan simulation over a request trace.

    ``warmup_frac``: leading fraction of the trace excluded from statistics
    (cold-start cache fills; the paper measures steady state).
    ``backend``: ``"stepper"`` (default) or ``"des"`` -- same contract,
    independent mechanics.
    """
    sim = make_backend(backend, [t.profile for t in tenants], plan, platform)
    horizon = max((r.arrival for r in requests), default=0.0)
    warmup_t = horizon * warmup_frac
    for req in sorted(requests, key=lambda r: r.arrival):
        sim.offer(req, record=req.arrival >= warmup_t)
    # Duration runs to the last completion, not the last arrival: under
    # backlog the servers keep draining after arrivals stop, and clipping
    # the horizon at the last arrival let tpu_utilization exceed 1.0.
    return sim.result(max(horizon, sim.drain()))
