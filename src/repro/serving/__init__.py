from repro.serving.cache import SramCache
from repro.serving.controller import (
    AdaptiveRunResult,
    SlidingRateEstimator,
    run_adaptive,
)
from repro.serving.des import DiscreteEventSimulator
from repro.serving.engine import CompletedRequest, ExecutableModel, ServingEngine
from repro.serving.result import SimResult
from repro.serving.scheduling import (
    FCFS,
    Discipline,
    DisciplineSpec,
    FcfsDiscipline,
    PriorityDiscipline,
    SwapBatchDiscipline,
    WeightedFairDiscipline,
    make_discipline,
)
from repro.serving.simulator import RuntimeSimulator, make_backend, simulate
from repro.serving.workload import (
    ChurnTrace,
    RatePhase,
    Request,
    Trace,
    as_trace,
    deterministic_trace,
    diurnal_trace,
    dynamic_trace,
    mmpp_trace,
    poisson_trace,
    tenant_churn_trace,
    trace_from_json,
    trace_to_json,
    with_service_jitter,
)

__all__ = [
    "AdaptiveRunResult",
    "ChurnTrace",
    "CompletedRequest",
    "Discipline",
    "DisciplineSpec",
    "DiscreteEventSimulator",
    "FCFS",
    "FcfsDiscipline",
    "PriorityDiscipline",
    "SwapBatchDiscipline",
    "WeightedFairDiscipline",
    "ExecutableModel",
    "RatePhase",
    "Request",
    "RuntimeSimulator",
    "ServingEngine",
    "SimResult",
    "SlidingRateEstimator",
    "SramCache",
    "Trace",
    "as_trace",
    "deterministic_trace",
    "diurnal_trace",
    "dynamic_trace",
    "make_backend",
    "make_discipline",
    "mmpp_trace",
    "poisson_trace",
    "run_adaptive",
    "simulate",
    "tenant_churn_trace",
    "trace_from_json",
    "trace_to_json",
    "with_service_jitter",
]
