from repro.serving.cache import SramCache
from repro.serving.controller import (
    AdaptiveRunResult,
    SlidingRateEstimator,
    run_adaptive,
)
from repro.serving.engine import CompletedRequest, ExecutableModel, ServingEngine
from repro.serving.simulator import RuntimeSimulator, SimResult, simulate
from repro.serving.workload import RatePhase, Request, dynamic_trace, poisson_trace

__all__ = [
    "AdaptiveRunResult",
    "CompletedRequest",
    "ExecutableModel",
    "RatePhase",
    "Request",
    "RuntimeSimulator",
    "ServingEngine",
    "SimResult",
    "SlidingRateEstimator",
    "SramCache",
    "dynamic_trace",
    "poisson_trace",
    "run_adaptive",
    "simulate",
]
