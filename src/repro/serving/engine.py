"""Real-execution multi-tenant engine (Section IV plumbing).

Executes actual JAX computations: a single global TPU-worker thread drains
an FCFS queue of prefix executions, forwarding intermediate activations to
per-model CPU thread pools that run the suffixes.  On this CPU-only
container the "TPU" worker is simply the jitted XLA path; the value of this
module is proving the runtime plumbing (queues, pools, plan switches,
backpressure) end-to-end with real tensors -- latency *validation* is done
against the discrete-event simulator, which models the paper's testbed
timing.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Sequence

import jax

from repro.core.planner import Plan

# A partitioned executable model: segment i maps activations -> activations.
SegmentFn = Callable[[Any], Any]


@dataclasses.dataclass
class ExecutableModel:
    """A chain of jitted segment functions + an input synthesizer."""

    name: str
    segments: tuple[SegmentFn, ...]
    make_input: Callable[[int], Any]   # seed -> model input

    @property
    def num_partition_points(self) -> int:
        return len(self.segments)


@dataclasses.dataclass
class CompletedRequest:
    model_idx: int
    submit_time: float
    done_time: float
    output: Any
    # The exception that aborted this request's execution, or None on
    # success (``output`` is None for errored records).  Errors surface as
    # completed records instead of vanishing inside worker threads, so
    # ``drain()`` always terminates and the caller sees every failure.
    error: BaseException | None = None

    @property
    def latency(self) -> float:
        return self.done_time - self.submit_time

    @property
    def ok(self) -> bool:
        return self.error is None


class _TpuWorker(threading.Thread):
    """Single global FCFS worker executing TPU prefixes."""

    def __init__(self, engine: "ServingEngine"):
        super().__init__(daemon=True, name="tpu-worker")
        self.engine = engine
        self.inbox: "queue.Queue" = queue.Queue()

    def run(self) -> None:
        while True:
            item = self.inbox.get()
            if item is None:
                return
            self.engine._run_prefix(*item)


class ServingEngine:
    """Multi-tenant collaborative-inference engine over executable models."""

    def __init__(
        self,
        models: Sequence[ExecutableModel],
        plan: Plan,
        k_max: int,
    ):
        self.models = list(models)
        self.k_max = k_max
        self._plan_lock = threading.Lock()
        self._tpu = _TpuWorker(self)
        self._pools: list[ThreadPoolExecutor | None] = [None] * len(models)
        self._completed: "queue.Queue[CompletedRequest]" = queue.Queue()
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._drained = threading.Event()
        self._drained.set()
        self.set_plan(plan)
        self._tpu.start()

    # -- configuration -------------------------------------------------------
    def set_plan(self, plan: Plan) -> None:
        if len(plan.partition) != len(self.models):
            raise ValueError("plan size mismatch")
        if sum(plan.cores) > self.k_max:
            raise ValueError("plan exceeds K_max")
        with self._plan_lock:
            self.plan = plan
            for i, k in enumerate(plan.cores):
                old = self._pools[i]
                if old is not None:
                    old.shutdown(wait=False)
                self._pools[i] = (
                    ThreadPoolExecutor(max_workers=k, thread_name_prefix=f"cpu-{i}")
                    if k > 0
                    else None
                )

    # -- request path ----------------------------------------------------------
    def submit(self, model_idx: int, x: Any) -> None:
        with self._inflight_lock:
            self._inflight += 1
            self._drained.clear()
        submit_t = time.perf_counter()
        with self._plan_lock:
            p = self.plan.partition[model_idx]
        if p > 0:
            self._tpu.inbox.put((model_idx, x, p, submit_t))
        else:
            try:
                self._dispatch_suffix(model_idx, x, 0, submit_t)
            except BaseException as exc:
                # The synchronous dispatch path (zero-core misconfiguration,
                # pool rejection) must not leak the in-flight slot it just
                # claimed: record the failure so drain() terminates, then
                # surface it to the submitter.
                self._finish(model_idx, None, submit_t, error=exc)
                raise

    def _run_prefix(self, model_idx: int, x: Any, p: int, submit_t: float) -> None:
        # Any failure here (a segment raising, a missing suffix pool) would
        # otherwise die inside the TPU worker thread with the in-flight count
        # still held, hanging every future drain().
        try:
            m = self.models[model_idx]
            for seg in m.segments[:p]:
                x = seg(x)
            x = jax.block_until_ready(x)
            if p < m.num_partition_points:
                self._dispatch_suffix(model_idx, x, p, submit_t)
            else:
                self._finish(model_idx, x, submit_t)
        except BaseException as exc:
            self._finish(model_idx, None, submit_t, error=exc)

    def _dispatch_suffix(self, model_idx: int, x: Any, p: int, submit_t: float) -> None:
        pool = self._pools[model_idx]
        if pool is None:
            raise RuntimeError(
                f"model {model_idx} has a CPU suffix but zero cores allocated"
            )

        def work() -> None:
            # Same containment as _run_prefix: a suffix failure becomes an
            # errored completion record, never a silently swallowed pool
            # exception plus a leaked in-flight slot.
            try:
                y = x
                m = self.models[model_idx]
                for seg in m.segments[p:]:
                    y = seg(y)
                y = jax.block_until_ready(y)
            except BaseException as exc:
                self._finish(model_idx, None, submit_t, error=exc)
            else:
                self._finish(model_idx, y, submit_t)

        pool.submit(work)

    def _finish(
        self,
        model_idx: int,
        out: Any,
        submit_t: float,
        error: BaseException | None = None,
    ) -> None:
        self._completed.put(
            CompletedRequest(
                model_idx=model_idx,
                submit_time=submit_t,
                done_time=time.perf_counter(),
                output=out,
                error=error,
            )
        )
        with self._inflight_lock:
            self._inflight -= 1
            if self._inflight == 0:
                self._drained.set()

    # -- collection ------------------------------------------------------------
    def drain(self, timeout: float = 60.0) -> list[CompletedRequest]:
        if not self._drained.wait(timeout):
            raise TimeoutError("engine did not drain in time")
        out = []
        while True:
            try:
                out.append(self._completed.get_nowait())
            except queue.Empty:
                return out

    def shutdown(self) -> None:
        self._tpu.inbox.put(None)
        for pool in self._pools:
            if pool is not None:
                pool.shutdown(wait=True)
