"""Shared observation record for every serving backend.

``SimResult`` is the one metrics container produced by the sequential
``RuntimeSimulator`` stepper, the event-driven ``DiscreteEventSimulator``,
and ``run_adaptive`` -- a model-vs-simulation comparison never depends on
which backend observed the trace.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class SimResult:
    # Per model, per completed request.  Scalar backends fill plain float
    # lists; the vectorized stepper fast path hands over NumPy arrays --
    # every metric below handles either (len/indexing/np reductions only).
    latencies: list[Sequence[float]]
    arrivals: list[Sequence[float]]            # arrival stamps (for timelines)
    tpu_busy: float
    duration: float
    misses: list[int]
    tpu_requests: list[int]
    # Fault bookkeeping (serving.faults.FaultStats) when the backend ran
    # under a FaultSchedule; None on every fault-free run -- the default
    # keeps the pre-fault construction paths byte-identical.
    fault: object | None = None

    def mean_latency(self, model_idx: int) -> float:
        """Mean observed latency; ``nan`` when the model completed nothing
        (an unknown mean, not a zero-latency one)."""
        ls = self.latencies[model_idx]
        return float(np.sum(ls)) / len(ls) if len(ls) else math.nan

    def overall_mean(self) -> float:
        """Mean over all completions; ``nan`` when nothing completed at all
        (same unknown-not-zero convention as ``mean_latency``)."""
        count = sum(len(ls) for ls in self.latencies)
        if not count:
            return math.nan
        return sum(float(np.sum(ls)) for ls in self.latencies) / count

    def request_weighted_mean(self, rates: Sequence[float] | None = None) -> float:
        """Per-model rate-weighted mean latency, Eq. 5's
        ``sum_i lambda_i T_i / sum_i lambda_i``.

        With ``rates`` given, the weights are the *offered* per-model rates
        (what the objective optimizes); without them, the observed request
        counts stand in, which recovers the plain overall mean.  Models with
        no recorded samples (e.g. all arrivals inside the warmup window)
        have an unknown mean and are excluded from both numerator and
        denominator rather than counted as zero latency.
        """
        if rates is None:
            weights: Sequence[float] = [len(ls) for ls in self.latencies]
        else:
            if len(rates) != len(self.latencies):
                raise ValueError("rates length must match model count")
            weights = rates
        pairs = [
            (w, self.mean_latency(i))
            for i, (w, ls) in enumerate(zip(weights, self.latencies))
            if len(ls)
        ]
        if not pairs:
            return math.nan  # nothing completed: the mean is unknown
        tot = sum(w for w, _ in pairs)
        if tot <= 0:
            # All-zero weights leave the weighted mean undefined, not zero
            # (the class-wide unknown-not-zero nan convention: a 0.0 here
            # silently wins comparisons and poisons downstream averages).
            return math.nan
        return sum(w * m for w, m in pairs) / tot

    def p99(self, model_idx: int) -> float:
        """Nearest-rank 99th percentile: the smallest latency with at least
        99% of samples at or below it (``ceil(0.99 n)``-th order statistic).
        ``nan`` when the model completed no requests.

        Selection (``np.partition``), not a sort: million-request traces
        from the vectorized fast path make the full Python sort the most
        expensive line of a sweep.  Same order statistic, no float math.

        The rank is computed in exact integer arithmetic:
        ``ceil(99 n / 100) == (99 n + 99) // 100``, which is the
        nearest-rank definition with no float product that could round
        across an integer boundary at large ``n`` (``ceil(0.99 * n)``
        agrees everywhere we could scan, but only by luck of the
        double-precision grid -- the integer form is correct by
        construction).  Boundary pins: n=1 and n=2 select the max
        (rank 1 of n), n=99 and n=100 select the 98th/99th order
        statistic (index 97/98), n=101 index 99.
        """
        ls = self.latencies[model_idx]
        n = len(ls)
        if not n:
            return math.nan
        rank = (99 * n + 99) // 100 - 1
        return float(np.partition(np.asarray(ls), rank)[rank])

    def per_model_p99(self) -> list[float]:
        """Per-model nearest-rank p99 drill-down: ``p99(i)`` for every
        model, ``nan`` for models with no completions (the class-wide
        unknown-not-zero convention).  On ``FleetSimResult`` the columns
        are the pooled fleet samples, so this is the merged per-model p99
        an external client observes."""
        return [self.p99(i) for i in range(len(self.latencies))]

    def deadline_misses(self, deadlines: Sequence[float | None]) -> list[int]:
        """Per-model count of completed requests that missed their
        deadline (observed latency strictly above the budget).

        Resolved post-hoc from the recorded latency columns -- identical
        across every backend by construction, and deadline tracking costs
        nothing on runs that never ask.  Models with no deadline (``None``
        or ``inf``) never miss.  Requests dropped by a fault policy are not
        completions and are counted separately (``requests_lost``), so a
        renege analysis reads both.
        """
        if len(deadlines) != len(self.latencies):
            raise ValueError("deadlines length must match model count")
        out = []
        for d, ls in zip(deadlines, self.latencies):
            if d is None or not len(ls) or math.isinf(d):
                out.append(0)
            else:
                out.append(int(np.sum(np.asarray(ls) > float(d))))
        return out

    def per_model_deadline_miss_rate(
        self, deadlines: Sequence[float | None]
    ) -> list[float]:
        """Per-model observed miss fraction; ``nan`` for a model with no
        completions (unknown, not zero) -- deadline-free models with
        completions read 0.0 (they observably never miss)."""
        misses = self.deadline_misses(deadlines)
        return [
            m / len(ls) if len(ls) else math.nan
            for m, ls in zip(misses, self.latencies)
        ]

    def deadline_miss_rate(self, deadlines: Sequence[float | None]) -> float:
        """Pooled miss fraction over deadline-bearing models' completions.

        Deadline-free models are excluded from both numerator and
        denominator (they cannot miss, and counting their completions would
        dilute the rate the SLO contracts on).  ``nan`` when no
        deadline-bearing model completed anything.
        """
        if len(deadlines) != len(self.latencies):
            raise ValueError("deadlines length must match model count")
        misses = self.deadline_misses(deadlines)
        tot_miss, tot_done = 0, 0
        for d, m, ls in zip(deadlines, misses, self.latencies):
            if d is None or math.isinf(d):
                continue
            tot_miss += m
            tot_done += len(ls)
        return tot_miss / tot_done if tot_done else math.nan

    def observed_miss_rate(self, model_idx: int) -> float:
        """Fraction of the model's TPU services that paid a swap-in;
        ``nan`` when the model never visited the TPU (full-CPU route or no
        recorded requests) -- an unknown rate, not a perfect hit rate, per
        the class's nan convention."""
        n = self.tpu_requests[model_idx]
        return self.misses[model_idx] / n if n else math.nan

    @property
    def tpu_utilization(self) -> float:
        return self.tpu_busy / self.duration if self.duration > 0 else 0.0

    # -- recovery metrics (defined only on faulted runs) ---------------------
    @property
    def requests_lost(self) -> int:
        """Requests dropped by the dropout lost-policy (0 without faults)."""
        return self.fault.total_lost if self.fault is not None else 0

    @property
    def requests_requeued(self) -> int:
        """Dropout deferral events under the requeue policy (0 without
        faults; a request crossing several gates counts each deferral)."""
        return self.fault.total_requeued if self.fault is not None else 0

    def recovery_times(self) -> list[float]:
        """Time-to-recover per dropout window: how long after the outage
        ends until the deferred backlog drains.

        Resolved post-hoc from the recorded (arrival, latency) columns: for
        a window ``[s, e)`` the backlog is every completion whose request
        arrived at or before ``e``, and recovery is the instant the last of
        them completes -- ``max(arrival + latency) - e``, clamped at 0 (an
        outage nobody was waiting behind recovers instantly).  Warmup-gated
        recording applies, like every other metric here.
        """
        if self.fault is None or not self.fault.down_windows:
            return []
        out = []
        for _, e in self.fault.down_windows:
            worst = -math.inf
            for arr_col, lat_col in zip(self.arrivals, self.latencies):
                if not len(arr_col):
                    continue
                a = np.asarray(arr_col, dtype=np.float64)
                l = np.asarray(lat_col, dtype=np.float64)
                sel = a <= e
                if sel.any():
                    worst = max(worst, float((a[sel] + l[sel]).max()))
            out.append(max(0.0, worst - e) if math.isfinite(worst) else 0.0)
        return out

    def degraded_window_mean(self) -> float:
        """Mean latency over requests that *arrived* inside any fault
        window (down, throttled, or swap-degraded) -- the cost clients paid
        while the system was impaired.  ``nan`` when no recorded request
        arrived in a window (unknown, not zero)."""
        if self.fault is None or not self.fault.degraded_windows:
            return math.nan
        tot, cnt = 0.0, 0
        for arr_col, lat_col in zip(self.arrivals, self.latencies):
            if not len(arr_col):
                continue
            a = np.asarray(arr_col, dtype=np.float64)
            l = np.asarray(lat_col, dtype=np.float64)
            sel = np.zeros(a.size, dtype=bool)
            for s, e in self.fault.degraded_windows:
                sel |= (a >= s) & (a < e)
            if sel.any():
                tot += float(l[sel].sum())
                cnt += int(sel.sum())
        return tot / cnt if cnt else math.nan


@dataclasses.dataclass
class FleetSimResult(SimResult):
    """Fleet-wide metrics merged from N per-device ``SimResult``s.

    The merged view pools every device's samples per model, so
    ``mean_latency`` is the request-weighted mean across the fleet and
    ``p99`` the nearest-rank percentile over the pooled samples (the
    *merged* p99, not a percentile of per-device percentiles -- the pooled
    order statistic is what an external client of the whole fleet
    observes).  Per-model sample order is device-major, not time-sorted;
    every ``SimResult`` metric is order-free.  ``per_device`` keeps the
    constituent results for drill-down.
    """

    per_device: list[SimResult] = dataclasses.field(default_factory=list)

    @property
    def n_devices(self) -> int:
        return len(self.per_device)

    @property
    def tpu_utilization(self) -> float:
        """Mean per-TPU utilization: aggregate busy time normalized by
        N devices' wall-clock (a 4-device fleet at 0.25 each reads 0.25,
        not 1.0)."""
        denom = self.duration * max(1, self.n_devices)
        return self.tpu_busy / denom if denom > 0 else 0.0


def _merge_columns(cols: list[Sequence[float]]) -> Sequence[float]:
    """Pool one model's per-device sample columns.

    All-list inputs concatenate as lists (the scalar backends' native form,
    and exactly the device's own objects when only one column is nonempty);
    anything else pools through ``np.concatenate``.
    """
    filled = [c for c in cols if len(c)]
    if not filled:
        return cols[0] if cols else []
    if len(filled) == 1:
        return filled[0]
    if all(isinstance(c, list) for c in filled):
        out: list[float] = []
        for c in filled:
            out.extend(c)
        return out
    return np.concatenate([np.asarray(c, dtype=np.float64) for c in filled])


def merge_fleet_results(per_device: Sequence[SimResult]) -> FleetSimResult:
    """Merge per-device results into the fleet-wide ``FleetSimResult``.

    Per-model latencies/arrivals pool across devices; ``misses`` and
    ``tpu_requests`` add elementwise; ``tpu_busy`` adds; ``duration`` is the
    fleet wall-clock (max over devices).  The single-device merge reuses
    the device's own column objects -- the bitwise N=1 contract.
    """
    if not per_device:
        raise ValueError("merge_fleet_results needs at least one result")
    n_models = len(per_device[0].latencies)
    for r in per_device:
        if len(r.latencies) != n_models:
            raise ValueError("per-device results cover different model counts")
    if len(per_device) == 1:
        r = per_device[0]
        return FleetSimResult(
            latencies=r.latencies,
            arrivals=r.arrivals,
            tpu_busy=r.tpu_busy,
            duration=r.duration,
            misses=r.misses,
            tpu_requests=r.tpu_requests,
            fault=r.fault,
            per_device=list(per_device),
        )
    from repro.serving.faults import merge_fault_stats

    return FleetSimResult(
        latencies=[
            _merge_columns([r.latencies[i] for r in per_device])
            for i in range(n_models)
        ],
        arrivals=[
            _merge_columns([r.arrivals[i] for r in per_device])
            for i in range(n_models)
        ],
        tpu_busy=sum(r.tpu_busy for r in per_device),
        duration=max(r.duration for r in per_device),
        misses=[
            sum(r.misses[i] for r in per_device) for i in range(n_models)
        ],
        tpu_requests=[
            sum(r.tpu_requests[i] for r in per_device) for i in range(n_models)
        ],
        fault=merge_fault_stats([r.fault for r in per_device], n_models),
        per_device=list(per_device),
    )
