"""Fleet serving: N per-device simulators driven off one split trace.

``simulate_fleet`` is the fleet analogue of ``simulate``: the request trace
is split by tenant placement (``workload.route_trace``), each device runs
its own independent simulator (stepper, DES, or jax -- same pluggable
backends) under its full-width device plan, and the per-device results
merge into one ``FleetSimResult`` (request-pooled means, merged
nearest-rank p99).

``run_adaptive_fleet`` is the fleet analogue of ``run_adaptive``: one
global sliding-window rate estimator, periodic per-device warm re-plans
(placement held fixed), and a *sustained-imbalance* trigger that re-runs
the full placement search only when the offered per-device load has stayed
skewed for several consecutive re-plan windows -- placement churn is
expensive for the serving tier (model redeploys), so a single bursty
window must not move tenants.

Degenerate case contract: a 1-device unit-speed fleet built
``DeviceSpec.from_platform(platform)`` makes ``simulate_fleet`` replay the
exact single-device ``simulate`` path -- same trace object, same simulator
construction, bitwise-identical ``SimResult`` fields
(``tests/test_fleet.py`` pins this for both backends).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core.fleet import (
    DeviceSpec,
    FleetPlan,
    FleetTablesCache,
    device_objectives,
    evacuate_device,
    fleet_hill_climb,
)
from repro.core.objective import Objective
from repro.core.planner import (
    DisciplineSpec,
    ModelProfile,
    Plan,
    TenantSpec,
    prefix_service_time,
)
from repro.serving.faults import FaultSchedule, LatencyWindowTracker
from repro.serving.result import FleetSimResult, SimResult, merge_fleet_results
from repro.serving.simulator import make_backend, sorted_trace_and_horizon
from repro.serving.workload import Request, Trace, as_trace, route_trace
from repro.serving.controller import SlidingRateEstimator, _should_cold_fallback

if TYPE_CHECKING:
    from repro.core.plan_cache import FleetPlanCache
    from repro.serving.forecast import RateForecaster


def _device_sims(
    profiles: Sequence[ModelProfile],
    fleet_plan: FleetPlan,
    fleet: Sequence[DeviceSpec],
    backend: str,
    faults: "FaultSchedule | None" = None,
):
    """One simulator per device: full-width scaled profiles, device plan,
    and (when a ``FaultSchedule`` is given) the device's fault view."""
    return [
        make_backend(
            backend,
            dev.scaled_profiles(profiles),
            fleet_plan.device_plans[d],
            dev.platform,
            faults=faults.view(d) if faults is not None else None,
        )
        for d, dev in enumerate(fleet)
    ]


def _drive(sim, sub, backend: str, warmup_t: float, vectorize: bool) -> None:
    """Feed one device's sub-trace through its simulator (the same driver
    dispatch ``simulate`` uses)."""
    if vectorize and isinstance(sub, Trace):
        if backend in ("stepper", "jax"):
            sim.run_trace(sub, record_from=warmup_t)
        else:
            sim.offer_trace(sub, record_from=warmup_t)
    else:
        for req in sub:
            sim.offer(req, record=req.arrival >= warmup_t)


def simulate_fleet(
    tenants: Sequence[TenantSpec],
    fleet_plan: FleetPlan,
    fleet: Sequence[DeviceSpec],
    requests: "Trace | Sequence[Request]",
    *,
    warmup_frac: float = 0.05,
    backend: str = "stepper",
    vectorize: bool = True,
    route_seed: int = 0,
    faults: "FaultSchedule | None" = None,
    reroute_on_dropout: bool = False,
) -> FleetSimResult:
    """Run a static fleet plan over a request trace.

    The trace is split by placement/routing into per-device sub-traces
    (global model indices preserved), each device simulates independently
    -- devices share nothing at runtime, which is what makes the fleet
    embarrassingly parallel -- and the results merge.  Warmup and duration
    are *global*: the warmup cutoff comes from the fleet-wide horizon and
    every device's duration extends to at least that horizon, so per-device
    metrics weight into the merged view on one clock.

    ``faults`` injects a ``serving.faults.FaultSchedule`` into every device
    simulator (each sees its own projection); ``reroute_on_dropout``
    additionally lets the router redraw requests away from devices that are
    down at their arrival instant (``route_trace``'s health-aware mode).
    Both default off, leaving the path bitwise the pre-fault fleet.
    """
    if len(fleet) != fleet_plan.n_devices:
        raise ValueError(
            f"fleet has {len(fleet)} devices, plan {fleet_plan.n_devices}"
        )
    if faults is not None:
        faults.validate(len(fleet))
    profiles = [t.profile for t in tenants]
    reqs, horizon = sorted_trace_and_horizon(requests)
    warmup_t = horizon * warmup_frac
    subs = route_trace(
        reqs,
        fleet_plan.placement,
        fleet_plan.routing,
        len(fleet),
        seed=route_seed,
        faults=faults if reroute_on_dropout else None,
    )
    results: list[SimResult] = []
    sims = _device_sims(profiles, fleet_plan, fleet, backend, faults=faults)
    for sim, sub in zip(sims, subs):
        _drive(sim, sub, backend, warmup_t, vectorize)
        results.append(sim.result(max(horizon, sim.drain())))
    return merge_fleet_results(results)


def offered_device_loads(
    tenants: Sequence[TenantSpec],
    fleet_plan: FleetPlan,
    fleet: Sequence[DeviceSpec],
    rates: Sequence[float],
) -> list[float]:
    """Offered TPU utilization per device under the current plan.

    ``rho_d = sum_i w_id * lambda_i * s_TPU(p_id)`` with ``s_TPU`` from the
    device's scaled profile on its platform -- the same Eq. 1 ingredient
    the analytic model uses, so the imbalance trigger and the planner agree
    on what "load" means.
    """
    loads = [0.0] * len(fleet)
    for i, t in enumerate(tenants):
        for dev_idx, w in zip(fleet_plan.placement[i], fleet_plan.routing[i]):
            dev = fleet[dev_idx]
            prof = t.profile.scaled(dev.tpu_speed, dev.cpu_speed)
            p = fleet_plan.device_plans[dev_idx].partition[i]
            loads[dev_idx] += (
                w * rates[i] * prefix_service_time(prof, p, dev.platform)
            )
    return loads


@dataclasses.dataclass
class FleetAdaptiveResult:
    """``run_adaptive_fleet`` outcome: merged metrics + the plan history."""

    sim: FleetSimResult
    replan_times: list[float]
    fleet_plans: list[FleetPlan]
    plan_compute_seconds: list[float]
    plan_objectives: list[float] = dataclasses.field(default_factory=list)
    # Boundaries where sustained imbalance triggered a full placement
    # re-plan (a subset of ``replan_times``).
    placement_replan_times: list[float] = dataclasses.field(default_factory=list)
    # Boundaries where the (opt-in) cold-fallback guard re-climbed the
    # device plans cold with placement held (a subset of ``replan_times``).
    cold_fallback_times: list[float] = dataclasses.field(default_factory=list)
    # Fault-aware controller history (all empty unless fault_aware=True):
    # boundaries where a device was detected down and evacuated, where a
    # down device was detected recovered and re-admitted, and where
    # degradation (throttle) re-planned against scaled DeviceSpecs.
    failover_times: list[float] = dataclasses.field(default_factory=list)
    restore_times: list[float] = dataclasses.field(default_factory=list)
    degraded_replan_times: list[float] = dataclasses.field(default_factory=list)


def run_adaptive_fleet(
    profiles: Sequence[ModelProfile],
    requests: "Trace | Sequence[Request]",
    fleet: Sequence[DeviceSpec],
    *,
    k_max: int | None = None,
    replan_period: float = 30.0,
    window: float = 30.0,
    rate_decay: float | None = None,
    initial_rates: Sequence[float] | None = None,
    min_rate: float = 0.05,
    warmup_frac: float = 0.05,
    backend: str = "stepper",
    vectorize: bool = True,
    imbalance_threshold: float = 0.5,
    imbalance_patience: int = 3,
    cold_fallback_margin: float | None = None,
    cold_fallback_window: int = 5,
    discipline_space: Sequence[DisciplineSpec] | None = None,
    forecaster: "RateForecaster | None" = None,
    plan_cache: "FleetPlanCache | None" = None,
    route_seed: int = 0,
    faults: "FaultSchedule | None" = None,
    fault_aware: bool = False,
    dropout_min_requests: int = 4,
    degrade_threshold: float = 2.0,
    degrade_restore: float = 1.3,
    min_speed_factor: float = 0.05,
    health_probe: bool = False,
    objective: Objective | None = None,
    rate_margin: float | None = None,
    deadlines: Sequence[float | None] | None = None,
) -> FleetAdaptiveResult:
    """Adaptive fleet serving: local re-plans, imbalance-gated placement.

    Every ``replan_period`` the global rate estimates feed N *warm*
    per-device climbs (placement and routing fixed -- ``fleet_hill_climb``
    with ``init=incumbent``), exactly as the single-device controller
    warm-starts ``hill_climb``.  The full placement search re-runs only on
    *sustained* imbalance: when the spread of offered per-device TPU
    utilization (``max - min`` of ``offered_device_loads``) exceeds
    ``imbalance_threshold`` for ``imbalance_patience`` consecutive re-plan
    boundaries, a cold ``fleet_hill_climb`` (placement included) runs and
    the better of warm/cold commits.  One bursty window never migrates
    tenants; a persistent skew does.

    Requests arriving between boundaries are routed by the *current*
    placement; each device's queued work drains under the plan its requests
    were bound at (both backends bind routes at arrival).  Per-span routing
    draws (split-placement tenants only) are seeded by span index on top of
    ``route_seed``, so a replayed trace routes identically.

    ``cold_fallback_margin`` (opt-in, default ``None`` = off) adds the
    single-device warm-tail guard alongside the imbalance gate: when a warm
    re-plan's normalized objective regresses past the margin against the
    recent trend (``_should_cold_fallback``), the device plans re-climb
    *cold with placement held* (``fleet_hill_climb(warm_start=False)``) and
    the better result commits.  The trend history is cleared whenever a
    placement re-plan commits -- post-migration objectives must never be
    judged against pre-migration history (the two placements have different
    normalized-objective baselines, so stale history would mis-fire or
    mask the guard).

    ``rate_decay``, ``forecaster`` and ``plan_cache`` mirror
    ``run_adaptive`` (the cache must be a
    ``repro.core.plan_cache.FleetPlanCache``); all default off, keeping
    this path bitwise the reactive fleet controller.  A memoized plan
    whose placement differs from the incumbent's counts as a placement
    re-plan (it migrates tenants), and the cache is bypassed at
    boundaries where the imbalance gate demands a genuine placement
    search.

    **Fault handling.** ``faults`` injects a ``serving.faults`` schedule
    into every device simulator (dropout / throttle / swap degradation).
    With ``fault_aware=False`` the controller is fault-*oblivious*: it
    keeps routing to a dead device and planning against nominal speeds --
    the baseline ``benchmarks/faults.py`` measures against.  With
    ``fault_aware=True`` the controller reacts to *observed* signals only
    (it never reads the schedule, except for the opt-in ``health_probe``
    heartbeat below):

    * *dropout*: a device offered >= ``dropout_min_requests`` in the last
      window whose ``last_completion`` did not advance is declared down; an
      out-of-band failover re-plan (``core.fleet.evacuate_device``) moves
      every tenant off it, recorded in ``failover_times``.  Recovery is
      declared when the device completes work again (its requeued backlog
      draining), or -- with ``health_probe=True`` -- when a heartbeat
      (the schedule's own ``is_down``) reports it up; a placement re-plan
      re-admits it, recorded in ``restore_times``.  Note the observational
      blind spot: under ``dropout_policy="lost"`` an evacuated device holds
      no requeued backlog and receives no traffic, so nothing ever
      completes on it and recovery is undetectable from observed signals
      alone -- use ``health_probe=True`` when lost-policy recovery matters.
    * *throttle*: a device whose observed windowed mean latency exceeds
      ``degrade_threshold`` x the model's prediction for it
      (``core.fleet.device_objectives`` / routed rate) gets an estimated
      speed factor ``clamp(pred/obs, min_speed_factor, 1)``; re-plans run
      against the *degraded* ``DeviceSpec`` (speeds scaled by the
      estimate) until the observed mean falls back under
      ``degrade_restore`` x prediction, each transition recorded in
      ``degraded_replan_times``.

    All fault parameters default off; ``faults=None, fault_aware=False``
    is bitwise the pre-fault controller.

    ``objective`` / ``rate_margin`` / ``deadlines`` mirror ``run_adaptive``:
    every planner invocation (warm, cold, failover) minimizes the chosen
    metric against optionally margin-inflated rates, with per-tenant
    deadline budgets carried on the planning mixes.  Fault *detection*
    stays on observed-vs-predicted means regardless of the planning
    objective (an SLO value is not a mean and cannot be compared against
    one).  All three default off, bitwise.
    """
    if not fleet:
        raise ValueError("fleet must contain at least one device")
    if faults is not None:
        faults.validate(len(fleet))
    if rate_margin is not None and rate_margin < 0:
        raise ValueError("rate_margin must be non-negative (or None)")
    n = len(profiles)
    if deadlines is not None and len(deadlines) != n:
        raise ValueError("deadlines length must match model count")
    dl: list[float | None] = (
        list(deadlines) if deadlines is not None else [None] * n
    )
    n_dev = len(fleet)
    est = SlidingRateEstimator(n, window=window, decay=rate_decay)
    cache = FleetTablesCache()

    def _plan_tenants(rates: Sequence[float]) -> list[TenantSpec]:
        """The mix every planner invocation sees: optionally
        margin-inflated rates, clamped, with deadline budgets attached."""
        if rate_margin is not None:
            rates = [r * (1.0 + rate_margin) for r in rates]
        return [
            TenantSpec(p, max(r, min_rate), deadline=d)
            for p, r, d in zip(profiles, rates, dl)
        ]

    # Normalized-objective trend for the opt-in warm-tail guard; cleared on
    # every committed placement re-plan (see the docstring).
    norm_history: collections.deque[float] = collections.deque(
        maxlen=max(1, cold_fallback_window)
    )
    cold_fallbacks: list[float] = []

    def plan_for(
        rates: Sequence[float],
        incumbent: FleetPlan | None,
        now: float,
        fleet_now: Sequence[DeviceSpec] | None = None,
    ) -> tuple[FleetPlan, float, float, bool]:
        """(plan, objective, seconds, placement_replanned).

        ``fleet_now`` substitutes degraded ``DeviceSpec``s for the nominal
        fleet (the fault-aware path); ``None`` -- every pre-fault call --
        plans against the nominal fleet unchanged.
        """
        eff_fleet = fleet if fleet_now is None else list(fleet_now)
        tenants = _plan_tenants(rates)
        tot_rate = sum(t.rate for t in tenants)
        gate_firing = (
            incumbent is not None and imbalance_streak >= imbalance_patience
        )

        def commit(
            plan: FleetPlan, obj: float, t0: float, moved: bool
        ) -> tuple[FleetPlan, float, float, bool]:
            # S2 fix: a committed placement re-plan resets the normalized-
            # objective baseline, so the guard's trend history restarts --
            # comparing post-migration objectives against pre-migration
            # history mis-fires the guard.  Nan-means-unknown: non-finite
            # or zero-traffic objectives carry no trend information.
            if moved:
                norm_history.clear()
            if tot_rate > 0 and math.isfinite(obj):
                norm_history.append(obj / tot_rate)
            return plan, obj, time.perf_counter() - t0, moved

        t0 = time.perf_counter()
        if plan_cache is not None and not gate_firing:
            hit = plan_cache.lookup(
                tenants,
                eff_fleet,
                k_max=k_max,
                discipline_space=discipline_space,
                objective=objective,
            )
            if hit is not None:
                plan, obj = hit
                moved = (
                    incumbent is not None
                    and plan.placement != incumbent.placement
                )
                return commit(plan, obj, t0, moved)
        if incumbent is None:
            plan, obj = fleet_hill_climb(
                tenants,
                eff_fleet,
                k_max=k_max,
                tables=cache,
                discipline_space=discipline_space,
                objective=objective,
            )
            if plan_cache is not None:
                plan_cache.store(
                    tenants,
                    eff_fleet,
                    plan,
                    obj,
                    k_max=k_max,
                    discipline_space=discipline_space,
                    objective=objective,
                )
            return commit(plan, obj, t0, False)
        plan, obj = fleet_hill_climb(
            tenants,
            eff_fleet,
            k_max=k_max,
            init=incumbent,
            tables=cache,
            discipline_space=discipline_space,
            objective=objective,
        )
        moved = False
        if gate_firing:
            cold_plan, cold_obj = fleet_hill_climb(
                tenants,
                eff_fleet,
                k_max=k_max,
                tables=cache,
                discipline_space=discipline_space,
                objective=objective,
            )
            if cold_obj < obj:
                plan, obj = cold_plan, cold_obj
                moved = True
        elif (
            cold_fallback_margin is not None
            and tot_rate > 0
            and _should_cold_fallback(
                obj / tot_rate, norm_history, cold_fallback_margin
            )
        ):
            # Warm-tail guard: re-climb the device plans cold, placement
            # held -- the fleet analogue of the single-device fallback.
            cold_plan, cold_obj = fleet_hill_climb(
                tenants,
                eff_fleet,
                k_max=k_max,
                init=incumbent,
                warm_start=False,
                tables=cache,
                discipline_space=discipline_space,
                objective=objective,
            )
            cold_fallbacks.append(now)
            if cold_obj < obj:
                plan, obj = cold_plan, cold_obj
        if plan_cache is not None:
            plan_cache.store(
                tenants,
                eff_fleet,
                plan,
                obj,
                k_max=k_max,
                discipline_space=discipline_space,
                objective=objective,
            )
        return commit(plan, obj, t0, moved)

    rates0 = list(initial_rates) if initial_rates is not None else [1.0] * n
    imbalance_streak = 0
    fleet_plan, obj, dt, _ = plan_for(rates0, None, 0.0)
    sims = _device_sims(profiles, fleet_plan, fleet, backend, faults=faults)

    replan_times = [0.0]
    fleet_plans = [fleet_plan]
    objectives = [obj]
    compute_times = [dt]
    placement_replans: list[float] = []

    # Fault-aware detection state (inert unless fault_aware=True).
    down_flags = [False] * n_dev
    speed_est = [1.0] * n_dev
    window_offered = [0] * n_dev
    last_comp_seen = [sim.last_completion for sim in sims]
    trackers = [LatencyWindowTracker(n) for _ in range(n_dev)]
    probe_views = (
        [faults.view(d) for d in range(n_dev)]
        if (fault_aware and health_probe and faults is not None)
        else None
    )
    failovers: list[float] = []
    restores: list[float] = []
    degraded_replans: list[float] = []

    def detect_faults(now: float, clamped: Sequence[float]) -> tuple[bool, bool]:
        """Update down/degraded state from this window's observed signals;
        returns (dropout state changed, degrade state changed)."""
        tenants_now = [
            TenantSpec(p, r) for p, r in zip(profiles, clamped)
        ]
        pred_obj = device_objectives(tenants_now, fleet_plan, fleet)
        drop_changed = False
        deg_changed = False
        for d in range(n_dev):
            comp = sims[d].last_completion
            cnt, obs_mean = trackers[d].poll_mean(sims[d].latencies)
            if probe_views is not None:
                down_now = probe_views[d].is_down(now)
                if down_now != down_flags[d]:
                    down_flags[d] = down_now
                    drop_changed = True
                    (failovers if down_now else restores).append(now)
            elif not down_flags[d]:
                # Silent device: offered a meaningful batch, completed
                # nothing new.  last_completion is not warmup-gated, so
                # this is safe during the recording warmup too.
                if (
                    window_offered[d] >= dropout_min_requests
                    and comp <= last_comp_seen[d]
                ):
                    down_flags[d] = True
                    drop_changed = True
                    failovers.append(now)
            elif comp > last_comp_seen[d]:
                # Completions resumed: the requeued backlog is draining,
                # so the device is back.
                down_flags[d] = False
                drop_changed = True
                restores.append(now)
            # Throttle estimation from observed-vs-predicted means (skipped
            # while the device is considered down -- an outage already
            # explains any latency signal).
            routed = sum(
                w * clamped[i]
                for i, devs in enumerate(fleet_plan.placement)
                for dd, w in zip(devs, fleet_plan.routing[i])
                if dd == d
            )
            pred_mean = pred_obj[d] / routed if routed > 0 else math.nan
            if (
                not down_flags[d]
                and cnt >= dropout_min_requests
                and math.isfinite(pred_mean)
                and pred_mean > 0
                and math.isfinite(obs_mean)
            ):
                if obs_mean > degrade_threshold * pred_mean:
                    f = min(1.0, max(min_speed_factor, pred_mean / obs_mean))
                    if speed_est[d] == 1.0 or f < 0.5 * speed_est[d]:
                        speed_est[d] = f
                        deg_changed = True
                elif speed_est[d] < 1.0 and obs_mean < degrade_restore * pred_mean:
                    speed_est[d] = 1.0
                    deg_changed = True
            last_comp_seen[d] = comp
            window_offered[d] = 0
        return drop_changed, deg_changed

    def effective_fleet() -> list[DeviceSpec]:
        return [
            dev
            if speed_est[d] == 1.0
            else dataclasses.replace(
                dev,
                tpu_speed=dev.tpu_speed * speed_est[d],
                cpu_speed=dev.cpu_speed * speed_est[d],
            )
            for d, dev in enumerate(fleet)
        ]

    reqs, horizon = sorted_trace_and_horizon(requests)
    warmup_t = horizon * warmup_frac
    next_replan = replan_period
    span_idx = 0

    def fire_due_replans(t: float) -> None:
        nonlocal next_replan, fleet_plan, imbalance_streak
        while t >= next_replan:
            for sim in sims:
                sim.advance_to(next_replan)
            rates = est.rates(next_replan)
            if forecaster is not None:
                forecaster.observe(next_replan, rates)
            if any(r > 0 for r in rates):
                clamped = [max(r, min_rate) for r in rates]
                tenants = [
                    TenantSpec(p, r) for p, r in zip(profiles, clamped)
                ]
                drop_changed = deg_changed = False
                if fault_aware:
                    drop_changed, deg_changed = detect_faults(
                        next_replan, clamped
                    )
                down_list = [d for d in range(n_dev) if down_flags[d]]
                fleet_now = (
                    effective_fleet()
                    if fault_aware
                    and (down_list or any(f < 1.0 for f in speed_est))
                    else None
                )
                # The imbalance gate judges *observed* offered load; only
                # the plan search runs against forecast rates.
                loads = offered_device_loads(
                    tenants, fleet_plan, fleet, clamped
                )
                spread = max(loads) - min(loads)
                imbalance_streak = (
                    imbalance_streak + 1
                    if spread > imbalance_threshold
                    else 0
                )
                if down_list:
                    # An evacuated placement is deliberately skewed; the
                    # imbalance gate must not re-admit a down device.
                    imbalance_streak = 0
                plan_rates = rates
                if forecaster is not None:
                    pred = forecaster.forecast(next_replan, replan_period)
                    if pred is not None:
                        plan_rates = pred
                if fault_aware and (drop_changed or deg_changed):
                    # Out-of-band fault-state-transition re-plan: failover
                    # (evacuate the down devices), restore (cold search
                    # re-admits the recovered device), or a throttle
                    # transition (cold search against the degraded specs --
                    # migration off a badly throttled device needs the
                    # placement search, which warm re-plans hold fixed).
                    tenants_plan = _plan_tenants(plan_rates)
                    eff = fleet_now if fleet_now is not None else list(fleet)
                    t0 = time.perf_counter()
                    if down_list:
                        try:
                            new_plan, obj = evacuate_device(
                                tenants_plan,
                                eff,
                                down_list,
                                k_max=k_max,
                                tables=cache,
                                discipline_space=discipline_space,
                                objective=objective,
                            )
                            dt = time.perf_counter() - t0
                            moved = True
                            norm_history.clear()
                        except ValueError:
                            # The surviving fleet cannot host every tenant:
                            # keep the incumbent placement, warm re-plan.
                            new_plan, obj, dt, moved = plan_for(
                                plan_rates,
                                fleet_plan,
                                next_replan,
                                fleet_now=fleet_now,
                            )
                    else:
                        new_plan, obj = fleet_hill_climb(
                            tenants_plan,
                            eff,
                            k_max=k_max,
                            tables=cache,
                            discipline_space=discipline_space,
                            objective=objective,
                        )
                        dt = time.perf_counter() - t0
                        moved = True
                        norm_history.clear()
                    if any(f < 1.0 for f in speed_est):
                        degraded_replans.append(next_replan)
                else:
                    new_plan, obj, dt, moved = plan_for(
                        plan_rates, fleet_plan, next_replan,
                        fleet_now=fleet_now,
                    )
                    if any(f < 1.0 for f in speed_est):
                        degraded_replans.append(next_replan)
                if moved:
                    placement_replans.append(next_replan)
                    imbalance_streak = 0
                for d, sim in enumerate(sims):
                    if new_plan.device_plans[d] != fleet_plan.device_plans[d]:
                        sim.set_plan(new_plan.device_plans[d], now=next_replan)
                fleet_plan = new_plan
                replan_times.append(next_replan)
                fleet_plans.append(new_plan)
                objectives.append(obj)
                compute_times.append(dt)
            next_replan += replan_period

    trace = as_trace(reqs)
    arrival = trace.arrival
    n_req = len(trace)
    idx = 0
    while idx < n_req:
        fire_due_replans(float(arrival[idx]))
        j = int(np.searchsorted(arrival, next_replan, side="left"))
        seg = trace[idx:j]
        est.observe_batch(seg.model_idx, seg.arrival)
        subs = route_trace(
            seg,
            fleet_plan.placement,
            fleet_plan.routing,
            len(fleet),
            seed=route_seed + span_idx,
        )
        for d, (sim, sub) in enumerate(zip(sims, subs)):
            _drive(sim, sub, backend, warmup_t, vectorize)
            if fault_aware:
                window_offered[d] += len(sub)
        span_idx += 1
        idx = j

    results = [
        sim.result(max(horizon, sim.drain())) for sim in sims
    ]
    return FleetAdaptiveResult(
        sim=merge_fleet_results(results),
        replan_times=replan_times,
        fleet_plans=fleet_plans,
        plan_compute_seconds=compute_times,
        plan_objectives=objectives,
        placement_replan_times=placement_replans,
        cold_fallback_times=cold_fallbacks,
        failover_times=failovers,
        restore_times=restores,
        degraded_replan_times=degraded_replans,
    )


__all__ = [
    "FleetAdaptiveResult",
    "offered_device_loads",
    "run_adaptive_fleet",
    "simulate_fleet",
]
