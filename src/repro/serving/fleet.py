"""Fleet serving: N per-device simulators driven off one split trace.

``simulate_fleet`` is the fleet analogue of ``simulate``: the request trace
is split by tenant placement (``workload.route_trace``), each device runs
its own independent simulator (stepper, DES, or jax -- same pluggable
backends) under its full-width device plan, and the per-device results
merge into one ``FleetSimResult`` (request-pooled means, merged
nearest-rank p99).

``run_adaptive_fleet`` is the fleet analogue of ``run_adaptive``: one
global sliding-window rate estimator, periodic per-device warm re-plans
(placement held fixed), and a *sustained-imbalance* trigger that re-runs
the full placement search only when the offered per-device load has stayed
skewed for several consecutive re-plan windows -- placement churn is
expensive for the serving tier (model redeploys), so a single bursty
window must not move tenants.

Degenerate case contract: a 1-device unit-speed fleet built
``DeviceSpec.from_platform(platform)`` makes ``simulate_fleet`` replay the
exact single-device ``simulate`` path -- same trace object, same simulator
construction, bitwise-identical ``SimResult`` fields
(``tests/test_fleet.py`` pins this for both backends).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core.fleet import (
    DeviceSpec,
    FleetPlan,
    FleetTablesCache,
    fleet_hill_climb,
)
from repro.core.planner import (
    DisciplineSpec,
    ModelProfile,
    Plan,
    TenantSpec,
    prefix_service_time,
)
from repro.serving.result import FleetSimResult, SimResult, merge_fleet_results
from repro.serving.simulator import make_backend, sorted_trace_and_horizon
from repro.serving.workload import Request, Trace, as_trace, route_trace
from repro.serving.controller import SlidingRateEstimator, _should_cold_fallback

if TYPE_CHECKING:
    from repro.core.plan_cache import FleetPlanCache
    from repro.serving.forecast import RateForecaster


def _device_sims(
    profiles: Sequence[ModelProfile],
    fleet_plan: FleetPlan,
    fleet: Sequence[DeviceSpec],
    backend: str,
):
    """One simulator per device: full-width scaled profiles, device plan."""
    return [
        make_backend(
            backend,
            dev.scaled_profiles(profiles),
            fleet_plan.device_plans[d],
            dev.platform,
        )
        for d, dev in enumerate(fleet)
    ]


def _drive(sim, sub, backend: str, warmup_t: float, vectorize: bool) -> None:
    """Feed one device's sub-trace through its simulator (the same driver
    dispatch ``simulate`` uses)."""
    if vectorize and isinstance(sub, Trace):
        if backend in ("stepper", "jax"):
            sim.run_trace(sub, record_from=warmup_t)
        else:
            sim.offer_trace(sub, record_from=warmup_t)
    else:
        for req in sub:
            sim.offer(req, record=req.arrival >= warmup_t)


def simulate_fleet(
    tenants: Sequence[TenantSpec],
    fleet_plan: FleetPlan,
    fleet: Sequence[DeviceSpec],
    requests: "Trace | Sequence[Request]",
    *,
    warmup_frac: float = 0.05,
    backend: str = "stepper",
    vectorize: bool = True,
    route_seed: int = 0,
) -> FleetSimResult:
    """Run a static fleet plan over a request trace.

    The trace is split by placement/routing into per-device sub-traces
    (global model indices preserved), each device simulates independently
    -- devices share nothing at runtime, which is what makes the fleet
    embarrassingly parallel -- and the results merge.  Warmup and duration
    are *global*: the warmup cutoff comes from the fleet-wide horizon and
    every device's duration extends to at least that horizon, so per-device
    metrics weight into the merged view on one clock.
    """
    if len(fleet) != fleet_plan.n_devices:
        raise ValueError(
            f"fleet has {len(fleet)} devices, plan {fleet_plan.n_devices}"
        )
    profiles = [t.profile for t in tenants]
    reqs, horizon = sorted_trace_and_horizon(requests)
    warmup_t = horizon * warmup_frac
    subs = route_trace(
        reqs,
        fleet_plan.placement,
        fleet_plan.routing,
        len(fleet),
        seed=route_seed,
    )
    results: list[SimResult] = []
    for sim, sub in zip(_device_sims(profiles, fleet_plan, fleet, backend), subs):
        _drive(sim, sub, backend, warmup_t, vectorize)
        results.append(sim.result(max(horizon, sim.drain())))
    return merge_fleet_results(results)


def offered_device_loads(
    tenants: Sequence[TenantSpec],
    fleet_plan: FleetPlan,
    fleet: Sequence[DeviceSpec],
    rates: Sequence[float],
) -> list[float]:
    """Offered TPU utilization per device under the current plan.

    ``rho_d = sum_i w_id * lambda_i * s_TPU(p_id)`` with ``s_TPU`` from the
    device's scaled profile on its platform -- the same Eq. 1 ingredient
    the analytic model uses, so the imbalance trigger and the planner agree
    on what "load" means.
    """
    loads = [0.0] * len(fleet)
    for i, t in enumerate(tenants):
        for dev_idx, w in zip(fleet_plan.placement[i], fleet_plan.routing[i]):
            dev = fleet[dev_idx]
            prof = t.profile.scaled(dev.tpu_speed, dev.cpu_speed)
            p = fleet_plan.device_plans[dev_idx].partition[i]
            loads[dev_idx] += (
                w * rates[i] * prefix_service_time(prof, p, dev.platform)
            )
    return loads


@dataclasses.dataclass
class FleetAdaptiveResult:
    """``run_adaptive_fleet`` outcome: merged metrics + the plan history."""

    sim: FleetSimResult
    replan_times: list[float]
    fleet_plans: list[FleetPlan]
    plan_compute_seconds: list[float]
    plan_objectives: list[float] = dataclasses.field(default_factory=list)
    # Boundaries where sustained imbalance triggered a full placement
    # re-plan (a subset of ``replan_times``).
    placement_replan_times: list[float] = dataclasses.field(default_factory=list)
    # Boundaries where the (opt-in) cold-fallback guard re-climbed the
    # device plans cold with placement held (a subset of ``replan_times``).
    cold_fallback_times: list[float] = dataclasses.field(default_factory=list)


def run_adaptive_fleet(
    profiles: Sequence[ModelProfile],
    requests: "Trace | Sequence[Request]",
    fleet: Sequence[DeviceSpec],
    *,
    k_max: int | None = None,
    replan_period: float = 30.0,
    window: float = 30.0,
    rate_decay: float | None = None,
    initial_rates: Sequence[float] | None = None,
    min_rate: float = 0.05,
    warmup_frac: float = 0.05,
    backend: str = "stepper",
    vectorize: bool = True,
    imbalance_threshold: float = 0.5,
    imbalance_patience: int = 3,
    cold_fallback_margin: float | None = None,
    cold_fallback_window: int = 5,
    discipline_space: Sequence[DisciplineSpec] | None = None,
    forecaster: "RateForecaster | None" = None,
    plan_cache: "FleetPlanCache | None" = None,
    route_seed: int = 0,
) -> FleetAdaptiveResult:
    """Adaptive fleet serving: local re-plans, imbalance-gated placement.

    Every ``replan_period`` the global rate estimates feed N *warm*
    per-device climbs (placement and routing fixed -- ``fleet_hill_climb``
    with ``init=incumbent``), exactly as the single-device controller
    warm-starts ``hill_climb``.  The full placement search re-runs only on
    *sustained* imbalance: when the spread of offered per-device TPU
    utilization (``max - min`` of ``offered_device_loads``) exceeds
    ``imbalance_threshold`` for ``imbalance_patience`` consecutive re-plan
    boundaries, a cold ``fleet_hill_climb`` (placement included) runs and
    the better of warm/cold commits.  One bursty window never migrates
    tenants; a persistent skew does.

    Requests arriving between boundaries are routed by the *current*
    placement; each device's queued work drains under the plan its requests
    were bound at (both backends bind routes at arrival).  Per-span routing
    draws (split-placement tenants only) are seeded by span index on top of
    ``route_seed``, so a replayed trace routes identically.

    ``cold_fallback_margin`` (opt-in, default ``None`` = off) adds the
    single-device warm-tail guard alongside the imbalance gate: when a warm
    re-plan's normalized objective regresses past the margin against the
    recent trend (``_should_cold_fallback``), the device plans re-climb
    *cold with placement held* (``fleet_hill_climb(warm_start=False)``) and
    the better result commits.  The trend history is cleared whenever a
    placement re-plan commits -- post-migration objectives must never be
    judged against pre-migration history (the two placements have different
    normalized-objective baselines, so stale history would mis-fire or
    mask the guard).

    ``rate_decay``, ``forecaster`` and ``plan_cache`` mirror
    ``run_adaptive`` (the cache must be a
    ``repro.core.plan_cache.FleetPlanCache``); all default off, keeping
    this path bitwise the reactive fleet controller.  A memoized plan
    whose placement differs from the incumbent's counts as a placement
    re-plan (it migrates tenants), and the cache is bypassed at
    boundaries where the imbalance gate demands a genuine placement
    search.
    """
    if not fleet:
        raise ValueError("fleet must contain at least one device")
    n = len(profiles)
    est = SlidingRateEstimator(n, window=window, decay=rate_decay)
    cache = FleetTablesCache()

    # Normalized-objective trend for the opt-in warm-tail guard; cleared on
    # every committed placement re-plan (see the docstring).
    norm_history: collections.deque[float] = collections.deque(
        maxlen=max(1, cold_fallback_window)
    )
    cold_fallbacks: list[float] = []

    def plan_for(
        rates: Sequence[float],
        incumbent: FleetPlan | None,
        now: float,
    ) -> tuple[FleetPlan, float, float, bool]:
        """(plan, objective, seconds, placement_replanned)"""
        tenants = [
            TenantSpec(p, max(r, min_rate)) for p, r in zip(profiles, rates)
        ]
        tot_rate = sum(t.rate for t in tenants)
        gate_firing = (
            incumbent is not None and imbalance_streak >= imbalance_patience
        )

        def commit(
            plan: FleetPlan, obj: float, t0: float, moved: bool
        ) -> tuple[FleetPlan, float, float, bool]:
            # S2 fix: a committed placement re-plan resets the normalized-
            # objective baseline, so the guard's trend history restarts --
            # comparing post-migration objectives against pre-migration
            # history mis-fires the guard.  Nan-means-unknown: non-finite
            # or zero-traffic objectives carry no trend information.
            if moved:
                norm_history.clear()
            if tot_rate > 0 and math.isfinite(obj):
                norm_history.append(obj / tot_rate)
            return plan, obj, time.perf_counter() - t0, moved

        t0 = time.perf_counter()
        if plan_cache is not None and not gate_firing:
            hit = plan_cache.lookup(
                tenants, fleet, k_max=k_max, discipline_space=discipline_space
            )
            if hit is not None:
                plan, obj = hit
                moved = (
                    incumbent is not None
                    and plan.placement != incumbent.placement
                )
                return commit(plan, obj, t0, moved)
        if incumbent is None:
            plan, obj = fleet_hill_climb(
                tenants,
                fleet,
                k_max=k_max,
                tables=cache,
                discipline_space=discipline_space,
            )
            if plan_cache is not None:
                plan_cache.store(
                    tenants,
                    fleet,
                    plan,
                    obj,
                    k_max=k_max,
                    discipline_space=discipline_space,
                )
            return commit(plan, obj, t0, False)
        plan, obj = fleet_hill_climb(
            tenants,
            fleet,
            k_max=k_max,
            init=incumbent,
            tables=cache,
            discipline_space=discipline_space,
        )
        moved = False
        if gate_firing:
            cold_plan, cold_obj = fleet_hill_climb(
                tenants,
                fleet,
                k_max=k_max,
                tables=cache,
                discipline_space=discipline_space,
            )
            if cold_obj < obj:
                plan, obj = cold_plan, cold_obj
                moved = True
        elif (
            cold_fallback_margin is not None
            and tot_rate > 0
            and _should_cold_fallback(
                obj / tot_rate, norm_history, cold_fallback_margin
            )
        ):
            # Warm-tail guard: re-climb the device plans cold, placement
            # held -- the fleet analogue of the single-device fallback.
            cold_plan, cold_obj = fleet_hill_climb(
                tenants,
                fleet,
                k_max=k_max,
                init=incumbent,
                warm_start=False,
                tables=cache,
                discipline_space=discipline_space,
            )
            cold_fallbacks.append(now)
            if cold_obj < obj:
                plan, obj = cold_plan, cold_obj
        if plan_cache is not None:
            plan_cache.store(
                tenants,
                fleet,
                plan,
                obj,
                k_max=k_max,
                discipline_space=discipline_space,
            )
        return commit(plan, obj, t0, moved)

    rates0 = list(initial_rates) if initial_rates is not None else [1.0] * n
    imbalance_streak = 0
    fleet_plan, obj, dt, _ = plan_for(rates0, None, 0.0)
    sims = _device_sims(profiles, fleet_plan, fleet, backend)

    replan_times = [0.0]
    fleet_plans = [fleet_plan]
    objectives = [obj]
    compute_times = [dt]
    placement_replans: list[float] = []

    reqs, horizon = sorted_trace_and_horizon(requests)
    warmup_t = horizon * warmup_frac
    next_replan = replan_period
    span_idx = 0

    def fire_due_replans(t: float) -> None:
        nonlocal next_replan, fleet_plan, imbalance_streak
        while t >= next_replan:
            for sim in sims:
                sim.advance_to(next_replan)
            rates = est.rates(next_replan)
            if forecaster is not None:
                forecaster.observe(next_replan, rates)
            if any(r > 0 for r in rates):
                clamped = [max(r, min_rate) for r in rates]
                tenants = [
                    TenantSpec(p, r) for p, r in zip(profiles, clamped)
                ]
                # The imbalance gate judges *observed* offered load; only
                # the plan search runs against forecast rates.
                loads = offered_device_loads(
                    tenants, fleet_plan, fleet, clamped
                )
                spread = max(loads) - min(loads)
                imbalance_streak = (
                    imbalance_streak + 1
                    if spread > imbalance_threshold
                    else 0
                )
                plan_rates = rates
                if forecaster is not None:
                    pred = forecaster.forecast(next_replan, replan_period)
                    if pred is not None:
                        plan_rates = pred
                new_plan, obj, dt, moved = plan_for(
                    plan_rates, fleet_plan, next_replan
                )
                if moved:
                    placement_replans.append(next_replan)
                    imbalance_streak = 0
                for d, sim in enumerate(sims):
                    if new_plan.device_plans[d] != fleet_plan.device_plans[d]:
                        sim.set_plan(new_plan.device_plans[d], now=next_replan)
                fleet_plan = new_plan
                replan_times.append(next_replan)
                fleet_plans.append(new_plan)
                objectives.append(obj)
                compute_times.append(dt)
            next_replan += replan_period

    trace = as_trace(reqs)
    arrival = trace.arrival
    n_req = len(trace)
    idx = 0
    while idx < n_req:
        fire_due_replans(float(arrival[idx]))
        j = int(np.searchsorted(arrival, next_replan, side="left"))
        seg = trace[idx:j]
        est.observe_batch(seg.model_idx, seg.arrival)
        subs = route_trace(
            seg,
            fleet_plan.placement,
            fleet_plan.routing,
            len(fleet),
            seed=route_seed + span_idx,
        )
        for sim, sub in zip(sims, subs):
            _drive(sim, sub, backend, warmup_t, vectorize)
        span_idx += 1
        idx = j

    results = [
        sim.result(max(horizon, sim.drain())) for sim in sims
    ]
    return FleetAdaptiveResult(
        sim=merge_fleet_results(results),
        replan_times=replan_times,
        fleet_plans=fleet_plans,
        plan_compute_seconds=compute_times,
        plan_objectives=objectives,
        placement_replan_times=placement_replans,
        cold_fallback_times=cold_fallbacks,
    )


__all__ = [
    "FleetAdaptiveResult",
    "offered_device_loads",
    "run_adaptive_fleet",
    "simulate_fleet",
]
