"""Workload scenario library: request traces for every serving backend.

Every generator returns a time-sorted ``list[Request]`` -- the one trace
interface shared by ``simulate`` (both the stepper and the discrete-event
backend) and ``run_adaptive``.  Beyond the paper's Poisson and
piecewise-rate (Fig. 8) traces, the library covers the dynamic/multi-tenant
settings the analytic model is *not* fit to: bursty MMPP arrivals, diurnal
rate cycles, heavy-tailed service-time jitter, and tenant churn.
``benchmarks/model_vs_sim.py`` sweeps these against the discrete-event
simulator to chart where Eq. 1-5 stays trustworthy.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    model_idx: int
    arrival: float
    # Multiplier on the request's *compute* service times (TPU prefix and
    # CPU suffix; transfers and swap reloads are bandwidth-bound and do not
    # scale).  1.0 everywhere reproduces the deterministic-service model the
    # analytic predictions assume; ``with_service_jitter`` perturbs it.
    service_scale: float = 1.0


def _check_rates(rates: Sequence[float]) -> list[float]:
    out = [float(r) for r in rates]
    if any(r < 0 for r in out):
        raise ValueError(f"arrival rates must be non-negative, got {out}")
    return out


def poisson_trace(
    rates: list[float],
    duration: float,
    seed: int = 0,
) -> list[Request]:
    """Independent Poisson arrival streams, merged and time-sorted."""
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    for idx, lam in enumerate(_check_rates(rates)):
        if lam <= 0:
            continue
        # Draw slightly more than needed, then trim.
        n_est = int(lam * duration * 1.5) + 20
        gaps = rng.exponential(1.0 / lam, size=n_est)
        times = np.cumsum(gaps)
        for t in times[times < duration]:
            reqs.append(Request(idx, float(t)))
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def deterministic_trace(rates: list[float], duration: float) -> list[Request]:
    """Evenly spaced arrivals per model (D/.../. input process).

    Model ``i`` sends requests at ``(j + (i+1)/(n+1)) / rate`` -- the
    per-stream phase offset staggers streams of *equal* rate so their j-th
    arrivals never collide (a shared half-offset would put them at the same
    instant, queueing one behind the other).  With inter-arrival gaps longer
    than the system's total service time this is the zero-queueing regime
    whose latency the closed-form static terms of Eq. 4 predict exactly
    (see ``tests/test_des.py``).
    """
    rates = _check_rates(rates)
    reqs: list[Request] = []
    for idx, lam in enumerate(rates):
        if lam <= 0:
            continue
        phase = (idx + 1) / (len(rates) + 1)
        n = int(np.floor(duration * lam))
        for j in range(n):
            t = (j + phase) / lam
            if t < duration:
                reqs.append(Request(idx, t))
    reqs.sort(key=lambda r: r.arrival)
    return reqs


@dataclasses.dataclass(frozen=True)
class RatePhase:
    """One phase of a dynamic workload: ``rates`` holding on [start, end)."""

    start: float
    end: float
    rates: tuple[float, ...]


def dynamic_trace(phases: list[RatePhase], seed: int = 0) -> list[Request]:
    """Piecewise-constant-rate Poisson arrivals (the paper's Fig. 8 setup)."""
    reqs: list[Request] = []
    for j, ph in enumerate(phases):
        sub = poisson_trace(list(ph.rates), ph.end - ph.start, seed=seed + 7919 * j)
        reqs.extend(Request(r.model_idx, r.arrival + ph.start) for r in sub)
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def mmpp_trace(
    rates: list[float],
    duration: float,
    *,
    burst_factor: float = 4.0,
    mean_normal: float = 60.0,
    mean_burst: float = 15.0,
    seed: int = 0,
) -> list[Request]:
    """Two-state Markov-modulated Poisson process (bursty arrivals).

    A global modulating chain alternates between a *normal* state (per-model
    rates ``rates``) and a *burst* state (``rates * burst_factor``), with
    exponentially distributed sojourn times of the given means -- the
    classic MMPP(2) burst model.  The long-run mean rate is
    ``rates * (mean_normal + burst_factor * mean_burst) / (mean_normal +
    mean_burst)``; bursts inflate queueing far beyond what a Poisson stream
    of the same mean rate produces, which is exactly the regime the M/G/1
    model underpredicts.
    """
    rates = _check_rates(rates)
    if burst_factor < 0:
        raise ValueError("burst_factor must be non-negative")
    if mean_normal <= 0 or mean_burst <= 0:
        raise ValueError("state sojourn means must be positive")
    rng = np.random.default_rng(seed)
    phases: list[RatePhase] = []
    t, burst = 0.0, False
    while t < duration:
        mean = mean_burst if burst else mean_normal
        hold = float(rng.exponential(mean))
        end = min(t + hold, duration)
        mult = burst_factor if burst else 1.0
        phases.append(RatePhase(t, end, tuple(r * mult for r in rates)))
        t, burst = end, not burst
    return dynamic_trace(phases, seed=seed + 104729)


def diurnal_trace(
    rates: list[float],
    duration: float,
    *,
    amplitude: float = 0.8,
    period: float = 600.0,
    seed: int = 0,
) -> list[Request]:
    """Sinusoidal rate cycle: ``lam_i(t) = rates[i] * (1 + A sin(2 pi t/T))``.

    Sampled exactly by thinning a homogeneous Poisson stream at the peak
    rate (Lewis & Shedler): candidate arrivals at rate ``lam_max`` are kept
    with probability ``lam(t)/lam_max``.  ``amplitude`` must lie in [0, 1]
    so the rate never goes negative.
    """
    rates = _check_rates(rates)
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError("amplitude must be in [0, 1]")
    if period <= 0:
        raise ValueError("period must be positive")
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    for idx, lam in enumerate(rates):
        if lam <= 0:
            continue
        lam_max = lam * (1.0 + amplitude)
        n_est = int(lam_max * duration * 1.5) + 20
        times = np.cumsum(rng.exponential(1.0 / lam_max, size=n_est))
        times = times[times < duration]
        accept = rng.uniform(size=times.size) * lam_max <= lam * (
            1.0 + amplitude * np.sin(2.0 * np.pi * times / period)
        )
        reqs.extend(Request(idx, float(t)) for t in times[accept])
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def with_service_jitter(
    requests: Sequence[Request],
    *,
    sigma: float = 0.6,
    seed: int = 0,
) -> list[Request]:
    """Attach heavy-tailed service-time jitter to an existing trace.

    Each request's ``service_scale`` is drawn i.i.d. from a mean-1 lognormal
    (``exp(N(-sigma^2/2, sigma^2))``): the *mean* service time is preserved,
    so the analytic utilization is unchanged, but E[S^2] grows by
    ``exp(sigma^2)`` -- the Pollaczek-Khinchine wait the deterministic
    two-atom mixture of Eq. 2 predicts becomes a lower bound.  Order and
    arrival stamps are untouched.
    """
    if sigma < 0:
        raise ValueError("sigma must be non-negative")
    rng = np.random.default_rng(seed)
    scales = rng.lognormal(mean=-0.5 * sigma * sigma, sigma=sigma, size=len(requests))
    return [
        dataclasses.replace(r, service_scale=float(r.service_scale * s))
        for r, s in zip(requests, scales)
    ]


@dataclasses.dataclass(frozen=True)
class ChurnTrace:
    """A tenant-churn workload: requests plus the generating schedule.

    ``active[i]`` holds model i's sessions as ``(join, leave)`` intervals;
    every request of model i falls inside one of them (property-tested).
    The schedule is what lets a controller test tenant arrival/departure
    handling without inferring sessions back from the gaps.
    """

    requests: tuple[Request, ...]
    active: tuple[tuple[tuple[float, float], ...], ...]


def tenant_churn_trace(
    rates: list[float],
    duration: float,
    *,
    mean_session: float = 120.0,
    mean_absence: float = 60.0,
    seed: int = 0,
) -> ChurnTrace:
    """Tenants join and depart: alternating active/absent renewal process.

    Each model independently alternates exponentially distributed active
    sessions (Poisson arrivals at its rate) and absences (no requests at
    all), starting active.  Models a multi-tenant edge box where apps
    start and stop -- the regime of Subedi et al.'s multi-tenancy study
    where static plans go stale.
    """
    rates = _check_rates(rates)
    if mean_session <= 0 or mean_absence <= 0:
        raise ValueError("session/absence means must be positive")
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    schedule: list[tuple[tuple[float, float], ...]] = []
    for idx, lam in enumerate(rates):
        sessions: list[tuple[float, float]] = []
        t, active = 0.0, True
        while t < duration:
            hold = float(
                rng.exponential(mean_session if active else mean_absence)
            )
            end = min(t + hold, duration)
            if active and lam > 0:
                sessions.append((t, end))
                n_est = int(lam * (end - t) * 1.5) + 20
                times = t + np.cumsum(rng.exponential(1.0 / lam, size=n_est))
                reqs.extend(
                    Request(idx, float(a)) for a in times[times < end]
                )
            t, active = end, not active
        schedule.append(tuple(sessions))
    reqs.sort(key=lambda r: r.arrival)
    return ChurnTrace(requests=tuple(reqs), active=tuple(schedule))


# -- deterministic trace replay ---------------------------------------------

def trace_to_json(requests: Sequence[Request]) -> str:
    """Serialize a trace for deterministic replay.

    Floats go through ``repr`` (Python's ``json``), which round-trips IEEE
    doubles exactly, so a replayed trace drives a simulator bit-identically.
    """
    return json.dumps(
        [
            {"model_idx": r.model_idx, "arrival": r.arrival,
             "service_scale": r.service_scale}
            for r in requests
        ]
    )


def trace_from_json(payload: str) -> list[Request]:
    """Inverse of ``trace_to_json``; validates and re-sorts by arrival."""
    rows = json.loads(payload)
    reqs = []
    for row in rows:
        r = Request(
            model_idx=int(row["model_idx"]),
            arrival=float(row["arrival"]),
            service_scale=float(row.get("service_scale", 1.0)),
        )
        if r.arrival < 0 or r.service_scale < 0:
            raise ValueError(f"negative arrival/service_scale in {row}")
        reqs.append(r)
    reqs.sort(key=lambda r: r.arrival)
    return reqs
