"""Workload scenario library: request traces for every serving backend.

Every generator returns a time-sorted columnar ``Trace`` -- parallel NumPy
arrays of ``model_idx`` / ``arrival`` / ``service_scale`` -- the one trace
interface shared by ``simulate`` (both the stepper and the discrete-event
backend) and ``run_adaptive``.  ``Trace`` behaves as a sequence of
``Request`` records (iteration, indexing, equality), so per-request
consumers are unchanged, while the columnar layout is what lets the
vectorized stepper fast path push millions of requests per second
(``repro.serving.simulator``).  ``Trace.to_requests()`` /
``Trace.from_requests()`` adapt to and from ``list[Request]`` for callers
that need the scalar form.

Beyond the paper's Poisson and piecewise-rate (Fig. 8) traces, the library
covers the dynamic/multi-tenant settings the analytic model is *not* fit
to: bursty MMPP arrivals, diurnal rate cycles, heavy-tailed service-time
jitter, and tenant churn.  ``benchmarks/model_vs_sim.py`` sweeps these
against the discrete-event simulator to chart where Eq. 1-5 stays
trustworthy.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterator, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True, slots=True)
class Request:
    model_idx: int
    arrival: float
    # Multiplier on the request's *compute* service times (TPU prefix and
    # CPU suffix; transfers and swap reloads are bandwidth-bound and do not
    # scale).  1.0 everywhere reproduces the deterministic-service model the
    # analytic predictions assume; ``with_service_jitter`` perturbs it.
    service_scale: float = 1.0


class Trace:
    """Columnar request trace: parallel arrays, one row per request.

    The native output of every generator in this module.  Reads as an
    immutable sequence of ``Request`` (iteration/indexing materialize
    records on demand); the arrays themselves are the contract the
    vectorized simulation fast paths consume directly.  Arrays are marked
    read-only -- a trace is a value, and both simulators replay it.
    """

    __slots__ = ("model_idx", "arrival", "service_scale", "_sorted", "_unit")

    def __init__(
        self,
        model_idx: np.ndarray,
        arrival: np.ndarray,
        service_scale: np.ndarray | None = None,
        *,
        _sorted: bool | None = None,
        _unit: bool | None = None,
        _own: bool = False,
    ):
        # A Trace freezes its columns (read-only): copy any caller-owned
        # writable array rather than freezing the caller's buffer in place.
        # Internal constructors pass freshly allocated arrays with
        # ``_own=True`` to stay zero-copy.
        def col(a, dtype):
            arr = np.ascontiguousarray(a, dtype=dtype)
            if not _own and arr is a and arr.flags.writeable:
                arr = arr.copy()
            return arr

        mi = col(model_idx, np.int64)
        ar = col(arrival, np.float64)
        if service_scale is None:
            sc = np.ones(ar.shape, dtype=np.float64)
            _unit = True
        else:
            sc = col(service_scale, np.float64)
        if not (mi.ndim == ar.ndim == sc.ndim == 1):
            raise ValueError("trace columns must be 1-D arrays")
        if not (mi.size == ar.size == sc.size):
            raise ValueError(
                f"trace column lengths differ: {mi.size}/{ar.size}/{sc.size}"
            )
        for a in (mi, ar, sc):
            a.setflags(write=False)
        object.__setattr__(self, "model_idx", mi)
        object.__setattr__(self, "arrival", ar)
        object.__setattr__(self, "service_scale", sc)
        object.__setattr__(self, "_sorted", _sorted)
        object.__setattr__(self, "_unit", _unit)

    def __setattr__(self, name, value):  # pragma: no cover - guard
        raise AttributeError("Trace is immutable")

    # -- sequence protocol (the list[Request] back-compat surface) ---------
    def __len__(self) -> int:
        return self.arrival.size

    def __iter__(self) -> Iterator[Request]:
        # One bulk tolist() per column: ~30x faster than per-row item().
        for m, a, s in zip(
            self.model_idx.tolist(),
            self.arrival.tolist(),
            self.service_scale.tolist(),
        ):
            yield Request(m, a, s)

    def __getitem__(self, key):
        if isinstance(key, slice):
            return Trace(
                self.model_idx[key],
                self.arrival[key],
                self.service_scale[key],
                _sorted=self._sorted if (key.step or 1) > 0 else None,
                _unit=self._unit,
                _own=True,  # read-only views of already-frozen columns
            )
        i = int(key)
        return Request(
            int(self.model_idx[i]),
            float(self.arrival[i]),
            float(self.service_scale[i]),
        )

    def __eq__(self, other) -> bool:
        if isinstance(other, Trace):
            return (
                np.array_equal(self.model_idx, other.model_idx)
                and np.array_equal(self.arrival, other.arrival)
                and np.array_equal(self.service_scale, other.service_scale)
            )
        if isinstance(other, (list, tuple)):
            return len(other) == len(self) and all(
                a == b for a, b in zip(self, other)
            )
        return NotImplemented

    __hash__ = None  # mutable-adjacent (holds arrays); not hashable

    def __repr__(self) -> str:
        return f"Trace(n={len(self)}, models={np.unique(self.model_idx).tolist()})"

    # -- adapters ----------------------------------------------------------
    def to_requests(self) -> list[Request]:
        """Materialize the scalar ``list[Request]`` form."""
        return list(self)

    @classmethod
    def from_requests(cls, requests: Sequence[Request]) -> "Trace":
        """Adapt a ``list[Request]`` (or any Request sequence) to columns."""
        if isinstance(requests, Trace):
            return requests
        n = len(requests)
        mi = np.fromiter((r.model_idx for r in requests), np.int64, count=n)
        ar = np.fromiter((r.arrival for r in requests), np.float64, count=n)
        sc = np.fromiter(
            (r.service_scale for r in requests), np.float64, count=n
        )
        return cls(mi, ar, sc, _own=True)

    @property
    def scale_is_unit(self) -> bool:
        """True when every ``service_scale`` is exactly 1.0 (checked once,
        then cached; known at construction for un-jittered generators).
        Lets the fast paths skip a no-op multiply without changing a bit
        (``s * 1.0 == s`` exactly)."""
        if self._unit is None:
            object.__setattr__(
                self, "_unit", bool(np.all(self.service_scale == 1.0))
            )
        return self._unit

    # -- ordering ----------------------------------------------------------
    @property
    def is_sorted(self) -> bool:
        """True when arrivals are nondecreasing (checked once, then cached).

        Every generator in this module emits sorted traces and marks them at
        construction, so the common-path check is O(1) -- the verify-then-skip
        that lets ``simulate``/``run_adaptive`` drop their defensive sort.
        """
        if self._sorted is None:
            ar = self.arrival
            object.__setattr__(
                self, "_sorted", bool(np.all(ar[1:] >= ar[:-1]))
            )
        return self._sorted

    def sorted_by_arrival(self) -> "Trace":
        """This trace in arrival order (self when already sorted; stable)."""
        if self.is_sorted:
            return self
        order = np.argsort(self.arrival, kind="stable")
        return Trace(
            self.model_idx[order],
            self.arrival[order],
            self.service_scale[order],
            _sorted=True,
            _unit=self._unit,
            _own=True,
        )


def as_trace(requests: "Trace | Sequence[Request]") -> Trace:
    """Coerce any accepted trace form to the columnar ``Trace``."""
    return Trace.from_requests(requests)


def _check_rates(rates: Sequence[float]) -> list[float]:
    out = [float(r) for r in rates]
    if any(r < 0 for r in out):
        raise ValueError(f"arrival rates must be non-negative, got {out}")
    return out


def _poisson_arrival_times(
    rng: np.random.Generator,
    lam: float,
    duration: float,
    *,
    _chunk: int | None = None,
) -> np.ndarray:
    """Arrival times of one rate-``lam`` Poisson stream covering [0, duration).

    Gaps are drawn in blocks and the draw *extends until the cumulative
    arrival time passes the horizon*.  The previous ``1.5 x lam x duration
    + 20`` single-block heuristic could -- rarely, when the sampled gaps ran
    long -- fall short of ``duration`` and silently truncate the tail of the
    trace.  The first block keeps the old size (so seeded traces that never
    needed extension are bit-identical); ``_chunk`` overrides the block size
    to force the extension loop in regression tests.
    """
    block = _chunk if _chunk is not None else int(lam * duration * 1.5) + 20
    times = np.cumsum(rng.exponential(1.0 / lam, size=block))
    while times[-1] < duration:
        more = np.cumsum(rng.exponential(1.0 / lam, size=block))
        times = np.concatenate([times, times[-1] + more])
    return times[times < duration]


def _merge_streams(streams: list[tuple[int, np.ndarray]]) -> Trace:
    """Merge per-model arrival arrays into one time-sorted trace.

    Stable sort after concatenation in model order: ties keep lower model
    index first, matching the historical ``list.sort`` merge exactly.
    """
    if not streams:
        return Trace(np.empty(0, np.int64), np.empty(0), _sorted=True, _own=True)
    idx = np.concatenate(
        [np.full(t.size, i, dtype=np.int64) for i, t in streams]
    )
    arr = np.concatenate([t for _, t in streams])
    order = np.argsort(arr, kind="stable")
    return Trace(idx[order], arr[order], _sorted=True, _own=True)


def poisson_trace(
    rates: list[float],
    duration: float,
    seed: int = 0,
    *,
    _chunk: int | None = None,
) -> Trace:
    """Independent Poisson arrival streams, merged and time-sorted."""
    rng = np.random.default_rng(seed)
    streams = [
        (idx, _poisson_arrival_times(rng, lam, duration, _chunk=_chunk))
        for idx, lam in enumerate(_check_rates(rates))
        if lam > 0
    ]
    return _merge_streams(streams)


def deterministic_trace(rates: list[float], duration: float) -> Trace:
    """Evenly spaced arrivals per model (D/.../. input process).

    Model ``i`` sends requests at ``(j + (i+1)/(n+1)) / rate`` -- the
    per-stream phase offset staggers streams of *equal* rate so their j-th
    arrivals never collide (a shared half-offset would put them at the same
    instant, queueing one behind the other).  With inter-arrival gaps longer
    than the system's total service time this is the zero-queueing regime
    whose latency the closed-form static terms of Eq. 4 predict exactly
    (see ``tests/test_des.py``).
    """
    rates = _check_rates(rates)
    streams = []
    for idx, lam in enumerate(rates):
        if lam <= 0:
            continue
        phase = (idx + 1) / (len(rates) + 1)
        # Over-draw and filter: floor(duration * lam) draws dropped the last
        # in-horizon arrival whenever the phase offset pushed index
        # floor(duration * lam) back under the horizon (e.g. lam=1,
        # duration=10.9, phase=0.5: the t=10.5 arrival) -- the same
        # truncation class the Poisson generators' extension loop fixed.
        n = int(np.ceil(duration * lam)) + 1
        times = (np.arange(n) + phase) / lam
        streams.append((idx, times[times < duration]))
    return _merge_streams(streams)


@dataclasses.dataclass(frozen=True)
class RatePhase:
    """One phase of a dynamic workload: ``rates`` holding on [start, end)."""

    start: float
    end: float
    rates: tuple[float, ...]


def dynamic_trace(phases: list[RatePhase], seed: int = 0) -> Trace:
    """Piecewise-constant-rate Poisson arrivals (the paper's Fig. 8 setup)."""
    models: list[np.ndarray] = []
    arrivals: list[np.ndarray] = []
    for j, ph in enumerate(phases):
        sub = poisson_trace(list(ph.rates), ph.end - ph.start, seed=seed + 7919 * j)
        models.append(sub.model_idx)
        arrivals.append(sub.arrival + ph.start)
    if not models:
        return Trace(np.empty(0, np.int64), np.empty(0), _sorted=True, _own=True)
    merged = Trace(
        np.concatenate(models),
        np.concatenate(arrivals),
        # service_scale omitted: per-phase Poisson sub-traces carry no jitter
        _own=True,
    )
    return merged.sorted_by_arrival()


def mmpp_trace(
    rates: list[float],
    duration: float,
    *,
    burst_factor: float = 4.0,
    mean_normal: float = 60.0,
    mean_burst: float = 15.0,
    seed: int = 0,
) -> Trace:
    """Two-state Markov-modulated Poisson process (bursty arrivals).

    A global modulating chain alternates between a *normal* state (per-model
    rates ``rates``) and a *burst* state (``rates * burst_factor``), with
    exponentially distributed sojourn times of the given means -- the
    classic MMPP(2) burst model.  The long-run mean rate is
    ``rates * (mean_normal + burst_factor * mean_burst) / (mean_normal +
    mean_burst)``; bursts inflate queueing far beyond what a Poisson stream
    of the same mean rate produces, which is exactly the regime the M/G/1
    model underpredicts.
    """
    rates = _check_rates(rates)
    if burst_factor < 0:
        raise ValueError("burst_factor must be non-negative")
    if mean_normal <= 0 or mean_burst <= 0:
        raise ValueError("state sojourn means must be positive")
    rng = np.random.default_rng(seed)
    phases: list[RatePhase] = []
    t, burst = 0.0, False
    while t < duration:
        mean = mean_burst if burst else mean_normal
        hold = float(rng.exponential(mean))
        end = min(t + hold, duration)
        mult = burst_factor if burst else 1.0
        phases.append(RatePhase(t, end, tuple(r * mult for r in rates)))
        t, burst = end, not burst
    return dynamic_trace(phases, seed=seed + 104729)


def diurnal_trace(
    rates: list[float],
    duration: float,
    *,
    amplitude: float = 0.8,
    period: float = 600.0,
    seed: int = 0,
) -> Trace:
    """Sinusoidal rate cycle: ``lam_i(t) = rates[i] * (1 + A sin(2 pi t/T))``.

    Sampled exactly by thinning a homogeneous Poisson stream at the peak
    rate (Lewis & Shedler): candidate arrivals at rate ``lam_max`` are kept
    with probability ``lam(t)/lam_max``.  ``amplitude`` must lie in [0, 1]
    so the rate never goes negative.
    """
    rates = _check_rates(rates)
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError("amplitude must be in [0, 1]")
    if period <= 0:
        raise ValueError("period must be positive")
    rng = np.random.default_rng(seed)
    streams = []
    for idx, lam in enumerate(rates):
        if lam <= 0:
            continue
        lam_max = lam * (1.0 + amplitude)
        times = _poisson_arrival_times(rng, lam_max, duration)
        accept = rng.uniform(size=times.size) * lam_max <= lam * (
            1.0 + amplitude * np.sin(2.0 * np.pi * times / period)
        )
        streams.append((idx, times[accept]))
    return _merge_streams(streams)


def with_service_jitter(
    requests: "Trace | Sequence[Request]",
    *,
    sigma: float = 0.6,
    seed: int = 0,
) -> "Trace | list[Request]":
    """Attach heavy-tailed service-time jitter to an existing trace.

    Each request's ``service_scale`` is drawn i.i.d. from a mean-1 lognormal
    (``exp(N(-sigma^2/2, sigma^2))``): the *mean* service time is preserved,
    so the analytic utilization is unchanged, but E[S^2] grows by
    ``exp(sigma^2)`` -- the Pollaczek-Khinchine wait the deterministic
    two-atom mixture of Eq. 2 predicts becomes a lower bound.  Order and
    arrival stamps are untouched.  A ``Trace`` comes back as a ``Trace``;
    a ``Request`` sequence as a ``list[Request]``.
    """
    if sigma < 0:
        raise ValueError("sigma must be non-negative")
    rng = np.random.default_rng(seed)
    scales = rng.lognormal(mean=-0.5 * sigma * sigma, sigma=sigma, size=len(requests))
    if isinstance(requests, Trace):
        return Trace(
            requests.model_idx,
            requests.arrival,
            requests.service_scale * scales,
            _sorted=requests._sorted,
            _own=True,  # sources are frozen columns; the product is fresh
        )
    return [
        dataclasses.replace(r, service_scale=float(r.service_scale * s))
        for r, s in zip(requests, scales)
    ]


@dataclasses.dataclass(frozen=True)
class ChurnTrace:
    """A tenant-churn workload: requests plus the generating schedule.

    ``active[i]`` holds model i's sessions as ``(join, leave)`` intervals;
    every request of model i falls inside one of them (property-tested).
    The schedule is what lets a controller test tenant arrival/departure
    handling without inferring sessions back from the gaps.
    """

    requests: Trace
    active: tuple[tuple[tuple[float, float], ...], ...]


def tenant_churn_trace(
    rates: list[float],
    duration: float,
    *,
    mean_session: float = 120.0,
    mean_absence: float = 60.0,
    seed: int = 0,
) -> ChurnTrace:
    """Tenants join and depart: alternating active/absent renewal process.

    Each model independently alternates exponentially distributed active
    sessions (Poisson arrivals at its rate) and absences (no requests at
    all), starting active.  Models a multi-tenant edge box where apps
    start and stop -- the regime of Subedi et al.'s multi-tenancy study
    where static plans go stale.
    """
    rates = _check_rates(rates)
    if mean_session <= 0 or mean_absence <= 0:
        raise ValueError("session/absence means must be positive")
    rng = np.random.default_rng(seed)
    streams: list[tuple[int, np.ndarray]] = []
    schedule: list[tuple[tuple[float, float], ...]] = []
    for idx, lam in enumerate(rates):
        sessions: list[tuple[float, float]] = []
        bursts: list[np.ndarray] = []
        t, active = 0.0, True
        while t < duration:
            hold = float(
                rng.exponential(mean_session if active else mean_absence)
            )
            end = min(t + hold, duration)
            if active and lam > 0:
                sessions.append((t, end))
                bursts.append(t + _poisson_arrival_times(rng, lam, end - t))
            t, active = end, not active
        streams.append(
            (idx, np.concatenate(bursts) if bursts else np.empty(0))
        )
        schedule.append(tuple(sessions))
    return ChurnTrace(requests=_merge_streams(streams), active=tuple(schedule))


# -- fleet routing ------------------------------------------------------------

def route_trace(
    requests: "Trace | Sequence[Request]",
    placement: Sequence[Sequence[int]],
    routing: Sequence[Sequence[float]],
    n_devices: int,
    *,
    seed: int = 0,
    faults=None,
) -> list[Trace]:
    """Split one trace into per-device columnar traces by tenant placement.

    ``placement[i]`` / ``routing[i]`` follow the ``FleetPlan`` contract: the
    devices tenant ``i`` may run on and the matching routing weights.  Every
    request keeps its *global* ``model_idx`` (device plans are full-width,
    so per-device simulators replay the splits without re-indexing), its
    arrival stamp, and its service scale; the returned traces partition the
    input exactly -- ``sum(len(t) for t in out) == len(trace)``.

    Single-placement tenants split deterministically (a pure boolean mask,
    preserving arrival order, so each sub-trace inherits sortedness).
    Tenants placed on several devices draw i.i.d. device choices from their
    routing weights with a ``seed``-keyed generator -- same trace + same
    seed is the same split, which keeps the JSON replay contract intact:
    replaying ``trace_from_json(trace_to_json(t))`` routes bit-identically.

    The degenerate single-device fleet returns ``[trace]`` itself (the
    bitwise N=1 contract: not a copy, the same object).

    ``faults`` (a ``serving.faults.FaultSchedule``): model a health-aware
    ingress router -- a request whose weighted draw lands on a device that
    is *down at its arrival instant* is redrawn across the tenant's other
    placed, currently-up devices (routing-weight proportional).  Tenants
    placed on a single device keep their requests (the device's own dropout
    gate decides requeue/lost); ``faults=None`` (default) leaves routing
    bitwise unchanged.
    """
    trace = as_trace(requests)
    if n_devices <= 0:
        raise ValueError("n_devices must be positive")
    if len(placement) != len(routing):
        raise ValueError("placement and routing must have equal length")
    if n_devices == 1 and all(tuple(p) == (0,) for p in placement):
        return [trace]

    mi = trace.model_idx
    n = len(trace)
    dev = np.full(n, -1, dtype=np.int64)
    rng: np.random.Generator | None = None
    for i, (devs, wts) in enumerate(zip(placement, routing)):
        if not devs:
            raise ValueError(f"tenant {i} placed on no device")
        if any(not 0 <= d < n_devices for d in devs):
            raise ValueError(f"tenant {i} placement {tuple(devs)} out of range")
        mask = mi == i
        if len(devs) == 1:
            dev[mask] = devs[0]
            continue
        if len(wts) != len(devs):
            raise ValueError(f"tenant {i}: weights/placement length mismatch")
        if rng is None:
            rng = np.random.default_rng(seed)
        cum = np.cumsum(np.asarray(wts, dtype=np.float64))
        if not cum.size or cum[-1] <= 0:
            raise ValueError(f"tenant {i}: routing weights sum to zero")
        cum /= cum[-1]
        choice = np.searchsorted(cum, rng.random(int(mask.sum())), side="right")
        dev[mask] = np.asarray(devs, dtype=np.int64)[
            np.minimum(choice, len(devs) - 1)
        ]
    unplaced = dev < 0
    if unplaced.any():
        bad = np.unique(mi[unplaced]).tolist()
        raise ValueError(f"trace contains unplaced model indices {bad}")

    if faults is not None:
        faults.validate(n_devices)
        views = [faults.view(d) for d in range(n_devices)]
        if any(v.down_windows for v in views):
            arr = trace.arrival
            for i, (devs, wts) in enumerate(zip(placement, routing)):
                devs = list(devs)
                if len(devs) < 2:
                    continue
                if len(wts) != len(devs):
                    wts = [1.0] * len(devs)
                sel_i = np.flatnonzero(mi == i)
                if not sel_i.size:
                    continue
                for k in sel_i.tolist():
                    d = int(dev[k])
                    t = float(arr[k])
                    if not views[d].is_down(t):
                        continue
                    alts = [
                        (x, w)
                        for x, w in zip(devs, wts)
                        if x != d and not views[x].is_down(t)
                    ]
                    if not alts:
                        continue  # whole placement dark: the gate decides
                    if rng is None:
                        rng = np.random.default_rng(seed)
                    cum = np.cumsum([max(w, 0.0) for _, w in alts])
                    if cum[-1] <= 0:
                        cum = np.arange(1.0, len(alts) + 1.0)
                    j = int(
                        np.searchsorted(
                            cum / cum[-1], rng.random(), side="right"
                        )
                    )
                    dev[k] = alts[min(j, len(alts) - 1)][0]

    out = []
    for d in range(n_devices):
        mask = dev == d
        out.append(
            Trace(
                # Boolean-mask gathers allocate fresh arrays: zero-copy-safe.
                trace.model_idx[mask],
                trace.arrival[mask],
                trace.service_scale[mask],
                # A subsequence of a sorted trace is sorted; unknown stays
                # unknown (never claim False -- the subset may well be sorted).
                _sorted=True if trace._sorted else None,
                _unit=True if trace._unit else None,
                _own=True,
            )
        )
    return out


# -- deterministic trace replay ---------------------------------------------

def trace_to_json(requests: "Trace | Sequence[Request]") -> str:
    """Serialize a trace for deterministic replay.

    Floats go through ``repr`` (Python's ``json``), which round-trips IEEE
    doubles exactly, so a replayed trace drives a simulator bit-identically.
    """
    return json.dumps(
        [
            {"model_idx": r.model_idx, "arrival": r.arrival,
             "service_scale": r.service_scale}
            for r in requests
        ]
    )


def trace_from_json(payload: str) -> Trace:
    """Inverse of ``trace_to_json``; validates and re-sorts by arrival."""
    rows = json.loads(payload)
    reqs = []
    for row in rows:
        r = Request(
            model_idx=int(row["model_idx"]),
            arrival=float(row["arrival"]),
            service_scale=float(row.get("service_scale", 1.0)),
        )
        if r.arrival < 0 or r.service_scale < 0:
            raise ValueError(f"negative arrival/service_scale in {row}")
        reqs.append(r)
    return Trace.from_requests(reqs).sorted_by_arrival()
