"""Workload generation: Poisson request traces and dynamic-rate scenarios."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    model_idx: int
    arrival: float


def poisson_trace(
    rates: list[float],
    duration: float,
    seed: int = 0,
) -> list[Request]:
    """Independent Poisson arrival streams, merged and time-sorted."""
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    for idx, lam in enumerate(rates):
        if lam <= 0:
            continue
        # Draw slightly more than needed, then trim.
        n_est = int(lam * duration * 1.5) + 20
        gaps = rng.exponential(1.0 / lam, size=n_est)
        times = np.cumsum(gaps)
        for t in times[times < duration]:
            reqs.append(Request(idx, float(t)))
    reqs.sort(key=lambda r: r.arrival)
    return reqs


@dataclasses.dataclass(frozen=True)
class RatePhase:
    """One phase of a dynamic workload: ``rates`` holding on [start, end)."""

    start: float
    end: float
    rates: tuple[float, ...]


def dynamic_trace(phases: list[RatePhase], seed: int = 0) -> list[Request]:
    """Piecewise-constant-rate Poisson arrivals (the paper's Fig. 8 setup)."""
    reqs: list[Request] = []
    for j, ph in enumerate(phases):
        sub = poisson_trace(list(ph.rates), ph.end - ph.start, seed=seed + 7919 * j)
        reqs.extend(Request(r.model_idx, r.arrival + ph.start) for r in sub)
    reqs.sort(key=lambda r: r.arrival)
    return reqs
