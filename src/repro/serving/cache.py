"""Explicit TPU SRAM residency simulator.

The Edge TPU driver's eviction policy is proprietary (the paper approximates
it with the conservative alpha of Eq. 10).  For ground-truth simulation we
implement a concrete, documented policy: model-granularity LRU over resident
prefixes.  A model whose prefix exceeds capacity ``C`` gets the full ``C``
as resident working set (the remainder streams every request -- intra-model
swap, accounted in the service time, not here).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class _Entry:
    bytes_resident: int
    last_used: float


class SramCache:
    def __init__(self, capacity: int):
        self.capacity = capacity
        self._entries: dict[int, _Entry] = {}

    def reset(self) -> None:
        self._entries.clear()

    @property
    def used(self) -> int:
        return sum(e.bytes_resident for e in self._entries.values())

    def resident(self, model_idx: int) -> bool:
        return model_idx in self._entries

    def access(self, model_idx: int, prefix_bytes: int, now: float) -> bool:
        """Touch ``model_idx``; returns True on a *miss* (weights must load).

        On a miss, LRU entries of other models are evicted until the new
        prefix's resident share (min(prefix, C)) fits.
        """
        want = min(prefix_bytes, self.capacity)
        entry = self._entries.get(model_idx)
        if entry is not None and entry.bytes_resident >= want:
            entry.last_used = now
            return False
        # Miss: make room.
        self._entries.pop(model_idx, None)
        while self.used + want > self.capacity and self._entries:
            lru = min(self._entries, key=lambda m: self._entries[m].last_used)
            del self._entries[lru]
        self._entries[model_idx] = _Entry(bytes_resident=want, last_used=now)
        return True
