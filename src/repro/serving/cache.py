"""Explicit TPU SRAM residency simulator.

The Edge TPU driver's eviction policy is proprietary (the paper approximates
it with the conservative alpha of Eq. 10).  For ground-truth simulation we
implement a concrete, documented policy: model-granularity LRU over resident
prefixes.  A model whose prefix exceeds capacity ``C`` gets the full ``C``
as resident working set (the remainder streams every request -- intra-model
swap, accounted in the service time, not here).

Every operation is O(1) amortized: a running ``used`` byte counter replaces
the per-access re-summation of all entries, and recency is the insertion
order of an ``OrderedDict`` (move-to-end on hit, pop-front on eviction)
replacing the O(n) ``min(..., key=last_used)`` eviction scan.  Simulators
access the cache at strictly increasing timestamps (server start times), so
recency order and the ``last_used`` ordering coincide and the rewrite is
behaviorally identical to the scan-based original
(``tests/test_sim_fastpath.py`` property-tests the equivalence against the
frozen reference in ``benchmarks/des_baseline.py``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Iterable


@dataclasses.dataclass(slots=True)
class _Entry:
    bytes_resident: int
    last_used: float


class SramCache:
    def __init__(self, capacity: int):
        self.capacity = capacity
        # Keys in recency order: least-recently-used first.
        self._entries: collections.OrderedDict[int, _Entry] = (
            collections.OrderedDict()
        )
        self._used = 0

    def reset(self) -> None:
        self._entries.clear()
        self._used = 0

    @property
    def used(self) -> int:
        return self._used

    def resident(self, model_idx: int) -> bool:
        return model_idx in self._entries

    def access(self, model_idx: int, prefix_bytes: int, now: float) -> bool:
        """Touch ``model_idx``; returns True on a *miss* (weights must load).

        On a miss, LRU entries of other models are evicted until the new
        prefix's resident share (min(prefix, C)) fits.
        """
        want = min(prefix_bytes, self.capacity)
        entries = self._entries
        entry = entries.get(model_idx)
        if entry is not None and entry.bytes_resident >= want:
            entry.last_used = now
            entries.move_to_end(model_idx)
            return False
        # Miss: make room.
        if entry is not None:
            del entries[model_idx]
            self._used -= entry.bytes_resident
        while self._used + want > self.capacity and entries:
            _, lru = entries.popitem(last=False)
            self._used -= lru.bytes_resident
        entries[model_idx] = _Entry(bytes_resident=want, last_used=now)
        self._used += want
        return True

    # -- bulk state handoff (vectorized stepper fast path) ------------------
    def state(self) -> list[tuple[int, int, float]]:
        """Snapshot as ``(model_idx, bytes_resident, last_used)`` rows in
        recency order (least-recently-used first)."""
        return [
            (m, e.bytes_resident, e.last_used) for m, e in self._entries.items()
        ]

    def restore(self, state: Iterable[tuple[int, int, float]]) -> None:
        """Replace the contents with a ``state()``-shaped snapshot.

        Rows must be in recency order (least-recently-used first), as the
        fast path's run-compressed LRU replay produces them.  Validates
        before mutating: a rejected snapshot leaves the cache untouched.
        """
        rows = list(state)
        used = sum(b for _, b, _ in rows)
        if used > self.capacity:
            raise ValueError(
                f"restored state uses {used} bytes > capacity {self.capacity}"
            )
        self._entries.clear()
        for m, b, t in rows:
            self._entries[m] = _Entry(bytes_resident=b, last_used=t)
        self._used = used
