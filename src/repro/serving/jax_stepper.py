"""JAX-native Lindley stepper: the vectorized fast path on-device.

``JaxStepper`` is ``RuntimeSimulator`` with the two sequential-bottleneck
recurrences -- the TPU FCFS Lindley pass and the single-core CPU-pool
passes -- evaluated by a jitted chunked max-plus scan instead of the NumPy
guess/classify/fixpoint of ``_server_ends``.  Everything order- or
integer-valued (routing, SRAM miss replay, recording, cache stamps,
multi-core CPU heaps) is inherited unchanged from the parent, so the two
backends differ *only* in float rounding of the busy-period recurrence.

Contract (ROADMAP standing invariant): the NumPy paths are the bitwise
references; the JAX paths are **statistically equivalent** -- float32
kernels, means/p99 within tolerance on seeded replicas, identical integer
observables.  The kernel works in *delay space* precisely to make float32
safe: absolute completion clocks (thousands of seconds) would lose the
microsecond-scale service times to cancellation, while queueing delays and
inter-arrival gaps stay small.

Mathematics
-----------
The FCFS busy-period recurrence over enqueue times ``tau`` and services
``s`` is ``end[j] = max(tau[j], end[j-1]) + s[j]``.  Substituting the
*delay* ``d[j] = end[j] - tau[j]`` and the gap ``g[j] = tau[j] -
tau[j-1]`` gives

    d[j] = max(0, d[j-1] - g[j]) + s[j]
         = max(A[j], d[j-1] + B[j]),   A[j] = s[j],  B[j] = s[j] - g[j].

Each request is thus an element of the max-plus affine semigroup
``f(x) = max(A, x + B)`` with the associative composition

    (f2 . f1)(x) = max(max(A2, A1 + B2), x + (B1 + B2)).

XLA:CPU runs a flat ``lax.scan`` an order of magnitude slower than NumPy's
fused cumulative kernels, so the evaluation is blocked: the trace reshapes
into ``C`` contiguous chunks of length ``L``; within each chunk the prefix
compositions collapse to one ``cumsum`` plus one associative ``cummax``
along the contiguous axis (``pB = cumsum(B)``, ``pA = pB + cummax(A -
pB)`` -- the classic Lindley identity, float32-safe because per-chunk
sums stay small); a short sequential scan combines the ``C`` chunk
carries; a fused elementwise resolve produces every delay.  The grid is
tuned for XLA:CPU (wide chunks, ``cumsum``/``associative_scan`` on the
minor axis); on an accelerator the same kernel shape parallelizes across
chunks and replicas.

``JaxStepper.run_trace_replicas`` is the Monte-Carlo engine this buys:
``R`` per-model service-jitter replicas of one arrival order resolve in a
handful of device calls -- arrival order, routing, and the SRAM miss
pattern are shared (service jitter cannot reorder FCFS enqueues), so they
are hoisted out of the replica loop, while the NumPy stepper must pay the
full pipeline per replica.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.simulator import RuntimeSimulator
from repro.serving.workload import Trace

__all__ = ["JaxStepper", "ReplicaStats", "lindley_ends"]

# Identity element of the max-plus affine semigroup: max(NEG, x + 0) == x
# for every finite float32 x.  Finite (not -inf) so composition arithmetic
# on padded lanes never produces inf - inf = nan.
_NEG = np.float32(-3e38)


def _grid(n: int) -> tuple[int, int]:
    """Chunk grid ``(C, L)`` with ``C * L >= n``, both powers of two.

    Tuned on XLA:CPU: C ~ 2048 keeps the within-chunk cumulative kernels
    on long contiguous rows (where XLA's cumsum/associative_scan are
    fastest) while the chunk-carry combine stays a short scan.  Power-of-
    two padding bounds the set of compiled shapes at ~log2(N).
    """
    padded = 1 << max(10, (n - 1).bit_length())
    c = min(2048, max(1, padded // 512))
    return c, padded // c


@partial(jax.jit, static_argnames=("c", "l"))
def _delays_kernel(a, b, x_init, c: int, l: int):
    """Batched Lindley delays: ``[R, c*l]`` elements -> ``[R, c*l]``.

    ``x_init`` is the per-replica initial backlog ``free0 - tau[0]``,
    shape ``[R]``.  Three stages (see module docstring): within-chunk
    prefix compositions (cumsum + associative cummax on the contiguous
    axis), a sequential combine over the C chunk carries, and the fused
    elementwise resolve.
    """
    a2 = a.reshape(-1, c, l)
    b2 = b.reshape(-1, c, l)
    pb = jnp.cumsum(b2, axis=2)
    pa = pb + jax.lax.associative_scan(jnp.maximum, a2 - pb, axis=2)

    # Chunk carries: x entering chunk k = chunks 0..k-1 applied to x_init.
    full_a = jnp.moveaxis(pa[:, :, -1], 1, 0)  # [C, R]
    full_b = jnp.moveaxis(pb[:, :, -1], 1, 0)

    def carry_step(x, elem):
        ca, cb = elem
        return jnp.maximum(ca, x + cb), x

    _, xc = jax.lax.scan(carry_step, x_init, (full_a, full_b))
    xc = jnp.moveaxis(xc, 0, 1)  # [R, C]

    d = jnp.maximum(pa, xc[:, :, None] + pb)
    return d.reshape(a.shape)


def _elements(enqueue: np.ndarray, service: np.ndarray):
    """Host-side float32 (A, B) build from float64 columns.

    A, B, and the initial backlog are all *small* (services and gaps);
    the cast here is the only precision loss in the pass -- the absolute
    clock never enters the kernel.
    """
    gaps = np.empty_like(enqueue)
    gaps[0] = 0.0
    np.subtract(enqueue[1:], enqueue[:-1], out=gaps[1:])
    return service.astype(np.float32), (service - gaps).astype(np.float32)


def _pad(arr: np.ndarray, size: int, fill) -> np.ndarray:
    if arr.shape[-1] == size:
        return arr
    out = np.full(arr.shape[:-1] + (size,), fill, dtype=arr.dtype)
    out[..., : arr.shape[-1]] = arr
    return out


def lindley_ends(
    enqueue: np.ndarray, service: np.ndarray, free0: float
) -> np.ndarray:
    """FCFS completion times via the jitted max-plus scan.

    Drop-in for ``simulator._server_ends`` under the statistical contract:
    delays are float32, the absolute clock is restored in float64 on the
    host (``ends = tau + d``), padded tail lanes carry identity elements
    so real prefixes are unaffected.
    """
    n = enqueue.size
    if n == 0:
        return np.empty(0)
    a, b = _elements(enqueue, service)
    c, l = _grid(n)
    a = _pad(a, c * l, _NEG)[None]
    b = _pad(b, c * l, np.float32(0.0))[None]
    x_init = np.asarray([free0 - enqueue[0]], dtype=np.float32)
    d = np.asarray(_delays_kernel(a, b, x_init, c, l))[0, :n]
    return enqueue + d.astype(np.float64)


@partial(jax.jit, static_argnames=("c", "l", "n_models"))
def _tpu_replicas_kernel(
    base, miss_load, g, tm, scales, x_init, c: int, l: int, n_models: int,
):
    """Fused TPU stage for R replicas: in-graph service build + delays +
    per-model delay sums + busy time.  ``scales`` is ``[R, n_models]``
    (per-model jitter -- the ``Trace.service_scale`` semantics applied
    model-wise), everything else is one shared padded column.

    Padding needs no mask: dead lanes carry ``base = miss_load = g = 0``
    (so ``svc = 0`` -- invisible to ``busy``) and ``tm = n_models``, whose
    one-hot row is all-zero -- invisible to the per-model sums.  Their
    element ``f(x) = max(0, x)`` is not the semigroup identity, but dead
    lanes sit strictly *after* every real request, so no real prefix ever
    composes through one.
    """
    svc = base * scales[:, tm] + miss_load  # [R, P]
    d = _delays_kernel(svc, svc - g, x_init, c, l)
    sums = d @ jax.nn.one_hot(tm, n_models, dtype=d.dtype)
    busy = svc.sum(axis=1)
    return d, sums, busy


@partial(jax.jit, static_argnames=("c", "l"))
def _cpu_replicas_kernel(
    d_tpu, sel, g_host, svc, x0_host, c: int, l: int
):
    """Fused single-core CPU-pool stage for one model across R replicas.

    The pool's enqueue column is ``t_in = ends[sel] + out_xfer``; only its
    *gap* structure matters, which splits into the shared host part
    (enqueue-time diffs) plus the replica-dependent part (TPU delay
    diffs) -- both small, both float32-safe.  ``svc`` is the replica's
    constant service ``s_cpu * scale_r`` (per-model jitter), ``x0_host``
    the shared part of the initial backlog ``-(enq[sel[0]] + out_xfer)``
    (an idle pool at t=0); the replica part is gathered in-graph.
    """
    dsel = d_tpu[:, sel]  # [R, n_i]
    dd = jnp.diff(dsel, axis=1, prepend=dsel[:, :1])
    g = g_host[None, :] + dd
    x_init = x0_host - dsel[:, 0]
    pad_n = c * l
    n_i = sel.shape[0]
    a = jnp.full((dsel.shape[0], pad_n), _NEG)
    a = a.at[:, :n_i].set(svc[:, None])
    b = jnp.zeros((dsel.shape[0], pad_n))
    b = b.at[:, :n_i].set(svc[:, None] - g)
    d = _delays_kernel(a, b, x_init, c, l)[:, :n_i]
    return d, d.sum(axis=1)


@dataclasses.dataclass(frozen=True)
class ReplicaStats:
    """Per-replica summaries from ``JaxStepper.run_trace_replicas``.

    ``mean_latency[r, m]`` matches ``SimResult.mean_latency(m)`` of the
    NumPy stepper run on the same replica's trace to float32 tolerance;
    ``counts``/``misses`` are exact and shared across replicas (service
    jitter cannot change arrival order or the SRAM access sequence).
    """

    mean_latency: np.ndarray   # [R, n_models] float64
    counts: np.ndarray         # [n_models] int64
    misses: np.ndarray         # [n_models] int64
    tpu_busy: np.ndarray       # [R] float64


class JaxStepper(RuntimeSimulator):
    """``RuntimeSimulator`` with on-device Lindley recurrences.

    Overrides exactly one hook -- ``_lindley`` -- so every other mechanism
    (scalar ``step`` fallback, deferred disciplines, SRAM replay, heap CPU
    pools, recording) is the parent's, behaviorally *and* textually.
    Integer observables are bitwise identical to the NumPy stepper; float
    observables agree to float32 tolerance (``tests/test_jax_sim.py``).
    """

    def _lindley(
        self, enqueue: np.ndarray, service: np.ndarray, free0: float
    ) -> np.ndarray:
        return lindley_ends(enqueue, service, free0)

    # -- Monte-Carlo replica engine ---------------------------------------
    def run_trace_replicas(
        self, trace: Trace, scales: np.ndarray
    ) -> ReplicaStats:
        """Resolve ``R`` per-model service-jitter replicas of one trace.

        ``scales`` is ``[R, n_models]``: replica r scales every request of
        model m by ``scales[r, m]`` (measurement-uncertainty Monte Carlo
        over the profiled service times -- the ``Trace.service_scale``
        column ``scales[r, trace.model_idx]`` gives the identical model on
        the NumPy stepper, which is exactly what the equivalence self-
        check replays).  Requirements: a fresh simulator (no prior
        offers), FCFS discipline, unit-scale sorted trace, and k <= 1 CPU
        pools -- the regime where both stages are pure Lindley scans.  The
        arrival order, routing, enqueue clock, and SRAM miss pattern are
        replica-invariant and hoisted; only the busy-period scans and the
        summary reductions run per replica (in one device call per stage).
        """
        scales = np.asarray(scales, dtype=np.float64)
        if scales.ndim != 2 or scales.shape[1] != self.n:
            raise ValueError("scales must be [n_replicas, n_models]")
        if self._disc is not None:
            raise ValueError("replica engine supports FCFS plans only")
        if any(len(pool) > 1 for pool in self._cpu_pools):
            raise ValueError("replica engine supports k<=1 CPU pools only")
        if self.tpu_free != 0.0 or self.tpu_busy != 0.0:
            raise ValueError("replica engine requires a fresh simulator")
        if not trace.is_sorted:
            raise ValueError("run_trace_replicas requires a sorted Trace")
        if not trace.scale_is_unit:
            raise ValueError(
                "per-request service_scale and per-model replica scales "
                "would compose ambiguously; pass a unit-scale trace"
            )
        n_req = len(trace)
        r_rep = scales.shape[0]
        m = trace.model_idx
        arr = trace.arrival
        has_tpu = self._part_arr > 0
        has_cpu = self._part_arr < self._points_arr

        counts = np.bincount(m, minlength=self.n)
        mean_lat = np.zeros((r_rep, self.n))
        misses_out = np.zeros(self.n, dtype=np.int64)
        busy = np.zeros(r_rep)
        if n_req == 0:
            return ReplicaStats(mean_lat, counts, misses_out, busy)

        # -- shared TPU-stage structure (replica-invariant) --------------
        if bool(has_tpu.all()):
            ti, tm = None, m
        else:
            ti = np.flatnonzero(has_tpu[m])
            tm = m[ti]
        d_tpu = None
        scales32 = scales.astype(np.float32)
        if tm.size:
            arr_t = arr if ti is None else arr[ti]
            enq = arr_t + self._in_xfer_arr[tm]
            last = np.full(self.n, -1, dtype=np.int64)
            last[tm] = np.arange(tm.size)
            first = np.full(self.n, -1, dtype=np.int64)
            first[tm[::-1]] = np.arange(tm.size - 1, -1, -1)
            miss, _ = self._replay_lru(tm, first, last)
            misses_out += np.bincount(tm[miss], minlength=self.n)

            gaps = np.empty_like(enq)
            gaps[0] = 0.0
            np.subtract(enq[1:], enq[:-1], out=gaps[1:])
            base = self._s_tpu_arr[tm].astype(np.float32)
            miss_load = np.where(miss, self._t_load_arr[tm], 0.0).astype(
                np.float32
            )
            c, l = _grid(tm.size)
            pad_n = c * l
            x_init = np.full(
                r_rep, 0.0 - enq[0], dtype=np.float32
            )
            d_tpu, sums, busy32 = _tpu_replicas_kernel(
                jnp.asarray(_pad(base, pad_n, np.float32(0.0))),
                jnp.asarray(_pad(miss_load, pad_n, np.float32(0.0))),
                jnp.asarray(_pad(gaps.astype(np.float32), pad_n,
                                 np.float32(0.0))),
                jnp.asarray(
                    _pad(tm.astype(np.int32), pad_n, np.int32(self.n))
                ),
                jnp.asarray(scales32),
                jnp.asarray(x_init),
                c, l, self.n,
            )
            busy += np.asarray(busy32, dtype=np.float64)
            # TPU-stage latency = in_xfer + delay (enqueue - arrival is
            # exactly the input transfer).
            sums_np = np.asarray(sums, dtype=np.float64)
            tpu_counts = np.bincount(tm, minlength=self.n)
            nz = tpu_counts > 0
            mean_lat[:, nz] += (
                self._in_xfer_arr[nz][None, :]
                + sums_np[:, nz] / tpu_counts[nz][None, :]
            )

        # -- per-model single-core CPU pools ------------------------------
        for i in np.flatnonzero(has_cpu).tolist():
            if ti is None:
                sel = np.flatnonzero(m == i)
                sel_t = sel
            else:
                sel = np.flatnonzero(m == i)
                # Position of model i's requests inside the TPU trace (all
                # of model i is TPU-routed when has_tpu[i]).
                sel_t = np.flatnonzero(tm == i) if has_tpu[i] else None
            if sel.size == 0:
                continue
            svc_cpu = (self._s_cpu[i] * scales[:, i]).astype(np.float32)
            if has_tpu[i]:
                # t_in = enq[sel_t] + d[sel_t] + out_xfer: split gaps into
                # the shared enqueue part and the replica delay part.
                enq_i = enq[sel_t]
                g_host = np.empty_like(enq_i)
                g_host[0] = 0.0
                np.subtract(enq_i[1:], enq_i[:-1], out=g_host[1:])
                c2, l2 = _grid(sel_t.size)
                x0_host = np.float32(
                    0.0 - (enq_i[0] + self._out_eff_arr[i])
                )
                _, cpu_sums = _cpu_replicas_kernel(
                    d_tpu,
                    jnp.asarray(sel_t.astype(np.int32)),
                    jnp.asarray(g_host.astype(np.float32)),
                    jnp.asarray(svc_cpu),
                    x0_host,
                    c2, l2,
                )
                # Total latency = in_xfer + d_tpu + out_xfer + d_cpu.
                mean_lat[:, i] += self._out_eff_arr[i] + np.asarray(
                    cpu_sums, dtype=np.float64
                ) / sel.size
            else:
                # Full-CPU route: the pool's enqueue column is the arrival
                # itself, shared across replicas.
                arr_i = arr[sel]
                a32, b32 = _elements(arr_i, np.zeros(sel.size))
                c2, l2 = _grid(sel.size)
                pad_n = c2 * l2
                g_i = (a32 - b32)  # recovers the float32 gaps
                a_k = np.full((r_rep, pad_n), _NEG, dtype=np.float32)
                b_k = np.zeros((r_rep, pad_n), dtype=np.float32)
                a_k[:, : sel.size] = svc_cpu[:, None]
                b_k[:, : sel.size] = svc_cpu[:, None] - g_i[None, :]
                x0 = np.full(r_rep, 0.0 - arr_i[0], dtype=np.float32)
                d_cpu = np.asarray(
                    _delays_kernel(
                        jnp.asarray(a_k), jnp.asarray(b_k),
                        jnp.asarray(x0), c2, l2,
                    )
                )[:, : sel.size]
                mean_lat[:, i] += d_cpu.sum(axis=1) / sel.size

        return ReplicaStats(mean_lat, counts, misses_out, busy)
