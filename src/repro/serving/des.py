"""Event-driven ground-truth simulator of the SwapLess runtime.

The sequential ``RuntimeSimulator`` stepper shares most of its structure with
the analytic model it is supposed to validate (it literally walks requests
through ``max(t, server_free)`` recurrences).  This module is the
independent check: a classic discrete-event simulation with

* an event heap ordered by (time, insertion sequence),
* one TPU server with explicit swap state -- parameter residency tracked by
  the model-granularity LRU ``SramCache``, the inter-model swap-in cost
  ``T_load`` charged at service start when the tenant switch evicted the
  weights, intra-model swap streaming folded into the bound service time,
* ``k_i`` CPU-core servers per model under the active ``Plan``,
* per-tenant FIFO queues in front of both stages (the TPU picks the
  earliest-enqueued head across tenants, i.e. global FCFS),
* mid-flight plan changes: ``set_plan`` re-routes *future* arrivals while
  queued and in-service work bound under the old plan drains unchanged.

The DES and the stepper implement the same system contract (same
``Request`` traces in, same ``SimResult`` out) with disjoint mechanics, so
agreement between them -- and between either and Eq. 1-5 -- is evidence,
not tautology.  ``tests/test_des.py`` pins the correspondence:
deterministic single-tenant latencies match the closed-form static terms to
float round-off, and seeded Poisson waits converge to ``mg1_wait``.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
from typing import Sequence

from repro.core.planner import (
    ModelProfile,
    Plan,
    load_time,
    prefix_service_time,
)
from repro.hw.specs import Platform
from repro.serving.cache import SramCache
from repro.serving.result import SimResult
from repro.serving.workload import Request

# Event kinds, in no particular priority: simultaneous events are resolved
# by insertion sequence, which matches the causal order they were scheduled.
_ARRIVAL, _TPU_ENQUEUE, _TPU_DONE, _CPU_ENQUEUE, _CPU_DONE = range(5)


@dataclasses.dataclass
class _Job:
    """One request in flight, with its route bound at arrival time."""

    req: Request
    record: bool
    p: int                 # partition point under the plan active at arrival
    tpu_service: float     # prefix compute + intra-swap stream (jitter-scaled)
    cpu_service: float     # 1-core suffix time (jitter-scaled)
    out_xfer: float        # boundary activation transfer (0 when no suffix)
    enq: float = 0.0       # FIFO stamp of the current queue
    seq: int = 0


class DiscreteEventSimulator:
    """Event-heap serving simulator; drop-in backend for ``simulate`` and
    ``run_adaptive`` (same driver surface as ``RuntimeSimulator``:
    ``offer`` / ``advance_to`` / ``set_plan`` / ``drain`` / ``result``)."""

    def __init__(
        self,
        profiles: Sequence[ModelProfile],
        plan: Plan,
        platform: Platform,
    ):
        self.profiles = list(profiles)
        self.platform = platform
        self.n = len(self.profiles)
        self.cache = SramCache(platform.sram_bytes)
        self.now = 0.0
        self.tpu_busy = 0.0
        self.last_completion = 0.0
        self.latencies: list[list[float]] = [[] for _ in range(self.n)]
        self.arrivals: list[list[float]] = [[] for _ in range(self.n)]
        self.misses = [0] * self.n
        self.tpu_requests = [0] * self.n
        self._heap: list[tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self._tpu_queues: list[collections.deque[_Job]] = [
            collections.deque() for _ in range(self.n)
        ]
        self._tpu_job: _Job | None = None
        self._cpu_queues: list[collections.deque[_Job]] = [
            collections.deque() for _ in range(self.n)
        ]
        self._cpu_busy = [0] * self.n
        self._plan: Plan | None = None
        self.set_plan(plan, now=0.0)

    # -- plan management ----------------------------------------------------
    def set_plan(self, plan: Plan, now: float) -> None:
        """Switch to a new (P, K) configuration at simulated time ``now``.

        Pending events up to ``now`` are processed first, so the switch is
        causally ordered against the workload.  Routing is bound per job at
        its arrival: jobs already past arrival keep their old partition and
        service times (a mid-flight request is not re-split), while new
        arrivals see the new plan.  CPU pools resize in place -- running
        suffixes finish on their core; a pool shrunk below its busy count
        just stops admitting new work until it drains (the paper preloads
        candidate partitions, so the switch itself is free).
        """
        if len(plan.partition) != self.n:
            raise ValueError("plan size mismatch")
        self.advance_to(now)
        self._plan = plan
        pf, pl = self.profiles, self.platform
        p = plan.partition
        self._prefix_bytes = [f.prefix_weight_bytes(q) for f, q in zip(pf, p)]
        self._s_tpu = [prefix_service_time(f, q, pl) for f, q in zip(pf, p)]
        self._t_load = [load_time(f, q, pl) for f, q in zip(pf, p)]
        self._s_cpu = [
            f.suffix_cpu_time(q, 1) if q < f.num_partition_points else 0.0
            for f, q in zip(pf, p)
        ]
        self._in_xfer = [f.input_bytes / pl.swap_bw for f in pf]
        self._out_xfer = [f.boundary_bytes(q) / pl.swap_bw for f, q in zip(pf, p)]
        # A grown pool can admit queued work immediately.
        for i in range(self.n):
            self._start_cpu(i)

    @property
    def plan(self) -> Plan:
        assert self._plan is not None
        return self._plan

    def _cpu_servers(self, i: int) -> int:
        # Suffix-bearing jobs always have somewhere to run, even if a plan
        # change dropped the model's allocation to 0 cores mid-flight (the
        # stepper sizes its pools max(k, 1) for the same reason).
        return max(self.plan.cores[i], 1)

    # -- driver surface -----------------------------------------------------
    def submit(self, req: Request, *, record: bool = True) -> None:
        """Schedule one request; its route binds when the arrival fires."""
        if not 0 <= req.model_idx < self.n:
            raise ValueError(f"model_idx {req.model_idx} out of range")
        if req.arrival < self.now:
            raise ValueError(
                f"arrival {req.arrival} is in the simulator's past ({self.now})"
            )
        self._push(req.arrival, _ARRIVAL, (req, record))

    def offer(self, req: Request, *, record: bool = True) -> None:
        """Advance to the request's arrival, then submit it (the shared
        in-order driver contract of ``simulate``/``run_adaptive``)."""
        self.advance_to(req.arrival)
        self.submit(req, record=record)

    def advance_to(self, t: float) -> None:
        """Process every event with timestamp <= ``t``; clock ends at ``t``."""
        if t < self.now:
            raise ValueError(f"cannot rewind the clock from {self.now} to {t}")
        while self._heap and self._heap[0][0] <= t:
            self._dispatch(*heapq.heappop(self._heap))
        self.now = t

    def drain(self) -> float:
        """Run the event loop dry; returns the last completion time."""
        while self._heap:
            self._dispatch(*heapq.heappop(self._heap))
        return self.last_completion

    def result(self, duration: float) -> SimResult:
        return SimResult(
            latencies=self.latencies,
            arrivals=self.arrivals,
            tpu_busy=self.tpu_busy,
            duration=duration,
            misses=self.misses,
            tpu_requests=self.tpu_requests,
        )

    # -- event machinery ----------------------------------------------------
    def _push(self, t: float, kind: int, payload: object) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def _dispatch(self, t: float, seq: int, kind: int, payload: object) -> None:
        self.now = max(self.now, t)
        if kind == _ARRIVAL:
            self._on_arrival(*payload)
        elif kind == _TPU_ENQUEUE:
            self._on_tpu_enqueue(payload)
        elif kind == _TPU_DONE:
            self._on_tpu_done(payload)
        elif kind == _CPU_ENQUEUE:
            self._on_cpu_enqueue(payload)
        else:
            self._on_cpu_done(payload)

    def _on_arrival(self, req: Request, record: bool) -> None:
        i = req.model_idx
        p = self.plan.partition[i]
        P_i = self.profiles[i].num_partition_points
        job = _Job(
            req=req,
            record=record,
            p=p,
            tpu_service=self._s_tpu[i] * req.service_scale,
            cpu_service=self._s_cpu[i] * req.service_scale,
            out_xfer=self._out_xfer[i] if 0 < p < P_i else 0.0,
        )
        if p > 0:
            # Input transfer is a pure delay: it occupies neither server
            # (the additive d/B term of Eq. 4).
            self._push(self.now + self._in_xfer[i], _TPU_ENQUEUE, job)
        else:
            self._on_cpu_enqueue(job)

    def _on_tpu_enqueue(self, job: _Job) -> None:
        job.enq, job.seq = self.now, next(self._seq)
        self._tpu_queues[job.req.model_idx].append(job)
        self._start_tpu()

    def _start_tpu(self) -> None:
        if self._tpu_job is not None:
            return
        # Global FCFS over per-tenant FIFO queues: serve the earliest head.
        heads = [q[0] for q in self._tpu_queues if q]
        if not heads:
            return
        job = min(heads, key=lambda j: (j.enq, j.seq))
        i = job.req.model_idx
        self._tpu_queues[i].popleft()
        self._tpu_job = job
        # Swap state transition: touching this tenant's weights may evict
        # another's; a miss (weights not resident) charges the swap-in.
        miss = self.cache.access(i, self._prefix_bytes_of(job), self.now)
        service = job.tpu_service + (self._t_load_of(job) if miss else 0.0)
        self.tpu_busy += service
        if job.record:
            self.tpu_requests[i] += 1
            if miss:
                self.misses[i] += 1
        self._push(self.now + service, _TPU_DONE, job)

    def _prefix_bytes_of(self, job: _Job) -> int:
        return self.profiles[job.req.model_idx].prefix_weight_bytes(job.p)

    def _t_load_of(self, job: _Job) -> float:
        return load_time(self.profiles[job.req.model_idx], job.p, self.platform)

    def _on_tpu_done(self, job: _Job) -> None:
        self._tpu_job = None
        if job.p < self.profiles[job.req.model_idx].num_partition_points:
            self._push(self.now + job.out_xfer, _CPU_ENQUEUE, job)
        else:
            self._complete(job)
        self._start_tpu()

    def _on_cpu_enqueue(self, job: _Job) -> None:
        job.enq, job.seq = self.now, next(self._seq)
        self._cpu_queues[job.req.model_idx].append(job)
        self._start_cpu(job.req.model_idx)

    def _start_cpu(self, i: int) -> None:
        while self._cpu_queues[i] and self._cpu_busy[i] < self._cpu_servers(i):
            job = self._cpu_queues[i].popleft()
            self._cpu_busy[i] += 1
            self._push(self.now + job.cpu_service, _CPU_DONE, job)

    def _on_cpu_done(self, job: _Job) -> None:
        i = job.req.model_idx
        self._cpu_busy[i] -= 1
        self._complete(job)
        self._start_cpu(i)

    def _complete(self, job: _Job) -> None:
        self.last_completion = max(self.last_completion, self.now)
        if job.record:
            i = job.req.model_idx
            self.latencies[i].append(self.now - job.req.arrival)
            self.arrivals[i].append(job.req.arrival)
