"""Event-driven ground-truth simulator of the SwapLess runtime.

The sequential ``RuntimeSimulator`` stepper shares most of its structure with
the analytic model it is supposed to validate (it literally walks requests
through ``max(t, server_free)`` recurrences).  This module is the
independent check: a classic discrete-event simulation with

* an event heap ordered by (time, insertion sequence),
* one TPU server with explicit swap state -- parameter residency tracked by
  the model-granularity LRU ``SramCache``, the inter-model swap-in cost
  ``T_load`` charged at service start when the tenant switch evicted the
  weights, intra-model swap streaming folded into the bound service time,
* ``k_i`` CPU-core servers per model under the active ``Plan``,
* global FCFS in front of the TPU and a per-tenant FIFO in front of each
  CPU pool,
* mid-flight plan changes: ``set_plan`` re-routes *future* arrivals while
  queued and in-service work bound under the old plan drains unchanged.

The DES and the stepper implement the same system contract (same
``Request`` traces in, same ``SimResult`` out) with disjoint mechanics, so
agreement between them -- and between either and Eq. 1-5 -- is evidence,
not tautology.  ``tests/test_des.py`` pins the correspondence:
deterministic single-tenant latencies match the closed-form static terms to
float round-off, and seeded Poisson waits converge to ``mg1_wait``.

Hot-loop notes (the optimization pass measured by
``benchmarks/sim_throughput.py`` and pinned bit-identical to the frozen
pre-optimization snapshot in ``benchmarks/des_baseline.py``):

* swap costs (``prefix_weight_bytes`` / ``load_time``) bind onto the job at
  arrival instead of being recomputed from the profile on every TPU start;
* jobs are plain tuples (see the ``_J_*`` field map): with routing bound at
  arrival no field ever mutates, and tuple construction/indexing beats a
  record class in the loop that runs once per event;
* events carry their *handler* (bound method) instead of a kind tag --
  the (time, seq) prefix alone orders the heap, so the handler slot is
  never compared;
* the TPU ready queue is a single global FIFO deque.  Jobs enter it in
  nondecreasing (event time, event sequence) order -- the heap pops events
  in that order and the enqueue stamp a job would carry is assigned at that
  very moment -- so popping the front IS the "earliest-enqueued head across
  per-tenant FIFOs" selection the baseline computed with an O(n_tenants)
  scan, for exactly the same job;
* ``offer`` inlines the arrival: ``advance_to(arrival)`` has already
  drained every event at or before that instant, so dispatching the arrival
  directly equals pushing-then-immediately-popping it (one heap round-trip
  saved per request).
"""
from __future__ import annotations

import collections
import heapq
import itertools
from typing import Sequence

from repro.core.planner import (
    ModelProfile,
    Plan,
    route_tables,
)
from repro.hw.specs import Platform
from repro.serving.cache import SramCache
from repro.serving.faults import FaultStats, as_view
from repro.serving.result import SimResult
from repro.serving.scheduling import WeightedFairDiscipline, make_discipline
from repro.serving.workload import Request

_heappush = heapq.heappush
_heappop = heapq.heappop

# _Job tuple field map: one request in flight, route bound at arrival time.
# (Plain tuple, not a class: nothing mutates after binding, and the loop
# that builds/reads one runs once per event.)
_J_MODEL = 0        # model index
_J_ARR = 1          # arrival stamp (for latency + the arrivals timeline)
_J_RECORD = 2       # include in reported statistics?
_J_TPU_S = 3        # prefix compute + intra-swap stream (jitter-scaled)
_J_CPU_S = 4        # 1-core suffix time (jitter-scaled)
_J_OUT_X = 5        # boundary activation transfer (0 when no suffix)
_J_PBYTES = 6       # resident-footprint bytes under the bound route
_J_TLOAD = 7        # swap-in cost charged when the prefix was evicted
_J_SUFFIX = 8       # p < P under the bound route (has a CPU suffix)


class DiscreteEventSimulator:
    """Event-heap serving simulator; drop-in backend for ``simulate`` and
    ``run_adaptive`` (same driver surface as ``RuntimeSimulator``:
    ``offer`` / ``advance_to`` / ``set_plan`` / ``drain`` / ``result``)."""

    def __init__(
        self,
        profiles: Sequence[ModelProfile],
        plan: Plan,
        platform: Platform,
        *,
        faults=None,
    ):
        self.profiles = list(profiles)
        self.platform = platform
        self.n = len(self.profiles)
        self.cache = SramCache(platform.sram_bytes)
        self.now = 0.0
        self.tpu_busy = 0.0
        self.last_completion = 0.0
        self.latencies: list[list[float]] = [[] for _ in range(self.n)]
        self.arrivals: list[list[float]] = [[] for _ in range(self.n)]
        self.misses = [0] * self.n
        self.tpu_requests = [0] * self.n
        self._points = [f.num_partition_points for f in self.profiles]
        self._heap: list[tuple] = []
        self._seq = itertools.count()
        self._tpu_ready: collections.deque[tuple] = collections.deque()
        self._tpu_job: tuple | None = None
        self._cpu_queues: list[collections.deque[tuple]] = [
            collections.deque() for _ in range(self.n)
        ]
        self._cpu_busy = [0] * self.n
        self._plan: Plan | None = None
        # TPU service discipline (repro.serving.scheduling).  ``None`` is the
        # native FCFS deque hot path, bitwise-pinned to the PR-3 baseline;
        # a non-default Plan.discipline installs a queue object instead.
        self._disc = None
        self._wf: WeightedFairDiscipline | None = None
        self._run_model: int | None = None
        self._run_len = 0
        # Fault injection (serving.faults): mirrors the stepper's gates at
        # the same event instants with the same float ops, so DES == stepper
        # stays elementwise under any schedule.  A trivial view normalizes
        # to None so faults=None (and an empty schedule) take the exact
        # pre-fault event handlers.
        fv = as_view(faults)
        self._faults = fv if fv is not None and fv.has_faults else None
        self._fault_lost = [0] * self.n
        self._fault_requeued = [0] * self.n
        self.set_plan(plan, now=0.0)

    # -- plan management ----------------------------------------------------
    def set_plan(self, plan: Plan, now: float) -> None:
        """Switch to a new (P, K) configuration at simulated time ``now``.

        Pending events up to ``now`` are processed first, so the switch is
        causally ordered against the workload.  Routing is bound per job at
        its arrival: jobs already past arrival keep their old partition and
        service times (a mid-flight request is not re-split), while new
        arrivals see the new plan.  CPU pools resize in place -- running
        suffixes finish on their core; a pool shrunk below its busy count
        just stops admitting new work until it drains (the paper preloads
        candidate partitions, so the switch itself is free).
        """
        if len(plan.partition) != self.n:
            raise ValueError("plan size mismatch")
        self.advance_to(now)
        old_spec = self._plan.discipline if self._plan is not None else None
        if plan.discipline != old_spec:
            # Discipline switch: queued jobs migrate between queue
            # representations in global enqueue order.  Jobs coming off the
            # native FCFS deque carry no enqueue stamps, so they re-enter
            # stamped at the switch instant (staleness clocks restart; the
            # relative order -- the thing correctness rests on -- is exact).
            new = make_discipline(plan.discipline, self.n)
            if new is not None:
                for job in self._tpu_ready:
                    new.push(job, now)
                self._tpu_ready.clear()
            if self._disc is not None:
                for _, t, job in self._disc.drain_rows():
                    if new is None:
                        self._tpu_ready.append(job)
                    else:
                        new.push(job, t)
            self._disc = new
            self._wf = new if isinstance(new, WeightedFairDiscipline) else None
            # Run state is only maintained under a discipline; restart it
            # at the switch (same legitimacy class as the staleness-clock
            # restart above).
            self._run_model = None
            self._run_len = 0
        if self._disc is not None and self._faults is not None:
            # Same refusal as the stepper: fault gates are specified on the
            # FCFS service order only.
            raise ValueError(
                "fault injection supports the FCFS discipline only"
            )
        self._plan = plan
        rt = route_tables(self.profiles, plan, self.platform)
        self._prefix_bytes = rt.prefix_bytes
        self._s_tpu = rt.s_tpu
        self._t_load = rt.t_load
        self._s_cpu = rt.s_cpu
        self._in_xfer = rt.in_xfer
        self._out_xfer = rt.out_xfer
        # Suffix-bearing jobs always have somewhere to run, even if a plan
        # change dropped the model's allocation to 0 cores mid-flight (the
        # stepper sizes its pools max(k, 1) for the same reason).
        self._k_eff = [max(k, 1) for k in plan.cores]
        # A grown pool can admit queued work immediately.
        for i in range(self.n):
            self._start_cpu(i)

    @property
    def plan(self) -> Plan:
        assert self._plan is not None
        return self._plan

    # -- driver surface -----------------------------------------------------
    def submit(self, req: Request, *, record: bool = True) -> None:
        """Schedule one request; its route binds when the arrival fires."""
        if not 0 <= req.model_idx < self.n:
            raise ValueError(f"model_idx {req.model_idx} out of range")
        if req.arrival < self.now:
            raise ValueError(
                f"arrival {req.arrival} is in the simulator's past ({self.now})"
            )
        _heappush(
            self._heap,
            (req.arrival, next(self._seq), self._on_arrival, (req, record)),
        )

    def offer(self, req: Request, *, record: bool = True) -> None:
        """Advance to the request's arrival, then process it (the shared
        in-order driver contract of ``simulate``/``run_adaptive``).

        ``advance_to`` drains every event stamped at or before the arrival,
        so handling the arrival inline is event-order-identical to
        ``submit`` + another advance -- minus a heap round-trip.
        """
        if req.arrival < self.now:
            raise ValueError(
                f"arrival {req.arrival} is in the simulator's past ({self.now})"
            )
        if not 0 <= req.model_idx < self.n:
            raise ValueError(f"model_idx {req.model_idx} out of range")
        self.advance_to(req.arrival)
        self._on_arrival((req, record))

    def advance_to(self, t: float) -> None:
        """Process every event with timestamp <= ``t``; clock ends at ``t``."""
        if t < self.now:
            raise ValueError(f"cannot rewind the clock from {self.now} to {t}")
        heap = self._heap
        if heap and heap[0][0] <= t:
            pop = _heappop
            while heap and heap[0][0] <= t:
                et, _, handler, payload = pop(heap)
                if et > self.now:
                    self.now = et
                handler(payload)
        self.now = t

    def drain(self) -> float:
        """Run the event loop dry; returns the last completion time."""
        heap, pop = self._heap, _heappop
        while heap:
            et, _, handler, payload = pop(heap)
            if et > self.now:
                self.now = et
            handler(payload)
        return self.last_completion

    def result(self, duration: float) -> SimResult:
        fault = None
        if self._faults is not None:
            fault = FaultStats(
                lost=list(self._fault_lost),
                requeued=list(self._fault_requeued),
                down_windows=self._faults.down_windows,
                degraded_windows=self._faults.degraded_windows,
            )
        return SimResult(
            latencies=self.latencies,
            arrivals=self.arrivals,
            tpu_busy=self.tpu_busy,
            duration=duration,
            misses=self.misses,
            tpu_requests=self.tpu_requests,
            fault=fault,
        )

    # -- columnar driver ----------------------------------------------------
    def offer_trace(self, trace, *, record_from: float = 0.0) -> None:
        """Offer a whole arrival-sorted columnar ``Trace`` under a static
        plan: semantically ``for r in trace: self.offer(r, record=...)``,
        with the per-request ``offer``/arrival plumbing inlined and every
        plan-derived table bound to a local (valid because the plan cannot
        change mid-call -- ``run_adaptive`` drives plan changes through the
        scalar ``offer``).  Event processing order -- hence every observable
        -- is identical to the scalar driver.
        """
        mi_col = trace.model_idx
        if mi_col.size == 0:
            return
        if self._faults is not None:
            # The inlined loop binds no-fault mechanics to locals; fault
            # gates live in the scalar handlers, so fall back to them.
            for r in trace:
                self.offer(r, record=r.arrival >= record_from)
            return
        if mi_col.min() < 0 or mi_col.max() >= self.n:
            raise ValueError("model_idx out of range in trace")
        if not trace.is_sorted:
            # The scalar offer() raises per request on a clock rewind; the
            # inlined driver must surface the same misuse, not corrupt the
            # event order silently.  O(1) for generator-produced traces.
            raise ValueError("offer_trace requires an arrival-sorted Trace")
        if trace.arrival[0] < self.now:
            raise ValueError(
                f"arrival {trace.arrival[0]} is in the simulator's past "
                f"({self.now})"
            )
        heap, pop = self._heap, _heappop
        push, seq = _heappush, self._seq
        s_tpu, s_cpu = self._s_tpu, self._s_cpu
        in_xfer, out_xfer = self._in_xfer, self._out_xfer
        pbytes, t_load = self._prefix_bytes, self._t_load
        points, partition = self._points, self._plan.partition
        enq = self._on_tpu_enqueue
        for i, a, scale in zip(
            mi_col.tolist(),
            trace.arrival.tolist(),
            trace.service_scale.tolist(),
        ):
            # Inlined advance_to(a) (sorted trace: the clock never rewinds).
            while heap and heap[0][0] <= a:
                et, _, handler, payload = pop(heap)
                if et > self.now:
                    self.now = et
                handler(payload)
            self.now = a
            p = partition[i]
            suffix = p < points[i]
            job = (
                i,
                a,
                a >= record_from,
                s_tpu[i] * scale,
                s_cpu[i] * scale,
                out_xfer[i] if 0 < p and suffix else 0.0,
                pbytes[i],
                t_load[i],
                suffix,
            )
            if p > 0:
                push(heap, (a + in_xfer[i], next(seq), enq, job))
            else:
                self._on_cpu_enqueue(job)

    # -- event machinery ----------------------------------------------------
    def _on_arrival(self, payload) -> None:
        req, record = payload
        i = req.model_idx
        if self._faults is not None:
            self._on_arrival_faulted(req, record)
            return
        p = self._plan.partition[i]
        scale = req.service_scale
        suffix = p < self._points[i]
        job = (
            i,
            req.arrival,
            record,
            self._s_tpu[i] * scale,
            self._s_cpu[i] * scale,
            self._out_xfer[i] if 0 < p and suffix else 0.0,
            self._prefix_bytes[i],
            self._t_load[i],
            suffix,
        )
        if p > 0:
            # Input transfer is a pure delay: it occupies neither server
            # (the additive d/B term of Eq. 4).
            _heappush(
                self._heap,
                (
                    self.now + self._in_xfer[i],
                    next(self._seq),
                    self._on_tpu_enqueue,
                    job,
                ),
            )
        else:
            self._on_cpu_enqueue(job)

    def _on_arrival_faulted(self, req: Request, record: bool) -> None:
        """Arrival with the ``serving.faults`` dropout gate applied.

        Lost policy drops at the arrival instant; requeue admits the
        request at the recovery instant (every same-route request arriving
        inside the same chained outage defers to the *same* instant, so
        queue entry keeps arrival order -- the property the stepper's
        in-arrival-order scalar loop gives for free).  The input transfer
        runs at the swap factor in effect when it begins.
        """
        fv = self._faults
        i = req.model_idx
        t = self.now
        if fv.is_down(t):
            if fv.lost:
                if record:
                    self._fault_lost[i] += 1
                return
            t = fv.down_until(t)
            if record:
                self._fault_requeued[i] += 1
        p = self._plan.partition[i]
        scale = req.service_scale
        suffix = p < self._points[i]
        job = (
            i,
            req.arrival,
            record,
            self._s_tpu[i] * scale,
            self._s_cpu[i] * scale,
            self._out_xfer[i] if 0 < p and suffix else 0.0,
            self._prefix_bytes[i],
            self._t_load[i],
            suffix,
        )
        if p > 0:
            _heappush(
                self._heap,
                (
                    t + self._in_xfer[i] / fv.swap_factor(t),
                    next(self._seq),
                    self._on_tpu_enqueue,
                    job,
                ),
            )
        elif t > self.now:
            _heappush(
                self._heap, (t, next(self._seq), self._on_cpu_enqueue, job)
            )
        else:
            self._on_cpu_enqueue(job)

    def _on_tpu_enqueue(self, job: tuple) -> None:
        # Ready jobs are appended in nondecreasing (event time, sequence)
        # order -- the heap's pop order -- so the deque front is always the
        # global-FCFS earliest-enqueued job.  Whenever the server is idle
        # the ready queue is empty (an idle server always drained it), so
        # starting the arriving job directly equals append-then-popleft.
        # An idle server grabs the arriving job no matter the discipline
        # (all disciplines are work-conserving); a busy one parks it in the
        # discipline queue, which for FCFS is the native deque.
        if self._tpu_job is None:
            self._begin_tpu(job)
        elif self._disc is None:
            self._tpu_ready.append(job)
        else:
            self._disc.push(job, self.now)

    def _begin_tpu(self, job: tuple) -> None:
        if self._faults is not None:
            self._begin_tpu_faulted(job)
            return
        self._tpu_job = job
        i = job[_J_MODEL]
        # Same-tenant run state: what swap_batch amortization extends.
        # Tracked only under a discipline -- the native FCFS hot loop stays
        # op-for-op the PR-3 baseline; a mid-flight switch *into* a
        # discipline starts with a cleared run (set_plan resets it), which
        # costs at most one head-ordered first decision.
        if self._disc is not None:
            if i == self._run_model:
                self._run_len += 1
            else:
                self._run_model = i
                self._run_len = 1
        # Swap state transition: touching this tenant's weights may evict
        # another's; a miss (weights not resident) charges the swap-in.
        miss = self.cache.access(i, job[_J_PBYTES], self.now)
        service = job[_J_TPU_S] + (job[_J_TLOAD] if miss else 0.0)
        self.tpu_busy += service
        if self._wf is not None:
            self._wf.charge(i, service)
        if job[_J_RECORD]:
            self.tpu_requests[i] += 1
            if miss:
                self.misses[i] += 1
        _heappush(
            self._heap,
            (self.now + service, next(self._seq), self._on_tpu_done, job),
        )

    def _begin_tpu_faulted(self, job: tuple) -> None:
        """TPU service start with fault gates: the dropout gate fires at
        the would-be start instant (lost drops and lets the server take the
        next ready job at the same instant; requeue pushes the start to the
        recovery instant, occupying the server through the stretched
        completion -- exactly the stepper's ``tpu_free`` evolution), and
        throttle/swap factors bind at the actual start."""
        fv = self._faults
        while True:
            start = self.now
            if fv.is_down(start):
                if fv.lost:
                    if job[_J_RECORD]:
                        self._fault_lost[job[_J_MODEL]] += 1
                    if self._tpu_ready:
                        job = self._tpu_ready.popleft()
                        continue
                    self._tpu_job = None
                    return
                start = fv.down_until(start)
                if job[_J_RECORD]:
                    self._fault_requeued[job[_J_MODEL]] += 1
            self._tpu_job = job
            i = job[_J_MODEL]
            miss = self.cache.access(i, job[_J_PBYTES], start)
            service = job[_J_TPU_S] / fv.tpu_factor(start)
            if miss:
                service += job[_J_TLOAD] / fv.swap_factor(start)
            self.tpu_busy += service
            if job[_J_RECORD]:
                self.tpu_requests[i] += 1
                if miss:
                    self.misses[i] += 1
            _heappush(
                self._heap,
                (start + service, next(self._seq), self._on_tpu_done, job),
            )
            return

    def _on_tpu_done(self, job: tuple) -> None:
        now = self.now
        if self._faults is not None:
            self._on_tpu_done_faulted(job, now)
            return
        if job[_J_SUFFIX]:
            _heappush(
                self._heap,
                (now + job[_J_OUT_X], next(self._seq), self._on_cpu_enqueue, job),
            )
        else:
            # Complete (inlined): full-TPU route ends here.
            if now > self.last_completion:
                self.last_completion = now
            if job[_J_RECORD]:
                i = job[_J_MODEL]
                self.latencies[i].append(now - job[_J_ARR])
                self.arrivals[i].append(job[_J_ARR])
        if self._disc is not None:
            # Discipline-managed queue: the selection hook replaces the
            # baseline's FCFS popleft (this is the one decision point a
            # service discipline owns).
            nxt = self._disc.pop(now, self._run_model, self._run_len)
            if nxt is None:
                self._tpu_job = None
            else:
                self._begin_tpu(nxt)
            return
        ready = self._tpu_ready
        if ready:
            # _begin_tpu, inlined at the hottest call site (the back-to-back
            # service chain of a busy server).
            nxt = ready.popleft()
            self._tpu_job = nxt
            i = nxt[_J_MODEL]
            miss = self.cache.access(i, nxt[_J_PBYTES], now)
            service = nxt[_J_TPU_S] + (nxt[_J_TLOAD] if miss else 0.0)
            self.tpu_busy += service
            if nxt[_J_RECORD]:
                self.tpu_requests[i] += 1
                if miss:
                    self.misses[i] += 1
            _heappush(
                self._heap,
                (now + service, next(self._seq), self._on_tpu_done, nxt),
            )
        else:
            self._tpu_job = None

    def _on_tpu_done_faulted(self, job: tuple, now: float) -> None:
        fv = self._faults
        if job[_J_SUFFIX]:
            _heappush(
                self._heap,
                (
                    now + job[_J_OUT_X] / fv.swap_factor(now),
                    next(self._seq),
                    self._on_cpu_enqueue,
                    job,
                ),
            )
        else:
            if now > self.last_completion:
                self.last_completion = now
            if job[_J_RECORD]:
                i = job[_J_MODEL]
                self.latencies[i].append(now - job[_J_ARR])
                self.arrivals[i].append(job[_J_ARR])
        ready = self._tpu_ready
        if ready:
            self._begin_tpu_faulted(ready.popleft())
        else:
            self._tpu_job = None

    def _on_cpu_enqueue(self, job: tuple) -> None:
        i = job[_J_MODEL]
        self._cpu_queues[i].append(job)
        self._start_cpu(i)

    def _start_cpu(self, i: int) -> None:
        if self._faults is not None:
            self._start_cpu_faulted(i)
            return
        queue = self._cpu_queues[i]
        while queue and self._cpu_busy[i] < self._k_eff[i]:
            job = queue.popleft()
            self._cpu_busy[i] += 1
            _heappush(
                self._heap,
                (
                    self.now + job[_J_CPU_S],
                    next(self._seq),
                    self._on_cpu_done,
                    job,
                ),
            )

    def _start_cpu_faulted(self, i: int) -> None:
        """CPU admission with fault gates: lost drops at the would-be start
        (the worker stays free); requeue admits the worker with a start
        deferred to the recovery instant (busy through the stretched end,
        matching the stepper's pool-heap evolution); the suffix runs at the
        CPU factor in effect at its actual start."""
        fv = self._faults
        queue = self._cpu_queues[i]
        while queue and self._cpu_busy[i] < self._k_eff[i]:
            job = queue.popleft()
            start = self.now
            if fv.is_down(start):
                if fv.lost:
                    if job[_J_RECORD]:
                        self._fault_lost[i] += 1
                    continue
                start = fv.down_until(start)
                if job[_J_RECORD]:
                    self._fault_requeued[i] += 1
            self._cpu_busy[i] += 1
            _heappush(
                self._heap,
                (
                    start + job[_J_CPU_S] / fv.cpu_factor(start),
                    next(self._seq),
                    self._on_cpu_done,
                    job,
                ),
            )

    def _on_cpu_done(self, job: tuple) -> None:
        i = job[_J_MODEL]
        self._cpu_busy[i] -= 1
        now = self.now
        if now > self.last_completion:
            self.last_completion = now
        if job[_J_RECORD]:
            self.latencies[i].append(now - job[_J_ARR])
            self.arrivals[i].append(job[_J_ARR])
        self._start_cpu(i)
