"""Fault injection: timed device faults for simulators and controllers.

The ROADMAP's "scenario diversity: faults" item: every simulated device so
far was immortal -- no dropout, no thermal throttling, no swap-channel
degradation -- while the paper's edge deployments are exactly the setting
where those happen (Subedi et al., arxiv 2107.12486, measure the
degradation axis for concurrent edge inference; Liang et al., arxiv
2003.12488, motivate pipelines that must keep serving through component
failure).  This module is the one definition of what a fault *is*; the
simulators consume it through a per-device ``DeviceFaultView`` and the
adaptive controllers react to its observable consequences.

Three event kinds, all windows ``[start, end)``:

* ``dropout`` -- the device is gone: requests newly arriving, and queued
  requests whose service would begin inside the window, are either
  *requeued* (service pushed to the recovery instant; the recorded latency
  includes the outage) or *lost* (dropped and counted), per the schedule's
  ``dropout_policy``.  Service already running when the window opens
  completes -- the outage is non-preemptive at request granularity, the
  same granularity every other mechanism in the repo works at.
* ``throttle`` -- thermal throttling as time-varying speed: TPU/CPU service
  times divide by ``tpu_factor`` / ``cpu_factor`` (a factor of 0.25 means
  the device runs at quarter speed).  The factor is looked up at *service
  start* and applied to the whole service -- the same bind-at-start
  discipline routes already follow (a request is not re-split mid-flight).
* ``swap_degrade`` -- the swap channel (inter-model ``T_load`` swap-ins and
  the input/boundary transfers of Eq. 4) runs at ``swap_factor`` of its
  nominal bandwidth, looked up when each transfer begins.

Semantics are defined once, here, so the DES (event hooks) and the stepper
(time-varying service scaling in the scalar recurrence) agree *exactly*:
both look factors up at identical instants and apply identical float ops,
so DES == stepper stays elementwise under any schedule
(``tests/test_faults.py``).  Injection is strictly opt-in: ``faults=None``
-- the default everywhere -- leaves every pre-fault code path untouched,
bitwise (standing invariant, self-checked by ``benchmarks/faults.py``).

``FaultSchedule`` is validated on construction and JSON-round-trippable
bit-exactly (floats serialize via ``repr``, like ``trace_to_json``), so a
fault scenario replays deterministically.
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import math
from typing import Iterable, Sequence

__all__ = [
    "DeviceFaultView",
    "FaultEvent",
    "FaultSchedule",
    "FaultStats",
    "LatencyWindowTracker",
    "merge_fault_stats",
]

_KINDS = ("dropout", "throttle", "swap_degrade")
_POLICIES = ("requeue", "lost")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One timed fault on one device; window is ``[start, end)``.

    ``end`` may be ``math.inf`` (a permanent fault).  Factors are the
    *fraction of nominal speed* in effect during the window, in ``(0, 1]``:
    a throttle that halves the TPU is ``tpu_factor=0.5``.  Factors above 1
    are rejected -- faults degrade; a >1 "factor" is almost certainly a
    slowdown multiplier passed where a speed fraction belongs.
    """

    kind: str
    device: int
    start: float
    end: float
    tpu_factor: float = 1.0
    cpu_factor: float = 1.0
    swap_factor: float = 1.0

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (want one of {_KINDS})"
            )
        if not isinstance(self.device, int) or self.device < 0:
            raise ValueError(f"device must be a non-negative int, got {self.device!r}")
        if not (math.isfinite(self.start) and self.start >= 0):
            raise ValueError(f"start must be finite and >= 0, got {self.start!r}")
        if not self.end > self.start:
            raise ValueError(
                f"end ({self.end!r}) must be > start ({self.start!r})"
            )
        for name in ("tpu_factor", "cpu_factor", "swap_factor"):
            f = getattr(self, name)
            if not (0.0 < f <= 1.0):
                raise ValueError(
                    f"{name} must be in (0, 1] (fraction of nominal speed), "
                    f"got {f!r}"
                )
        if self.kind == "dropout" and (
            self.tpu_factor != 1.0
            or self.cpu_factor != 1.0
            or self.swap_factor != 1.0
        ):
            raise ValueError("dropout events carry no speed factors")
        if self.kind == "throttle" and self.swap_factor != 1.0:
            raise ValueError(
                "throttle events scale TPU/CPU speed; use swap_degrade for "
                "the swap channel"
            )
        if self.kind == "swap_degrade" and (
            self.tpu_factor != 1.0 or self.cpu_factor != 1.0
        ):
            raise ValueError("swap_degrade events carry only swap_factor")

    def as_dict(self) -> dict:
        d = {
            "kind": self.kind,
            "device": self.device,
            "start": self.start,
            "end": self.end,
        }
        if self.kind == "throttle":
            d["tpu_factor"] = self.tpu_factor
            d["cpu_factor"] = self.cpu_factor
        elif self.kind == "swap_degrade":
            d["swap_factor"] = self.swap_factor
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        return cls(
            kind=str(d["kind"]),
            device=int(d["device"]),
            start=float(d["start"]),
            end=float(d["end"]),
            tpu_factor=float(d.get("tpu_factor", 1.0)),
            cpu_factor=float(d.get("cpu_factor", 1.0)),
            swap_factor=float(d.get("swap_factor", 1.0)),
        )


def _check_disjoint(events: Sequence[FaultEvent], kind: str, device: int) -> None:
    wins = sorted(
        (e.start, e.end) for e in events if e.kind == kind and e.device == device
    )
    for (s0, e0), (s1, _) in zip(wins, wins[1:]):
        if s1 < e0:
            raise ValueError(
                f"overlapping {kind} windows on device {device}: "
                f"[{s0}, {e0}) and [{s1}, ...) -- same-kind windows on one "
                "device must be disjoint (adjacent is fine)"
            )


class FaultSchedule:
    """A validated set of timed fault events across a device fleet.

    Events are canonicalized to ``(start, device, kind)`` order, so two
    schedules built from the same events in any order compare (and
    serialize) identically.  Same-kind windows on one device must be
    disjoint; different kinds may overlap (a throttled device may also
    drop).  ``dropout_policy`` is schedule-wide: ``"requeue"`` (default)
    defers affected requests to the recovery instant, ``"lost"`` drops and
    counts them.

    ``validate(n_devices)`` additionally rejects events naming a device
    outside the fleet -- simulators and ``simulate_fleet`` call it before
    injecting.  ``view(d)`` projects the schedule onto one device as the
    ``DeviceFaultView`` the simulators actually consume.
    """

    def __init__(
        self,
        events: Iterable[FaultEvent] = (),
        *,
        dropout_policy: str = "requeue",
    ):
        if dropout_policy not in _POLICIES:
            raise ValueError(
                f"unknown dropout_policy {dropout_policy!r} "
                f"(want one of {_POLICIES})"
            )
        evs = []
        for e in events:
            if not isinstance(e, FaultEvent):
                raise TypeError(f"expected FaultEvent, got {type(e).__name__}")
            evs.append(e)
        self.events: tuple[FaultEvent, ...] = tuple(
            sorted(evs, key=lambda e: (e.start, e.device, e.kind, e.end))
        )
        self.dropout_policy = dropout_policy
        for dev in {e.device for e in self.events}:
            for kind in _KINDS:
                _check_disjoint(self.events, kind, dev)

    # -- identity ------------------------------------------------------------
    def __eq__(self, other: object) -> bool:
        return (
            isinstance(other, FaultSchedule)
            and self.events == other.events
            and self.dropout_policy == other.dropout_policy
        )

    def __hash__(self) -> int:
        return hash((self.events, self.dropout_policy))

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        return (
            f"FaultSchedule({list(self.events)!r}, "
            f"dropout_policy={self.dropout_policy!r})"
        )

    @property
    def max_device(self) -> int:
        """Largest device index named by any event (-1 when empty)."""
        return max((e.device for e in self.events), default=-1)

    def validate(self, n_devices: int) -> "FaultSchedule":
        """Reject events addressing devices outside ``[0, n_devices)``."""
        for e in self.events:
            if e.device >= n_devices:
                raise ValueError(
                    f"fault event names device {e.device}, but the fleet has "
                    f"{n_devices} device(s)"
                )
        return self

    # -- serialization (bit-exact: floats round-trip via repr) ---------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "format": "repro-fault-schedule-v1",
                "dropout_policy": self.dropout_policy,
                "events": [e.as_dict() for e in self.events],
            }
        )

    @classmethod
    def from_json(cls, payload: str) -> "FaultSchedule":
        d = json.loads(payload)
        if d.get("format") != "repro-fault-schedule-v1":
            raise ValueError(
                f"not a fault-schedule payload (format={d.get('format')!r})"
            )
        return cls(
            (FaultEvent.from_dict(e) for e in d["events"]),
            dropout_policy=str(d["dropout_policy"]),
        )

    # -- projection ----------------------------------------------------------
    def view(self, device: int) -> "DeviceFaultView":
        """This schedule as seen from one device (what simulators consume)."""
        mine = [e for e in self.events if e.device == device]
        return DeviceFaultView(
            down=tuple(
                (e.start, e.end) for e in mine if e.kind == "dropout"
            ),
            tpu=tuple(
                (e.start, e.end, e.tpu_factor)
                for e in mine
                if e.kind == "throttle"
            ),
            cpu=tuple(
                (e.start, e.end, e.cpu_factor)
                for e in mine
                if e.kind == "throttle"
            ),
            swap=tuple(
                (e.start, e.end, e.swap_factor)
                for e in mine
                if e.kind == "swap_degrade"
            ),
            lost=self.dropout_policy == "lost",
        )

    def down_windows(self, device: int) -> tuple[tuple[float, float], ...]:
        return tuple(
            (e.start, e.end)
            for e in self.events
            if e.device == device and e.kind == "dropout"
        )


class _StepFactor:
    """A piecewise-constant speed factor: 1.0 outside its (disjoint,
    start-sorted) windows, the window's factor inside ``[start, end)``."""

    __slots__ = ("_starts", "_ends", "_factors", "trivial")

    def __init__(self, windows: Sequence[tuple[float, float, float]]):
        wins = sorted(windows)
        self._starts = [w[0] for w in wins]
        self._ends = [w[1] for w in wins]
        self._factors = [w[2] for w in wins]
        self.trivial = all(f == 1.0 for f in self._factors)

    def at(self, t: float) -> float:
        j = bisect.bisect_right(self._starts, t) - 1
        if j >= 0 and t < self._ends[j]:
            return self._factors[j]
        return 1.0


class DeviceFaultView:
    """One device's projection of a ``FaultSchedule``.

    The only fault surface the simulators touch: ``is_down`` /
    ``down_until`` implement the dropout gate, the three factor lookups
    implement throttling and swap degradation.  All lookups are
    O(log windows) bisects on static arrays -- the fault path is scalar by
    design (a schedule forces the per-request reference loop), so the
    lookup cost is immaterial next to the per-request Python overhead.
    """

    __slots__ = ("down_windows", "_down_starts", "_down_ends",
                 "_tpu", "_cpu", "_swap", "lost")

    def __init__(
        self,
        *,
        down: tuple[tuple[float, float], ...] = (),
        tpu: tuple[tuple[float, float, float], ...] = (),
        cpu: tuple[tuple[float, float, float], ...] = (),
        swap: tuple[tuple[float, float, float], ...] = (),
        lost: bool = False,
    ):
        self.down_windows = tuple(sorted(down))
        self._down_starts = [w[0] for w in self.down_windows]
        self._down_ends = [w[1] for w in self.down_windows]
        self._tpu = _StepFactor(tpu)
        self._cpu = _StepFactor(cpu)
        self._swap = _StepFactor(swap)
        self.lost = lost

    # -- dropout gate --------------------------------------------------------
    def is_down(self, t: float) -> bool:
        j = bisect.bisect_right(self._down_starts, t) - 1
        return j >= 0 and t < self._down_ends[j]

    def down_until(self, t: float) -> float:
        """First non-down instant at or after ``t`` (chained adjacent
        windows are pushed through in one call)."""
        while True:
            j = bisect.bisect_right(self._down_starts, t) - 1
            if j < 0 or t >= self._down_ends[j]:
                return t
            t = self._down_ends[j]

    # -- speed factors (looked up at service/transfer start) -----------------
    def tpu_factor(self, t: float) -> float:
        return self._tpu.at(t)

    def cpu_factor(self, t: float) -> float:
        return self._cpu.at(t)

    def swap_factor(self, t: float) -> float:
        return self._swap.at(t)

    @property
    def degraded_windows(self) -> tuple[tuple[float, float], ...]:
        """Every window where the device is impaired (down, throttled, or
        swap-degraded) -- the spans ``SimResult.degraded_window_mean``
        filters arrivals by."""
        spans = list(self.down_windows)
        for sf in (self._tpu, self._cpu, self._swap):
            spans.extend(
                (s, e)
                for s, e, f in zip(sf._starts, sf._ends, sf._factors)
                if f != 1.0
            )
        return tuple(sorted(set(spans)))

    @property
    def has_faults(self) -> bool:
        return bool(
            self.down_windows
            or not self._tpu.trivial
            or not self._cpu.trivial
            or not self._swap.trivial
        )


def as_view(faults: "FaultSchedule | DeviceFaultView | None"):
    """Normalize a single-device ``faults=`` argument to a view (or None).

    A ``FaultSchedule`` handed to a single-device simulator must address
    device 0 only (``validate(1)``); fleet callers project per device with
    ``schedule.view(d)`` themselves.
    """
    if faults is None or isinstance(faults, DeviceFaultView):
        return faults
    if isinstance(faults, FaultSchedule):
        return faults.validate(1).view(0)
    raise TypeError(
        f"faults must be a FaultSchedule or DeviceFaultView, "
        f"got {type(faults).__name__}"
    )


# -- observation record -------------------------------------------------------

@dataclasses.dataclass
class FaultStats:
    """Per-simulator fault bookkeeping, attached to ``SimResult.fault``.

    ``lost[i]`` / ``requeued[i]`` count per-model recorded requests dropped
    by the lost policy / deferral events under the requeue policy (a
    request deferred at both the arrival gate and the service gate counts
    one deferral each).  Windows are carried so recovery metrics
    (``SimResult.recovery_times`` / ``degraded_window_mean``) resolve
    post-hoc from the recorded arrival/latency columns -- the simulators
    track nothing but the two counters.
    """

    lost: list[int]
    requeued: list[int]
    down_windows: tuple[tuple[float, float], ...] = ()
    degraded_windows: tuple[tuple[float, float], ...] = ()

    @property
    def total_lost(self) -> int:
        return sum(self.lost)

    @property
    def total_requeued(self) -> int:
        return sum(self.requeued)


def merge_fault_stats(
    stats: Sequence["FaultStats | None"], n_models: int
) -> "FaultStats | None":
    """Fleet merge: counters add elementwise, windows pool (sorted, from
    every device -- drill into ``per_device`` results for attribution).
    ``None`` when no device carried fault stats at all."""
    present = [s for s in stats if s is not None]
    if not present:
        return None
    lost = [0] * n_models
    requeued = [0] * n_models
    down: list[tuple[float, float]] = []
    degraded: list[tuple[float, float]] = []
    for s in present:
        for i in range(n_models):
            lost[i] += s.lost[i]
            requeued[i] += s.requeued[i]
        down.extend(s.down_windows)
        degraded.extend(s.degraded_windows)
    return FaultStats(
        lost=lost,
        requeued=requeued,
        down_windows=tuple(sorted(set(down))),
        degraded_windows=tuple(sorted(set(degraded))),
    )


# -- controller-side signal tracking ------------------------------------------

class LatencyWindowTracker:
    """Mean latency of samples recorded since the previous poll.

    The adaptive controllers detect degradation from *observed* signals;
    this tracker turns a simulator's append-only per-model latency columns
    (floats from the scalar paths, NumPy chunks from the vectorized ones)
    into per-boundary deltas without copying history: it remembers how many
    chunks of each model's column it has consumed and reduces only the new
    tail.
    """

    def __init__(self, n_models: int):
        self._pos = [0] * n_models

    def poll(self, latencies: Sequence[Sequence[float]]) -> tuple[int, float]:
        """(count, sum) of samples recorded since the last poll."""
        count, total = 0, 0.0
        for i, col in enumerate(latencies):
            for part in col[self._pos[i]:]:
                if isinstance(part, (int, float)):
                    count += 1
                    total += float(part)
                else:  # NumPy chunk from a vectorized path
                    count += int(len(part))
                    total += float(part.sum()) if len(part) else 0.0
            self._pos[i] = len(col)
        return count, total

    def poll_mean(self, latencies: Sequence[Sequence[float]]) -> tuple[int, float]:
        """(count, mean) -- mean is ``nan`` when nothing new was recorded."""
        count, total = self.poll(latencies)
        return count, (total / count if count else math.nan)
