"""Online adaptation: sliding-window rate estimation + periodic re-planning.

Implements Section IV's online phase: request rates are monitored with a
sliding window; the resource-allocation algorithm re-runs periodically and
the runtime switches to the new (P, K).  The paper reports <2 ms per
invocation for the allocator -- ``benchmarks/alg_overhead.py`` measures ours.

The simulated runtime underneath is pluggable (``backend="stepper"`` or
``"des"``): both speak the shared driver surface (``offer`` /
``advance_to`` / ``set_plan`` / ``drain``), so with the event-driven
backend a re-plan lands mid-flight -- queued and in-service requests bound
under the old plan drain while new arrivals take the new one.
"""
from __future__ import annotations

import collections
import dataclasses
import inspect
import math
import statistics
import time
from typing import TYPE_CHECKING, Callable, Sequence

import numpy as np

from repro.core.allocator import hill_climb
from repro.core.latency import penalized_objective
from repro.core.objective import Objective, is_default
from repro.core.plan_tables import PlanTables
from repro.core.planner import DisciplineSpec, ModelProfile, Plan, TenantSpec
from repro.hw.specs import Platform
from repro.serving.faults import LatencyWindowTracker
from repro.serving.result import SimResult
from repro.serving.simulator import make_backend, sorted_trace_and_horizon
from repro.serving.workload import Request, Trace

if TYPE_CHECKING:
    from repro.core.plan_cache import PlanCache
    from repro.serving.faults import FaultSchedule
    from repro.serving.forecast import RateForecaster

# ``run_adaptive``'s cold-fallback default is 0.05 in single-device mode but
# must NOT leak into fleet mode (the fleet guard is opt-in; the delegation
# contract pins run_adaptive(fleet=...) defaults bitwise against
# run_adaptive_fleet defaults).  The sentinel tells the defaults apart from
# an explicit caller value, which forwards verbatim.
_UNSET_MARGIN = object()


class SlidingRateEstimator:
    """lambda-hat per model from a sliding window of arrival timestamps.

    ``decay`` (seconds, default ``None`` = off) switches the estimate from
    the uniform stamp count ``N / horizon`` to an exponentially weighted
    one: each stamp at age ``a`` contributes ``exp(-a / decay)`` and the
    total is normalized by ``decay * (1 - exp(-horizon / decay))``, which
    keeps the estimator unbiased for stationary Poisson arrivals while
    fixing the burst-decay bias of the uniform window -- after a burst
    ends, the uniform estimate stays inflated until the last burst stamp
    ages out (up to a full ``window``), whereas the weighted estimate
    relaxes as ``exp(-t_since_burst / decay)``.  ``decay=None`` is bitwise
    the original estimator.
    """

    def __init__(
        self,
        n_models: int,
        window: float = 30.0,
        decay: float | None = None,
    ):
        if decay is not None and decay <= 0:
            raise ValueError("decay must be positive (or None to disable)")
        self.window = window
        self.decay = decay
        self._stamps: list[collections.deque[float]] = [
            collections.deque() for _ in range(n_models)
        ]
        self._eval_now = 0.0  # high-water mark of rates() evaluation times

    def observe(self, model_idx: int, t: float) -> None:
        self._stamps[model_idx].append(t)

    def observe_batch(self, model_idx: np.ndarray, times: np.ndarray) -> None:
        """Columnar ``observe``: ingest one trace segment's arrivals at once.

        Extends the same per-model stamp windows the scalar path fills, so
        ``rates`` is bit-identical between the two -- the adaptive fast path
        must re-plan from exactly the estimates the scalar loop would see.
        """
        for i in np.unique(model_idx).tolist():
            self._stamps[i].extend(times[model_idx == i].tolist())

    def rates(self, now: float) -> list[float]:
        # Eviction is destructive, so evaluation must be monotone: a caller
        # probing rates(t1) then rates(t0) with t0 < t1 would otherwise get
        # estimates that depend on which stamps the *first* call already
        # evicted.  The clock is clamped to its high-water mark -- backdated
        # probes answer at the latest evaluated instant instead of silently
        # mixing two windows (stamps older than t1's window are gone).
        now = self._eval_now = max(now, self._eval_now)
        # Before one full window has elapsed the divisor is the elapsed time,
        # not the window length -- dividing by the full window would
        # systematically underestimate lambda-hat on early re-plans.
        horizon = min(self.window, now)
        cutoff = now - self.window
        tau = self.decay
        # Normalizer of the decayed estimate: the integral of the weight
        # kernel over the observed horizon, so a stationary Poisson stream
        # of rate lambda has expectation lambda regardless of tau.
        denom = tau * -math.expm1(-horizon / tau) if tau is not None else 0.0
        out = []
        for dq in self._stamps:
            # Strict < keeps a stamp sitting exactly on the window boundary
            # (dq[0] == now - window), so re-evaluating at the same ``now``
            # is idempotent: the boundary stamp is counted every time, never
            # evicted by one call and missed by the next.
            while dq and dq[0] < cutoff:
                dq.popleft()
            if tau is None:
                out.append(len(dq) / horizon if horizon > 0 else 0.0)
            elif denom > 0:
                w = sum(math.exp((t - now) / tau) for t in dq)
                out.append(w / denom)
            else:
                out.append(0.0)
        return out


def _should_cold_fallback(
    norm_objective: float, history: Sequence[float], margin: float
) -> bool:
    """Warm-start quality-tail guard (ROADMAP open item).

    The warm descent always ties or beats the *incumbent plan* under the new
    rates, so a regression can only be detected against the incumbent's
    trend: if the warm plan's predicted mean latency (objective normalized
    by the offered rate mass) exceeds the *median* of the recent re-plans by
    more than ``margin``, the basin the warm walk settled in is suspect and
    a cold re-climb is worth its ~10x cost.  The median (not the min) is the
    trend statistic because rate-estimate noise swings the normalized
    objective by tens of percent near high utilization, and anchoring on the
    luckiest recent estimate would fire the guard on every swing.  False
    positives (the load genuinely rose) cost one cold climb and nothing
    else -- the better of the two plans is kept either way.

    Nan-means-unknown convention (PR 5): a non-finite normalized objective
    carries no trend information (an idle boundary or a degenerate
    evaluation), so it neither fires the guard nor -- at the call sites --
    enters the history deque.  Callers guard ``tot_rate > 0`` before
    dividing, so no division by zero can reach this function.
    """
    if not history or not math.isfinite(norm_objective):
        return False
    return norm_objective > (1.0 + margin) * statistics.median(history)


@dataclasses.dataclass
class AdaptiveRunResult:
    sim: SimResult
    replan_times: list[float]
    plans: list[Plan]
    plan_compute_seconds: list[float]
    # Predicted Eq. 5 objective of each committed plan (same indexing as
    # ``plans``) and the re-plan times where the cold-fallback guard fired.
    plan_objectives: list[float] = dataclasses.field(default_factory=list)
    cold_fallback_times: list[float] = dataclasses.field(default_factory=list)
    # Re-plan boundaries where the fault-aware controller planned against
    # throttle-degraded speeds (empty on every fault-free run).
    degraded_replan_times: list[float] = dataclasses.field(default_factory=list)


def run_adaptive(
    profiles: Sequence[ModelProfile],
    requests: Sequence[Request],
    platform: Platform,
    k_max: int,
    *,
    replan_period: float = 30.0,
    window: float = 30.0,
    rate_decay: float | None = None,
    initial_rates: Sequence[float] | None = None,
    planner: Callable[..., tuple[Plan, float]] = hill_climb,
    min_rate: float = 0.05,
    warmup_frac: float = 0.05,
    backend: str = "stepper",
    vectorize: bool = True,
    cold_fallback_margin: float | None = _UNSET_MARGIN,  # type: ignore[assignment]
    cold_fallback_window: int = 5,
    discipline_space: Sequence[DisciplineSpec] | None = None,
    forecaster: "RateForecaster | None" = None,
    plan_cache: "PlanCache | None" = None,
    fleet: Sequence | None = None,
    faults: "FaultSchedule | None" = None,
    fault_aware: bool = False,
    dropout_min_requests: int = 4,
    degrade_threshold: float = 2.0,
    degrade_restore: float = 1.3,
    min_speed_factor: float = 0.05,
    health_probe: bool = False,
    objective: Objective | None = None,
    rate_margin: float | None = None,
    deadlines: Sequence[float | None] | None = None,
) -> AdaptiveRunResult:
    """Simulate the full adaptive runtime over a (possibly dynamic) trace.

    ``warmup_frac`` mirrors ``simulate()``: the leading fraction of the trace
    is excluded from the reported statistics (cold-start cache fills), so
    adaptive-vs-static comparisons (Fig. 8) measure the same steady state.
    The controller itself still observes warmup arrivals -- only the metrics
    skip them.

    Each periodic re-plan is warm-started from the incumbent plan when the
    planner supports it (``hill_climb(init_plan=...)``): successive rate
    estimates drift slowly, so the incremental search converges in a few
    delta-evaluated moves instead of re-climbing from all-CPU.

    ``cold_fallback_margin`` guards the warm-start quality tail: when the
    warm plan's predicted mean latency regresses by more than the margin
    against the best of the last ``cold_fallback_window`` re-plans, a cold
    climb runs too and the better plan wins (``None`` disables the guard;
    fired times are reported in ``AdaptiveRunResult.cold_fallback_times``).

    With the stepper backend and a columnar ``Trace``, each constant-plan
    span between re-plan boundaries resolves through the vectorized
    ``run_trace`` fast path (``vectorize=False`` forces the scalar
    per-request loop).  Re-plan times, rate estimates, and committed plans
    are identical either way; observed latencies agree to float round-off.

    ``discipline_space`` makes every re-plan a joint (partition, cores,
    discipline) search over the given specs when the planner supports it
    (``hill_climb(discipline_space=...)``); the committed plans carry the
    chosen spec and ``set_plan`` switches the runtime's TPU discipline
    mid-flight along with the rest of the configuration.  ``None`` (the
    default) keeps the planner untouched: plain FCFS, bit-identical to the
    pre-discipline controller.

    ``rate_decay`` (seconds) switches the sliding-window estimator to
    exponential-decay weighting (see ``SlidingRateEstimator``); ``None``
    (the default) keeps the original uniform window, bitwise.

    ``forecaster`` (opt-in) makes each re-plan predictive: the controller
    feeds the forecaster every boundary's rate estimate and, when it is
    warmed up, plans against the *forecast* rate vector one re-plan period
    ahead instead of the trailing-window estimate -- the plan switch lands
    before a forecastable burst rather than one window after it.
    Boundaries where the forecaster returns ``None`` fall back to the
    reactive estimate, so ``forecaster=None`` (and any not-yet-warm
    forecaster) replays the reactive controller bitwise.

    ``plan_cache`` (opt-in, a ``repro.core.plan_cache.PlanCache``) memoizes
    committed plans keyed on the quantized rate vector: a recurring traffic
    state re-plans with one verify evaluation instead of a ``hill_climb``.
    Every hit is re-scored under the exact fresh rates and rejected back to
    the warm planner when worse than the cache's margin.  ``None`` (the
    default) is bitwise the uncached controller.

    ``fleet`` switches the controller to fleet mode: a sequence of
    ``repro.core.fleet.DeviceSpec`` replaces ``platform`` (which is then
    ignored -- each device carries its own), ``k_max`` caps every device's
    core budget on top of its own ``cpu_cores``, per-device plans re-plan
    warm each period while tenant placement moves only on sustained load
    imbalance, and the return value is a
    ``repro.serving.fleet.FleetAdaptiveResult``.  A custom ``planner``
    raises.  ``forecaster`` / ``rate_decay`` forward verbatim
    (``plan_cache`` must then be a ``FleetPlanCache``), and so do the
    cold-fallback knobs when given *explicitly* -- the single-device
    default margin does not leak into fleet mode, where the guard is
    opt-in alongside the imbalance gate (``run_adaptive_fleet``'s own
    default), keeping ``run_adaptive(fleet=...)`` defaults bitwise equal
    to ``run_adaptive_fleet`` defaults.

    ``objective`` (opt-in, ``repro.core.objective``) selects the metric
    every re-plan minimizes -- mean (the ``None`` default, bitwise the
    pre-objective controller), ``p_tail(q)``, or ``deadline_miss`` against
    the per-tenant budgets in ``deadlines`` (seconds, ``None`` entries =
    no budget).  The committed ``plan_objectives`` are then values of that
    metric, and the plan cache keys fold in the objective identity.  The
    fault-aware throttle detector always judges observed *means* against a
    fresh Eq. 5 prediction of the committed plan, whatever the planning
    objective.

    ``rate_margin`` (opt-in) plans against rates inflated by the factor
    ``1 + rate_margin`` instead of the point estimate -- a cheap
    upper-quantile stand-in for forecast uncertainty, so the committed
    plan keeps headroom when the estimate lags a rising burst.  The
    estimator and the simulator always see real traffic; only the
    planner's input inflates.  ``None`` (the default) is bitwise the
    margin-free controller.

    ``faults`` injects a ``serving.faults.FaultSchedule`` into the
    underlying simulator (device 0 in single-device mode); ``fault_aware``
    reacts to the *observed* degradation: when the windowed mean latency
    exceeds ``degrade_threshold`` x the incumbent plan's predicted mean, a
    speed factor ``clamp(pred/obs, min_speed_factor, 1)`` is estimated and
    re-plans run against profiles scaled to the degraded speed
    (``ModelProfile.scaled``) until the observed mean drops back under
    ``degrade_restore`` x prediction; boundaries planned degraded are
    reported in ``degraded_replan_times``.  Single-device mode has no
    failover target, so dropout handling is the schedule's own
    requeue/lost policy; fleet mode (``fleet=...``) forwards every fault
    parameter to ``run_adaptive_fleet``, which adds failover/restore
    placement re-plans.  All fault parameters default off and the
    ``faults=None`` path is bitwise the pre-fault controller.
    """
    if fleet is not None:
        if planner is not hill_climb:
            raise ValueError(
                "fleet mode plans with fleet_hill_climb; a custom planner= "
                "is not supported (use run_adaptive_fleet directly)"
            )
        # Lazy import: the single-device controller must not depend on the
        # fleet layer at module load (serving.fleet imports this module).
        from repro.serving.fleet import run_adaptive_fleet

        return run_adaptive_fleet(
            profiles,
            requests,
            fleet,
            k_max=k_max,
            replan_period=replan_period,
            window=window,
            rate_decay=rate_decay,
            initial_rates=initial_rates,
            min_rate=min_rate,
            warmup_frac=warmup_frac,
            backend=backend,
            vectorize=vectorize,
            cold_fallback_margin=(
                None
                if cold_fallback_margin is _UNSET_MARGIN
                else cold_fallback_margin
            ),
            cold_fallback_window=cold_fallback_window,
            discipline_space=discipline_space,
            forecaster=forecaster,
            plan_cache=plan_cache,
            faults=faults,
            fault_aware=fault_aware,
            dropout_min_requests=dropout_min_requests,
            degrade_threshold=degrade_threshold,
            degrade_restore=degrade_restore,
            min_speed_factor=min_speed_factor,
            health_probe=health_probe,
            objective=objective,
            rate_margin=rate_margin,
            deadlines=deadlines,
        )
    if cold_fallback_margin is _UNSET_MARGIN:
        cold_fallback_margin = 0.05
    if rate_margin is not None and rate_margin < 0:
        raise ValueError("rate_margin must be non-negative (or None)")
    n = len(profiles)
    if deadlines is not None and len(deadlines) != n:
        raise ValueError("deadlines length must match model count")
    dl: list[float | None] = (
        list(deadlines) if deadlines is not None else [None] * n
    )
    est = SlidingRateEstimator(n, window=window, decay=rate_decay)

    # The rate-free half of the vectorized evaluation engine depends only on
    # (profiles, platform): build it once and reuse it on every re-plan so
    # the per-invocation planner cost stays within the paper's <2 ms budget.
    planner_kwargs = {}
    warm_capable = False
    try:
        params = inspect.signature(planner).parameters
    except (TypeError, ValueError):
        params = {}  # builtins/partials without introspectable signatures
    if "tables" in params:
        planner_kwargs["tables"] = PlanTables.build(profiles, platform, k_max)
    warm_capable = "init_plan" in params
    # A **kwargs wrapper around hill_climb accepts kwargs without naming
    # them, so VAR_KEYWORD counts as support.
    takes_kw = any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )
    if discipline_space is not None:
        if "discipline_space" not in params and not takes_kw:
            raise ValueError(
                "planner does not support discipline co-optimization "
                "(needs a discipline_space parameter)"
            )
        planner_kwargs["discipline_space"] = tuple(discipline_space)
    if objective is not None:
        if "objective" not in params and not takes_kw:
            raise ValueError(
                "planner does not support SLO objectives "
                "(needs an objective parameter)"
            )
        planner_kwargs["objective"] = objective

    # Normalized (per-request) objectives of recent committed plans: the
    # incumbent trend the cold-fallback guard compares against.
    norm_history: collections.deque[float] = collections.deque(
        maxlen=max(1, cold_fallback_window)
    )
    cold_fallback_times: list[float] = []

    def plan_for(
        rates: Sequence[float],
        incumbent: Plan | None = None,
        now: float = 0.0,
        speed: float = 1.0,
    ) -> tuple[Plan, float, float]:
        if rate_margin is not None:
            # Headroom planning: the plan is searched for inflated rates,
            # everything else (estimator, simulator, metrics) sees reality.
            rates = [r * (1.0 + rate_margin) for r in rates]
        if speed < 1.0:
            # Degraded (fault-aware throttle) re-plan: score against the
            # observed slowdown by scaling the profiles, skip the plan
            # cache and precomputed tables (both are keyed to the nominal
            # speed) and the cold-fallback trend (a degraded normalized
            # objective is a different baseline).
            tenants = [
                TenantSpec(p.scaled(speed, speed), max(r, min_rate), deadline=d)
                for p, r, d in zip(profiles, rates, dl)
            ]
            t0 = time.perf_counter()
            kwargs = {
                k: v for k, v in planner_kwargs.items() if k != "tables"
            }
            if warm_capable and incumbent is not None:
                kwargs["init_plan"] = incumbent
            plan, obj = planner(tenants, platform, k_max, **kwargs)
            return plan, obj, time.perf_counter() - t0
        tenants = [
            TenantSpec(p, max(r, min_rate), deadline=d)
            for p, r, d in zip(profiles, rates, dl)
        ]
        tot_rate = sum(t.rate for t in tenants)
        t0 = time.perf_counter()
        if plan_cache is not None:
            hit = plan_cache.lookup(
                tenants,
                platform,
                k_max,
                discipline_space=discipline_space,
                objective=objective,
            )
            if hit is not None:
                plan, obj = hit
                dt = time.perf_counter() - t0
                if tot_rate > 0 and math.isfinite(obj):
                    norm_history.append(obj / tot_rate)
                return plan, obj, dt
        kwargs = dict(planner_kwargs)
        warm = warm_capable and incumbent is not None
        if warm:
            kwargs["init_plan"] = incumbent
        plan, obj = planner(tenants, platform, k_max, **kwargs)
        if (
            warm
            and cold_fallback_margin is not None
            and tot_rate > 0
            and _should_cold_fallback(
                obj / tot_rate, norm_history, cold_fallback_margin
            )
        ):
            cold_kwargs = dict(planner_kwargs)
            cold_plan, cold_obj = planner(tenants, platform, k_max, **cold_kwargs)
            cold_fallback_times.append(now)
            if cold_obj < obj:
                plan, obj = cold_plan, cold_obj
        if plan_cache is not None:
            plan_cache.store(
                tenants,
                platform,
                k_max,
                plan,
                obj,
                discipline_space=discipline_space,
                objective=objective,
            )
        dt = time.perf_counter() - t0
        # Nan-means-unknown: only finite normalized objectives carry trend
        # information for the cold-fallback guard (idle boundaries never
        # reach here -- ``fire_due_replans`` skips all-zero estimates).
        if tot_rate > 0 and math.isfinite(obj):
            norm_history.append(obj / tot_rate)
        return plan, obj, dt

    def _detection_value(rates: Sequence[float], p: Plan, value: float) -> float:
        """What the throttle detector's predicted-mean baseline divides.

        Observed window means must be judged against a *mean* prediction:
        with a non-mean planning objective the committed value is a tail
        quantile sum or a miss rate, so the committed plan is re-scored
        under Eq. 5 here.  On the default mean path this returns ``value``
        untouched (bitwise pin), and without ``fault_aware`` the baseline
        is never read, so no extra evaluation is paid.
        """
        if is_default(objective) or not fault_aware:
            return value
        tenants = [
            TenantSpec(pr, max(r, min_rate), deadline=d)
            for pr, r, d in zip(profiles, rates, dl)
        ]
        return penalized_objective(tenants, p, platform)

    rates0 = list(initial_rates) if initial_rates is not None else [1.0] * n
    plan, obj, dt = plan_for(rates0)
    sim = make_backend(backend, profiles, plan, platform, faults=faults)
    replan_times = [0.0]
    plans = [plan]
    objectives = [obj]
    compute_times = [dt]

    # Fault-aware throttle detection state (inert unless fault_aware=True).
    speed_est = 1.0
    base0 = _detection_value(rates0, plan, obj)
    pred_mean_inc = base0 / sum(max(r, min_rate) for r in rates0) if (
        math.isfinite(base0) and sum(max(r, min_rate) for r in rates0) > 0
    ) else math.nan
    tracker = LatencyWindowTracker(n)
    degraded_replan_times: list[float] = []

    reqs, horizon = sorted_trace_and_horizon(requests)
    n_req = len(reqs)
    warmup_t = horizon * warmup_frac
    next_replan = replan_period

    def fire_due_replans(t: float) -> None:
        """Run every re-plan boundary at or before arrival time ``t`` (the
        body of the scalar loop's ``while req.arrival >= next_replan``)."""
        nonlocal next_replan, speed_est, pred_mean_inc
        while t >= next_replan:
            sim.advance_to(next_replan)
            rates = est.rates(next_replan)
            if forecaster is not None:
                forecaster.observe(next_replan, rates)
            if fault_aware:
                # Throttle detection: compare the window's observed mean
                # latency against the incumbent plan's predicted mean.
                cnt, obs_mean = tracker.poll_mean(sim.latencies)
                if (
                    cnt >= dropout_min_requests
                    and math.isfinite(pred_mean_inc)
                    and pred_mean_inc > 0
                    and math.isfinite(obs_mean)
                ):
                    if obs_mean > degrade_threshold * pred_mean_inc:
                        f = min(
                            1.0,
                            max(min_speed_factor, pred_mean_inc / obs_mean),
                        )
                        if speed_est == 1.0 or f < 0.5 * speed_est:
                            speed_est = f
                    elif (
                        speed_est < 1.0
                        and obs_mean < degrade_restore * pred_mean_inc
                    ):
                        speed_est = 1.0
            if any(r > 0 for r in rates):
                plan_rates = rates
                if forecaster is not None:
                    # Predictive re-plan: the committed plan serves the next
                    # replan_period, so score it against the rates forecast
                    # at that horizon.  None = not warmed up -> reactive.
                    pred = forecaster.forecast(next_replan, replan_period)
                    if pred is not None:
                        plan_rates = pred
                new_plan, obj, dt = plan_for(
                    plan_rates,
                    incumbent=sim.plan,
                    now=next_replan,
                    speed=speed_est,
                )
                if speed_est < 1.0:
                    degraded_replan_times.append(next_replan)
                if new_plan != sim.plan:
                    sim.set_plan(new_plan, now=next_replan)
                replan_times.append(next_replan)
                plans.append(new_plan)
                objectives.append(obj)
                compute_times.append(dt)
                tot = sum(max(r, min_rate) for r in plan_rates)
                if speed_est == 1.0 and math.isfinite(obj) and tot > 0:
                    # The incumbent prediction the next window's observation
                    # is judged against.  Only *nominal* commits move the
                    # baseline: a degraded objective already folds in the
                    # estimated slowdown, and judging against it would
                    # declare recovery the moment the degraded plan performs
                    # as (degraded-)predicted -- an oscillating restore.
                    base = _detection_value(plan_rates, new_plan, obj)
                    if math.isfinite(base):
                        pred_mean_inc = base / tot
            next_replan += replan_period

    if (
        vectorize
        and backend in ("stepper", "jax")
        and isinstance(reqs, Trace)
    ):
        # Columnar fast path: between consecutive re-plan boundaries the
        # plan is constant, so each span resolves as one vectorized
        # run_trace segment.  Boundary firing and rate estimation see the
        # exact arrivals the scalar loop would feed them.
        arrival = reqs.arrival
        idx = 0
        while idx < n_req:
            fire_due_replans(float(arrival[idx]))
            j = int(np.searchsorted(arrival, next_replan, side="left"))
            seg = reqs[idx:j]
            est.observe_batch(seg.model_idx, seg.arrival)
            sim.run_trace(seg, record_from=warmup_t)
            idx = j
    else:
        for req in reqs:
            fire_due_replans(req.arrival)
            est.observe(req.model_idx, req.arrival)
            sim.offer(req, record=req.arrival >= warmup_t)

    # Duration runs to the last *completion*: under backlog the queue drains
    # past the last arrival, and clipping there inflated tpu_utilization
    # beyond 1.0.
    duration = max(horizon, sim.drain())
    return AdaptiveRunResult(
        sim=sim.result(duration),
        replan_times=replan_times,
        plans=plans,
        plan_compute_seconds=compute_times,
        plan_objectives=objectives,
        cold_fallback_times=cold_fallback_times,
        degraded_replan_times=degraded_replan_times,
    )
