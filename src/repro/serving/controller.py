"""Online adaptation: sliding-window rate estimation + periodic re-planning.

Implements Section IV's online phase: request rates are monitored with a
sliding window; the resource-allocation algorithm re-runs periodically and
the runtime switches to the new (P, K).  The paper reports <2 ms per
invocation for the allocator -- ``benchmarks/alg_overhead.py`` measures ours.
"""
from __future__ import annotations

import collections
import dataclasses
import inspect
import time
from typing import Callable, Sequence

from repro.core.allocator import hill_climb
from repro.core.plan_tables import PlanTables
from repro.core.planner import ModelProfile, Plan, TenantSpec
from repro.hw.specs import Platform
from repro.serving.simulator import RuntimeSimulator, SimResult
from repro.serving.workload import Request


class SlidingRateEstimator:
    """lambda-hat per model from a sliding window of arrival timestamps."""

    def __init__(self, n_models: int, window: float = 30.0):
        self.window = window
        self._stamps: list[collections.deque[float]] = [
            collections.deque() for _ in range(n_models)
        ]

    def observe(self, model_idx: int, t: float) -> None:
        self._stamps[model_idx].append(t)

    def rates(self, now: float) -> list[float]:
        out = []
        for dq in self._stamps:
            while dq and dq[0] < now - self.window:
                dq.popleft()
            out.append(len(dq) / self.window)
        return out


@dataclasses.dataclass
class AdaptiveRunResult:
    sim: SimResult
    replan_times: list[float]
    plans: list[Plan]
    plan_compute_seconds: list[float]


def run_adaptive(
    profiles: Sequence[ModelProfile],
    requests: Sequence[Request],
    platform: Platform,
    k_max: int,
    *,
    replan_period: float = 30.0,
    window: float = 30.0,
    initial_rates: Sequence[float] | None = None,
    planner: Callable[..., tuple[Plan, float]] = hill_climb,
    min_rate: float = 0.05,
) -> AdaptiveRunResult:
    """Simulate the full adaptive runtime over a (possibly dynamic) trace."""
    n = len(profiles)
    est = SlidingRateEstimator(n, window=window)

    # The rate-free half of the vectorized evaluation engine depends only on
    # (profiles, platform): build it once and reuse it on every re-plan so
    # the per-invocation planner cost stays within the paper's <2 ms budget.
    planner_kwargs = {}
    try:
        if "tables" in inspect.signature(planner).parameters:
            planner_kwargs["tables"] = PlanTables.build(profiles, platform, k_max)
    except (TypeError, ValueError):
        pass  # builtins/partials without introspectable signatures

    def plan_for(rates: Sequence[float]) -> tuple[Plan, float]:
        tenants = [
            TenantSpec(p, max(r, min_rate)) for p, r in zip(profiles, rates)
        ]
        t0 = time.perf_counter()
        plan, _ = planner(tenants, platform, k_max, **planner_kwargs)
        return plan, time.perf_counter() - t0

    rates0 = list(initial_rates) if initial_rates is not None else [1.0] * n
    plan, dt = plan_for(rates0)
    sim = RuntimeSimulator(profiles, plan, platform)
    replan_times = [0.0]
    plans = [plan]
    compute_times = [dt]

    next_replan = replan_period
    for req in sorted(requests, key=lambda r: r.arrival):
        while req.arrival >= next_replan:
            rates = est.rates(next_replan)
            if any(r > 0 for r in rates):
                new_plan, dt = plan_for(rates)
                if new_plan != sim.plan:
                    sim.set_plan(new_plan, now=next_replan)
                replan_times.append(next_replan)
                plans.append(new_plan)
                compute_times.append(dt)
            next_replan += replan_period
        est.observe(req.model_idx, req.arrival)
        sim.step(req)

    duration = max((r.arrival for r in requests), default=0.0)
    return AdaptiveRunResult(
        sim=sim.result(duration),
        replan_times=replan_times,
        plans=plans,
        plan_compute_seconds=compute_times,
    )
