"""Online adaptation: sliding-window rate estimation + periodic re-planning.

Implements Section IV's online phase: request rates are monitored with a
sliding window; the resource-allocation algorithm re-runs periodically and
the runtime switches to the new (P, K).  The paper reports <2 ms per
invocation for the allocator -- ``benchmarks/alg_overhead.py`` measures ours.
"""
from __future__ import annotations

import collections
import dataclasses
import inspect
import time
from typing import Callable, Sequence

from repro.core.allocator import hill_climb
from repro.core.plan_tables import PlanTables
from repro.core.planner import ModelProfile, Plan, TenantSpec
from repro.hw.specs import Platform
from repro.serving.simulator import RuntimeSimulator, SimResult
from repro.serving.workload import Request


class SlidingRateEstimator:
    """lambda-hat per model from a sliding window of arrival timestamps."""

    def __init__(self, n_models: int, window: float = 30.0):
        self.window = window
        self._stamps: list[collections.deque[float]] = [
            collections.deque() for _ in range(n_models)
        ]

    def observe(self, model_idx: int, t: float) -> None:
        self._stamps[model_idx].append(t)

    def rates(self, now: float) -> list[float]:
        # Before one full window has elapsed the divisor is the elapsed time,
        # not the window length -- dividing by the full window would
        # systematically underestimate lambda-hat on early re-plans.
        horizon = min(self.window, now)
        out = []
        for dq in self._stamps:
            while dq and dq[0] < now - self.window:
                dq.popleft()
            out.append(len(dq) / horizon if horizon > 0 else 0.0)
        return out


@dataclasses.dataclass
class AdaptiveRunResult:
    sim: SimResult
    replan_times: list[float]
    plans: list[Plan]
    plan_compute_seconds: list[float]


def run_adaptive(
    profiles: Sequence[ModelProfile],
    requests: Sequence[Request],
    platform: Platform,
    k_max: int,
    *,
    replan_period: float = 30.0,
    window: float = 30.0,
    initial_rates: Sequence[float] | None = None,
    planner: Callable[..., tuple[Plan, float]] = hill_climb,
    min_rate: float = 0.05,
    warmup_frac: float = 0.05,
) -> AdaptiveRunResult:
    """Simulate the full adaptive runtime over a (possibly dynamic) trace.

    ``warmup_frac`` mirrors ``simulate()``: the leading fraction of the trace
    is excluded from the reported statistics (cold-start cache fills), so
    adaptive-vs-static comparisons (Fig. 8) measure the same steady state.
    The controller itself still observes warmup arrivals -- only the metrics
    skip them.

    Each periodic re-plan is warm-started from the incumbent plan when the
    planner supports it (``hill_climb(init_plan=...)``): successive rate
    estimates drift slowly, so the incremental search converges in a few
    delta-evaluated moves instead of re-climbing from all-CPU.
    """
    n = len(profiles)
    est = SlidingRateEstimator(n, window=window)

    # The rate-free half of the vectorized evaluation engine depends only on
    # (profiles, platform): build it once and reuse it on every re-plan so
    # the per-invocation planner cost stays within the paper's <2 ms budget.
    planner_kwargs = {}
    warm_capable = False
    try:
        params = inspect.signature(planner).parameters
        if "tables" in params:
            planner_kwargs["tables"] = PlanTables.build(profiles, platform, k_max)
        warm_capable = "init_plan" in params
    except (TypeError, ValueError):
        pass  # builtins/partials without introspectable signatures

    def plan_for(
        rates: Sequence[float], incumbent: Plan | None = None
    ) -> tuple[Plan, float]:
        tenants = [
            TenantSpec(p, max(r, min_rate)) for p, r in zip(profiles, rates)
        ]
        kwargs = dict(planner_kwargs)
        if warm_capable and incumbent is not None:
            kwargs["init_plan"] = incumbent
        t0 = time.perf_counter()
        plan, _ = planner(tenants, platform, k_max, **kwargs)
        return plan, time.perf_counter() - t0

    rates0 = list(initial_rates) if initial_rates is not None else [1.0] * n
    plan, dt = plan_for(rates0)
    sim = RuntimeSimulator(profiles, plan, platform)
    replan_times = [0.0]
    plans = [plan]
    compute_times = [dt]

    horizon = max((r.arrival for r in requests), default=0.0)
    warmup_t = horizon * warmup_frac
    next_replan = replan_period
    for req in sorted(requests, key=lambda r: r.arrival):
        while req.arrival >= next_replan:
            rates = est.rates(next_replan)
            if any(r > 0 for r in rates):
                new_plan, dt = plan_for(rates, incumbent=sim.plan)
                if new_plan != sim.plan:
                    sim.set_plan(new_plan, now=next_replan)
                replan_times.append(next_replan)
                plans.append(new_plan)
                compute_times.append(dt)
            next_replan += replan_period
        est.observe(req.model_idx, req.arrival)
        sim.step(req, record=req.arrival >= warmup_t)

    # Duration runs to the last *completion*: under backlog the queue drains
    # past the last arrival, and clipping there inflated tpu_utilization
    # beyond 1.0.
    duration = max(horizon, sim.last_completion)
    return AdaptiveRunResult(
        sim=sim.result(duration),
        replan_times=replan_times,
        plans=plans,
        plan_compute_seconds=compute_times,
    )
