"""Rate forecasting for predictive re-planning.

The adaptive controller (``run_adaptive`` / ``run_adaptive_fleet``) is
reactive by default: every re-plan scores the plan space against the
sliding-window rate estimate, so a plan switch lands one window *after*
the traffic that needed it.  The MMPP and diurnal scenarios in
``workload.py`` are forecastable, and the forecasters here close that gap:
at each re-plan boundary the controller feeds the forecaster the fresh
rate estimate and, when the forecaster is warmed up, plans against the
*predicted* rate vector one re-plan horizon ahead instead of the trailing
estimate -- the plan switch lands before the burst, not after (the
model-driven resource-management discipline of Liang et al. 2201.07312).

Contract (``RateForecaster``): ``observe(now, rates)`` ingests one rate
sample per re-plan boundary; ``forecast(now, horizon)`` returns the
predicted per-model rate vector at ``now + horizon``, or ``None`` while
the forecaster cannot commit to a prediction yet -- the controller falls
back to the reactive estimate for exactly that boundary, so a forecaster
that always returns ``None`` replays the reactive controller bitwise
(``benchmarks/predictive.py`` self-checks this before timing anything).

Everything here is opt-in: ``run_adaptive(forecaster=None)`` (the
default) never imports or touches this module's state.
"""
from __future__ import annotations

import math
from typing import Callable, Protocol, Sequence, runtime_checkable


@runtime_checkable
class RateForecaster(Protocol):
    """Duck-typed forecaster surface the adaptive controllers consume."""

    def observe(self, now: float, rates: Sequence[float]) -> None:
        """Ingest the rate estimate evaluated at time ``now``."""

    def forecast(self, now: float, horizon: float) -> list[float] | None:
        """Predicted rates at ``now + horizon``; ``None`` = not warmed up."""


def _clamped(rates: Sequence[float]) -> list[float]:
    """Forecasts are rate vectors: negative extrapolations clamp to idle."""
    return [max(0.0, float(r)) for r in rates]


class EwmaTrendForecaster:
    """Per-model EWMA level + trend (Holt's linear method, time-aware).

    Each observation ``(t, x_i)`` updates model i's level ``l_i`` and
    per-second trend ``b_i``::

        pred  = l_i + b_i * dt
        l_i'  = alpha * x_i + (1 - alpha) * pred
        b_i'  = beta * (l_i' - l_i) / dt + (1 - beta) * b_i

    with ``dt`` the elapsed time since the previous sample (the controller
    samples at re-plan boundaries, so ``dt`` is usually the re-plan
    period, but irregular boundaries are handled).  The forecast at
    ``now + horizon`` extrapolates ``l_i + b_i * (now + horizon - t_last)``
    and clamps at zero.  On a noiseless linear ramp the trend converges to
    the true slope (pinned by ``tests/test_predictive.py``); on an MMPP
    step the trailing-window estimate starts rising as soon as the burst
    enters the window and the trend term extrapolates the rise, landing
    the plan switch roughly one re-plan period before the reactive
    controller's.

    ``forecast`` returns ``None`` until two samples have been observed
    (no trend exists yet), so the leading boundaries replay the reactive
    controller exactly.
    """

    def __init__(
        self, n_models: int, *, alpha: float = 0.5, beta: float = 0.3
    ):
        if not 0.0 < alpha <= 1.0 or not 0.0 < beta <= 1.0:
            raise ValueError("alpha and beta must lie in (0, 1]")
        self.alpha = alpha
        self.beta = beta
        self.level = [0.0] * n_models
        self.trend = [0.0] * n_models
        self._t_last = 0.0
        self._n_obs = 0

    def observe(self, now: float, rates: Sequence[float]) -> None:
        if len(rates) != len(self.level):
            raise ValueError(
                f"rate vector has {len(rates)} models, forecaster "
                f"{len(self.level)}"
            )
        if self._n_obs == 0:
            self.level = [float(r) for r in rates]
            self._t_last = now
            self._n_obs = 1
            return
        dt = now - self._t_last
        if dt <= 0.0:
            # Re-observation at the same instant: refresh the level only
            # (no time elapsed to attribute a trend to).
            a = self.alpha
            self.level = [
                a * float(x) + (1.0 - a) * l
                for x, l in zip(rates, self.level)
            ]
            return
        a, b = self.alpha, self.beta
        for i, x in enumerate(rates):
            pred = self.level[i] + self.trend[i] * dt
            new_level = a * float(x) + (1.0 - a) * pred
            self.trend[i] = (
                b * (new_level - self.level[i]) / dt
                + (1.0 - b) * self.trend[i]
            )
            self.level[i] = new_level
        self._t_last = now
        self._n_obs += 1

    def forecast(self, now: float, horizon: float) -> list[float] | None:
        if self._n_obs < 2:
            return None
        ahead = (now - self._t_last) + horizon
        return _clamped(
            l + b * ahead for l, b in zip(self.level, self.trend)
        )


class PeriodicForecaster:
    """Binned periodic rate profile for diurnal (cyclical) traffic.

    The period is divided into ``n_bins`` equal bins; each observation is
    accumulated into the bin of ``now mod period`` and the forecast at
    ``now + horizon`` answers with the running mean of the target time's
    bin.  A target bin with no samples yet returns ``None`` (reactive
    fallback), so the first cycle of a diurnal trace runs reactively and
    every later cycle re-plans against the profile learned from the
    earlier ones -- recurring daily states are anticipated, not chased.

    On a noiseless periodic rate signal sampled at a fixed cadence the
    recovered profile equals the per-bin mean of the signal exactly
    (pinned by ``tests/test_predictive.py``).
    """

    def __init__(self, n_models: int, period: float, *, n_bins: int = 48):
        if period <= 0:
            raise ValueError("period must be positive")
        if n_bins < 1:
            raise ValueError("n_bins must be >= 1")
        self.period = float(period)
        self.n_bins = int(n_bins)
        self._sum = [[0.0] * n_models for _ in range(self.n_bins)]
        self._count = [0] * self.n_bins

    def _bin(self, t: float) -> int:
        frac = (t % self.period) / self.period
        return min(int(frac * self.n_bins), self.n_bins - 1)

    def observe(self, now: float, rates: Sequence[float]) -> None:
        b = self._bin(now)
        acc = self._sum[b]
        if len(rates) != len(acc):
            raise ValueError(
                f"rate vector has {len(rates)} models, forecaster "
                f"{len(acc)}"
            )
        for i, r in enumerate(rates):
            acc[i] += float(r)
        self._count[b] += 1

    def profile(self, bin_idx: int) -> list[float] | None:
        """Learned mean rate vector of one bin (``None`` if unseen)."""
        c = self._count[bin_idx]
        if c == 0:
            return None
        return [s / c for s in self._sum[bin_idx]]

    def forecast(self, now: float, horizon: float) -> list[float] | None:
        prof = self.profile(self._bin(now + horizon))
        return None if prof is None else _clamped(prof)


class OracleForecaster:
    """Perfect-knowledge forecaster: wraps the true rate function.

    ``fn(t)`` must return the per-model rate vector at absolute time
    ``t``.  Used by tests and benchmarks to bound what forecasting can
    buy -- predictive re-planning with an oracle is the headroom any
    learned forecaster is chasing.
    """

    def __init__(self, fn: Callable[[float], Sequence[float]]):
        self._fn = fn

    def observe(self, now: float, rates: Sequence[float]) -> None:
        pass

    def forecast(self, now: float, horizon: float) -> list[float] | None:
        return _clamped(self._fn(now + horizon))


class NeverForecaster:
    """Forecaster that never commits: every boundary falls back reactive.

    Exists to pin the fallback contract -- ``run_adaptive(forecaster=
    NeverForecaster())`` must replay ``run_adaptive()`` bitwise (the
    benchmark self-check and ``tests/test_predictive.py`` both use it).
    """

    def observe(self, now: float, rates: Sequence[float]) -> None:
        pass

    def forecast(self, now: float, horizon: float) -> None:
        return None


def piecewise_rate_fn(
    phases: Sequence,  # Sequence[workload.RatePhase]
) -> Callable[[float], tuple[float, ...]]:
    """True rate function of a ``dynamic_trace`` phase list, for oracles.

    Times before the first phase answer with the first phase's rates,
    past the last with the last's (the controller may probe one horizon
    beyond the trace end).
    """
    if not phases:
        raise ValueError("phases must not be empty")

    def fn(t: float) -> tuple[float, ...]:
        for ph in phases:
            if t < ph.end:
                return tuple(ph.rates)
        return tuple(phases[-1].rates)

    return fn


__all__ = [
    "EwmaTrendForecaster",
    "NeverForecaster",
    "OracleForecaster",
    "PeriodicForecaster",
    "RateForecaster",
    "piecewise_rate_fn",
]
