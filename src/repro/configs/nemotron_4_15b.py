"""nemotron-4-15b [dense]: GQA + squared-ReLU MLP.

[arXiv:2402.16819]  32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    citation="arXiv:2402.16819",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    mlp="relu2",
    attn_kind="full",
    rope_theta=1e4,
)
