"""hymba-1.5b [hybrid]: parallel attention + SSM heads in every layer.

[arXiv:2411.13676]  32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16.  Sliding-window attention everywhere except three full-
attention layers (first, middle, last), mirroring the Hymba recipe -- this
plus the O(1) SSM state makes long_500k decode feasible.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    citation="arXiv:2411.13676",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    mlp="swiglu",
    attn_kind="local_global",
    window=1024,
    full_attn_layers=(0, 16, 31),
    block="hymba",
    ssm_state=16,
    ssm_inner=3200,         # 2x d_model Mamba-style expansion
    rope_theta=1e4,
)
