"""rwkv6-7b "Finch" [ssm]: attention-free, data-dependent decay.

[arXiv:2404.05892]  32L d_model=4096 (64 heads of 64) d_ff=14336
vocab=65536.  O(1) recurrent state per layer -> long_500k decode is the
natural fit.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    arch_type="ssm",
    citation="arXiv:2404.05892",
    n_layers=32,
    d_model=4096,
    n_heads=64,             # WKV heads (head_dim 64)
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    attn_kind="none",
    block="rwkv6",
    decay_rank=64,
)
