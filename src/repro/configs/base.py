"""Architecture config schema + input-shape suite.

Every assigned architecture is a selectable ``ArchConfig``; smoke tests use
``reduced()`` variants (2 layers, d_model <= 512, <= 4 experts) and the
dry-run exercises the full configs symbolically.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free blocks
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp: str = "swiglu"             # swiglu | gelu | relu2
    # Attention pattern.
    attn_kind: str = "full"         # full | local_global | none
    window: int = 0
    global_period: int = 0          # every Nth layer global (gemma3: 6)
    full_attn_layers: tuple[int, ...] = ()  # explicit global layers (hymba)
    # Mixture of experts.
    n_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1             # every Nth layer is MoE (llama4: 2)
    capacity_factor: float = 1.25
    # Block family.
    block: str = "transformer"      # transformer | rwkv6 | hymba
    ssm_state: int = 0
    ssm_inner: int = 0              # hymba SSM path width
    decay_rank: int = 64            # rwkv6 decay LoRA rank
    # Modality frontend (stub; embeddings provided by input_specs).
    frontend: str = "none"          # none | vision | audio
    frontend_dim: int = 0
    n_patches: int = 0
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    # §Perf knobs (False = paper-faithful baseline lowering).
    use_chunked_scan: bool = False  # chunked closed-form WKV/SSD recurrences
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs)
    parallelism: str = "tp"         # tp (data x tensor) | fsdp (ZeRO-3 over
                                    # ALL axes; small models where weight
                                    # all-gather << activation all-reduce)
    moe_weight_gather: bool = False # constrain expert weights replicated on
                                    # the intra-expert axis at use: AG the
                                    # (small) weight shards instead of
                                    # all-reducing the (huge) FFN outputs

    def __post_init__(self):
        if self.block == "transformer" or self.block == "hymba":
            assert self.n_heads > 0
            hd = self.head_dim or self.d_model // self.n_heads
            assert self.n_heads % self.n_kv_heads == 0
        if self.n_experts:
            assert self.experts_per_token >= 1
            assert self.n_layers % self.moe_period == 0

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def group_size(self) -> int:
        """Scan unit: moe_period layers for MoE archs (last one MoE), else 1."""
        return self.moe_period if self.is_moe else 1

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.group_size

    def layer_is_moe(self, i: int) -> bool:
        return self.is_moe and (i % self.moe_period == self.moe_period - 1)

    def layer_is_global(self, i: int) -> bool:
        """True if layer i uses full (global) attention."""
        if self.attn_kind == "full":
            return True
        if self.attn_kind == "none":
            return False
        if self.full_attn_layers:
            return i in self.full_attn_layers
        if self.global_period > 0:
            return (i + 1) % self.global_period == 0
        return False

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded per-layer state?

        True for attention-free (rwkv6) and local/global archs whose *local*
        layers ring-buffer; global layers still keep full caches but are a
        small minority (their O(S) cache is the documented cost).
        """
        return self.block == "rwkv6" or self.attn_kind == "local_global"

    def supports_shape(self, shape_name: str) -> bool:
        if shape_name == "long_500k":
            return self.sub_quadratic
        return True

    # -- parameter accounting (used by roofline MODEL_FLOPS) ----------------
    def param_count(self) -> int:
        from repro.models.transformer import count_params  # lazy, avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params
        return count_params(self, active_only=True)

    # -- smoke-scale variant -------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """2 layers, d_model <= 512, <= 4 experts; same family behaviour."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, n_heads) if n_heads else 0
        if n_heads and n_heads % max(n_kv, 1) != 0:
            n_kv = 1
        group = 2 if self.is_moe else 1
        n_layers = 2 * group if self.is_moe and self.moe_period > 1 else 2
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=max(n_kv, 1) if n_heads else 0,
            head_dim=d_model // n_heads if n_heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            n_experts=min(self.n_experts, 4) if self.is_moe else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.is_moe else 0,
            moe_period=2 if self.is_moe and self.moe_period > 1 else self.moe_period,
            window=min(self.window, 16) if self.window else 0,
            global_period=min(self.global_period, 2) if self.global_period else 0,
            full_attn_layers=(0,) if self.full_attn_layers else (),
            ssm_inner=min(self.ssm_inner, 256) if self.ssm_inner else 0,
            decay_rank=min(self.decay_rank, 16),
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
