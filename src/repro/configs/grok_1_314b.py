"""grok-1-314b [moe]: 8 experts, top-2 routing, every layer MoE.

[hf:xai-org/grok-1]  64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    arch_type="moe",
    citation="hf:xai-org/grok-1",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    mlp="gelu",
    attn_kind="full",
    n_experts=8,
    experts_per_token=2,
    moe_period=1,
    rope_theta=1e4,
)
