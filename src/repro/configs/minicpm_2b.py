"""minicpm-2b [dense]: llama-like architecture trained with the WSD
(warmup-stable-decay) schedule -- the schedule lives in repro/training.

[arXiv:2404.06395]  40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    arch_type="dense",
    citation="arXiv:2404.06395",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    mlp="swiglu",
    attn_kind="full",
    rope_theta=1e4,
)
