"""gemma3-1b [dense]: 5:1 local:global sliding-window attention, 128k ctx.

[hf:google/gemma-3-1b-pt]  26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144, head_dim=256, 512-token sliding window with every 6th layer
global.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    arch_type="dense",
    citation="hf:google/gemma-3-1b-pt",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    mlp="gelu",
    attn_kind="local_global",
    window=512,
    global_period=6,
    rope_theta=1e6,
)
