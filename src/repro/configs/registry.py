"""Registry of assigned architectures: ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape
from repro.configs.phi_3_vision_4_2b import CONFIG as PHI3V
from repro.configs.llama4_maverick_400b_a17b import CONFIG as LLAMA4
from repro.configs.gemma3_1b import CONFIG as GEMMA3
from repro.configs.nemotron_4_15b import CONFIG as NEMOTRON
from repro.configs.musicgen_large import CONFIG as MUSICGEN
from repro.configs.qwen1_5_0_5b import CONFIG as QWEN
from repro.configs.hymba_1_5b import CONFIG as HYMBA
from repro.configs.grok_1_314b import CONFIG as GROK
from repro.configs.rwkv6_7b import CONFIG as RWKV6
from repro.configs.minicpm_2b import CONFIG as MINICPM

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        PHI3V,
        LLAMA4,
        GEMMA3,
        NEMOTRON,
        MUSICGEN,
        QWEN,
        HYMBA,
        GROK,
        RWKV6,
        MINICPM,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def all_pairs() -> list[tuple[ArchConfig, InputShape]]:
    """All 40 (arch x shape) pairs; unsupported pairs are flagged by
    cfg.supports_shape and skipped by the dry-run with a documented reason."""
    return [
        (a, s) for a in ARCHS.values() for s in INPUT_SHAPES.values()
    ]
