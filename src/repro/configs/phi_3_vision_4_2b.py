"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP-style vision frontend.

[hf:microsoft/Phi-3-vision-128k-instruct]  32L d_model=3072 32H (GQA kv=32)
d_ff=8192 vocab=32064.  The ViT encoder is a stub per assignment; the
backbone consumes precomputed patch embeddings via a learned projector.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    mlp="swiglu",
    attn_kind="full",
    frontend="vision",
    frontend_dim=1024,      # CLIP ViT-L/14 patch feature width
    n_patches=256,
    rope_theta=1e4,
)
