"""The paper's nine evaluation models (Table II) as synthetic profile specs.

Size / FLOPs / partition-point counts are taken verbatim from Table II.
``speedup_front`` / ``speedup_back`` encode each model's Fig.-3-style
TPU-vs-CPU per-segment speedup curve, calibrated so that derived
intra-model swap-overhead fractions reproduce the ranges in Figs. 1-2
(20.2% for DenseNet201 up to 62.4% for InceptionV4).
"""
from __future__ import annotations

from repro.core.planner import ModelProfile
from repro.hw.specs import EDGE_TPU_PLATFORM, Platform
from repro.profiler.synthetic import SyntheticModelSpec, build_profile

# name, size(MB), GFLOPs, partition points  -- Table II
PAPER_MODEL_SPECS: dict[str, SyntheticModelSpec] = {
    s.name: s
    for s in [
        SyntheticModelSpec("squeezenet", 1.4, 0.81, 2, speedup_front=30, speedup_back=1.6),
        SyntheticModelSpec("mobilenetv2", 4.1, 0.30, 5, speedup_front=25, speedup_back=1.05),
        SyntheticModelSpec("efficientnet", 6.7, 0.39, 6, speedup_front=25, speedup_back=1.05),
        SyntheticModelSpec("mnasnet", 7.1, 0.31, 7, speedup_front=25, speedup_back=1.05),
        SyntheticModelSpec("gpunet", 12.2, 0.62, 5, speedup_front=40, speedup_back=1.2),
        SyntheticModelSpec("densenet201", 19.7, 4.32, 7, speedup_front=50, speedup_back=1.4),
        SyntheticModelSpec("resnet50v2", 25.3, 4.49, 8, speedup_front=66, speedup_back=1.2),
        SyntheticModelSpec("xception", 26.1, 8.38, 11, speedup_front=160, speedup_back=1.35, flops_decay=0.62),
        SyntheticModelSpec("inceptionv4", 43.2, 12.27, 11, speedup_front=210, speedup_back=1.45, flops_decay=0.58),
    ]
}

PAPER_MODEL_NAMES = tuple(PAPER_MODEL_SPECS)


def paper_profile(name: str, platform: Platform = EDGE_TPU_PLATFORM) -> ModelProfile:
    return build_profile(PAPER_MODEL_SPECS[name], platform)


def all_paper_profiles(platform: Platform = EDGE_TPU_PLATFORM) -> dict[str, ModelProfile]:
    return {n: paper_profile(n, platform) for n in PAPER_MODEL_SPECS}
