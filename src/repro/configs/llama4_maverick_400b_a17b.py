"""llama4-maverick-400b-a17b [moe]: interleaved MoE + chunked local attention.

[hf:meta-llama/Llama-4-Scout-17B-16E family]  48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1, early fusion.  MoE layers
interleave with dense layers (moe_period=2, matching Maverick's
interleave_moe_layer_step); attention follows the iRoPE pattern of 3 chunked
local layers (8192-token chunks) per global layer, which is what makes
long_500k decode feasible for this arch.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp="swiglu",
    attn_kind="local_global",
    window=8192,
    global_period=4,
    n_experts=128,
    experts_per_token=1,
    moe_period=2,
    rope_theta=5e5,
)
