"""musicgen-large [audio]: decoder-only LM over EnCodec tokens.

[arXiv:2306.05284]  48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.
The EnCodec conv codec is a stub per assignment: input_specs provides
precomputed frame embeddings; the decoder predicts codebook tokens.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    arch_type="audio",
    citation="arXiv:2306.05284",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp="gelu",
    attn_kind="full",
    frontend="audio",
    frontend_dim=128,       # EnCodec latent frame width
    rope_theta=1e4,
)
