from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape
from repro.configs.registry import ARCHS, all_pairs, get_arch, get_shape

__all__ = [
    "ARCHS",
    "ArchConfig",
    "INPUT_SHAPES",
    "InputShape",
    "all_pairs",
    "get_arch",
    "get_shape",
]
