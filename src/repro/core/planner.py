"""Model partition plans and profiles.

A model is viewed as a chain of ``Segment`` blocks separated by candidate
partition points (the paper's offline phase enumerates these along
single-edge cuts of the graph).  A partition point ``p`` in ``{0..P}`` places
``segments[:p]`` on the accelerator (the "TPU prefix") and ``segments[p:]``
on the host CPU (the "CPU suffix"); ``p == 0`` is full-CPU, ``p == P`` full-TPU.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import numpy as np

from repro.hw.specs import Platform


@dataclasses.dataclass(frozen=True)
class Segment:
    """One partitionable block of a model, with profiled per-block costs."""

    name: str
    flops: float              # ops in this block
    weight_bytes: int         # parameter footprint of this block
    out_bytes: int            # activation size at the block's output boundary
    tpu_time: float           # profiled service time on the accelerator (s)
    cpu_time_1core: float     # profiled service time on one host core (s)
    cpu_parallel_frac: float  # Amdahl parallel fraction for multi-core scaling

    def cpu_time(self, k_cores: int) -> float:
        """Amdahl-scaled CPU service time on ``k_cores`` cores."""
        if k_cores <= 0:
            return math.inf
        f = self.cpu_parallel_frac
        return self.cpu_time_1core * ((1.0 - f) + f / k_cores)


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Offline profile of one model: segments + I/O sizes."""

    name: str
    segments: tuple[Segment, ...]
    input_bytes: int

    @property
    def num_partition_points(self) -> int:
        return len(self.segments)

    @property
    def total_weight_bytes(self) -> int:
        return sum(s.weight_bytes for s in self.segments)

    @property
    def total_flops(self) -> float:
        return sum(s.flops for s in self.segments)

    @functools.cached_property
    def fingerprint(self) -> tuple:
        """Structural identity for memoization keys.

        Name, I/O size, and the full per-segment cost table: two profiles
        with equal fingerprints yield identical objectives for any plan, so
        the plan cache (``core/plan_cache.py``) keys tenant mixes on this
        rather than on object identity.  ``Segment`` is a frozen dataclass,
        so the tuple is hashable and the hash is cached with the property.
        """
        return (self.name, self.input_bytes, self.segments)

    # --- cached cumulative tables (hot path of the online allocator) -----
    @functools.cached_property
    def _cum_weight(self) -> np.ndarray:
        return np.concatenate(
            [[0], np.cumsum([s.weight_bytes for s in self.segments])]
        )

    @functools.cached_property
    def _cum_tpu(self) -> np.ndarray:
        return np.concatenate(
            [[0.0], np.cumsum([s.tpu_time for s in self.segments])]
        )

    @functools.cached_property
    def _cum_cpu1(self) -> np.ndarray:
        return np.concatenate(
            [[0.0], np.cumsum([s.cpu_time_1core for s in self.segments])]
        )

    @functools.cached_property
    def _suffix_cpu1(self) -> np.ndarray:
        """1-core suffix time for every partition point: t1[p] = sum cpu_time
        of ``segments[p:]`` (length P+1, last entry 0)."""
        return self._cum_cpu1[-1] - self._cum_cpu1

    @functools.cached_property
    def pareto_points(self) -> np.ndarray:
        """Non-dominated partition points (the pruned search frontier).

        A point ``p`` is *dominated* by ``q`` when ``q`` is no worse on every
        cost dimension the objective (Eq. 1-5, Eq. 10) can see for this model:

            w:  prefix weight bytes   (footprint, T_load, intra-swap overflow)
            s:  cumulative TPU time   (prefix compute)
            c:  1-core CPU suffix time
            b:  boundary tensor bytes (charged only on split plans, so the
                endpoints 0 and P dominate regardless of their b)

        with at least one dimension strictly better (exact duplicates keep
        the smallest ``p``).  The comparison is platform-free: every
        platform-dependent term is monotone in (w, s, c, b) -- prefix service
        is ``s + max(0, w - C)/B``, T_load is ``min(w, C)/B``, transfer times
        scale b by ``1/B``, and Amdahl scaling multiplies c by a k-dependent
        positive factor -- so one frontier is exact for all platforms.

        Exactness: replacing a dominated ``p_i`` by its dominator ``q`` in any
        feasible plan is feasible (``q = P`` frees model i's cores, ``q = 0``
        keeps them) and never increases the objective: model i's own static
        terms shrink termwise, and the coupled terms -- the M/G/1 moment
        numerators, lambda_TPU, the aggregate footprint W(P) and the Eq. 10
        swap sums -- are all nondecreasing in (w_i, s_i, 1{p_i>0}), as is the
        infeasibility overload.  Hence the pruned plan space always retains an
        optimum of the NLIP; for a single tenant (where Eq. 10 collapses to
        alpha = 0) the argument is termwise immediate.  The greedy hill-climb
        additionally never *commits* to a point dominated from below (the move
        cannot strictly improve), so sweeping the frontier is how Algorithm 1
        exploits this; ``prune=False`` on the search routines opts out.
        """
        P = self.num_partition_points
        idx = np.arange(P + 1)
        if P <= 1:
            return idx
        w = self._cum_weight.astype(np.float64)
        s = self._cum_tpu
        c = self._suffix_cpu1
        b = np.array([self.boundary_bytes(p) for p in idx], dtype=np.float64)
        b_dom = b.copy()
        b_dom[0] = b_dom[P] = -np.inf  # endpoints never pay a boundary xfer
        # le[p, q]: q weakly dominates p on every dimension.
        le = (
            (w[None, :] <= w[:, None])
            & (s[None, :] <= s[:, None])
            & (c[None, :] <= c[:, None])
            & (b_dom[None, :] <= b[:, None])
        )
        lt = (
            (w[None, :] < w[:, None])
            | (s[None, :] < s[:, None])
            | (c[None, :] < c[:, None])
            | (b_dom[None, :] < b[:, None])
        )
        dom = le & (lt | (idx[None, :] < idx[:, None]))
        np.fill_diagonal(dom, False)
        dominated = dom.any(axis=1)
        # The all-CPU start of Algorithm 1 and the full-TPU class (k = 0)
        # are structural; never prune them.
        dominated[0] = dominated[P] = False
        out = idx[~dominated]
        out.setflags(write=False)
        return out

    @functools.lru_cache(maxsize=8)
    def suffix_cpu_matrix(self, k_max: int) -> np.ndarray:
        """Amdahl-scaled suffix CPU time for every ``(p, k)`` pair.

        Shape ``[P+1, k_max+1]``; entry ``[p, k]`` equals
        ``suffix_cpu_time(p, k)``.  Column 0 is ``inf`` wherever a suffix
        exists (no cores cannot serve it) and 0 on the full-TPU row ``p=P``.
        The matrix is the vectorized engine's lookup table -- one gather
        replaces a Python call per candidate plan.
        """
        t1 = self._suffix_cpu1  # [P+1]
        f = self.segments[-1].cpu_parallel_frac if self.segments else 0.0
        k = np.arange(k_max + 1, dtype=np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            scale = (1.0 - f) + f / k  # scale[0] = inf (or 1-f if f==0)
            mat = t1[:, None] * scale[None, :]
        # k=0 can serve no suffix (matches suffix_cpu_time's k<=0 -> inf),
        # except on the full-TPU row p=P where there is nothing to serve.
        mat[:-1, 0] = np.inf
        mat[-1, 0] = 0.0
        mat.setflags(write=False)
        return mat

    # --- block aggregates -----------------------------------------------
    def prefix_weight_bytes(self, p: int) -> int:
        return int(self._cum_weight[p])

    def prefix_tpu_time(self, p: int) -> float:
        """Pure compute time of the TPU prefix (no swap)."""
        return float(self._cum_tpu[p])

    def suffix_cpu_time(self, p: int, k_cores: int) -> float:
        """Service time of the CPU suffix ``segments[p:]`` on ``k_cores``."""
        if p >= len(self.segments):
            return 0.0
        if k_cores <= 0:
            return math.inf
        t1 = float(self._cum_cpu1[-1] - self._cum_cpu1[p])
        f = self.segments[-1].cpu_parallel_frac
        return t1 * ((1.0 - f) + f / k_cores)

    def suffix_cpu_time_1core(self, p: int) -> float:
        return float(self._cum_cpu1[-1] - self._cum_cpu1[p])

    def boundary_bytes(self, p: int) -> int:
        """Intermediate tensor size d_out at partition point ``p``."""
        if p <= 0:
            return self.input_bytes
        return self.segments[p - 1].out_bytes

    def scaled(self, tpu_speed: float = 1.0, cpu_speed: float = 1.0) -> "ModelProfile":
        """This profile re-timed for a device running its accelerator at
        ``tpu_speed`` x and its host cores at ``cpu_speed`` x the profiled
        reference (service times divide by the factor; sizes are unchanged).

        The fleet layer views a heterogeneous device through the profiles it
        hosts: everything downstream -- the analytic model, both simulators,
        the plan tables -- consumes profiled *times*, so speed factors enter
        here once and nowhere else.  Cached per (self, factors), so repeated
        calls return the *same object* -- the identity that lets
        ``PlanTables``/``EvalTables`` caches built for a device class match
        across re-plans.  Factor 1.0x1.0 returns ``self`` unchanged, which
        is what pins the single-device degenerate case bitwise -- checked
        *before* the cache, because the LRU keys on profile *value*: an
        equal-but-distinct profile's cached result must never shadow the
        ``self`` identity.
        """
        if tpu_speed == 1.0 and cpu_speed == 1.0:
            return self
        return self._scaled_cached(tpu_speed, cpu_speed)

    @functools.lru_cache(maxsize=64)
    def _scaled_cached(self, tpu_speed: float, cpu_speed: float) -> "ModelProfile":
        if tpu_speed <= 0 or cpu_speed <= 0:
            raise ValueError("speed factors must be positive")
        segments = tuple(
            dataclasses.replace(
                s,
                tpu_time=s.tpu_time / tpu_speed,
                cpu_time_1core=s.cpu_time_1core / cpu_speed,
            )
            for s in self.segments
        )
        return ModelProfile(
            name=f"{self.name}@x{tpu_speed:g}/{cpu_speed:g}",
            segments=segments,
            input_bytes=self.input_bytes,
        )


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One co-located model with its arrival rate (requests/s).

    ``deadline`` is the tenant's end-to-end latency budget in seconds
    (``None`` = no SLO): it is carried on the mix so the opt-in
    ``deadline_miss`` objective (``repro.core.objective``) can price plans
    against it, and it is ignored by every default (mean-objective) path.
    """

    profile: ModelProfile
    rate: float
    deadline: float | None = None


_DISCIPLINE_KINDS = ("fcfs", "swap_batch", "priority", "weighted_fair")


@dataclasses.dataclass(frozen=True)
class DisciplineSpec:
    """Which TPU service discipline a plan runs under (value object).

    The spec is *data* carried by a ``Plan`` (so the planner can co-optimize
    it and the simulators can switch it mid-flight via ``set_plan``); the
    runtime queue mechanics live in ``repro.serving.scheduling`` -- the
    dependency stays core <- serving.

    Kinds:

    * ``fcfs`` -- single global FCFS queue (the paper's Section IV runtime
      and the permanent bitwise-pinned reference).
    * ``swap_batch`` -- serve runs of up to ``batch_cap`` queued same-model
      requests back-to-back so one inter-model swap-in (Eq. 2's T_load)
      amortizes over the whole run; ``batch_cap`` doubles as the fairness
      bound (any queued head-of-line job is overtaken by at most
      ``batch_cap - 1`` batched services before FCFS order resumes), and
      ``staleness`` optionally breaks a run early once the globally oldest
      queued job has waited longer than that many seconds.
    * ``priority`` -- strict non-preemptive priority across tenants
      (``weights[i]`` higher = served first; FIFO within a tenant).
    * ``weighted_fair`` -- served-time-weighted fair queueing: the nonempty
      tenant with the smallest accumulated TPU service per unit ``weight``
      goes next (FIFO within a tenant).

    ``batch_cap <= 1`` disables batching: every evaluator and simulator
    treats such a spec exactly as FCFS semantics with bookkeeping, and the
    planner's co-optimization returns the FCFS plan unchanged.
    """

    kind: str = "fcfs"
    batch_cap: int = 1
    staleness: float = math.inf
    weights: tuple[float, ...] | None = None

    def __post_init__(self) -> None:
        if self.kind not in _DISCIPLINE_KINDS:
            raise ValueError(
                f"unknown discipline {self.kind!r} (want one of {_DISCIPLINE_KINDS})"
            )
        if self.batch_cap < 1:
            raise ValueError("batch_cap must be >= 1")
        if not self.staleness > 0:
            raise ValueError("staleness must be positive (math.inf disables)")
        if self.weights is not None and any(w < 0 for w in self.weights):
            raise ValueError("discipline weights must be non-negative")

    @property
    def batches(self) -> bool:
        """True when the spec actually amortizes swaps (cap of 1 is FCFS)."""
        return self.kind == "swap_batch" and self.batch_cap > 1


FCFS = DisciplineSpec()


@dataclasses.dataclass(frozen=True)
class Plan:
    """A global configuration: partition vector P, core vector K, and the
    TPU service discipline the runtime serves the queue with."""

    partition: tuple[int, ...]
    cores: tuple[int, ...]
    discipline: DisciplineSpec = FCFS

    def __post_init__(self) -> None:
        if len(self.partition) != len(self.cores):
            raise ValueError("partition and cores must have equal length")


def validate_plan(plan: Plan, tenants: Sequence[TenantSpec], k_max: int) -> None:
    """Enforce the NLIP constraints (6)-(9)."""
    for p_i, k_i, t in zip(plan.partition, plan.cores, tenants):
        P_i = t.profile.num_partition_points
        if not 0 <= p_i <= P_i:
            raise ValueError(f"{t.profile.name}: partition {p_i} outside [0,{P_i}]")
        if p_i < P_i and k_i < 1:
            raise ValueError(f"{t.profile.name}: CPU suffix requires >=1 core")
        if p_i == P_i and k_i != 0:
            raise ValueError(f"{t.profile.name}: full-TPU must have 0 cores")
        if k_i < 0:
            raise ValueError("negative core count")
    if sum(plan.cores) > k_max:
        raise ValueError(f"core allocation {plan.cores} exceeds K_max={k_max}")
    w = plan.discipline.weights
    if w is not None and len(w) != len(tenants):
        raise ValueError(
            f"discipline weights length {len(w)} != {len(tenants)} tenants"
        )


def intra_swap_bytes(profile: ModelProfile, p: int, platform: Platform) -> int:
    """Bytes streamed per inference due to *intra-model* swapping.

    When a TPU prefix exceeds SRAM capacity ``C``, the runtime keeps the first
    ``C`` bytes resident and streams the remainder from host memory on every
    request (the Edge TPU runtime's sequential segment-swap behaviour).
    """
    return max(0, profile.prefix_weight_bytes(p) - platform.sram_bytes)


def prefix_service_time(profile: ModelProfile, p: int, platform: Platform) -> float:
    """s_TPU for the prefix: deterministic compute + intra-model swap."""
    if p <= 0:
        return 0.0
    swap = intra_swap_bytes(profile, p, platform) / platform.swap_bw
    return profile.prefix_tpu_time(p) + swap


def load_time(profile: ModelProfile, p: int, platform: Platform) -> float:
    """T_load: inter-model swap latency = resident prefix bytes / bandwidth B.

    Only the portion that is (normally) resident needs reloading after an
    eviction; the intra-swapped overflow is streamed every request anyway.
    """
    resident = min(profile.prefix_weight_bytes(p), platform.sram_bytes)
    return resident / platform.swap_bw


@dataclasses.dataclass(frozen=True)
class RouteTables:
    """Per-model service/transfer tables a runtime derives from one plan.

    Both simulators (`serving.simulator.RuntimeSimulator._derive` and
    `serving.des.DiscreteEventSimulator.set_plan`) need exactly these six
    lists; deriving them in one place keeps the two bitwise-identical by
    construction.  Plain Python floats/ints, same expressions the
    simulators historically used -- the pinned fast-path tests see the
    exact same values.
    """

    prefix_bytes: list[int]   # resident-candidate prefix weight bytes
    s_tpu: list[float]        # prefix service incl. intra-swap streaming
    t_load: list[float]       # inter-model swap-in on an SRAM miss
    s_cpu: list[float]        # 1-core CPU suffix service time
    in_xfer: list[float]      # input tensor host->TPU transfer
    out_xfer: list[float]     # boundary tensor TPU->host transfer


def route_tables(
    profiles: Sequence[ModelProfile], plan: Plan, platform: Platform
) -> RouteTables:
    """Derive the per-model routing tables for ``plan`` on ``platform``."""
    pf, pl, p = profiles, platform, plan.partition
    return RouteTables(
        prefix_bytes=[f.prefix_weight_bytes(q) for f, q in zip(pf, p)],
        s_tpu=[prefix_service_time(f, q, pl) for f, q in zip(pf, p)],
        t_load=[load_time(f, q, pl) for f, q in zip(pf, p)],
        s_cpu=[
            f.suffix_cpu_time(q, 1) if q < f.num_partition_points else 0.0
            for f, q in zip(pf, p)
        ],
        in_xfer=[f.input_bytes / pl.swap_bw for f in pf],
        out_xfer=[f.boundary_bytes(q) / pl.swap_bw for f, q in zip(pf, p)],
    )
