"""Queueing primitives from the paper's analytic model (Section III-B).

* TPU: single unified M/G/1/FCFS queue; expected wait via Pollaczek-Khinchine
  (Eq. 1) with the effective service time the lambda-weighted mixture over
  model prefixes including inter-model swap latency (Eq. 2).
* CPU: per-model M/D/k queues with dedicated cores (Eq. 3).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class Mg1Metrics:
    """Per-term M/G/1 predictions (Eq. 1 decomposed for validation).

    ``benchmarks/model_vs_sim.py`` and the differential DES tests compare
    each term against its simulated observable: ``rho`` against busy-time /
    duration, ``wait`` against mean time-in-queue, ``sojourn`` against mean
    wait + service, ``queue_len`` (Little's law, ``lam * sojourn``) against
    the time-averaged number in system.
    """

    rho: float
    wait: float
    sojourn: float
    queue_len: float


def mg1_metrics(lam: float, es: float, es2: float) -> Mg1Metrics:
    """All M/G/1 steady-state predictions the simulators can observe.

    Same inputs and stability semantics as ``mg1_wait`` (unstable queues
    report ``inf`` waits); ``rho`` is reported even when >= 1.
    """
    wait = mg1_wait(lam, es, es2)
    sojourn = wait + es if lam > 0.0 else es
    return Mg1Metrics(
        rho=lam * es,
        wait=wait,
        sojourn=sojourn,
        queue_len=lam * sojourn,
    )


def mg1_wait(lam: float, es: float, es2: float) -> float:
    """Pollaczek-Khinchine expected queueing delay for an M/G/1/FCFS queue.

    Args:
      lam: aggregate Poisson arrival rate (1/s).
      es: E[S], mean service time (s).
      es2: E[S^2], second moment of service time (s^2).

    Returns:
      E[W] in seconds; ``inf`` if the queue is unstable (rho >= 1).
    """
    if lam <= 0.0:
        return 0.0
    rho = lam * es
    if rho >= 1.0:
        return math.inf
    return lam * es2 / (2.0 * (1.0 - rho))


def mdk_wait(lam: float, mu: float, k: int) -> float:
    """Approximate expected queueing delay for an M/D/k queue (Eq. 3).

    E[W] ~= 1/2 * (1/(k*mu - lam) - 1/(k*mu))  -- i.e. half the M/M/1-style
    wait of a pooled server, halved for deterministic service.
    """
    if lam <= 0.0:
        return 0.0
    if k <= 0 or mu <= 0:
        return math.inf
    cap = k * mu
    if lam >= cap:
        return math.inf
    return 0.5 * (1.0 / (cap - lam) - 1.0 / cap)


def mg1_wait_batch(lam: np.ndarray, es: np.ndarray, es2: np.ndarray) -> np.ndarray:
    """Broadcasting Pollaczek-Khinchine wait; element-wise ``mg1_wait``.

    Any shape; unstable entries (rho >= 1) come back ``inf``, empty queues
    (lam <= 0) come back 0, mirroring the scalar branch structure exactly.
    """
    lam = np.asarray(lam, dtype=np.float64)
    rho = lam * es
    with np.errstate(divide="ignore", invalid="ignore"):
        wait = lam * es2 / (2.0 * (1.0 - rho))
    wait = np.where(rho >= 1.0, np.inf, wait)
    return np.where(lam <= 0.0, 0.0, wait)


def mdk_wait_batch(lam: np.ndarray, mu: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Broadcasting M/D/k wait approximation; element-wise ``mdk_wait``.

    ``mu`` may be ``inf`` (zero service time): the pooled capacity is then
    infinite and the wait collapses to 0, as in the scalar version.
    """
    lam = np.asarray(lam, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        cap = k * mu  # k=0 with mu=inf -> nan; masked by the k<=0 branch below
        wait = 0.5 * (1.0 / (cap - lam) - 1.0 / cap)
    wait = np.where(lam >= cap, np.inf, wait)
    wait = np.where((k <= 0) | (mu <= 0), np.inf, wait)
    return np.where(lam <= 0.0, 0.0, wait)


def mixture_moments(weights: list[float], values: list[float]) -> tuple[float, float]:
    """First and second moments of a discrete mixture distribution.

    ``weights`` need not be normalized; each request class i has a
    *deterministic* service time ``values[i]`` and probability proportional
    to ``weights[i]`` -- the TPU service distribution of Eq. 2.
    """
    tot = sum(weights)
    if tot <= 0.0:
        return 0.0, 0.0
    m1 = sum(w * v for w, v in zip(weights, values)) / tot
    m2 = sum(w * v * v for w, v in zip(weights, values)) / tot
    return m1, m2


def mixture_moments_batch(
    weights: np.ndarray, values: np.ndarray, axis: int = -1
) -> tuple[np.ndarray, np.ndarray]:
    """Batched ``mixture_moments``: reduce the atom axis of stacked mixtures.

    ``weights`` and ``values`` broadcast against each other; mixtures whose
    total weight is <= 0 get (0, 0), matching the scalar guard.
    """
    weights = np.asarray(weights, dtype=np.float64)
    values = np.asarray(values, dtype=np.float64)
    tot = weights.sum(axis=axis)
    with np.errstate(divide="ignore", invalid="ignore"):
        m1 = (weights * values).sum(axis=axis) / tot
        m2 = (weights * values * values).sum(axis=axis) / tot
    ok = tot > 0.0
    return np.where(ok, m1, 0.0), np.where(ok, m2, 0.0)
