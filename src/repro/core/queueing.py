"""Queueing primitives from the paper's analytic model (Section III-B).

* TPU: single unified M/G/1/FCFS queue; expected wait via Pollaczek-Khinchine
  (Eq. 1) with the effective service time the lambda-weighted mixture over
  model prefixes including inter-model swap latency (Eq. 2).
* CPU: per-model M/D/k queues with dedicated cores (Eq. 3).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class Mg1Metrics:
    """Per-term M/G/1 predictions (Eq. 1 decomposed for validation).

    ``benchmarks/model_vs_sim.py`` and the differential DES tests compare
    each term against its simulated observable: ``rho`` against busy-time /
    duration, ``wait`` against mean time-in-queue, ``sojourn`` against mean
    wait + service, ``queue_len`` (Little's law, ``lam * sojourn``) against
    the time-averaged number in system.
    """

    rho: float
    wait: float
    sojourn: float
    queue_len: float


def mg1_metrics(lam: float, es: float, es2: float) -> Mg1Metrics:
    """All M/G/1 steady-state predictions the simulators can observe.

    Same inputs and stability semantics as ``mg1_wait`` (unstable queues
    report ``inf`` waits); ``rho`` is reported even when >= 1.
    """
    wait = mg1_wait(lam, es, es2)
    sojourn = wait + es if lam > 0.0 else es
    return Mg1Metrics(
        rho=lam * es,
        wait=wait,
        sojourn=sojourn,
        queue_len=lam * sojourn,
    )


def mg1_wait(lam: float, es: float, es2: float) -> float:
    """Pollaczek-Khinchine expected queueing delay for an M/G/1/FCFS queue.

    Args:
      lam: aggregate Poisson arrival rate (1/s).
      es: E[S], mean service time (s).
      es2: E[S^2], second moment of service time (s^2).

    Returns:
      E[W] in seconds; ``inf`` if the queue is unstable (rho >= 1).
    """
    if lam <= 0.0:
        return 0.0
    rho = lam * es
    if rho >= 1.0:
        return math.inf
    return lam * es2 / (2.0 * (1.0 - rho))


def mdk_wait(lam: float, mu: float, k: int) -> float:
    """Approximate expected queueing delay for an M/D/k queue (Eq. 3).

    E[W] ~= 1/2 * (1/(k*mu - lam) - 1/(k*mu))  -- i.e. half the M/M/1-style
    wait of a pooled server, halved for deterministic service.
    """
    if lam <= 0.0:
        return 0.0
    if k <= 0 or mu <= 0:
        return math.inf
    cap = k * mu
    if lam >= cap:
        return math.inf
    return 0.5 * (1.0 / (cap - lam) - 1.0 / cap)


def mg1_wait_batch(lam: np.ndarray, es: np.ndarray, es2: np.ndarray) -> np.ndarray:
    """Broadcasting Pollaczek-Khinchine wait; element-wise ``mg1_wait``.

    Any shape; unstable entries (rho >= 1) come back ``inf``, empty queues
    (lam <= 0) come back 0, mirroring the scalar branch structure exactly.
    """
    lam = np.asarray(lam, dtype=np.float64)
    rho = lam * es
    with np.errstate(divide="ignore", invalid="ignore"):
        wait = lam * es2 / (2.0 * (1.0 - rho))
    wait = np.where(rho >= 1.0, np.inf, wait)
    return np.where(lam <= 0.0, 0.0, wait)


def mdk_wait_batch(lam: np.ndarray, mu: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Broadcasting M/D/k wait approximation; element-wise ``mdk_wait``.

    ``mu`` may be ``inf`` (zero service time): the pooled capacity is then
    infinite and the wait collapses to 0, as in the scalar version.
    """
    lam = np.asarray(lam, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        cap = k * mu  # k=0 with mu=inf -> nan; masked by the k<=0 branch below
        wait = 0.5 * (1.0 / (cap - lam) - 1.0 / cap)
    wait = np.where(lam >= cap, np.inf, wait)
    wait = np.where((k <= 0) | (mu <= 0), np.inf, wait)
    return np.where(lam <= 0.0, 0.0, wait)


def wait_exceed_prob(wq, rho, t):
    """P(W > t) for an M/G/1-style queueing delay, exponential-tail model.

    The waiting time has an atom at zero of mass ``1 - rho``; the
    conditional wait is approximated as exponential with mean ``wq / rho``
    (the exact conditional mean), so

        P(W > t) ~= rho * exp(-rho * t / wq)          for t >= 0.

    This is exact for M/M/1 and a standard light-tail approximation for
    M/G/1 (the same model the ``swap_batch_amortization`` staleness bracket
    uses).  ``benchmarks/model_vs_sim.py`` maps where it breaks against the
    DES ground truth.

    Broadcasting element-wise over any shapes.  Conventions:

    * ``rho <= 0`` (idle queue) -> 0.
    * ``rho >= 1`` or ``wq`` infinite (unstable) -> 1.
    * ``wq <= 0`` with ``0 < rho < 1`` (degenerate zero wait) -> 0.
    * ``t < 0`` is clamped to 0, so the result at ``t <= 0`` is ``rho``
      (the probability of waiting at all).
    """
    wq = np.asarray(wq, dtype=np.float64)
    rho = np.asarray(rho, dtype=np.float64)
    t = np.maximum(np.asarray(t, dtype=np.float64), 0.0)
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        p = rho * np.exp(-rho * t / wq)
    p = np.where((wq <= 0.0) | ~np.isfinite(wq), 0.0, p)
    p = np.where((rho >= 1.0) | np.isinf(wq), 1.0, p)
    return np.where(rho <= 0.0, 0.0, p)


def wait_tail_quantile(wq, rho, q):
    """q-th quantile of the queueing delay under the same tail model.

    Inverting ``wait_exceed_prob``: the quantile is 0 while the zero atom
    covers it (``1 - q >= rho``), else

        W(q) = (wq / rho) * ln(rho / (1 - q)).

    Broadcasting element-wise.  Unstable entries (``rho >= 1`` or infinite
    ``wq``) return ``inf``; idle or degenerate queues (``rho <= 0`` or
    ``wq <= 0``) return 0, mirroring ``wait_exceed_prob``'s conventions.
    """
    wq = np.asarray(wq, dtype=np.float64)
    rho = np.asarray(rho, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        tail = (wq / rho) * np.log(rho / (1.0 - q))
    tail = np.where((1.0 - q) >= rho, 0.0, tail)
    tail = np.where((rho >= 1.0) | np.isinf(wq), np.inf, tail)
    return np.where((rho <= 0.0) | (wq <= 0.0), 0.0, tail)


# Finite stand-in for an infinite queueing delay inside the swap-batch
# fixed-point iteration (damping with a literal inf would poison the
# average); any real wait is astronomically below this.
_WAIT_CAP = 1e12


def swap_batch_amortization(
    lam,
    s1,
    s2,
    rates,
    alphas,
    t_load,
    service,
    batch_cap: int,
    *,
    staleness: float = math.inf,
    iters: int = 60,
):
    """Batch-amortized M/G/1 swap model: the Eq. 1/Eq. 2 generalization for
    the ``swap_batch`` TPU discipline (``repro.serving.scheduling``).

    Under FCFS every inter-model switch pays ``T_load`` and tenant i's
    switch-in probability is the Eq. 10 ``alpha_i``.  ``swap_batch`` keeps
    serving the resident tenant while (a) the same-tenant run is shorter
    than ``batch_cap`` and (b) a same-tenant request is queued, so the
    probability that a service *continues* tenant i's run is

        c_i = q_i + (1 - q_i) * p_i

    where ``p_i = r_i / lam`` is the FCFS natural continuation (the next
    head happens to be the same tenant -- all a cap-1 scheduler gets) and
    ``q_i`` is the probability a same-tenant request is queued at the
    completion *and* the staleness bound still allows an extension.
    Availability comes from Little's law on the queue: with ``N_i^q``
    approximately geometric with mean ``r_i * W_q``,

        q_i = [1 - exp(-staleness / W_q)] * r_i W_q / (1 + r_i W_q)

    -- the bracket is the probability the global head has waited less than
    ``staleness`` under the M/G/1 wait's exponential tail approximation
    (exactly 1 at the default ``staleness = inf``, so the unthrottled model
    is untouched; a staleness far below ``W_q`` collapses the model to
    FCFS, matching the discipline whose runs the bound keeps breaking).

    Mean run length (extensions capped at ``batch_cap``, natural FCFS
    continuation beyond it uncapped, exactly as the discipline behaves):

        E[L_i] = (1 - c_i^B) / (1 - c_i)  +  c_i^(B-1) p_i / (1 - p_i)

    and the amortized switch-in probability is ``alpha_i^B = alpha_i *
    g_i`` with ``g_i = 1 / ((1 - p_i) E[L_i])`` -- ``g_i = 1`` exactly at
    ``B = 1`` or an empty queue (checks: both limits collapse ``E[L_i]`` to
    the FCFS run length ``1/(1 - p_i)``), decaying toward ``1 / (B (1 -
    p_i) + p_i)`` under backlog.  The amortized swap sums feed back into
    Pollaczek-Khinchine, and ``W_q`` is the fixed point of that loop
    (amortization lengthens with queueing, queueing shrinks with
    amortization): a damped iteration from the optimistic end, which both
    the scalar and the batched evaluator run with identical formulas and
    iteration count so the two stay within round-off of each other.  At the
    ``iters`` cap the residual is checked explicitly; elements where the
    damped sweep failed to close (a period-2 orbit appears near saturation,
    where the decreasing map's slope passes -3) fall back to the
    unamortized FCFS swap term -- see the inline note at the check.

    Array contract: per-tenant inputs (``rates``/``alphas``/``t_load``/
    ``service``) reduce along their last axis; ``lam``/``s1``/``s2`` are
    the matching leading shape (scalars for one plan, ``[B]`` against
    ``[B, n]`` for a batch of plans).  ``s1``/``s2`` are the *swap-free*
    aggregate moments ``sum r_i s_i`` and ``sum r_i s_i^2``.

    Returns ``(wait, rho, alpha_eff)``: the amortized queueing delay (inf
    when unstable even at full amortization), the amortized utilization,
    and the per-tenant effective switch-in probabilities.
    """
    lam = np.asarray(lam, dtype=np.float64)
    s1 = np.asarray(s1, dtype=np.float64)
    s2 = np.asarray(s2, dtype=np.float64)
    rates = np.asarray(rates, dtype=np.float64)
    alphas = np.asarray(alphas, dtype=np.float64)
    t_load = np.asarray(t_load, dtype=np.float64)
    service = np.asarray(service, dtype=np.float64)
    B = int(batch_cap)

    lam_e = lam[..., None]
    with np.errstate(divide="ignore", invalid="ignore"):
        p = np.where(lam_e > 0.0, rates / lam_e, 0.0)
    # A tenant with alpha = 0 never pays a switch-in; its g is irrelevant
    # and p -> 1 (single active tenant) would otherwise produce 0 * inf.
    live = (alphas > 0.0) & (p < 1.0)
    p = np.where(live, p, 0.0)
    aT = rates * alphas * t_load                 # switch-rate summand
    aU = aT * (2.0 * service + t_load)           # E[S^2] swap summand

    def sweep(wq):
        with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
            wq_e = wq[..., None]
            rw = rates * wq_e
            # P(head fresh enough to let the run extend): exactly 1.0 at
            # staleness=inf (exp(-inf) == 0, and 1.0 * q == q bitwise), and
            # exp(-inf) == 0 again at wq == 0 (idle queue, nothing queued).
            fresh = 1.0 - np.exp(
                -np.divide(staleness, wq_e, where=wq_e > 0.0,
                           out=np.full_like(wq_e, np.inf))
            )
            q = np.where(live, fresh * rw / (1.0 + rw), 0.0)
            c = q + (1.0 - q) * p
            run = np.where(
                c < 1.0,
                (1.0 - c**B) / (1.0 - c) + c ** (B - 1) * p / (1.0 - p),
                # c -> 1 limit: the geometric sum tends to B and the
                # natural-continuation tail to p/(1-p) (p < 1 for live
                # tenants) -- dropping the tail would overstate the
                # amortized swap term by up to (1-p)B : (1-p)B + p.
                float(B) + p / (1.0 - p),
            )
            g = np.where(live, 1.0 / ((1.0 - p) * run), 1.0)
            sl = (g * aT).sum(axis=-1)
            u = (g * aU).sum(axis=-1)
            rho = s1 + sl
            wq_next = np.where(
                rho < 1.0, (s2 + u) / (2.0 * (1.0 - rho)), _WAIT_CAP
            )
        return wq_next, rho, g

    # Start from the large-backlog limit.  With staleness = inf that is the
    # point of maximal amortization, so "unstable even there" means
    # unstable, full stop; with finite staleness a huge backlog instead
    # collapses amortization toward FCFS (the head is always stale), which
    # is again exactly the regime whose rho decides stability.
    wq, rho_opt, _ = sweep(np.broadcast_to(_WAIT_CAP, lam.shape).astype(float))
    for _ in range(iters):
        wq_next, _, _ = sweep(wq)
        wq = 0.5 * (wq + wq_next)
    wait, rho, g = sweep(wq)
    # Explicit convergence check at the iteration cap.  The sweep map is
    # *decreasing* in wq (a longer backlog amortizes more, which shortens
    # the wait), so the damped iterate h(w) = (w + f(w)) / 2 is contractive
    # only while f' > -3; near saturation f' can approach and pass that and
    # the orbit either converges too slowly for the cap or settles into a
    # genuine period-2 cycle, where every reported value is an artifact of
    # the iteration count.  Converged elements (every input outside a thin
    # near-saturation shell) sit at float-epsilon residual after the damped
    # loop, far inside the tolerance, and stay bitwise untouched by all of
    # the handling below (updates are masked ``np.where`` writes and each
    # element's iteration count depends only on its own values, so the
    # batch == scalar invariant survives every branch).
    resid_bad = lambda f_wq, w: np.abs(f_wq - w) > (1e-12 + 1e-6 * np.abs(w))
    diverged = resid_bad(wait, wq)
    if np.any(diverged):
        # Slow-but-contractive elements (|h'| just under 1) close with a
        # deterministic extension budget; lanes already converged are frozen
        # by the mask, so their values never move.
        for _ in range(9 * iters):
            wq_next, _, _ = sweep(wq)
            wq = np.where(diverged, 0.5 * (wq + wq_next), wq)
        wait_x, rho_x, g_x = sweep(wq)
        wait = np.where(diverged, wait_x, wait)
        rho = np.where(diverged, rho_x, rho)
        g = np.where(diverged[..., None], g_x, g)
        diverged = resid_bad(wait, wq)
    if np.any(diverged):
        # Genuine non-convergence (a period-2 orbit): fall back to the
        # *unamortized* swap term (g = 1, the plain FCFS Eq. 1/Eq. 10
        # moments).  Amortization can only shorten the wait, so this is a
        # safe conservative price -- it may report inf for a queue that
        # batching would just barely stabilize, which is preferable to an
        # oscillation artifact that depends on the iteration cap.
        sl_f = aT.sum(axis=-1)
        u_f = aU.sum(axis=-1)
        rho_f = s1 + sl_f
        with np.errstate(divide="ignore", invalid="ignore"):
            wait_f = np.where(
                rho_f < 1.0, (s2 + u_f) / (2.0 * (1.0 - rho_f)), np.inf
            )
        wait = np.where(diverged, wait_f, wait)
        rho = np.where(diverged, rho_f, rho)
        g = np.where(diverged[..., None], 1.0, g)
    unstable = rho_opt >= 1.0
    wait = np.where(unstable, np.inf, np.where(lam > 0.0, wait, 0.0))
    return wait, rho, np.where(live, g * alphas, alphas)


def mixture_moments(weights: list[float], values: list[float]) -> tuple[float, float]:
    """First and second moments of a discrete mixture distribution.

    ``weights`` need not be normalized; each request class i has a
    *deterministic* service time ``values[i]`` and probability proportional
    to ``weights[i]`` -- the TPU service distribution of Eq. 2.
    """
    tot = sum(weights)
    if tot <= 0.0:
        return 0.0, 0.0
    m1 = sum(w * v for w, v in zip(weights, values)) / tot
    m2 = sum(w * v * v for w, v in zip(weights, values)) / tot
    return m1, m2


def mixture_moments_batch(
    weights: np.ndarray, values: np.ndarray, axis: int = -1
) -> tuple[np.ndarray, np.ndarray]:
    """Batched ``mixture_moments``: reduce the atom axis of stacked mixtures.

    ``weights`` and ``values`` broadcast against each other; mixtures whose
    total weight is <= 0 get (0, 0), matching the scalar guard.
    """
    weights = np.asarray(weights, dtype=np.float64)
    values = np.asarray(values, dtype=np.float64)
    tot = weights.sum(axis=axis)
    with np.errstate(divide="ignore", invalid="ignore"):
        m1 = (weights * values).sum(axis=axis) / tot
        m2 = (weights * values * values).sum(axis=axis) / tot
    ok = tot > 0.0
    return np.where(ok, m1, 0.0), np.where(ok, m2, 0.0)
