"""Queueing primitives from the paper's analytic model (Section III-B).

* TPU: single unified M/G/1/FCFS queue; expected wait via Pollaczek-Khinchine
  (Eq. 1) with the effective service time the lambda-weighted mixture over
  model prefixes including inter-model swap latency (Eq. 2).
* CPU: per-model M/D/k queues with dedicated cores (Eq. 3).
"""
from __future__ import annotations

import math


def mg1_wait(lam: float, es: float, es2: float) -> float:
    """Pollaczek-Khinchine expected queueing delay for an M/G/1/FCFS queue.

    Args:
      lam: aggregate Poisson arrival rate (1/s).
      es: E[S], mean service time (s).
      es2: E[S^2], second moment of service time (s^2).

    Returns:
      E[W] in seconds; ``inf`` if the queue is unstable (rho >= 1).
    """
    if lam <= 0.0:
        return 0.0
    rho = lam * es
    if rho >= 1.0:
        return math.inf
    return lam * es2 / (2.0 * (1.0 - rho))


def mdk_wait(lam: float, mu: float, k: int) -> float:
    """Approximate expected queueing delay for an M/D/k queue (Eq. 3).

    E[W] ~= 1/2 * (1/(k*mu - lam) - 1/(k*mu))  -- i.e. half the M/M/1-style
    wait of a pooled server, halved for deterministic service.
    """
    if lam <= 0.0:
        return 0.0
    if k <= 0 or mu <= 0:
        return math.inf
    cap = k * mu
    if lam >= cap:
        return math.inf
    return 0.5 * (1.0 / (cap - lam) - 1.0 / cap)


def mixture_moments(weights: list[float], values: list[float]) -> tuple[float, float]:
    """First and second moments of a discrete mixture distribution.

    ``weights`` need not be normalized; each request class i has a
    *deterministic* service time ``values[i]`` and probability proportional
    to ``weights[i]`` -- the TPU service distribution of Eq. 2.
    """
    tot = sum(weights)
    if tot <= 0.0:
        return 0.0, 0.0
    m1 = sum(w * v for w, v in zip(weights, values)) / tot
    m2 = sum(w * v * v for w, v in zip(weights, values)) / tot
    return m1, m2
