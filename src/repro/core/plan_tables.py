"""Precomputed per-tenant cost tables for the vectorized plan evaluator.

The online allocator (Algorithm 1) evaluates hundreds of candidate plans per
re-planning step.  Each evaluation of the scalar objective walks Python-level
per-tenant loops; its cost grows with tenants x partition points and caps the
mixes the <2 ms re-plan budget can handle.

Two cache levels feed the batch evaluator (``latency.*_batch``):

* ``PlanTables`` -- rate-independent per-``(tenant, p[, k])`` quantities
  (prefix service, T_load, boundary transfer, suffix CPU times, prefix
  weights).  Depends only on (profiles, platform), so a serving controller
  builds it once and reuses it across every re-plan as rates drift.

* ``EvalTables`` -- rate-aware contribution tables derived from a
  ``PlanTables``.  The Eq. 1-5 objective decomposes into per-tenant sums
  plus row-global coupling through ``lam_TPU`` and the shared-cache regime
  of Eq. 10:

      total = sum_i phi(i, p_i, k_i)                 [static per-tenant]
            + lam_TPU * W_TPU                        [M/G/1 wait, Eq. 1]
            + shared * (SL - Q / lam_TPU)            [swap term, Eq. 10]

  with the M/G/1 moment numerators themselves per-tenant sums
  (S1 + shared*(SL - Q/lam), S2 + shared*(U - V/lam)).  ``EvalTables``
  stores every per-tenant summand as a dense array, so evaluating B
  candidate plans costs two gathers + two row-sums + O(1) vector ops on
  [B]-shaped arrays -- independent of the per-plan Python work the scalar
  path pays.

Padded (p > P_i) cells are poisoned with NaN: any accidental gather of an
out-of-range partition point surfaces as NaN instead of silently pricing an
impossible plan.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import queueing
from repro.core.planner import ModelProfile, TenantSpec
from repro.hw.specs import Platform

_PAD = np.nan

# Column layout of EvalTables.pstack ([n, P_max+1, 9]).
(
    PCOL_LAM,      # rate * 1{p > 0}              -> lam_TPU
    PCOL_ACTIVE,   # 1{p > 0}                     -> n_active (Eq. 10 regime)
    PCOL_WEIGHT,   # prefix weight bytes          -> aggregate footprint W(P)
    PCOL_S1,       # rate * s_tpu                 -> E[S] numerator
    PCOL_S2,       # rate * s_tpu^2               -> E[S^2] numerator
    PCOL_SL,       # rate * T_load                -> swap-term sum
    PCOL_Q,        # rate^2 * T_load              -> swap-term / lam part
    PCOL_U,        # rate * T_load * (2 s + T_load)   -> E[S^2] swap part
    PCOL_V,        # rate^2 * T_load * (2 s + T_load) -> E[S^2] / lam part
) = range(9)

# Column layout of EvalTables.pkstack ([n, P_max+1, k_max+1, 2]).
PKCOL_STATIC, PKCOL_OVERLOAD = range(2)


def _padded(rows: Sequence[np.ndarray], width: int) -> np.ndarray:
    out = np.full((len(rows), width), _PAD, dtype=np.float64)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


@dataclasses.dataclass(frozen=True)
class PlanTables:
    """Rate-free per-(tenant, p[, k]) cost tables on one platform."""

    profiles: tuple[ModelProfile, ...]
    platform: Platform
    num_points: np.ndarray      # [n] int, P_i per tenant
    input_xfer: np.ndarray      # [n] input transfer time (s)
    prefix_service: np.ndarray  # [n, P_max+1] s_TPU: compute + intra-swap
    load: np.ndarray            # [n, P_max+1] T_load
    boundary: np.ndarray        # [n, P_max+1] boundary transfer at cut p
    suffix1: np.ndarray         # [n, P_max+1] 1-core CPU suffix time
    prefix_weight: np.ndarray   # [n, P_max+1] TPU-resident bytes
    k_max: int
    tenant_idx: np.ndarray = dataclasses.field(repr=False, default=None)  # [n]
    # Per-tenant sorted non-dominated partition points (see
    # ``ModelProfile.pareto_points`` for the dominance relation and proof).
    # Always contains 0 and P_i; the searchers' ``prune`` flag opts out.
    frontiers: tuple[np.ndarray, ...] = dataclasses.field(
        repr=False, default=None
    )

    def __post_init__(self) -> None:
        if self.tenant_idx is None:
            object.__setattr__(self, "tenant_idx", np.arange(len(self.profiles)))
        if self.frontiers is None:
            object.__setattr__(
                self, "frontiers", tuple(p.pareto_points for p in self.profiles)
            )

    @property
    def frontier_sizes(self) -> np.ndarray:
        return np.array([len(f) for f in self.frontiers])

    @property
    def n_tenants(self) -> int:
        return len(self.profiles)

    @classmethod
    def build(
        cls,
        profiles: Sequence[ModelProfile],
        platform: Platform,
        k_max: int,
    ) -> "PlanTables":
        bw = platform.swap_bw
        sram = platform.sram_bytes
        n_points = np.array([p.num_partition_points for p in profiles])
        width = int(n_points.max()) + 1 if len(profiles) else 1

        svc_rows, load_rows, bnd_rows, w_rows, sfx_rows = [], [], [], [], []
        for prof in profiles:
            P = prof.num_partition_points
            cum_w = prof._cum_weight.astype(np.float64)  # [P+1]
            cum_tpu = prof._cum_tpu                      # [P+1]
            # s_TPU(p) = prefix compute + overflow streamed per request.
            overflow = np.maximum(0.0, cum_w - sram)
            svc = cum_tpu + overflow / bw
            svc[0] = 0.0  # prefix_service_time short-circuits p <= 0
            svc_rows.append(svc)
            # T_load(p): only the normally-resident part reloads on a miss.
            load_rows.append(np.minimum(cum_w, sram) / bw)
            # Boundary tensor transfer at cut p: d_out(p)/B (p=0 entry is the
            # input tensor, matching boundary_bytes; the evaluator charges it
            # only on genuinely split plans).
            bnd = np.empty(P + 1)
            bnd[0] = prof.input_bytes / bw
            if P:
                bnd[1:] = np.array([s.out_bytes for s in prof.segments]) / bw
            bnd_rows.append(bnd)
            w_rows.append(cum_w)
            sfx_rows.append(prof._suffix_cpu1)

        return cls(
            profiles=tuple(profiles),
            platform=platform,
            num_points=n_points,
            input_xfer=np.array([p.input_bytes for p in profiles]) / bw,
            prefix_service=_padded(svc_rows, width),
            load=_padded(load_rows, width),
            boundary=_padded(bnd_rows, width),
            suffix1=_padded(sfx_rows, width),
            prefix_weight=_padded(w_rows, width),
            k_max=k_max,
        )

    @classmethod
    def for_tenants(
        cls,
        tenants: Sequence[TenantSpec],
        platform: Platform,
        k_max: int,
    ) -> "PlanTables":
        return cls.build([t.profile for t in tenants], platform, k_max)

    def matches(
        self, tenants: Sequence[TenantSpec], platform: Platform | None = None
    ) -> bool:
        """True when built for exactly these profiles (and, when given, for
        this platform -- the hardware constants are baked into the tables)."""
        if platform is not None and platform != self.platform:
            return False
        return len(tenants) == len(self.profiles) and all(
            t.profile is p for t, p in zip(tenants, self.profiles)
        )

    def matches_profiles(
        self, profiles: Sequence[ModelProfile], platform: Platform | None = None
    ) -> bool:
        """`matches` on raw profiles (no rates attached) -- the fleet cache
        keys tables on (device class, hosted profiles) where tenant specs
        don't exist yet.  Same `is` identity contract as `matches`."""
        if platform is not None and platform != self.platform:
            return False
        return len(profiles) == len(self.profiles) and all(
            q is p for q, p in zip(profiles, self.profiles)
        )


@dataclasses.dataclass(frozen=True)
class EvalTables:
    """Rate-aware per-tenant contribution tables for one tenant mix.

    ``pstack[i, p, c]`` holds the nine per-(tenant, p) summands (PCOL_*) of
    the row-global objective decomposition; ``pkstack[i, p, k, c]`` holds the
    static latency contribution phi and the CPU-overload term (PKCOL_*).
    Rebuild whenever rates change (~100 us); reuse the ``base`` PlanTables
    across rebuilds.
    """

    base: PlanTables
    rates: np.ndarray           # [n]
    sram_bytes: int
    k_max: int
    pstack: np.ndarray          # [n, P_max+1, 9]
    pkstack: np.ndarray         # [n, P_max+1, k_max+1, 2]

    @property
    def tenant_idx(self) -> np.ndarray:
        return self.base.tenant_idx

    @property
    def num_points(self) -> np.ndarray:
        return self.base.num_points

    @classmethod
    def build(
        cls,
        tenants: Sequence[TenantSpec],
        platform: Platform,
        k_max: int,
        *,
        base: PlanTables | None = None,
    ) -> "EvalTables":
        if base is None or not base.matches(tenants, platform):
            base = PlanTables.for_tenants(tenants, platform, k_max)
        n = len(tenants)
        rates = np.array([t.rate for t in tenants], dtype=np.float64)
        r = rates[:, None]                                  # [n, 1]
        svc, tl, s1 = base.prefix_service, base.load, base.suffix1
        width = svc.shape[1]
        col = np.arange(width)[None, :]                     # [1, W]
        on_tpu = col > 0
        on_cpu = col < base.num_points[:, None]

        # --- per-(tenant, p) summands -------------------------------------
        # s_tpu and T_load are 0 at p=0, so only lam/active need the mask.
        t2 = tl * (2.0 * svc + tl)
        pstack = np.stack(
            [
                r * on_tpu,             # PCOL_LAM (finite in pad cells; the
                on_tpu + 0.0 * svc,     # PCOL_ACTIVE   svc NaN poisons S1)
                base.prefix_weight,     # PCOL_WEIGHT
                r * svc,                # PCOL_S1
                r * svc * svc,          # PCOL_S2
                r * tl,                 # PCOL_SL
                r * r * tl,             # PCOL_Q
                r * t2,                 # PCOL_U
                r * r * t2,             # PCOL_V
            ],
            axis=-1,
        )

        # --- per-(tenant, p, k) summands ----------------------------------
        # phi(i, p, k) = r_i * [ 1{p>0}(input_xfer + s_tpu)
        #                        + 1{0<p<P} boundary_xfer
        #                        + 1{p<P}(mdk_wait + s_cpu_1core) ]
        with np.errstate(divide="ignore", invalid="ignore"):
            mu_one = 1.0 / s1                               # inf on empty suffix
        k = np.arange(k_max + 1, dtype=np.float64)[None, None, :]
        mdk = queueing.mdk_wait_batch(r[:, :, None], mu_one[:, :, None], k)
        cpu_term = np.where(
            on_cpu[:, :, None], s1[:, :, None] + mdk, 0.0
        )                                                   # [n, W, K+1]
        tpu_term = np.where(on_tpu, base.input_xfer[:, None] + svc, 0.0)
        bnd_term = np.where(on_tpu & on_cpu, base.boundary, 0.0)
        phi = r[:, :, None] * ((tpu_term + bnd_term)[:, :, None] + cpu_term)
        # CPU overload: max(0, r * s1 / max(k, 1) - 1); 0 on full-TPU rows
        # (s1 == 0) without an explicit 1{p<P} mask, as in the scalar path.
        over = np.maximum(0.0, (r * s1)[:, :, None] / np.maximum(k, 1.0) - 1.0)
        pkstack = np.stack([phi, over], axis=-1)

        return cls(
            base=base,
            rates=rates,
            sram_bytes=platform.sram_bytes,
            k_max=k_max,
            pstack=pstack,
            pkstack=pkstack,
        )

    def matches(
        self, tenants: Sequence[TenantSpec], platform: Platform | None = None
    ) -> bool:
        """True when built for exactly these profiles at exactly these rates
        (and, when given, for this platform)."""
        return self.base.matches(tenants, platform) and all(
            t.rate == r for t, r in zip(tenants, self.rates)
        )

    def to_jax(self):
        """Device-resident ``repro.core.jax_eval.JaxPlanEvaluator`` over
        these tables (float32, statistical-equivalence contract; the NumPy
        evaluator over ``self`` stays the bitwise reference).  Imported
        lazily so this module keeps zero accelerator dependencies."""
        from repro.core.jax_eval import JaxPlanEvaluator

        return JaxPlanEvaluator.from_tables(self)
