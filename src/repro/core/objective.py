"""Pluggable planning objectives: mean (Eq. 5), tail latency, deadline miss.

The paper's planner minimizes the *mean* end-to-end response time (Eq. 5,
``sum_i lambda_i * T_i``).  Real multi-tenant deployments contract on SLOs
-- per-tenant p99 budgets and deadline-miss rates -- so every evaluator
(``latency.penalized_objective`` scalar reference, the ``EvalTables``
batched + delta paths, ``JaxPlanEvaluator``, ``fleet_plan_objective``) and
both adaptive controllers accept an ``objective=`` spec:

* ``MEAN`` (or ``objective=None``, the default): Eq. 5 exactly.  The
  ``None`` default routes through the pre-refactor code paths untouched --
  "objectives are opt-in; mean stays pinned" (ROADMAP standing invariant).
* ``p_tail(q)``: ``sum_i lambda_i * T_i(q)`` where ``T_i(q)`` adds the
  q-quantile of each queueing delay (``queueing.wait_tail_quantile``, the
  M/G/1 exponential-tail model) instead of its mean.  Summing marginal
  quantiles is conservative (the waits are positively associated through
  the shared TPU queue but the quantile of a sum is below the sum of
  quantiles); ``benchmarks/model_vs_sim.py`` maps the approximation error
  against the DES ground truth.
* ``deadline_miss()``: ``sum_i lambda_i * P(T_i > d_i)`` against the
  per-tenant latency budgets carried on the mix (``TenantSpec.deadline``).
  Tenants without a deadline never miss (they contribute 0); a tenant
  whose *static* latency already exceeds its budget misses with
  probability 1, making the objective monotone in the budget.

Objective identity (including the deadline vector, which the mix
fingerprint does not cover) must enter every memoization key -- see
``objective_key`` and ``core.plan_cache``.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

_KINDS = ("mean", "p_tail", "deadline_miss")


@dataclasses.dataclass(frozen=True)
class Objective:
    """Planning-objective spec consumed by every evaluator.

    ``kind`` is one of ``mean`` / ``p_tail`` / ``deadline_miss``; ``q`` is
    the tail quantile (only meaningful for ``p_tail``, kept at its default
    elsewhere so specs hash and compare predictably).
    """

    kind: str = "mean"
    q: float = 0.99

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown objective kind {self.kind!r}: valid kinds are "
                f"{', '.join(_KINDS)}"
            )
        if not (0.0 < self.q < 1.0):
            raise ValueError(f"quantile q must be in (0, 1), got {self.q}")

    @property
    def is_mean(self) -> bool:
        return self.kind == "mean"


MEAN = Objective()


def p_tail(q: float = 0.99) -> Objective:
    """Tail-latency objective: minimize ``sum_i lambda_i * T_i(q)``."""
    return Objective("p_tail", q)


def deadline_miss() -> Objective:
    """Deadline objective: minimize the rate of deadline misses."""
    return Objective("deadline_miss")


def is_default(objective: Objective | None) -> bool:
    """True when ``objective`` selects the pinned Eq. 5 mean path.

    Both ``None`` and an explicit mean spec route through the exact
    pre-refactor code -- the bitwise standing invariant.
    """
    return objective is None or objective.is_mean


def deadlines_of(tenants) -> np.ndarray:
    """Per-tenant deadline vector; no-deadline tenants get ``inf``.

    ``inf`` budgets make the miss probability exactly 0 through the
    ``wait_exceed_prob`` conventions, so deadline-free tenants contribute
    nothing to a ``deadline_miss`` objective without special-casing.
    """
    return np.array(
        [
            math.inf if t.deadline is None else float(t.deadline)
            for t in tenants
        ],
        dtype=np.float64,
    )


def objective_key(objective: Objective | None, tenants):
    """Hashable objective-identity component for plan-cache keys.

    ``None`` for the default mean (keeps the pinned keyspace); otherwise
    the kind plus whatever extra state the objective reads -- the quantile
    for ``p_tail``, the full per-tenant deadline vector for
    ``deadline_miss`` (the mix fingerprint excludes deadlines, so without
    this two mixes differing only in budgets would collide and
    verify-then-reuse would compare different metrics).
    """
    if is_default(objective):
        return None
    if objective.kind == "p_tail":
        return ("p_tail", objective.q)
    return (
        "deadline_miss",
        tuple(None if t.deadline is None else float(t.deadline) for t in tenants),
    )
