"""JAX-native batched plan evaluator: Eq. 1-5 / Eq. 10 on device.

``JaxPlanEvaluator`` ports the ``EvalTables`` decomposition of the Eq. 5
objective (see ``plan_tables.py``) to jitted JAX: scoring B candidate
plans is two device gathers, two row-sums, and O(1) vector math -- one
fused XLA call for a whole hill-climb neighbor frontier, or for a
Monte-Carlo batch of rate draws.  ``hill_climb(evaluator=...)`` plugs it
into Algorithm 1's batched walk.

Contract (ROADMAP standing invariant): the NumPy evaluator
(``latency.objective_batch`` et al.) is the bitwise-pinned reference;
this one runs in float32 (no global ``jax_enable_x64`` -- the serving
stack's float64 NumPy paths must stay untouched) and is *statistically
equivalent*: objectives agree to ~1e-5 relative, and committed hill-climb
plans are identical except where two candidates tie within float32
round-off (~1e-7 relative -- orders of magnitude below any latency
difference the paper's mixes produce; ``tests/test_jax_sim.py`` pins plan
identity on the benchmark mixes).

Both aggregation tails are ported exactly:

* the FCFS tail with the Eq. 10 shared-occupancy collapse
  ``(SL - Q/lam)`` / ``(U - V/lam)`` and the Pollaczek-Khinchine wait;
* the ``swap_batch`` tail with the damped amortization fixed point --
  same formulas, same 60-sweep damped loop, same masked 540-sweep
  extension and unamortized-FCFS fallback as
  ``queueing.swap_batch_amortization``, so the two implementations agree
  wherever the fixed point converges (the extension is a ``lax.cond`` so
  the common converged case never pays for it).

The padded (p > P_i) table cells keep their NaN poison: a candidate row
gathering an out-of-range partition point scores NaN, never a silently
finite price.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.plan_tables import (
    EvalTables,
    PCOL_ACTIVE,
    PCOL_LAM,
    PCOL_Q,
    PCOL_S1,
    PCOL_S2,
    PCOL_SL,
    PCOL_U,
    PCOL_V,
    PCOL_WEIGHT,
    PKCOL_OVERLOAD,
    PKCOL_STATIC,
)
from repro.core.objective import Objective, is_default
from repro.core.planner import FCFS, DisciplineSpec, TenantSpec
from repro.hw.specs import Platform

__all__ = ["JaxPlanEvaluator"]

_WAIT_CAP = 1e12
_PENALTY_BASE = 1e9  # mirrors latency._PENALTY_BASE


def _tail_quantile(wq, rho, q):
    """jnp port of ``queueing.wait_tail_quantile`` (same conventions)."""
    tail = (wq / rho) * jnp.log(rho / (1.0 - q))
    tail = jnp.where((1.0 - q) >= rho, 0.0, tail)
    tail = jnp.where((rho >= 1.0) | jnp.isinf(wq), jnp.inf, tail)
    return jnp.where((rho <= 0.0) | (wq <= 0.0), 0.0, tail)


def _exceed_prob(wq, rho, t):
    """jnp port of ``queueing.wait_exceed_prob`` (same conventions)."""
    t = jnp.maximum(t, 0.0)
    p = rho * jnp.exp(-rho * t / wq)
    p = jnp.where((wq <= 0.0) | ~jnp.isfinite(wq), 0.0, p)
    p = jnp.where((rho >= 1.0) | jnp.isinf(wq), 1.0, p)
    return jnp.where(rho <= 0.0, 0.0, p)


def _miss_prob(wt, rho_t, wc, rho_c, slack):
    """jnp port of ``latency._miss_prob``: slack split across the TPU and
    CPU waits proportionally to their means, independence combine."""
    wsum = wt + wc
    ft = jnp.where(wsum > 0.0, wt / wsum, 0.0)
    fc = jnp.where(wsum > 0.0, wc / wsum, 0.0)
    sa = jnp.where(ft > 0.0, slack * ft, 0.0)
    sb = jnp.where(fc > 0.0, slack * fc, 0.0)
    pt = _exceed_prob(wt, rho_t, sa)
    pc = _exceed_prob(wc, rho_c, sb)
    miss = 1.0 - (1.0 - pt) * (1.0 - pc)
    return jnp.where(slack < 0.0, 1.0, miss)


@partial(jax.jit, static_argnames=("force_alpha_zero", "kind", "q"))
def _slo_kernel(
    pstack, pkstack, rates, svc_tab, tl_tab, ix_tab, bnd_tab, s1c_tab,
    npoints, sram_bytes, deadlines,
    P, K,
    force_alpha_zero: bool, kind: str, q: float,
):
    """(value, overload) for B plans under a non-mean objective.

    The jnp port of ``latency._batch_eval_slo``'s FCFS tail: per-tenant
    [B, n] gathers of the static pieces, the Eq. 10 per-tenant alphas, the
    Pollaczek-Khinchine wait, the in-graph M/D/k pool wait, then either the
    quantile latencies (``p_tail``) or the slack-split miss probabilities
    (``deadline_miss``).  Same float32 statistical-equivalence contract as
    the mean kernel.
    """
    n = P.shape[1]
    ti = jnp.arange(n)
    A = pstack[ti, P].sum(axis=1)        # [B, 9]
    F = pkstack[ti, P, K].sum(axis=1)    # [B, 2]
    lam = A[:, PCOL_LAM]
    on = P > 0
    on_cpu = P < npoints[None, :]
    r_full = jnp.broadcast_to(rates[None, :], P.shape)
    r = jnp.where(on, r_full, 0.0)
    svc = jnp.where(on, svc_tab[ti, P], 0.0)
    tl = jnp.where(on, tl_tab[ti, P], 0.0)

    if force_alpha_zero:
        alphas = jnp.zeros_like(r)
    else:
        shared = (
            (A[:, PCOL_WEIGHT] > sram_bytes)
            & (A[:, PCOL_ACTIVE] > 1.0)
            & (lam > 0.0)
        )
        safe_lam = jnp.where(lam > 0.0, lam, 1.0)
        alphas = jnp.where(
            shared[:, None] & on,
            jnp.maximum(0.0, 1.0 - r / safe_lam[:, None]),
            0.0,
        )
    sl = (r * alphas * tl).sum(axis=-1)
    u = (r * alphas * tl * (2.0 * svc + tl)).sum(axis=-1)
    rho_tpu = A[:, PCOL_S1] + sl
    es2_num = A[:, PCOL_S2] + u
    tpu_wait = jnp.where(
        rho_tpu >= 1.0, jnp.inf, es2_num / (2.0 * (1.0 - rho_tpu))
    )
    swap_i = alphas * tl

    s1c = jnp.where(on_cpu, s1c_tab[ti, P], 0.0)
    kf = K.astype(svc.dtype)
    mu_one = jnp.where(s1c > 0.0, 1.0 / jnp.where(s1c > 0.0, s1c, 1.0), jnp.inf)
    cap = kf * mu_one
    cpu_wait = 0.5 * (1.0 / (cap - r_full) - 1.0 / cap)
    cpu_wait = jnp.where(r_full >= cap, jnp.inf, cpu_wait)
    cpu_wait = jnp.where((kf <= 0.0) | (mu_one <= 0.0), jnp.inf, cpu_wait)
    cpu_wait = jnp.where(r_full <= 0.0, 0.0, cpu_wait)
    cpu_wait = jnp.where(on_cpu, cpu_wait, 0.0)
    rho_cpu = r_full * s1c / jnp.maximum(kf, 1.0)

    static = (
        jnp.where(on, ix_tab[None, :], 0.0)
        + svc
        + jnp.where(on & on_cpu, bnd_tab[ti, P], 0.0)
        + s1c
    )
    wt = jnp.where(on, tpu_wait[:, None], 0.0)
    if kind == "p_tail":
        tail_t = _tail_quantile(wt, rho_tpu[:, None], q)
        tail_c = _tail_quantile(cpu_wait, rho_cpu, q)
        vals = static + swap_i + tail_t + tail_c
    else:
        slack = deadlines[None, :] - static - swap_i
        vals = _miss_prob(wt, rho_tpu[:, None], cpu_wait, rho_cpu, slack)
    value = (r_full * vals).sum(axis=1)
    overload = jnp.maximum(0.0, rho_tpu - 1.0) + F[:, PKCOL_OVERLOAD]
    return value, overload


@partial(
    jax.jit,
    static_argnames=("force_alpha_zero", "batches", "batch_cap", "staleness"),
)
def _objective_kernel(
    pstack, pkstack, rates, svc_tab, tl_tab, sram_bytes,
    P, K,
    force_alpha_zero: bool, batches: bool, batch_cap: int, staleness: float,
):
    """(total, overload) for B candidate plans; [B, n] int32 P/K inputs.

    One fused graph: gathers, per-tenant sums, and whichever aggregation
    tail the (static) discipline flags select.
    """
    n = P.shape[1]
    ti = jnp.arange(n)
    A = pstack[ti, P].sum(axis=1)        # [B, 9]
    F = pkstack[ti, P, K].sum(axis=1)    # [B, 2]
    lam = A[:, PCOL_LAM]
    S1 = A[:, PCOL_S1]
    S2 = A[:, PCOL_S2]
    zero_rate = (rates <= 0.0).any()

    if batches and not force_alpha_zero:
        # ---- swap_batch amortized tail --------------------------------
        on = P > 0
        r = jnp.where(on, rates[None, :], 0.0)
        svc = jnp.where(on, svc_tab[ti, P], 0.0)
        tl = jnp.where(on, tl_tab[ti, P], 0.0)
        shared = (
            (A[:, PCOL_WEIGHT] > sram_bytes)
            & (A[:, PCOL_ACTIVE] > 1.0)
            & (lam > 0.0)
        )
        safe_lam = jnp.where(lam > 0.0, lam, 1.0)
        alphas = jnp.where(
            shared[:, None] & on,
            jnp.maximum(0.0, 1.0 - r / safe_lam[:, None]),
            0.0,
        )
        p = jnp.where(lam[:, None] > 0.0, r / safe_lam[:, None], 0.0)
        live = (alphas > 0.0) & (p < 1.0)
        p = jnp.where(live, p, 0.0)
        aT = r * alphas * tl
        aU = aT * (2.0 * svc + tl)
        Bc = int(batch_cap)

        def sweep(wq):
            wq_e = wq[..., None]
            rw = r * wq_e
            ratio = jnp.where(wq_e > 0.0, staleness / wq_e, jnp.inf)
            fresh = 1.0 - jnp.exp(-ratio)
            q = jnp.where(live, fresh * rw / (1.0 + rw), 0.0)
            c = q + (1.0 - q) * p
            run = jnp.where(
                c < 1.0,
                (1.0 - c**Bc) / (1.0 - c) + c ** (Bc - 1) * p / (1.0 - p),
                float(Bc) + p / (1.0 - p),
            )
            g = jnp.where(live, 1.0 / ((1.0 - p) * run), 1.0)
            sl = (g * aT).sum(axis=-1)
            u = (g * aU).sum(axis=-1)
            rho = S1 + sl
            wq_next = jnp.where(
                rho < 1.0, (S2 + u) / (2.0 * (1.0 - rho)), _WAIT_CAP
            )
            return wq_next, rho, g

        wq0, rho_opt, _ = sweep(jnp.full(lam.shape, _WAIT_CAP))
        wq = jax.lax.fori_loop(
            0, 60, lambda _, w: 0.5 * (w + sweep(w)[0]), wq0
        )
        wait, rho, g = sweep(wq)

        # Relative residual: float32 never resolves the 1e-12 absolute
        # floor, so the effective tolerance is the 1e-6 relative part --
        # converged lanes sit at ~1e-7 relative after the damped loop.
        resid_bad = lambda f_wq, w: jnp.abs(f_wq - w) > (
            1e-12 + 1e-6 * jnp.abs(w)
        )
        diverged = resid_bad(wait, wq)

        def extend(args):
            wq, wait, rho, g, diverged = args

            def body(_, w):
                return jnp.where(diverged, 0.5 * (w + sweep(w)[0]), w)

            wq = jax.lax.fori_loop(0, 9 * 60, body, wq)
            wait_x, rho_x, g_x = sweep(wq)
            wait2 = jnp.where(diverged, wait_x, wait)
            rho2 = jnp.where(diverged, rho_x, rho)
            g2 = jnp.where(diverged[..., None], g_x, g)
            still = resid_bad(wait2, wq)
            # Period-2 orbits: unamortized FCFS fallback (g = 1).
            sl_f = aT.sum(axis=-1)
            u_f = aU.sum(axis=-1)
            rho_f = S1 + sl_f
            wait_f = jnp.where(
                rho_f < 1.0, (S2 + u_f) / (2.0 * (1.0 - rho_f)), jnp.inf
            )
            wait2 = jnp.where(still, wait_f, wait2)
            rho2 = jnp.where(still, rho_f, rho2)
            g2 = jnp.where(still[..., None], 1.0, g2)
            return wq, wait2, rho2, g2, diverged

        wq, wait, rho, g = jax.lax.cond(
            diverged.any(),
            extend,
            lambda args: args[:4] + (args[4],),
            (wq, wait, rho, g, diverged),
        )[:4]

        unstable = rho_opt >= 1.0
        wait = jnp.where(
            unstable, jnp.inf, jnp.where(lam > 0.0, wait, 0.0)
        )
        alpha_eff = jnp.where(live, g * alphas, alphas)
        swap_latency = (r * alpha_eff * tl).sum(axis=-1)
        total = F[:, PKCOL_STATIC] + lam * wait + swap_latency
        # Zero-rate NaN convention: a zero-rate tenant on an unstable TPU
        # queue contributes 0 * inf = NaN in the scalar per-tenant sum.
        zr_on_tpu = ((rates <= 0.0)[None, :] & (P > 0)).any(axis=1)
        total = jnp.where(
            zero_rate & zr_on_tpu & jnp.isinf(wait), jnp.nan, total
        )
        overload = jnp.maximum(0.0, rho - 1.0) + F[:, PKCOL_OVERLOAD]
        return total, overload

    # ---- FCFS tail ----------------------------------------------------
    if force_alpha_zero:
        swap_term = jnp.zeros_like(lam)
        rho_tpu = S1
        es2_num = S2
    else:
        shared = (
            (A[:, PCOL_WEIGHT] > sram_bytes)
            & (A[:, PCOL_ACTIVE] > 1.0)
            & (lam > 0.0)
        )
        inv_lam = jnp.where(shared, 1.0 / jnp.where(lam > 0.0, lam, 1.0), 0.0)
        swap_term = (A[:, PCOL_SL] - A[:, PCOL_Q] * inv_lam) * shared
        rho_tpu = S1 + swap_term
        es2_num = S2 + (A[:, PCOL_U] - A[:, PCOL_V] * inv_lam) * shared

    tpu_wait = jnp.where(
        rho_tpu >= 1.0, jnp.inf, es2_num / (2.0 * (1.0 - rho_tpu))
    )
    total = F[:, PKCOL_STATIC] + lam * tpu_wait + swap_term
    zr_on_tpu = ((rates <= 0.0)[None, :] & (P > 0)).any(axis=1)
    total = jnp.where(
        zero_rate & zr_on_tpu & jnp.isinf(tpu_wait), jnp.nan, total
    )
    overload = jnp.maximum(0.0, rho_tpu - 1.0) + F[:, PKCOL_OVERLOAD]
    return total, overload


@dataclasses.dataclass(frozen=True)
class JaxPlanEvaluator:
    """Device-resident batched Eq. 5 evaluator for one (mix, rates) pair.

    Build once per re-plan (``EvalTables.to_jax()`` or
    ``JaxPlanEvaluator.build``); every ``*_batch`` call is then one jitted
    gather/sum/aggregate graph.  Rebuild when rates change, exactly like
    ``EvalTables`` itself (the device transfer is a few kilobytes).
    """

    et: EvalTables
    pstack: jax.Array     # [n, W, 9] float32
    pkstack: jax.Array    # [n, W, K+1, 2] float32
    rates: jax.Array      # [n] float32
    svc_tab: jax.Array    # [n, W] float32
    tl_tab: jax.Array     # [n, W] float32
    ix_tab: jax.Array     # [n] float32 input transfer (SLO objectives)
    bnd_tab: jax.Array    # [n, W] float32 boundary transfer
    s1c_tab: jax.Array    # [n, W] float32 one-core CPU suffix time
    npoints: jax.Array    # [n] int32 partition points per tenant

    @classmethod
    def from_tables(cls, et: EvalTables) -> "JaxPlanEvaluator":
        return cls(
            et=et,
            pstack=jnp.asarray(et.pstack, dtype=jnp.float32),
            pkstack=jnp.asarray(et.pkstack, dtype=jnp.float32),
            rates=jnp.asarray(et.rates, dtype=jnp.float32),
            svc_tab=jnp.asarray(et.base.prefix_service, dtype=jnp.float32),
            tl_tab=jnp.asarray(et.base.load, dtype=jnp.float32),
            ix_tab=jnp.asarray(et.base.input_xfer, dtype=jnp.float32),
            bnd_tab=jnp.asarray(et.base.boundary, dtype=jnp.float32),
            s1c_tab=jnp.asarray(et.base.suffix1, dtype=jnp.float32),
            npoints=jnp.asarray(et.base.num_points, dtype=jnp.int32),
        )

    @classmethod
    def build(
        cls,
        tenants: Sequence[TenantSpec],
        platform: Platform,
        k_max: int,
        *,
        tables=None,
    ) -> "JaxPlanEvaluator":
        base = getattr(tables, "base", tables)
        et = (
            tables
            if isinstance(tables, EvalTables)
            and tables.matches(tenants, platform)
            else EvalTables.build(tenants, platform, k_max, base=base)
        )
        return cls.from_tables(et)

    def matches(
        self, tenants: Sequence[TenantSpec], platform: Platform | None = None
    ) -> bool:
        return self.et.matches(tenants, platform)

    def _eval(
        self,
        partitions,
        cores,
        force_alpha_zero,
        discipline,
        objective=None,
        deadlines=None,
    ):
        P = jnp.asarray(np.asarray(partitions, dtype=np.int32))
        K = jnp.asarray(np.asarray(cores, dtype=np.int32))
        if P.ndim != 2 or P.shape != K.shape:
            raise ValueError(
                f"expected [B, n] partitions/cores, got {P.shape}/{K.shape}"
            )
        if not is_default(objective):
            if discipline.batches:
                raise ValueError(
                    "JaxPlanEvaluator does not support SLO objectives under "
                    "batching disciplines; use the NumPy evaluator "
                    "(hill_climb without evaluator=)"
                )
            if deadlines is None:
                deadlines = np.full(self.rates.shape[0], np.inf)
            value, overload = _slo_kernel(
                self.pstack, self.pkstack, self.rates,
                self.svc_tab, self.tl_tab, self.ix_tab, self.bnd_tab,
                self.s1c_tab, self.npoints,
                float(self.et.sram_bytes),
                jnp.asarray(np.asarray(deadlines, dtype=np.float32)),
                P, K,
                force_alpha_zero=bool(force_alpha_zero),
                kind=objective.kind,
                q=float(objective.q),
            )
            return value, overload
        total, overload = _objective_kernel(
            self.pstack, self.pkstack, self.rates, self.svc_tab, self.tl_tab,
            float(self.et.sram_bytes), P, K,
            force_alpha_zero=bool(force_alpha_zero),
            batches=bool(discipline.batches),
            batch_cap=int(discipline.batch_cap),
            staleness=float(discipline.staleness),
        )
        return total, overload

    def objective_batch(
        self,
        partitions,
        cores,
        *,
        force_alpha_zero: bool = False,
        discipline: DisciplineSpec = FCFS,
        objective: Objective | None = None,
        deadlines=None,
    ) -> np.ndarray:
        """Eq. 5 objective for B plans; float32-on-device, float64 out."""
        total, _ = self._eval(
            partitions, cores, force_alpha_zero, discipline, objective,
            deadlines,
        )
        return np.asarray(total, dtype=np.float64)

    def penalized_objective_batch(
        self,
        partitions,
        cores,
        *,
        force_alpha_zero: bool = False,
        discipline: DisciplineSpec = FCFS,
        objective: Objective | None = None,
        deadlines=None,
    ) -> np.ndarray:
        """Batched ``latency.penalized_objective`` under the statistical
        contract: infeasible plans priced at ``_PENALTY_BASE * (1 +
        overload)``, exactly the NumPy convention.

        ``objective=`` selects the opt-in SLO objectives (``deadlines``
        carries the per-tenant budget vector for ``deadline_miss`` -- the
        evaluator holds tables, not tenant specs); the ``None`` default is
        the pinned mean kernel.
        """
        total, overload = self._eval(
            partitions, cores, force_alpha_zero, discipline, objective,
            deadlines,
        )
        total = np.asarray(total, dtype=np.float64)
        overload = np.asarray(overload, dtype=np.float64)
        feasible = (overload == 0.0) & np.isfinite(total)
        return np.where(feasible, total, _PENALTY_BASE * (1.0 + overload))
