"""Weight-miss probability and aggregate-footprint modeling (Eq. 10)."""
from __future__ import annotations

from typing import Sequence

from repro.core.planner import ModelProfile, Plan, TenantSpec
from repro.hw.specs import Platform


def aggregate_footprint(tenants: Sequence[TenantSpec], partition: Sequence[int]) -> int:
    """W(P): total TPU-resident weight bytes under partitioning P."""
    return sum(
        t.profile.prefix_weight_bytes(p) for t, p in zip(tenants, partition)
    )


def tpu_arrival_rate(tenants: Sequence[TenantSpec], partition: Sequence[int]) -> float:
    """lambda_TPU = sum over models with a non-empty TPU prefix."""
    return sum(t.rate for t, p in zip(tenants, partition) if p > 0)


def weight_miss_probs(
    tenants: Sequence[TenantSpec],
    partition: Sequence[int],
    platform: Platform,
) -> list[float]:
    """alpha_Mi(P) per Eq. 10.

    Regime 1 (alpha = 0): the aggregate footprint fits in SRAM, or only a
    single tenant uses the TPU (driver keeps weights persistent).
    Regime 2: shared-occupancy cache; conservative upper bound
    ``1 - lambda_i / lambda_TPU`` -- any intervening request of a different
    model is assumed to evict M_i.
    """
    lam_tpu = tpu_arrival_rate(tenants, partition)
    active = [p > 0 for p in partition]
    n_active = sum(active)
    fits = aggregate_footprint(tenants, partition) <= platform.sram_bytes

    alphas: list[float] = []
    for t, p in zip(tenants, partition):
        if p <= 0:
            alphas.append(0.0)
        elif fits or n_active <= 1 or lam_tpu <= 0.0:
            alphas.append(0.0)
        else:
            alphas.append(max(0.0, 1.0 - t.rate / lam_tpu))
    return alphas
