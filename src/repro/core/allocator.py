"""Greedy hill-climbing joint partitioning + core allocation (Algorithm 1),
the PropAlloc fair-share routine, baseline policies, and a brute-force NLIP
oracle used by tests on small instances.
"""
from __future__ import annotations

import itertools
import math
from typing import Sequence

from repro.core import latency
from repro.core.planner import Plan, TenantSpec, validate_plan
from repro.hw.specs import Platform


def prop_alloc(
    tenants: Sequence[TenantSpec],
    partition: Sequence[int],
    k_max: int,
) -> tuple[int, ...]:
    """Proportional fair-share integer core allocation (Alg. 1, line 2/10).

    Models with a CPU suffix receive cores proportional to their CPU workload
    ``lambda_i * s_cpu_suffix(1 core)``, subject to constraint (8): at least
    one core for any model with a suffix, zero cores for full-TPU models.
    Largest-remainder rounding keeps the total at ``min(K_max, ...)``.
    """
    n = len(tenants)
    needs_cpu = [p < t.profile.num_partition_points for t, p in zip(tenants, partition)]
    if not any(needs_cpu):
        return (0,) * n
    loads = [
        t.rate * t.profile.suffix_cpu_time_1core(p) if need else 0.0
        for t, p, need in zip(tenants, partition, needs_cpu)
    ]
    n_need = sum(needs_cpu)
    if n_need > k_max:
        raise ValueError(
            f"{n_need} models need a CPU core but only K_max={k_max} available"
        )
    # Start from the constraint floor: 1 core per suffix-bearing model.
    cores = [1 if need else 0 for need in needs_cpu]
    spare = k_max - n_need
    total_load = sum(loads)
    if spare > 0 and total_load > 0:
        shares = [spare * l / total_load for l in loads]
        floors = [int(math.floor(s)) for s in shares]
        for i in range(n):
            cores[i] += floors[i]
        leftover = spare - sum(floors)
        # Largest remainder first; stable tie-break on index.
        order = sorted(range(n), key=lambda i: (-(shares[i] - floors[i]), i))
        for i in order[:leftover]:
            if needs_cpu[i]:
                cores[i] += 1
            else:
                leftover_targets = [j for j in order if needs_cpu[j]]
                if leftover_targets:
                    cores[leftover_targets[0]] += 1
    return tuple(cores)


def hill_climb(
    tenants: Sequence[TenantSpec],
    platform: Platform,
    k_max: int,
    *,
    force_alpha_zero: bool = False,
    max_iters: int = 10_000,
) -> tuple[Plan, float]:
    """Algorithm 1: greedy hill-climbing resource allocation.

    Starts all-CPU, each iteration tries moving h in {1,2} layers of each
    model from CPU to TPU, re-running PropAlloc for each candidate, and
    commits the best strictly-improving move.  The 2-step lookahead lets the
    search hop over single-point latency spikes (local optima).

    Returns the final (Plan, predicted objective).
    """
    n = len(tenants)
    partition = [0] * n
    cores = prop_alloc(tenants, partition, k_max)
    plan = Plan(tuple(partition), cores)
    l_curr = latency.penalized_objective(
        tenants, plan, platform, force_alpha_zero=force_alpha_zero
    )

    for _ in range(max_iters):
        best: tuple[float, int, int, tuple[int, ...]] | None = None
        for m in range(n):
            P_m = tenants[m].profile.num_partition_points
            for h in (1, 2):
                if partition[m] + h > P_m:
                    continue
                cand = list(partition)
                cand[m] += h
                try:
                    k_cand = prop_alloc(tenants, cand, k_max)
                except ValueError:
                    continue
                l_cand = latency.penalized_objective(
                    tenants,
                    Plan(tuple(cand), k_cand),
                    platform,
                    force_alpha_zero=force_alpha_zero,
                )
                if best is None or l_cand < best[0]:
                    best = (l_cand, m, h, k_cand)
        if best is None or best[0] >= l_curr:
            break
        l_cand, m_star, h_star, k_star = best
        partition[m_star] += h_star
        cores = k_star
        l_curr = l_cand

    plan = Plan(tuple(partition), tuple(cores))
    validate_plan(plan, tenants, k_max)
    return plan, l_curr


# --------------------------------------------------------------------------
# Baselines (Section V-A3)
# --------------------------------------------------------------------------

def edge_tpu_compiler_plan(tenants: Sequence[TenantSpec]) -> Plan:
    """Industry-default baseline: every model fully on the TPU (p_i = P_i),
    co-compiled, sharing TPU memory; no CPU offload."""
    partition = tuple(t.profile.num_partition_points for t in tenants)
    cores = (0,) * len(tenants)
    return Plan(partition, cores)


def threshold_plan(
    tenants: Sequence[TenantSpec],
    platform: Platform,
    k_max: int,
    *,
    threshold: float = 0.10,
) -> Plan:
    """Threshold-based partitioning baseline: walk segments from the last
    layer backward and offload a segment to CPU if its 1-core CPU time is
    within ``threshold`` of its TPU time.  Ignores queueing/multi-tenancy."""
    partition: list[int] = []
    for t in tenants:
        segs = t.profile.segments
        p = len(segs)
        while p > 0:
            seg = segs[p - 1]
            if seg.cpu_time_1core <= (1.0 + threshold) * seg.tpu_time:
                p -= 1
            else:
                break
        partition.append(p)
    cores = prop_alloc(tenants, partition, k_max)
    return Plan(tuple(partition), cores)


def swapless_plan(
    tenants: Sequence[TenantSpec], platform: Platform, k_max: int
) -> Plan:
    """Full SwapLess: Algorithm 1 with the complete analytic model."""
    plan, _ = hill_climb(tenants, platform, k_max)
    return plan


def swapless_alpha0_plan(
    tenants: Sequence[TenantSpec], platform: Platform, k_max: int
) -> Plan:
    """SwapLess (alpha=0) ablation: plans with queueing but no swap model."""
    plan, _ = hill_climb(tenants, platform, k_max, force_alpha_zero=True)
    return plan


def brute_force_oracle(
    tenants: Sequence[TenantSpec],
    platform: Platform,
    k_max: int,
) -> tuple[Plan, float]:
    """Exhaustive NLIP solve over all feasible (P, K).  Exponential --
    only for tests/validation on small instances."""
    n = len(tenants)
    best_plan: Plan | None = None
    best_obj = math.inf
    part_ranges = [range(t.profile.num_partition_points + 1) for t in tenants]
    for partition in itertools.product(*part_ranges):
        needs = [p < t.profile.num_partition_points for t, p in zip(tenants, partition)]
        n_need = sum(needs)
        if n_need > k_max:
            continue
        core_ranges = [
            range(1, k_max + 1) if need else range(0, 1) for need in needs
        ]
        for cores in itertools.product(*core_ranges):
            if sum(cores) > k_max:
                continue
            plan = Plan(tuple(partition), tuple(cores))
            obj = latency.objective(tenants, plan, platform)
            if obj < best_obj:
                best_obj = obj
                best_plan = plan
    assert best_plan is not None
    return best_plan, best_obj
