"""Greedy hill-climbing joint partitioning + core allocation (Algorithm 1),
the PropAlloc fair-share routine, baseline policies, and a brute-force NLIP
oracle used by tests on small instances.

Both search routines score candidates through the vectorized plan-space
engine (``latency.penalized_objective_batch`` / ``objective_batch``): all
moves of a hill-climb iteration, and chunks of the oracle's exhaustive
enumeration, are priced in a single NumPy pass.  The seed scalar
implementations are kept (``batch=False``) as the reference the batched
paths are tested byte-identical against.
"""
from __future__ import annotations

import itertools
import math
from typing import Iterator, Sequence

import numpy as np

from repro.core import latency
from repro.core.objective import Objective, deadlines_of, is_default
from repro.core.plan_tables import EvalTables, PlanTables
from repro.core.planner import (
    FCFS,
    DisciplineSpec,
    Plan,
    TenantSpec,
    validate_plan,
)
from repro.hw.specs import Platform


def prop_alloc(
    tenants: Sequence[TenantSpec],
    partition: Sequence[int],
    k_max: int,
) -> tuple[int, ...]:
    """Proportional fair-share integer core allocation (Alg. 1, line 2/10).

    Models with a CPU suffix receive cores proportional to their CPU workload
    ``lambda_i * s_cpu_suffix(1 core)``, subject to constraint (8): at least
    one core for any model with a suffix, zero cores for full-TPU models.
    Largest-remainder rounding keeps the total at ``min(K_max, ...)``.
    """
    n = len(tenants)
    needs_cpu = [p < t.profile.num_partition_points for t, p in zip(tenants, partition)]
    if not any(needs_cpu):
        return (0,) * n
    loads = [
        t.rate * t.profile.suffix_cpu_time_1core(p) if need else 0.0
        for t, p, need in zip(tenants, partition, needs_cpu)
    ]
    n_need = sum(needs_cpu)
    if n_need > k_max:
        raise ValueError(
            f"{n_need} models need a CPU core but only K_max={k_max} available"
        )
    # Start from the constraint floor: 1 core per suffix-bearing model.
    cores = [1 if need else 0 for need in needs_cpu]
    spare = k_max - n_need
    total_load = sum(loads)
    if spare > 0 and total_load > 0:
        shares = [spare * l / total_load for l in loads]
        floors = [int(math.floor(s)) for s in shares]
        for i in range(n):
            cores[i] += floors[i]
        leftover = spare - sum(floors)
        # Largest remainder first; stable tie-break on index.
        order = sorted(range(n), key=lambda i: (-(shares[i] - floors[i]), i))
        for i in order[:leftover]:
            if needs_cpu[i]:
                cores[i] += 1
            else:
                leftover_targets = [j for j in order if needs_cpu[j]]
                if leftover_targets:
                    cores[leftover_targets[0]] += 1
    return tuple(cores)


def prop_alloc_batch(
    tenants: Sequence[TenantSpec],
    partitions: np.ndarray,
    k_max: int,
    *,
    tables: PlanTables | None = None,
    rates: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized PropAlloc over B candidate partitionings at once.

    Returns ``(cores[B, n], feasible[B])``.  Feasible rows reproduce
    ``prop_alloc`` exactly -- same largest-remainder rounding, same stable
    index tie-break, same redirect of leftovers landing on a no-suffix
    tenant; infeasible rows (more suffix models than K_max, where the scalar
    version raises ValueError) come back flagged with unspecified cores.
    """
    P = np.asarray(partitions, dtype=np.intp)
    B, n = P.shape
    if tables is not None and (rates is not None or tables.matches(tenants)):
        num_points, suffix1, ti = tables.num_points, tables.suffix1, tables.tenant_idx
    else:
        # Only the platform-independent tables are needed here.
        num_points = np.array([t.profile.num_partition_points for t in tenants])
        width = int(num_points.max()) + 1
        suffix1 = np.full((n, width), np.nan)
        for i, t in enumerate(tenants):
            suffix1[i, : num_points[i] + 1] = t.profile._suffix_cpu1
        ti = np.arange(n)
    if rates is None:
        rates = np.array([t.rate for t in tenants], dtype=np.float64)[None, :]
    needs = P < num_points[None, :]                             # [B, n]
    n_need = needs.sum(axis=1)
    feasible = n_need <= k_max
    cores = needs.astype(np.int64)  # constraint floor: 1 core per suffix
    loads = rates * suffix1[ti, P] * needs
    spare = k_max - n_need                                      # [B]
    total_load = loads.sum(axis=1)
    dist = feasible & (spare > 0) & (total_load > 0)
    if not dist.any():
        return cores, feasible
    shares = np.divide(
        spare[:, None] * loads,
        total_load[:, None],
        out=np.zeros_like(loads),
        where=dist[:, None],
    )
    floors = np.floor(shares)
    cores += floors.astype(np.int64)
    leftover = (spare - floors.sum(axis=1).astype(np.int64)) * dist
    # Largest remainder first, stable index tie-break: argsort(-rem) with a
    # stable kind is exactly sorted(key=(-(rem), i)).
    order = np.argsort(floors - shares, axis=1, kind="stable")  # [B, n]
    rank = np.argsort(order, axis=1, kind="stable")             # inverse perm
    chosen = rank < leftover[:, None]
    cores += (chosen & needs).astype(np.int64)
    # Leftovers landing on a no-suffix tenant are redirected to the first
    # suffix-bearing tenant in remainder order (seed fallback branch).
    misdirected = (chosen & ~needs).sum(axis=1)
    if misdirected.any():
        needy_rank = np.where(needs, rank, n + 1)
        fallback = np.argmin(needy_rank, axis=1)                # [B]
        cores[np.arange(B), fallback] += np.where(
            needs.any(axis=1), misdirected, 0
        )
    return cores, feasible


# Crossover where the batched engine's fixed NumPy dispatch cost beats the
# scalar loop's per-candidate Python cost (measured on the dev box; the
# scalar side grows ~quadratically in tenants, so the exact value is soft).
_BATCH_MIN_TENANTS = 5


def _ensure_eval_tables(
    tables: PlanTables | EvalTables | None,
    tenants: Sequence[TenantSpec],
    platform: Platform,
    k_max: int,
) -> EvalTables:
    """A prebuilt ``EvalTables`` when it is valid for this (mix, platform,
    k-range); otherwise a rebuild reusing whatever rate-free base is
    available.  The one cache-validity policy shared by every climb path."""
    if (
        isinstance(tables, EvalTables)
        and tables.matches(tenants, platform)
        and tables.k_max >= k_max
    ):
        return tables
    return EvalTables.build(
        tenants,
        platform,
        k_max,
        base=tables.base if isinstance(tables, EvalTables) else tables,
    )


# Public alias: the fleet planner applies the same cache-validity policy
# when it warm-climbs each device against per-device-class tables.
ensure_eval_tables = _ensure_eval_tables


def hill_climb(
    tenants: Sequence[TenantSpec],
    platform: Platform,
    k_max: int,
    *,
    force_alpha_zero: bool = False,
    max_iters: int = 10_000,
    batch: bool | None = None,
    tables: PlanTables | EvalTables | None = None,
    init_plan: Plan | None = None,
    prune: bool = True,
    discipline: DisciplineSpec = FCFS,
    discipline_space: Sequence[DisciplineSpec] | None = None,
    evaluator=None,
    objective: Objective | None = None,
) -> tuple[Plan, float]:
    """Algorithm 1: greedy hill-climbing resource allocation.

    Starts all-CPU, each iteration tries moving h in {1,2} layers of each
    model from CPU to TPU, re-running PropAlloc for each candidate, and
    commits the best strictly-improving move.  The 2-step lookahead lets the
    search hop over single-point latency spikes (local optima).

    With ``batch=True`` all (m, h) moves of an iteration are scored in one
    delta-evaluation call against precomputed rate-aware ``EvalTables``
    (pass rate-free ``tables`` to reuse the platform-dependent half across
    re-plans); ``batch=False`` runs the seed scalar loop; the default
    ``None`` picks by mix size (NumPy dispatch overhead beats the scalar
    loop only from ~_BATCH_MIN_TENANTS tenants up).  All paths return the
    same plans for profiles whose Pareto frontier is full -- every synthetic
    paper profile is (ROADMAP.md invariant; ties within ~1 ulp may resolve
    to either tied plan).  On profiles with dominated points the pruned
    batched walk may legitimately commit different moves than the scalar
    scan; pass ``prune=False`` for a scalar-faithful batched search.

    Incremental re-planning (batched path only):

    * ``prune=True`` walks each tenant's Pareto frontier of partition points
      (``ModelProfile.pareto_points``) instead of the raw 0..P_i axis, so a
      move advances h *frontier positions*.  Dominated points can never be
      the strictly-best committed move, so with smooth profiles (no
      dominated points) the walk is bit-identical to the unpruned one.
    * ``init_plan`` warm-starts the climb from an incumbent plan (the online
      controller passes the previous re-plan's result) and enables the
      h in {-1,-2} down-moves, making the search a bidirectional local
      descent -- successive re-plans are near each other as rates drift, so
      the warm climb converges in a handful of iterations instead of
      re-walking up from all-CPU.

    Discipline co-optimization:

    * ``discipline`` scores the whole climb under one TPU service
      discipline (the returned plan carries it); the FCFS default keeps
      every code path identical to the pre-discipline planner.
    * ``discipline_space`` searches (partition, cores, discipline) jointly:
      the climb runs once per candidate spec -- the discipline axis is tiny
      and its effect on the objective is global, so exhausting it around
      the inner climb *is* the joint search -- and the strictly best plan
      is returned.  Ties resolve non-batching-first, plain FCFS ahead of
      priority/weighted-fair, regardless of how the caller ordered the
      space (on a predicted tie, e.g. a no-swap mix that batching prices
      identically but measurably hurts, or a priority spec the mean
      objective cannot separate from FCFS, the most-FCFS-like plan must
      win).  Specs that cannot batch are all scored on the unmodified
      FCFS objective, so they share one climb and a space of only such
      specs returns the FCFS plan unchanged.

    JAX scoring (``evaluator``):

    * ``evaluator`` plugs a ``repro.core.jax_eval.JaxPlanEvaluator`` (built
      for exactly these tenants/rates/platform) into the batched walk: every
      iteration scores the *whole* fixed-shape move frontier in one jitted
      device call (invalid and infeasible moves ride along as copies of the
      incumbent and are masked to ``inf`` on the host, so one compiled
      shape serves the entire climb).  The NumPy batched path stays the
      bitwise reference; the evaluator runs in float32 under the
      statistical-equivalence contract -- committed plans are identical
      unless two candidates tie within float32 round-off.

    SLO objectives (``objective``):

    * ``objective`` selects which metric the climb minimizes
      (``repro.core.objective``: mean / ``p_tail(q)`` / ``deadline_miss``
      against the budgets on the mix).  The ``None`` default is bitwise
      the pre-refactor Eq. 5 mean search on every path; non-mean
      objectives score through the same penalty convention, so the
      returned float is the chosen objective's value.

    Returns the final (Plan, predicted objective).
    """
    if evaluator is not None:
        if not evaluator.matches(tenants, platform):
            raise ValueError(
                "evaluator was built for different tenants/rates/platform"
            )
        batch = True
    if batch is None:
        batch = init_plan is not None or len(tenants) >= _BATCH_MIN_TENANTS
    if discipline_space is not None:
        if not discipline_space:
            raise ValueError("discipline_space must not be empty")
        # The evaluation tables are discipline-independent: build them once
        # and share across the per-spec climbs (only the climbs themselves
        # depend on the discipline; the scalar path never touches tables).
        shared = (
            _ensure_eval_tables(tables, tenants, platform, k_max)
            if batch
            else tables
        )
        # Non-batching specs first, plain FCFS ahead of the rest (stable
        # within each group): on a predicted tie -- e.g. a no-swap mix,
        # where batching prices identically but measurably hurts the
        # simulated system, or a priority spec the mean objective cannot
        # distinguish from FCFS -- the most-FCFS-like plan wins no matter
        # how the caller ordered the space.  All non-batching specs are
        # priced on the identical FCFS objective, so one climb scores the
        # whole group: the first spec in tie-break order represents it
        # (the others could only ever tie, and ties keep the first).
        ordered = sorted(
            discipline_space, key=lambda s: (s.batches, s.kind != "fcfs")
        )
        best: tuple[Plan, float] | None = None
        nonbatching_done = False
        for spec in ordered:
            if not spec.batches:
                if nonbatching_done:
                    continue
                nonbatching_done = True
            cand = hill_climb(
                tenants,
                platform,
                k_max,
                force_alpha_zero=force_alpha_zero,
                max_iters=max_iters,
                batch=batch,
                tables=shared,
                init_plan=init_plan,
                prune=prune,
                discipline=spec,
                evaluator=evaluator,
                objective=objective,
            )
            if best is None or cand[1] < best[1]:
                best = cand
        return best
    if not batch:
        if init_plan is not None:
            raise ValueError("init_plan warm start requires the batched path")
        return _hill_climb_scalar(
            tenants,
            platform,
            k_max,
            force_alpha_zero=force_alpha_zero,
            max_iters=max_iters,
            discipline=discipline,
            objective=objective,
        )
    n = len(tenants)
    etab = _ensure_eval_tables(
        evaluator.et if evaluator is not None else tables,
        tenants,
        platform,
        k_max,
    )
    rates = etab.rates[None, :]
    if prune:
        fronts = etab.base.frontiers
    else:
        fronts = tuple(np.arange(P_i + 1) for P_i in etab.num_points)
    flen = np.array([len(f) for f in fronts])
    fr = np.zeros((n, int(flen.max())), dtype=np.intp)
    for i, f in enumerate(fronts):
        fr[i, : len(f)] = f

    ev_slo = (
        {}
        if is_default(objective)
        else {"objective": objective, "deadlines": deadlines_of(tenants)}
    )
    pos = np.zeros(n, dtype=np.intp)
    if init_plan is not None:
        if len(init_plan.partition) != n:
            raise ValueError("init_plan size mismatch")
        # Snap each incumbent point to the nearest frontier point below it
        # (identity when the incumbent came from a pruned search; a snapped
        # interior point stays interior, so PropAlloc feasibility carries
        # over from the incumbent).
        for i, f in enumerate(fronts):
            pos[i] = np.searchsorted(f, init_plan.partition[i], side="right") - 1
    partition = fr[np.arange(n), pos]
    cores = np.array(prop_alloc(tenants, partition, k_max), dtype=np.int64)
    if evaluator is not None:
        l_curr = float(
            evaluator.penalized_objective_batch(
                partition[None, :],
                cores[None, :],
                force_alpha_zero=force_alpha_zero,
                discipline=discipline,
                **ev_slo,
            )[0]
        )
    else:
        l_curr = float(
            latency.penalized_objective_batch(
                tenants,
                partition[None, :],
                cores[None, :],
                platform,
                force_alpha_zero=force_alpha_zero,
                tables=etab,
                discipline=discipline,
                objective=objective,
            )[0]
        )

    # Fixed move set in the scalar iteration order (m ascending, h in (1, 2))
    # so first-minimum argmin tie-breaks identically to the scalar scan; a
    # warm start appends the down-moves after the up-moves it may need to
    # retreat from the incumbent as rates drift back.
    hs = (1, 2, -1, -2) if init_plan is not None else (1, 2)
    move_m = np.repeat(np.arange(n), len(hs))
    move_h = np.tile(np.array(hs), n)

    for _ in range(max_iters):
        cpos = pos[move_m] + move_h
        valid = (cpos >= 0) & (cpos < flen[move_m])
        if not valid.any():
            break
        if evaluator is not None:
            # Fixed-shape frontier: every (m, h) move scored each iteration
            # so the jitted evaluator compiles once per mix shape.  Invalid
            # moves ride along as copies of the incumbent row and are
            # masked out after scoring.
            cpos_c = np.where(valid, cpos, pos[move_m])
            parts = np.repeat(partition[None, :], len(move_m), axis=0)
            parts[np.arange(len(move_m)), move_m] = fr[move_m, cpos_c]
            k_cand, feasible = prop_alloc_batch(
                tenants, parts, k_max, tables=etab.base, rates=rates
            )
            ok = valid & feasible
            if not ok.any():
                break
            k_cand[~feasible] = cores
            objs = evaluator.penalized_objective_batch(
                parts,
                k_cand,
                force_alpha_zero=force_alpha_zero,
                discipline=discipline,
                **ev_slo,
            )
            objs[~ok] = np.inf
            j = int(np.argmin(objs))  # first minimum, like the scalar scan
            if not objs[j] < l_curr:
                break
            partition = parts[j]
            cores = k_cand[j]
            pos[move_m[j]] = cpos[j]
            l_curr = float(objs[j])
            continue
        vm, vpos = move_m[valid], cpos[valid]
        parts = np.repeat(partition[None, :], len(vm), axis=0)
        parts[np.arange(len(vm)), vm] = fr[vm, vpos]
        k_cand, feasible = prop_alloc_batch(
            tenants, parts, k_max, tables=etab.base, rates=rates
        )
        if not feasible.all():
            parts, k_cand = parts[feasible], k_cand[feasible]
            vm, vpos = vm[feasible], vpos[feasible]
            if parts.shape[0] == 0:
                break
        objs = latency.penalized_objective_delta_batch(
            tenants,
            partition,
            cores,
            parts,
            k_cand,
            platform,
            force_alpha_zero=force_alpha_zero,
            tables=etab,
            discipline=discipline,
            objective=objective,
        )
        j = int(np.argmin(objs))  # first minimum, like the scalar scan
        if not objs[j] < l_curr:
            break
        partition = parts[j]
        cores = k_cand[j]
        pos[vm[j]] = vpos[j]
        l_curr = float(objs[j])

    plan = Plan(
        tuple(int(p) for p in partition),
        tuple(int(k) for k in cores),
        discipline,
    )
    validate_plan(plan, tenants, k_max)
    return plan, l_curr


def _hill_climb_scalar(
    tenants: Sequence[TenantSpec],
    platform: Platform,
    k_max: int,
    *,
    force_alpha_zero: bool = False,
    max_iters: int = 10_000,
    discipline: DisciplineSpec = FCFS,
    objective: Objective | None = None,
) -> tuple[Plan, float]:
    """Seed scalar Algorithm 1; reference for the batched path."""
    n = len(tenants)
    partition = [0] * n
    cores = prop_alloc(tenants, partition, k_max)
    plan = Plan(tuple(partition), cores, discipline)
    l_curr = latency.penalized_objective(
        tenants, plan, platform, force_alpha_zero=force_alpha_zero,
        objective=objective,
    )

    for _ in range(max_iters):
        best: tuple[float, int, int, tuple[int, ...]] | None = None
        for m in range(n):
            P_m = tenants[m].profile.num_partition_points
            for h in (1, 2):
                if partition[m] + h > P_m:
                    continue
                cand = list(partition)
                cand[m] += h
                try:
                    k_cand = prop_alloc(tenants, cand, k_max)
                except ValueError:
                    continue
                l_cand = latency.penalized_objective(
                    tenants,
                    Plan(tuple(cand), k_cand, discipline),
                    platform,
                    force_alpha_zero=force_alpha_zero,
                    objective=objective,
                )
                if best is None or l_cand < best[0]:
                    best = (l_cand, m, h, k_cand)
        if best is None or best[0] >= l_curr:
            break
        l_cand, m_star, h_star, k_star = best
        partition[m_star] += h_star
        cores = k_star
        l_curr = l_cand

    plan = Plan(tuple(partition), tuple(cores), discipline)
    validate_plan(plan, tenants, k_max)
    return plan, l_curr


# --------------------------------------------------------------------------
# Baselines (Section V-A3)
# --------------------------------------------------------------------------

def edge_tpu_compiler_plan(tenants: Sequence[TenantSpec]) -> Plan:
    """Industry-default baseline: every model fully on the TPU (p_i = P_i),
    co-compiled, sharing TPU memory; no CPU offload."""
    partition = tuple(t.profile.num_partition_points for t in tenants)
    cores = (0,) * len(tenants)
    return Plan(partition, cores)


def threshold_plan(
    tenants: Sequence[TenantSpec],
    platform: Platform,
    k_max: int,
    *,
    threshold: float = 0.10,
) -> Plan:
    """Threshold-based partitioning baseline: walk segments from the last
    layer backward and offload a segment to CPU if its 1-core CPU time is
    within ``threshold`` of its TPU time.  Ignores queueing/multi-tenancy."""
    partition: list[int] = []
    for t in tenants:
        segs = t.profile.segments
        p = len(segs)
        while p > 0:
            seg = segs[p - 1]
            if seg.cpu_time_1core <= (1.0 + threshold) * seg.tpu_time:
                p -= 1
            else:
                break
        partition.append(p)
    cores = prop_alloc(tenants, partition, k_max)
    return Plan(tuple(partition), cores)


def swapless_plan(
    tenants: Sequence[TenantSpec], platform: Platform, k_max: int
) -> Plan:
    """Full SwapLess: Algorithm 1 with the complete analytic model."""
    plan, _ = hill_climb(tenants, platform, k_max)
    return plan


def swapless_alpha0_plan(
    tenants: Sequence[TenantSpec], platform: Platform, k_max: int
) -> Plan:
    """SwapLess (alpha=0) ablation: plans with queueing but no swap model."""
    plan, _ = hill_climb(tenants, platform, k_max, force_alpha_zero=True)
    return plan


def _feasible_plans(
    tenants: Sequence[TenantSpec],
    k_max: int,
    frontiers: Sequence[Sequence[int]] | None = None,
) -> Iterator[tuple[tuple[int, ...], tuple[int, ...]]]:
    """Every (partition, cores) satisfying constraints (6)-(9), in the seed
    oracle's deterministic enumeration order.  ``frontiers`` restricts each
    tenant's partition axis to its non-dominated points -- a subsequence of
    the full enumeration, so strict ``<`` tracking still returns the first
    optimum in seed order among the surviving plans."""
    if frontiers is None:
        part_ranges: list[Sequence[int]] = [
            range(t.profile.num_partition_points + 1) for t in tenants
        ]
    else:
        part_ranges = [[int(p) for p in f] for f in frontiers]
    for partition in itertools.product(*part_ranges):
        needs = [p < t.profile.num_partition_points for t, p in zip(tenants, partition)]
        if sum(needs) > k_max:
            continue
        core_ranges = [
            range(1, k_max + 1) if need else range(0, 1) for need in needs
        ]
        for cores in itertools.product(*core_ranges):
            if sum(cores) > k_max:
                continue
            yield partition, cores


def brute_force_oracle(
    tenants: Sequence[TenantSpec],
    platform: Platform,
    k_max: int,
    *,
    batch: bool = True,
    chunk_size: int = 4096,
    prune: bool = True,
    discipline: DisciplineSpec = FCFS,
    objective: Objective | None = None,
) -> tuple[Plan, float]:
    """Exhaustive NLIP solve over all feasible (P, K).  Exponential --
    only for tests/validation on small instances.  ``discipline`` scores
    the enumeration under that TPU service discipline (the returned plan
    carries it); the discipline axis itself is not enumerated here.

    The feasible set is streamed through ``objective_batch`` in chunks of
    ``chunk_size`` plans (``batch=False`` restores the seed scalar loop);
    strict ``<`` tracking over the same enumeration order keeps the returned
    plan identical between the two paths, except when two *distinct* plans
    tie to within float round-off (~1 ulp) -- the decomposed batch objective
    rounds differently from the scalar one, so either of the tied optima may
    win.  The objectives themselves always agree to ~1e-12.

    ``prune=True`` sweeps only each tenant's Pareto frontier of partition
    points; dominated points never carry the unique optimum (proof in
    ``ModelProfile.pareto_points``), so the pruned optimum equals the full
    one -- modulo the same tied-plans caveat when a pruned point ties a
    frontier point exactly.
    """
    if not batch:
        return _brute_force_scalar(
            tenants, platform, k_max, discipline=discipline,
            objective=objective,
        )
    tables = EvalTables.build(tenants, platform, k_max)
    best_plan: Plan | None = None
    best_obj = math.inf
    it = _feasible_plans(
        tenants, k_max, frontiers=tables.base.frontiers if prune else None
    )
    while True:
        chunk = list(itertools.islice(it, chunk_size))
        if not chunk:
            break
        parts = np.array([c[0] for c in chunk])
        cores = np.array([c[1] for c in chunk])
        objs = latency.objective_batch(
            tenants, parts, cores, platform, tables=tables,
            discipline=discipline, objective=objective,
        )
        # NaN (zero-rate tenant on an unstable queue) never beats ``best`` in
        # the scalar loop; map to inf so argmin skips it the same way.
        objs = np.where(np.isnan(objs), np.inf, objs)
        j = int(np.argmin(objs))
        if objs[j] < best_obj:
            best_obj = float(objs[j])
            best_plan = Plan(chunk[j][0], chunk[j][1], discipline)
    assert best_plan is not None
    return best_plan, best_obj


def _brute_force_scalar(
    tenants: Sequence[TenantSpec],
    platform: Platform,
    k_max: int,
    *,
    discipline: DisciplineSpec = FCFS,
    objective: Objective | None = None,
) -> tuple[Plan, float]:
    """Seed scalar oracle; reference for the chunked batch path."""
    best_plan: Plan | None = None
    best_obj = math.inf
    for partition, cores in _feasible_plans(tenants, k_max):
        plan = Plan(tuple(partition), tuple(cores), discipline)
        obj = latency.objective(tenants, plan, platform, objective=objective)
        if obj < best_obj:
            best_obj = obj
            best_plan = plan
    assert best_plan is not None
    return best_plan, best_obj
