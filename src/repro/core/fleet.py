"""Fleet-scale plan space: placement + routing across N heterogeneous devices.

The paper optimizes one Edge TPU; the north star is a *fleet*.  This module
lifts the single-device ``Plan`` contract into a two-level plan:

* ``DeviceSpec`` -- one device's hardware envelope: SRAM bytes, swap
  bandwidth, host core count, and relative TPU/CPU speed factors against the
  profiled reference device.  Speed factors enter the system exactly once,
  through ``ModelProfile.scaled`` -- every downstream consumer (the analytic
  model, both simulators, the plan tables) sees profiled *times* and never
  learns about heterogeneity.
* ``FleetPlan`` -- tenant -> device placement with per-tenant request-routing
  weights, plus one full-width per-device ``Plan``.  Device plans keep every
  tenant's row (unplaced tenants are pinned at the inert ``(P_i, 0)``
  full-TPU/zero-core point and receive no traffic) so a mid-run placement
  change never needs a simulator rebuild -- the same ``set_plan`` switch the
  single-device controller already performs.
* ``fleet_hill_climb`` -- the cluster-level planner: greedy load-balanced
  bin packing seeds the placement, each device's (partition, cores,
  discipline) is then optimized by the existing warm-startable ``hill_climb``
  (one batched NumPy pass scores each device's whole neighbor frontier, with
  ``PlanTables`` shared across all devices of one class via
  ``FleetTablesCache``), and a bounded improvement loop migrates tenants off
  the worst-objective device while the move pays.

Degenerate case contract (ROADMAP invariant): a 1-device fleet whose
``DeviceSpec`` wraps the reference platform at unit speed factors routes
through *identical* calls as the single-device API -- ``fleet_hill_climb``
returns exactly ``hill_climb``'s plan and objective, bitwise
(``tests/test_fleet.py`` pins this).

Grounding: Villarrubia et al. (arxiv 2503.01025) profile cross-device model
segmentation on multi-TPU systems; Liang et al. (arxiv 2201.07312) supply
the model-driven placement/routing layer this planner follows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.allocator import ensure_eval_tables, hill_climb
from repro.core.latency import penalized_objective
from repro.core.objective import Objective
from repro.core.plan_tables import PlanTables
from repro.core.planner import (
    FCFS,
    DisciplineSpec,
    ModelProfile,
    Plan,
    TenantSpec,
    validate_plan,
)
from repro.hw.specs import AcceleratorSpec, HostCPUSpec, Platform

_W_SUM_TOL = 1e-9  # routing weights must sum to 1 within this


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One device of a heterogeneous serving fleet.

    ``tpu_speed`` / ``cpu_speed`` are multipliers against the device the
    profiles were measured on (2.0 = twice as fast); they reach the rest of
    the system only through ``ModelProfile.scaled``.  Two devices with equal
    (sram, bw, cores, speeds) form one *device class* (``class_key``) and
    share plan tables regardless of their names.
    """

    name: str
    sram_bytes: int
    swap_bw: float
    cpu_cores: int
    tpu_speed: float = 1.0
    cpu_speed: float = 1.0
    # The exact Platform object this spec was derived from, when it was
    # (``from_platform``).  Excluded from equality -- it carries no state
    # beyond (sram_bytes, swap_bw) that any consumer reads -- but keeping
    # the original object makes the N=1 degenerate path use *the same*
    # platform value the single-device API was called with.
    base_platform: Platform | None = dataclasses.field(
        default=None, compare=False, repr=False
    )

    def __post_init__(self) -> None:
        if self.sram_bytes < 0:
            raise ValueError("sram_bytes must be non-negative")
        if self.swap_bw <= 0:
            raise ValueError("swap_bw must be positive")
        if self.cpu_cores < 0:
            raise ValueError("cpu_cores must be non-negative")
        if self.tpu_speed <= 0 or self.cpu_speed <= 0:
            raise ValueError("speed factors must be positive")

    @classmethod
    def from_platform(
        cls,
        platform: Platform,
        *,
        name: str | None = None,
        cpu_cores: int | None = None,
        tpu_speed: float = 1.0,
        cpu_speed: float = 1.0,
    ) -> "DeviceSpec":
        """A device wrapping an existing ``Platform`` (the N=1 entry point)."""
        return cls(
            name=name or platform.accelerator.name,
            sram_bytes=platform.sram_bytes,
            swap_bw=platform.swap_bw,
            cpu_cores=cpu_cores if cpu_cores is not None else platform.cpu.n_cores,
            tpu_speed=tpu_speed,
            cpu_speed=cpu_speed,
            base_platform=platform,
        )

    @property
    def class_key(self) -> tuple:
        """Hashable device-class identity (name excluded): devices of one
        class share ``Platform`` values, scaled profiles, and plan tables."""
        return (
            self.sram_bytes,
            self.swap_bw,
            self.cpu_cores,
            self.tpu_speed,
            self.cpu_speed,
        )

    @property
    def platform(self) -> Platform:
        """The ``Platform`` the per-device planner/simulators run against.

        When built ``from_platform`` this is the original object (exact N=1
        degeneracy); otherwise a synthesized platform whose names derive
        from the class key, so equal-class devices compare ``==`` and
        ``PlanTables.matches`` reuses tables across them.
        """
        if self.base_platform is not None:
            return self.base_platform
        tag = f"sram{self.sram_bytes}-bw{self.swap_bw:g}"
        return Platform(
            accelerator=AcceleratorSpec(
                name=f"fleet-accel-{tag}",
                peak_ops=4.0e12,
                sram_bytes=self.sram_bytes,
                host_bw=self.swap_bw,
            ),
            cpu=HostCPUSpec(
                name=f"fleet-host-{self.cpu_cores}c",
                n_cores=self.cpu_cores,
                ops_per_core=4.0e9,
                parallel_frac=0.90,
            ),
        )

    def scaled_profiles(
        self, profiles: Sequence[ModelProfile]
    ) -> list[ModelProfile]:
        """The hosted profiles re-timed for this device (identity-stable:
        unit factors return the originals; repeats return cached objects)."""
        return [p.scaled(self.tpu_speed, self.cpu_speed) for p in profiles]


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """A two-level plan: who runs where, and each device's local plan.

    ``placement[i]`` lists the devices tenant ``i``'s requests may run on;
    ``routing[i]`` holds the matching request-routing weights (same length,
    non-negative, summing to 1).  ``device_plans[d]`` is a *full-width*
    single-device ``Plan``: one (partition, cores) row per tenant, with
    tenants not placed on ``d`` pinned at the inert ``(P_i, 0)`` point.
    Full width is a deliberate invariant -- every device simulator keeps the
    global tenant indexing, so traces split by placement replay without
    re-indexing and a placement change is just a ``set_plan``.
    """

    placement: tuple[tuple[int, ...], ...]
    routing: tuple[tuple[float, ...], ...]
    device_plans: tuple[Plan, ...]

    @property
    def n_devices(self) -> int:
        return len(self.device_plans)

    @property
    def n_tenants(self) -> int:
        return len(self.placement)

    def device_of(self, tenant: int) -> int:
        """The single device hosting ``tenant`` (errors on split routing)."""
        devs = self.placement[tenant]
        if len(devs) != 1:
            raise ValueError(f"tenant {tenant} routes to {len(devs)} devices")
        return devs[0]


def validate_fleet_plan(
    fleet_plan: FleetPlan,
    tenants: Sequence[TenantSpec],
    fleet: Sequence[DeviceSpec],
) -> None:
    """Enforce the fleet-plan contract (the two-level analogue of the NLIP
    constraint checks in ``validate_plan``).

    Checks, in order: shape consistency; every tenant placed on >= 1
    in-range, duplicate-free device; routing weights aligned with the
    placement, non-negative, summing to 1; every device plan full-width and
    valid under its device's core budget; unplaced tenants pinned inert.
    """
    n, d = len(tenants), len(fleet)
    if fleet_plan.n_tenants != n or len(fleet_plan.routing) != n:
        raise ValueError(
            f"placement/routing cover {fleet_plan.n_tenants}/"
            f"{len(fleet_plan.routing)} tenants, want {n}"
        )
    if fleet_plan.n_devices != d:
        raise ValueError(
            f"plan has {fleet_plan.n_devices} device plans for {d} devices"
        )
    placed: list[set[int]] = [set() for _ in range(d)]
    for i, (devs, wts) in enumerate(zip(fleet_plan.placement, fleet_plan.routing)):
        name = tenants[i].profile.name
        if not devs:
            raise ValueError(f"{name}: tenant placed on no device")
        if len(set(devs)) != len(devs):
            raise ValueError(f"{name}: duplicate devices in placement {devs}")
        for dev in devs:
            if not 0 <= dev < d:
                raise ValueError(f"{name}: device {dev} outside [0,{d})")
            placed[dev].add(i)
        if len(wts) != len(devs):
            raise ValueError(
                f"{name}: {len(wts)} routing weights for {len(devs)} devices"
            )
        if any(w < 0 for w in wts):
            raise ValueError(f"{name}: negative routing weight in {wts}")
        if not math.isclose(sum(wts), 1.0, rel_tol=0.0, abs_tol=_W_SUM_TOL):
            raise ValueError(
                f"{name}: routing weights {wts} sum to {sum(wts)!r}, want 1"
            )
    for dev, (spec, plan) in enumerate(zip(fleet, fleet_plan.device_plans)):
        if len(plan.partition) != n:
            raise ValueError(
                f"device {spec.name}: plan width {len(plan.partition)} != {n} "
                "tenants (device plans are full-width)"
            )
        validate_plan(plan, tenants, spec.cpu_cores)
        for i, t in enumerate(tenants):
            if i in placed[dev]:
                continue
            P_i = t.profile.num_partition_points
            if plan.partition[i] != P_i or plan.cores[i] != 0:
                raise ValueError(
                    f"device {spec.name}: unplaced tenant {t.profile.name} "
                    f"must be pinned at ({P_i}, 0), got "
                    f"({plan.partition[i]}, {plan.cores[i]})"
                )


class FleetTablesCache:
    """Plan tables shared across every device of one class.

    ``PlanTables`` depends only on (profiles, platform); with speed factors
    folded into identity-cached scaled profiles, every device of a class
    hosting the same profile set reuses one table build.  Keys use profile
    *identity* (the same ``is`` contract as ``PlanTables.matches``), so a
    64-device warm re-plan pays the table cost once per (class, mix), not
    per device.
    """

    def __init__(self) -> None:
        self._tables: dict[tuple, PlanTables] = {}

    def tables_for(
        self, device: DeviceSpec, profiles: Sequence[ModelProfile], k_max: int
    ) -> PlanTables:
        key = (device.class_key, tuple(id(p) for p in profiles), k_max)
        pt = self._tables.get(key)
        if pt is None or not pt.matches_profiles(profiles, device.platform):
            pt = PlanTables.build(profiles, device.platform, k_max)
            self._tables[key] = pt
        return pt


def _pin_row(profile: ModelProfile) -> tuple[int, int]:
    """The inert row for a tenant not placed on a device: full-TPU route,
    zero cores.  Valid under every constraint and, with no traffic routed,
    it never touches the device's SRAM, queue, or core budget."""
    return profile.num_partition_points, 0


def _expand(
    sub_plan: Plan,
    members: Sequence[int],
    tenants: Sequence[TenantSpec],
) -> Plan:
    """Widen a subset plan over ``members`` to the full tenant width."""
    part = [_pin_row(t.profile)[0] for t in tenants]
    cores = [0] * len(tenants)
    for j, i in enumerate(members):
        part[i] = sub_plan.partition[j]
        cores[i] = sub_plan.cores[j]
    return Plan(tuple(part), tuple(cores), discipline=sub_plan.discipline)


def _restrict(plan: Plan, members: Sequence[int]) -> Plan:
    """Project a full-width device plan onto its placed-tenant subset."""
    return Plan(
        tuple(plan.partition[i] for i in members),
        tuple(plan.cores[i] for i in members),
        discipline=plan.discipline,
    )


def _climb_device(
    device: DeviceSpec,
    members: Sequence[int],
    tenants: Sequence[TenantSpec],
    k_max: int,
    cache: FleetTablesCache,
    *,
    init_sub: Plan | None = None,
    discipline: DisciplineSpec = FCFS,
    discipline_space: Sequence[DisciplineSpec] | None = None,
    objective: Objective | None = None,
) -> tuple[Plan, float]:
    """Optimize one device's local plan for its placed tenants.

    Returns ``(full-width plan, Eq. 5 objective contribution)``.  The climb
    runs on the placed subset (so the search space is the device's own),
    against tables cached per device class; the batched engine inside
    ``hill_climb`` scores each neighbor frontier in one NumPy pass.
    """
    if not members:
        return _expand(Plan((), (), discipline=discipline), (), tenants), 0.0
    sub = [
        TenantSpec(
            tenants[i].profile.scaled(device.tpu_speed, device.cpu_speed),
            tenants[i].rate,
            deadline=tenants[i].deadline,
        )
        for i in members
    ]
    kwargs: dict = {
        "tables": cache.tables_for(device, [t.profile for t in sub], k_max)
    }
    if objective is not None:
        kwargs["objective"] = objective
    if init_sub is not None:
        kwargs["init_plan"] = init_sub
    if discipline_space is not None:
        kwargs["discipline_space"] = tuple(discipline_space)
    elif discipline != FCFS:
        kwargs["discipline"] = discipline
    plan, obj = hill_climb(sub, device.platform, k_max, **kwargs)
    return _expand(plan, members, tenants), obj


def _device_k_max(device: DeviceSpec, k_max: int | None) -> int:
    return device.cpu_cores if k_max is None else min(k_max, device.cpu_cores)


def _greedy_placement(
    tenants: Sequence[TenantSpec],
    fleet: Sequence[DeviceSpec],
    k_caps: Sequence[int],
) -> list[list[int]]:
    """Load-balanced bin packing: heaviest tenants first, each onto the
    device with the smallest projected (TPU busy time + SRAM-pressure)
    score.  A seed for the per-device climbs, not a final answer -- the
    improvement loop in ``fleet_hill_climb`` migrates what it got wrong.

    Capacity: ``hill_climb`` starts all-CPU (Algorithm 1), so a device can
    host at most as many tenants as it has cores (constraint (8): every
    CPU-suffix model needs a dedicated core).  The packer never exceeds it.
    """
    n_dev = len(fleet)
    if sum(k_caps) < len(tenants):
        raise ValueError(
            f"fleet core capacity {sum(k_caps)} cannot host "
            f"{len(tenants)} tenants (each needs >= 1 core for its "
            "CPU-suffix start)"
        )
    # Per-tenant proxies: full-TPU compute demand and resident footprint.
    demand = [
        t.rate * t.profile.prefix_tpu_time(t.profile.num_partition_points)
        for t in tenants
    ]
    footprint = [float(t.profile.total_weight_bytes) for t in tenants]
    order = sorted(range(len(tenants)), key=lambda i: -demand[i])

    members: list[list[int]] = [[] for _ in range(n_dev)]
    load = [0.0] * n_dev       # offered TPU busy fraction (reference time)
    mem = [0.0] * n_dev        # summed resident footprint
    rate = [0.0] * n_dev
    for i in order:
        best_d, best_score = -1, math.inf
        for d, dev in enumerate(fleet):
            if len(members[d]) >= k_caps[d]:
                continue
            busy = (load[d] + demand[i]) / dev.tpu_speed
            # Overflow beyond SRAM streams over the swap channel on every
            # request the device serves: price it in seconds per second.
            over = max(0.0, mem[d] + footprint[i] - dev.sram_bytes)
            pressure = (over / dev.swap_bw) * (rate[d] + tenants[i].rate)
            score = busy + pressure
            if score < best_score - 1e-15:
                best_d, best_score = d, score
        members[best_d].append(i)
        load[best_d] += demand[i]
        mem[best_d] += footprint[i]
        rate[best_d] += tenants[i].rate
    return members


def fleet_hill_climb(
    tenants: Sequence[TenantSpec],
    fleet: Sequence[DeviceSpec],
    *,
    k_max: int | None = None,
    init: FleetPlan | None = None,
    replan_placement: bool | None = None,
    warm_start: bool = True,
    tables: FleetTablesCache | None = None,
    discipline: DisciplineSpec = FCFS,
    discipline_space: Sequence[DisciplineSpec] | None = None,
    max_moves: int | None = None,
    objective: Objective | None = None,
) -> tuple[FleetPlan, float]:
    """Cluster-level planner: placement + routing + per-device plans.

    Cold path (no ``init``): greedy load-balanced bin packing places each
    tenant on one device, every device's local plan is hill-climbed, then a
    bounded improvement loop repeatedly takes the worst-objective device and
    tries migrating each of its tenants to every other device, committing
    the best strictly-improving move (both affected devices re-climb
    warm-started from their incumbent local plans).

    Warm path (``init`` given, ``replan_placement=False`` -- the default
    when an ``init`` is supplied): placement and routing are kept; each
    device warm-starts ``hill_climb`` from its incumbent plan against the
    new rates.  This is the controller's periodic re-plan: N independent
    warm climbs against class-shared tables, no placement churn.
    ``warm_start=False`` keeps the placement/routing of ``init`` but
    re-climbs every device cold (all-CPU start, Algorithm 1) -- the
    fleet analogue of the single-device cold fallback, for escaping a
    drifted warm basin without migrating tenants.

    ``objective`` selects the metric every per-device climb minimizes and
    the fleet total sums (``repro.core.objective``); ``None`` stays bitwise
    the pinned Eq. 5 mean.  ``k_max=None`` gives every device its own
    ``cpu_cores`` budget; an int
    caps all devices.  ``tables`` carries ``PlanTables`` across calls (one
    build per device class x mix).  Returns ``(FleetPlan, objective)`` where
    the objective is the sum of per-device Eq. 5 penalized objectives --
    request-rate-weighted, so the fleet-wide mean latency is
    ``objective / sum(rates)``.

    Degenerate N=1 contract: a single unit-speed ``from_platform`` device
    makes this function delegate to exactly the ``hill_climb`` call the
    single-device API performs -- same plan, same objective, bitwise.
    """
    if not fleet:
        raise ValueError("fleet must contain at least one device")
    if discipline_space is not None or discipline != FCFS:
        for spec in list(discipline_space or ()) + [discipline]:
            if spec.weights is not None:
                raise ValueError(
                    "per-tenant discipline weights are not supported in "
                    "fleet plans (subset climbs cannot carry full-width "
                    "weight vectors)"
                )
    if replan_placement is None:
        replan_placement = init is None
    cache = tables if tables is not None else FleetTablesCache()
    n_dev = len(fleet)
    k_caps = [_device_k_max(d, k_max) for d in fleet]

    if init is not None and not replan_placement:
        # Warm: keep placement, re-climb each device from its incumbent.
        if init.n_tenants != len(tenants) or init.n_devices != n_dev:
            raise ValueError("init plan shape does not match tenants/fleet")
        members = [
            [i for i in range(len(tenants)) if d in init.placement[i]]
            for d in range(n_dev)
        ]
        plans, objs = [], []
        for d, dev in enumerate(fleet):
            full, obj = _climb_device(
                dev,
                members[d],
                tenants,
                k_caps[d],
                cache,
                init_sub=(
                    _restrict(init.device_plans[d], members[d])
                    if warm_start
                    else None
                ),
                discipline=discipline,
                discipline_space=discipline_space,
                objective=objective,
            )
            plans.append(full)
            objs.append(obj)
        return (
            FleetPlan(init.placement, init.routing, tuple(plans)),
            float(sum(objs)),
        )

    # Cold: greedy packing, per-device climbs, then bounded improvement.
    members = _greedy_placement(tenants, fleet, k_caps)
    plans, objs = [], []
    for d, dev in enumerate(fleet):
        full, obj = _climb_device(
            dev,
            members[d],
            tenants,
            k_caps[d],
            cache,
            discipline=discipline,
            discipline_space=discipline_space,
            objective=objective,
        )
        plans.append(full)
        objs.append(obj)

    if n_dev > 1:
        budget = max_moves if max_moves is not None else len(tenants)
        for _ in range(budget):
            # An infinite objective (overload) ranks worst and any finite
            # rearrangement improves it; only an *empty* worst device (the
            # whole fleet idle or single-tenant devices) ends the loop.
            worst = max(range(n_dev), key=lambda d: objs[d])
            if not members[worst]:
                break
            best = None  # (delta, i, dst, plan_src, obj_src, plan_dst, obj_dst)
            for i in members[worst]:
                rest = [j for j in members[worst] if j != i]
                p_src, o_src = _climb_device(
                    fleet[worst],
                    rest,
                    tenants,
                    k_caps[worst],
                    cache,
                    init_sub=_restrict(plans[worst], rest),
                    discipline=discipline,
                    discipline_space=discipline_space,
                    objective=objective,
                )
                for dst in range(n_dev):
                    if dst == worst or len(members[dst]) >= k_caps[dst]:
                        continue
                    grown = members[dst] + [i]
                    seed = _restrict(plans[dst], members[dst])
                    seed = Plan(
                        seed.partition + (_pin_row(tenants[i].profile)[0],),
                        seed.cores + (0,),
                        discipline=seed.discipline,
                    )
                    p_dst, o_dst = _climb_device(
                        fleet[dst],
                        grown,
                        tenants,
                        k_caps[dst],
                        cache,
                        init_sub=seed,
                        discipline=discipline,
                        discipline_space=discipline_space,
                        objective=objective,
                    )
                    delta = (o_src + o_dst) - (objs[worst] + objs[dst])
                    if not delta < -1e-12:
                        continue
                    if best is None or delta < best[0]:
                        best = (delta, i, dst, p_src, o_src, p_dst, o_dst)
            if best is None:
                break
            _, i, dst, p_src, o_src, p_dst, o_dst = best
            members[worst].remove(i)
            members[dst].append(i)
            plans[worst], objs[worst] = p_src, o_src
            plans[dst], objs[dst] = p_dst, o_dst

    placement = [None] * len(tenants)
    for d in range(n_dev):
        for i in members[d]:
            placement[i] = (d,)
    return (
        FleetPlan(
            placement=tuple(placement),
            routing=tuple((1.0,) for _ in tenants),
            device_plans=tuple(plans),
        ),
        float(sum(objs)),
    )


def round_robin_fleet_plan(
    tenants: Sequence[TenantSpec],
    fleet: Sequence[DeviceSpec],
    *,
    k_max: int | None = None,
    tables: FleetTablesCache | None = None,
) -> tuple[FleetPlan, float]:
    """Naive placement baseline: tenant ``i`` on device ``i % N`` (blind to
    heterogeneity and footprint), then the same per-device ``hill_climb`` as
    the real planner -- so a comparison isolates the *placement* decision."""
    if not fleet:
        raise ValueError("fleet must contain at least one device")
    cache = tables if tables is not None else FleetTablesCache()
    n_dev = len(fleet)
    members = [
        [i for i in range(len(tenants)) if i % n_dev == d] for d in range(n_dev)
    ]
    plans, objs = [], []
    for d, dev in enumerate(fleet):
        full, obj = _climb_device(
            dev, members[d], tenants, _device_k_max(dev, k_max), cache
        )
        plans.append(full)
        objs.append(obj)
    return (
        FleetPlan(
            placement=tuple((i % n_dev,) for i in range(len(tenants))),
            routing=tuple((1.0,) for _ in tenants),
            device_plans=tuple(plans),
        ),
        float(sum(objs)),
    )


def fleet_plan_objective(
    tenants: Sequence[TenantSpec],
    fleet_plan: FleetPlan,
    fleet: Sequence[DeviceSpec],
    *,
    objective: Objective | None = None,
) -> float:
    """Re-score an existing ``FleetPlan`` under fresh tenant rates.

    Sum of per-device Eq. 5 penalized objectives -- the same total
    ``fleet_hill_climb`` reports for the plan it returns (up to batched-vs-
    scalar float noise), but without any search: each device's placed
    subset is projected out with ``_restrict`` and scored directly with
    ``penalized_objective`` on the device-scaled profiles and the routed
    share of each tenant's rate.  This is the verify step of the fleet
    plan cache (``core/plan_cache.py``): one cheap evaluation decides
    whether a memoized plan is still within margin of its stored quality.
    ``objective`` must match the metric the plan was searched under, or the
    comparison is apples-to-oranges -- the cache threads it automatically.
    """
    if fleet_plan.n_tenants != len(tenants) or fleet_plan.n_devices != len(
        fleet
    ):
        raise ValueError("fleet plan shape does not match tenants/fleet")
    total = 0.0
    for d, dev in enumerate(fleet):
        members = [
            i
            for i in range(len(tenants))
            if d in fleet_plan.placement[i]
        ]
        if not members:
            continue
        sub = [
            TenantSpec(
                tenants[i].profile.scaled(dev.tpu_speed, dev.cpu_speed),
                tenants[i].rate
                * fleet_plan.routing[i][fleet_plan.placement[i].index(d)],
                deadline=tenants[i].deadline,
            )
            for i in members
        ]
        total += penalized_objective(
            sub,
            _restrict(fleet_plan.device_plans[d], members),
            dev.platform,
            objective=objective,
        )
    return float(total)


def device_objectives(
    tenants: Sequence[TenantSpec],
    fleet_plan: FleetPlan,
    fleet: Sequence[DeviceSpec],
    *,
    objective: Objective | None = None,
) -> list[float]:
    """Per-device Eq. 5 objective contributions of an existing plan.

    The same scoring ``fleet_plan_objective`` sums, reported per device
    (0.0 for a device hosting nothing).  This is the *predicted* per-device
    request-weighted total latency the fault-aware controller compares
    observed latencies against: ``objective[d] / routed_rate[d]`` is the
    model's expected mean on device ``d``, so a sustained observed mean far
    above it is the throttling signal (``serving.fleet.run_adaptive_fleet``
    with ``fault_aware=True``).
    """
    if fleet_plan.n_tenants != len(tenants) or fleet_plan.n_devices != len(
        fleet
    ):
        raise ValueError("fleet plan shape does not match tenants/fleet")
    out = []
    for d, dev in enumerate(fleet):
        members = [
            i
            for i in range(len(tenants))
            if d in fleet_plan.placement[i]
        ]
        if not members:
            out.append(0.0)
            continue
        sub = [
            TenantSpec(
                tenants[i].profile.scaled(dev.tpu_speed, dev.cpu_speed),
                tenants[i].rate
                * fleet_plan.routing[i][fleet_plan.placement[i].index(d)],
                deadline=tenants[i].deadline,
            )
            for i in members
        ]
        out.append(
            float(
                penalized_objective(
                    sub,
                    _restrict(fleet_plan.device_plans[d], members),
                    dev.platform,
                    objective=objective,
                )
            )
        )
    return out


def evacuate_device(
    tenants: Sequence[TenantSpec],
    fleet: Sequence[DeviceSpec],
    down: Sequence[int],
    *,
    k_max: int | None = None,
    tables: FleetTablesCache | None = None,
    discipline_space: Sequence[DisciplineSpec] | None = None,
    objective: Objective | None = None,
) -> tuple[FleetPlan, float]:
    """Failover placement: re-plan the fleet with ``down`` devices removed.

    A cold ``fleet_hill_climb`` runs over the surviving sub-fleet, and the
    result embeds back at full fleet width: placements re-index to the full
    fleet, down devices host no tenant and carry the inert full-pin device
    plan (``_pin_row`` for every tenant -- valid, traffic-free).  The
    returned objective is the surviving fleet's; the down device
    contributes nothing, exactly as ``fleet_plan_objective`` would score
    the embedded plan.

    Raises ``ValueError`` when the surviving fleet cannot host every tenant
    (constraint (8) core capacity) or every device is down -- callers keep
    the incumbent plan and surface the overload instead of half-placing.
    """
    down_set = set(down)
    for d in down_set:
        if not 0 <= d < len(fleet):
            raise ValueError(f"down device {d} outside the fleet")
    up = [d for d in range(len(fleet)) if d not in down_set]
    if not up:
        raise ValueError("cannot evacuate: every device is down")
    sub_plan, obj = fleet_hill_climb(
        tenants,
        [fleet[d] for d in up],
        k_max=k_max,
        tables=tables,
        discipline_space=discipline_space,
        objective=objective,
    )
    inert = Plan(
        tuple(_pin_row(t.profile)[0] for t in tenants),
        tuple(0 for _ in tenants),
    )
    sub_of = {d: j for j, d in enumerate(up)}
    device_plans = tuple(
        inert if d in down_set else sub_plan.device_plans[sub_of[d]]
        for d in range(len(fleet))
    )
    placement = tuple(
        tuple(up[x] for x in devs) for devs in sub_plan.placement
    )
    return (
        FleetPlan(placement, sub_plan.routing, device_plans),
        obj,
    )


__all__ = [
    "DeviceSpec",
    "FleetPlan",
    "FleetTablesCache",
    "device_objectives",
    "evacuate_device",
    "fleet_hill_climb",
    "fleet_plan_objective",
    "round_robin_fleet_plan",
    "validate_fleet_plan",
]
