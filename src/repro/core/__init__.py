"""SwapLess core: the paper's analytic queueing model and resource allocator."""
from repro.core.planner import (
    ModelProfile,
    Plan,
    Segment,
    TenantSpec,
    intra_swap_bytes,
    load_time,
    prefix_service_time,
    validate_plan,
)
from repro.core.queueing import mdk_wait, mg1_wait, mixture_moments
from repro.core.swap import aggregate_footprint, tpu_arrival_rate, weight_miss_probs
from repro.core.latency import (
    LatencyBreakdown,
    SystemPrediction,
    objective,
    penalized_objective,
    predict,
)
from repro.core.allocator import (
    brute_force_oracle,
    edge_tpu_compiler_plan,
    hill_climb,
    prop_alloc,
    swapless_alpha0_plan,
    swapless_plan,
    threshold_plan,
)

__all__ = [
    "LatencyBreakdown",
    "ModelProfile",
    "Plan",
    "Segment",
    "SystemPrediction",
    "TenantSpec",
    "aggregate_footprint",
    "brute_force_oracle",
    "edge_tpu_compiler_plan",
    "hill_climb",
    "intra_swap_bytes",
    "load_time",
    "mdk_wait",
    "mg1_wait",
    "mixture_moments",
    "objective",
    "penalized_objective",
    "predict",
    "prefix_service_time",
    "prop_alloc",
    "swapless_alpha0_plan",
    "swapless_plan",
    "threshold_plan",
    "tpu_arrival_rate",
    "validate_plan",
    "weight_miss_probs",
]
