"""Plan memoization: LRU cache over quantized rate states, verify-then-reuse.

Diurnal and bursty workloads revisit the same traffic states over and over
(the morning mix looks like yesterday's morning mix), yet the reactive
controller pays a fresh ``hill_climb`` at every re-plan boundary.  This
module memoizes plans keyed on the *quantized* rate vector plus the mix
fingerprint, so a recurring state re-plans with one cache probe instead of
a search.

Key design points:

* **Quantized keys.**  Rates are snapped to a multiplicative grid
  (``quantize_rates``): two vectors whose per-model rates agree within the
  relative cell width ``rel`` share a key.  The grid is logarithmic, so
  0.10 vs 0.11 req/s land together while 1 vs 2 req/s do not; rates at or
  below ``idle_floor`` share one idle cell.

* **Verify-then-reuse.**  Quantization means a hit's stored plan was
  optimized for *nearby* rates, not these exact rates, and the plan space
  is rugged enough that "nearby" can occasionally be bad (e.g. the cell
  straddles a stability boundary).  Every hit is therefore delta-evaluated:
  one ``penalized_objective`` call re-scores the cached plan under the
  fresh exact rates, and the plan is reused only when its normalized
  objective (obj / total rate, the controller's Eq. 10 trend statistic) is
  within ``margin`` of the quality recorded when it was stored -- and
  finite, and below the infeasibility penalty floor.  Anything else is a
  *reject*: the caller falls back to its normal warm ``hill_climb`` and
  the fresh result overwrites the entry.  A hit costs one plan evaluation
  (~100 us at 64 tenants) instead of a search.

* **Opt-in.**  ``run_adaptive(plan_cache=None)`` -- the default -- never
  constructs or consults a cache; the no-cache path is bitwise the
  reactive controller (standing invariant, self-checked by
  ``benchmarks/predictive.py`` before any timing).

``PlanCache`` serves the single-device controller; ``FleetPlanCache`` is
the same machinery for ``run_adaptive_fleet``, with the verify step
delegated to ``fleet_plan_objective`` and fleet identity (device class
keys) folded into the key.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Sequence

from repro.core.latency import _PENALTY_BASE, penalized_objective
from repro.core.planner import DisciplineSpec, Plan, TenantSpec
from repro.hw.specs import Platform

#: Default relative width of one quantization cell (10% in rate).
DEFAULT_REL = 0.10
#: Rates at or below this (req/s) collapse into a single "idle" cell.
IDLE_FLOOR = 1e-3


def quantize_rates(
    rates: Sequence[float],
    rel: float = DEFAULT_REL,
    *,
    idle_floor: float = IDLE_FLOOR,
) -> tuple[int, ...]:
    """Snap a rate vector onto a multiplicative grid of width ``rel``.

    Each rate maps to ``round(log(r / idle_floor) / log(1 + rel))`` -- a
    geometric bucket index -- so two rates within about ``rel`` of each
    other share a bucket at any traffic scale.  Rates at or below
    ``idle_floor`` (including exact zero) map to the sentinel ``-1``.
    """
    if rel <= 0:
        raise ValueError("rel must be positive")
    step = math.log1p(rel)
    out = []
    for r in rates:
        if r <= idle_floor:
            out.append(-1)
        else:
            out.append(int(round(math.log(r / idle_floor) / step)))
    return tuple(out)


def mix_fingerprint(tenants: Sequence[TenantSpec]) -> tuple:
    """Order-sensitive structural identity of a tenant mix's models."""
    return tuple(t.profile.fingerprint for t in tenants)


def _space_key(
    discipline_space: Sequence[DisciplineSpec] | None,
) -> tuple | None:
    return None if discipline_space is None else tuple(discipline_space)


@dataclasses.dataclass
class CacheStats:
    """Lookup counters: a *reject* is a key hit whose plan failed verify."""

    hits: int = 0
    misses: int = 0
    rejects: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses + self.rejects

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "rejects": self.rejects,
            "hit_rate": self.hit_rate,
        }


@dataclasses.dataclass
class _Entry:
    plan: Plan
    norm_objective: float  # obj / tot_rate at store time (finite by contract)


class _LruMixin:
    """Shared LRU bookkeeping for the single-device and fleet caches."""

    def __init__(self, capacity: int, rel: float, margin: float):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if margin < 0:
            raise ValueError("margin must be non-negative")
        self.capacity = int(capacity)
        self.rel = float(rel)
        self.margin = float(margin)
        self.stats = CacheStats()
        self._entries: collections.OrderedDict = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()

    def _get(self, key):
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
        return entry

    def _put(self, key, entry) -> None:
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def _admit(self, entry, objective: float, tot_rate: float):
        """Verify-then-reuse decision shared by both caches.

        ``objective`` is the cached plan re-scored under the fresh rates.
        Returns the (plan, objective) pair to reuse, or ``None`` for a
        reject.  Non-finite or penalty-range objectives never pass: an
        infeasible cached plan is worthless no matter what was stored
        (nan-means-unknown convention -- see ``serving/controller.py``).
        """
        if not math.isfinite(objective) or objective >= _PENALTY_BASE:
            return None
        if tot_rate > 0:
            norm = objective / tot_rate
            if norm > (1.0 + self.margin) * entry.norm_objective:
                return None
        return entry.plan, float(objective)


class PlanCache(_LruMixin):
    """LRU plan memoization for the single-device adaptive controller.

    ``lookup`` returns ``(plan, objective)`` on a verified hit or ``None``
    (miss or reject) -- the caller then runs its warm ``hill_climb`` and
    ``store``s the result, refreshing the cell.  See the module docstring
    for the key structure and verify semantics.
    """

    def __init__(
        self,
        capacity: int = 256,
        *,
        rel: float = DEFAULT_REL,
        margin: float = 0.10,
    ):
        super().__init__(capacity, rel, margin)

    def _key(
        self,
        tenants: Sequence[TenantSpec],
        platform: Platform,
        k_max: int,
        discipline_space: Sequence[DisciplineSpec] | None,
    ) -> tuple:
        return (
            quantize_rates([t.rate for t in tenants], self.rel),
            mix_fingerprint(tenants),
            platform,
            int(k_max),
            _space_key(discipline_space),
        )

    def lookup(
        self,
        tenants: Sequence[TenantSpec],
        platform: Platform,
        k_max: int,
        *,
        discipline_space: Sequence[DisciplineSpec] | None = None,
    ) -> tuple[Plan, float] | None:
        entry = self._get(self._key(tenants, platform, k_max, discipline_space))
        if entry is None:
            self.stats.misses += 1
            return None
        obj = penalized_objective(tenants, entry.plan, platform)
        hit = self._admit(entry, obj, sum(t.rate for t in tenants))
        if hit is None:
            self.stats.rejects += 1
            return None
        self.stats.hits += 1
        return hit

    def store(
        self,
        tenants: Sequence[TenantSpec],
        platform: Platform,
        k_max: int,
        plan: Plan,
        objective: float,
        *,
        discipline_space: Sequence[DisciplineSpec] | None = None,
    ) -> None:
        """Record a freshly planned state; silently skips unusable entries
        (idle mix, infeasible/non-finite objective)."""
        tot_rate = sum(t.rate for t in tenants)
        if not tot_rate > 0:
            return
        norm = objective / tot_rate
        if not math.isfinite(norm) or objective >= _PENALTY_BASE:
            return
        self._put(
            self._key(tenants, platform, k_max, discipline_space),
            _Entry(plan, norm),
        )


class FleetPlanCache(_LruMixin):
    """LRU memoization of ``FleetPlan``s for ``run_adaptive_fleet``.

    Same quantize / fingerprint / verify-then-reuse scheme as
    ``PlanCache``; the key additionally folds in each device's
    ``class_key`` (speeds, platform) so heterogeneous fleets never share
    entries, and the verify step re-scores the whole fleet plan with
    ``fleet_plan_objective``.
    """

    def __init__(
        self,
        capacity: int = 256,
        *,
        rel: float = DEFAULT_REL,
        margin: float = 0.10,
    ):
        super().__init__(capacity, rel, margin)

    def _key(
        self,
        tenants: Sequence[TenantSpec],
        fleet: Sequence,
        k_max: int | None,
        discipline_space: Sequence[DisciplineSpec] | None,
    ) -> tuple:
        return (
            quantize_rates([t.rate for t in tenants], self.rel),
            mix_fingerprint(tenants),
            tuple(d.class_key for d in fleet),
            None if k_max is None else int(k_max),
            _space_key(discipline_space),
        )

    def lookup(
        self,
        tenants: Sequence[TenantSpec],
        fleet: Sequence,
        *,
        k_max: int | None = None,
        discipline_space: Sequence[DisciplineSpec] | None = None,
    ):
        from repro.core.fleet import fleet_plan_objective

        entry = self._get(self._key(tenants, fleet, k_max, discipline_space))
        if entry is None:
            self.stats.misses += 1
            return None
        obj = fleet_plan_objective(tenants, entry.plan, fleet)
        hit = self._admit(entry, obj, sum(t.rate for t in tenants))
        if hit is None:
            self.stats.rejects += 1
            return None
        self.stats.hits += 1
        return hit

    def store(
        self,
        tenants: Sequence[TenantSpec],
        fleet: Sequence,
        fleet_plan,
        objective: float,
        *,
        k_max: int | None = None,
        discipline_space: Sequence[DisciplineSpec] | None = None,
    ) -> None:
        tot_rate = sum(t.rate for t in tenants)
        if not tot_rate > 0:
            return
        norm = objective / tot_rate
        if not math.isfinite(norm) or objective >= _PENALTY_BASE:
            return
        self._put(
            self._key(tenants, fleet, k_max, discipline_space),
            _Entry(fleet_plan, norm),
        )


__all__ = [
    "CacheStats",
    "FleetPlanCache",
    "PlanCache",
    "mix_fingerprint",
    "quantize_rates",
]
