"""Plan memoization: LRU cache over quantized rate states, verify-then-reuse.

Diurnal and bursty workloads revisit the same traffic states over and over
(the morning mix looks like yesterday's morning mix), yet the reactive
controller pays a fresh ``hill_climb`` at every re-plan boundary.  This
module memoizes plans keyed on the *quantized* rate vector plus the mix
fingerprint, so a recurring state re-plans with one cache probe instead of
a search.

Key design points:

* **Quantized keys.**  Rates are snapped to a multiplicative grid
  (``quantize_rates``): two vectors whose per-model rates agree within the
  relative cell width ``rel`` share a key.  The grid is logarithmic, so
  0.10 vs 0.11 req/s land together while 1 vs 2 req/s do not; rates at or
  below ``idle_floor`` share one idle cell.

* **Verify-then-reuse.**  Quantization means a hit's stored plan was
  optimized for *nearby* rates, not these exact rates, and the plan space
  is rugged enough that "nearby" can occasionally be bad (e.g. the cell
  straddles a stability boundary).  Every hit is therefore delta-evaluated:
  one ``penalized_objective`` call re-scores the cached plan under the
  fresh exact rates, and the plan is reused only when its normalized
  objective (obj / total rate, the controller's Eq. 10 trend statistic) is
  within ``margin`` of the quality recorded when it was stored -- and
  finite, and below the infeasibility penalty floor.  Anything else is a
  *reject*: the caller falls back to its normal warm ``hill_climb`` and
  the fresh result overwrites the entry.  A hit costs one plan evaluation
  (~100 us at 64 tenants) instead of a search.

* **Opt-in.**  ``run_adaptive(plan_cache=None)`` -- the default -- never
  constructs or consults a cache; the no-cache path is bitwise the
  reactive controller (standing invariant, self-checked by
  ``benchmarks/predictive.py`` before any timing).

``PlanCache`` serves the single-device controller; ``FleetPlanCache`` is
the same machinery for ``run_adaptive_fleet``, with the verify step
delegated to ``fleet_plan_objective`` and fleet identity (device class
keys) folded into the key.

Objective identity (``repro.core.objective.objective_key``) is part of
both keys: a plan searched for the mean and one searched for p99 are
different answers to different questions, and the verify step must
re-score with the same metric or verify-then-reuse silently compares
different quantities.  The default mean objective appends *nothing* --
the pre-refactor keyspace (and every persisted digest) is preserved
bitwise.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import math
from typing import Sequence

from repro.core.latency import _PENALTY_BASE, penalized_objective
from repro.core.objective import Objective, objective_key
from repro.core.planner import DisciplineSpec, Plan, TenantSpec
from repro.hw.specs import Platform

#: On-disk payload format tag for ``persist``/``restore``.
PERSIST_FORMAT = "repro-plan-cache-v1"

#: Default relative width of one quantization cell (10% in rate).
DEFAULT_REL = 0.10
#: Rates at or below this (req/s) collapse into a single "idle" cell.
IDLE_FLOOR = 1e-3


def quantize_rates(
    rates: Sequence[float],
    rel: float = DEFAULT_REL,
    *,
    idle_floor: float = IDLE_FLOOR,
) -> tuple[int, ...]:
    """Snap a rate vector onto a multiplicative grid of width ``rel``.

    Each rate maps to ``round(log(r / idle_floor) / log(1 + rel))`` -- a
    geometric bucket index -- so two rates within about ``rel`` of each
    other share a bucket at any traffic scale.  Rates at or below
    ``idle_floor`` (including exact zero) map to the sentinel ``-1``.
    """
    if rel <= 0:
        raise ValueError("rel must be positive")
    step = math.log1p(rel)
    out = []
    for r in rates:
        if r <= idle_floor:
            out.append(-1)
        else:
            out.append(int(round(math.log(r / idle_floor) / step)))
    return tuple(out)


def mix_fingerprint(tenants: Sequence[TenantSpec]) -> tuple:
    """Order-sensitive structural identity of a tenant mix's models."""
    return tuple(t.profile.fingerprint for t in tenants)


def _space_key(
    discipline_space: Sequence[DisciplineSpec] | None,
) -> tuple | None:
    return None if discipline_space is None else tuple(discipline_space)


@dataclasses.dataclass
class CacheStats:
    """Lookup counters: a *reject* is a key hit whose plan failed verify."""

    hits: int = 0
    misses: int = 0
    rejects: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses + self.rejects

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "rejects": self.rejects,
            "hit_rate": self.hit_rate,
        }


@dataclasses.dataclass
class _Entry:
    plan: Plan
    norm_objective: float  # obj / tot_rate at store time (finite by contract)


def _digest(key: tuple) -> str:
    """Stable cross-session identity of a cache key.

    Keys hold value-semantic frozen dataclasses (``Platform``,
    ``DisciplineSpec``) and plain tuples, so their ``repr`` is a
    deterministic function of the values -- the hash survives a process
    restart, which is exactly what ``persist``/``restore`` need (the raw
    tuples themselves are not JSON-representable).
    """
    return hashlib.sha256(repr(key).encode()).hexdigest()


class _LruMixin:
    """Shared LRU bookkeeping for the single-device and fleet caches."""

    def __init__(self, capacity: int, rel: float, margin: float):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if margin < 0:
            raise ValueError("margin must be non-negative")
        self.capacity = int(capacity)
        self.rel = float(rel)
        self.margin = float(margin)
        self.stats = CacheStats()
        self._entries: collections.OrderedDict = collections.OrderedDict()
        # Entries loaded by ``restore``, keyed by digest: a live key cannot
        # be reconstructed from JSON, so restored entries wait here and are
        # promoted into ``_entries`` (under the real tuple key) on their
        # first hit.  Empty unless restore() ran -- every probe below is
        # gated on that, keeping the never-restored hot path untouched.
        self._restored: collections.OrderedDict = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._entries) + len(self._restored)

    def clear(self) -> None:
        self._entries.clear()
        self._restored.clear()

    def _get(self, key):
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            return entry
        if self._restored:
            entry = self._restored.pop(_digest(key), None)
            if entry is not None:
                self._put(key, entry)
                return entry
        return None

    def _put(self, key, entry) -> None:
        if self._restored:
            # A fresh store supersedes any still-unclaimed restored twin.
            self._restored.pop(_digest(key), None)
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) + len(self._restored) > self.capacity:
            # Unclaimed restored entries are older than anything live.
            if self._restored:
                self._restored.popitem(last=False)
            else:
                self._entries.popitem(last=False)

    # -- persistence ---------------------------------------------------------
    _kind = ""  # overridden: "plan" / "fleet"

    def _plan_to_json(self, plan):
        raise NotImplementedError

    def _plan_from_json(self, data):
        raise NotImplementedError

    def persist(self) -> str:
        """Serialize the cache to a JSON string (LRU order preserved:
        oldest first, so ``restore`` + eviction keep the same victims)."""
        entries = [
            [digest, self._plan_to_json(e.plan), e.norm_objective]
            for digest, e in self._restored.items()
        ] + [
            [_digest(key), self._plan_to_json(e.plan), e.norm_objective]
            for key, e in self._entries.items()
        ]
        return json.dumps(
            {
                "format": PERSIST_FORMAT,
                "kind": self._kind,
                "capacity": self.capacity,
                "rel": self.rel,
                "margin": self.margin,
                "entries": entries,
            }
        )

    def restore(self, payload: str) -> int:
        """Replace the cache contents from a ``persist`` payload.

        Raises ``ValueError`` when the payload's fingerprint does not match
        this cache: wrong format tag, wrong cache kind (single-device vs
        fleet), or a different quantization grid ``rel`` (the persisted key
        digests embed the grid, so entries from another grid could never be
        hit -- restoring them would only silently waste capacity).  Returns
        the number of entries restored (trimmed to ``capacity``, newest
        kept).
        """
        try:
            data = json.loads(payload)
        except json.JSONDecodeError as exc:
            raise ValueError(f"unreadable plan-cache payload: {exc}") from exc
        if not isinstance(data, dict) or data.get("format") != PERSIST_FORMAT:
            raise ValueError(
                f"not a {PERSIST_FORMAT} payload "
                f"(format={data.get('format')!r})"
                if isinstance(data, dict)
                else "not a plan-cache payload"
            )
        if data.get("kind") != self._kind:
            raise ValueError(
                f"cache kind mismatch: payload is {data.get('kind')!r}, "
                f"this cache is {self._kind!r}"
            )
        if float(data.get("rel", -1.0)) != self.rel:
            raise ValueError(
                f"quantization grid mismatch: payload rel={data.get('rel')}, "
                f"this cache rel={self.rel} (digested keys are grid-specific)"
            )
        entries = data.get("entries", [])
        self.clear()
        for digest, plan_data, norm in entries[-self.capacity:]:
            self._restored[str(digest)] = _Entry(
                self._plan_from_json(plan_data), float(norm)
            )
        return len(self._restored)

    def _admit(self, entry, objective: float, tot_rate: float):
        """Verify-then-reuse decision shared by both caches.

        ``objective`` is the cached plan re-scored under the fresh rates.
        Returns the (plan, objective) pair to reuse, or ``None`` for a
        reject.  Non-finite or penalty-range objectives never pass: an
        infeasible cached plan is worthless no matter what was stored
        (nan-means-unknown convention -- see ``serving/controller.py``).
        """
        if not math.isfinite(objective) or objective >= _PENALTY_BASE:
            return None
        if tot_rate > 0:
            norm = objective / tot_rate
            if norm > (1.0 + self.margin) * entry.norm_objective:
                return None
        return entry.plan, float(objective)


def _discipline_to_json(d: DisciplineSpec) -> dict:
    return {
        "kind": d.kind,
        "batch_cap": d.batch_cap,
        "staleness": None if math.isinf(d.staleness) else d.staleness,
        "weights": None if d.weights is None else list(d.weights),
    }


def _discipline_from_json(x: dict) -> DisciplineSpec:
    return DisciplineSpec(
        kind=x["kind"],
        batch_cap=int(x["batch_cap"]),
        staleness=math.inf if x["staleness"] is None else float(x["staleness"]),
        weights=(
            None if x["weights"] is None else tuple(float(w) for w in x["weights"])
        ),
    )


def _plan_to_json(p: Plan) -> dict:
    return {
        "partition": list(p.partition),
        "cores": list(p.cores),
        "discipline": _discipline_to_json(p.discipline),
    }


def _plan_from_json(x: dict) -> Plan:
    return Plan(
        partition=tuple(int(v) for v in x["partition"]),
        cores=tuple(int(v) for v in x["cores"]),
        discipline=_discipline_from_json(x["discipline"]),
    )


class PlanCache(_LruMixin):
    """LRU plan memoization for the single-device adaptive controller.

    ``lookup`` returns ``(plan, objective)`` on a verified hit or ``None``
    (miss or reject) -- the caller then runs its warm ``hill_climb`` and
    ``store``s the result, refreshing the cell.  See the module docstring
    for the key structure and verify semantics.
    """

    _kind = "plan"

    def __init__(
        self,
        capacity: int = 256,
        *,
        rel: float = DEFAULT_REL,
        margin: float = 0.10,
    ):
        super().__init__(capacity, rel, margin)

    def _plan_to_json(self, plan):
        return _plan_to_json(plan)

    def _plan_from_json(self, data):
        return _plan_from_json(data)

    def _key(
        self,
        tenants: Sequence[TenantSpec],
        platform: Platform,
        k_max: int,
        discipline_space: Sequence[DisciplineSpec] | None,
        objective: Objective | None = None,
    ) -> tuple:
        key = (
            quantize_rates([t.rate for t in tenants], self.rel),
            mix_fingerprint(tenants),
            platform,
            int(k_max),
            _space_key(discipline_space),
        )
        okey = objective_key(objective, tenants)
        # The default mean appends nothing: pre-refactor keys (and their
        # persisted digests) stay bitwise identical.
        return key if okey is None else key + (okey,)

    def lookup(
        self,
        tenants: Sequence[TenantSpec],
        platform: Platform,
        k_max: int,
        *,
        discipline_space: Sequence[DisciplineSpec] | None = None,
        objective: Objective | None = None,
    ) -> tuple[Plan, float] | None:
        entry = self._get(
            self._key(tenants, platform, k_max, discipline_space, objective)
        )
        if entry is None:
            self.stats.misses += 1
            return None
        obj = penalized_objective(
            tenants, entry.plan, platform, objective=objective
        )
        hit = self._admit(entry, obj, sum(t.rate for t in tenants))
        if hit is None:
            self.stats.rejects += 1
            return None
        self.stats.hits += 1
        return hit

    def store(
        self,
        tenants: Sequence[TenantSpec],
        platform: Platform,
        k_max: int,
        plan: Plan,
        value: float,
        *,
        discipline_space: Sequence[DisciplineSpec] | None = None,
        objective: Objective | None = None,
    ) -> None:
        """Record a freshly planned state; silently skips unusable entries
        (idle mix, infeasible/non-finite value).  ``value`` is the plan's
        scored objective; ``objective`` is the metric spec it was scored
        under (part of the key)."""
        tot_rate = sum(t.rate for t in tenants)
        if not tot_rate > 0:
            return
        norm = value / tot_rate
        if not math.isfinite(norm) or value >= _PENALTY_BASE:
            return
        self._put(
            self._key(tenants, platform, k_max, discipline_space, objective),
            _Entry(plan, norm),
        )


class FleetPlanCache(_LruMixin):
    """LRU memoization of ``FleetPlan``s for ``run_adaptive_fleet``.

    Same quantize / fingerprint / verify-then-reuse scheme as
    ``PlanCache``; the key additionally folds in each device's
    ``class_key`` (speeds, platform) so heterogeneous fleets never share
    entries, and the verify step re-scores the whole fleet plan with
    ``fleet_plan_objective``.
    """

    _kind = "fleet"

    def __init__(
        self,
        capacity: int = 256,
        *,
        rel: float = DEFAULT_REL,
        margin: float = 0.10,
    ):
        super().__init__(capacity, rel, margin)

    def _plan_to_json(self, plan):
        return {
            "placement": [list(devs) for devs in plan.placement],
            "routing": [list(ws) for ws in plan.routing],
            "device_plans": [_plan_to_json(p) for p in plan.device_plans],
        }

    def _plan_from_json(self, data):
        from repro.core.fleet import FleetPlan

        return FleetPlan(
            placement=tuple(
                tuple(int(d) for d in devs) for devs in data["placement"]
            ),
            routing=tuple(
                tuple(float(w) for w in ws) for ws in data["routing"]
            ),
            device_plans=tuple(
                _plan_from_json(p) for p in data["device_plans"]
            ),
        )

    def _key(
        self,
        tenants: Sequence[TenantSpec],
        fleet: Sequence,
        k_max: int | None,
        discipline_space: Sequence[DisciplineSpec] | None,
        objective: Objective | None = None,
    ) -> tuple:
        key = (
            quantize_rates([t.rate for t in tenants], self.rel),
            mix_fingerprint(tenants),
            tuple(d.class_key for d in fleet),
            None if k_max is None else int(k_max),
            _space_key(discipline_space),
        )
        okey = objective_key(objective, tenants)
        return key if okey is None else key + (okey,)

    def lookup(
        self,
        tenants: Sequence[TenantSpec],
        fleet: Sequence,
        *,
        k_max: int | None = None,
        discipline_space: Sequence[DisciplineSpec] | None = None,
        objective: Objective | None = None,
    ):
        from repro.core.fleet import fleet_plan_objective

        entry = self._get(
            self._key(tenants, fleet, k_max, discipline_space, objective)
        )
        if entry is None:
            self.stats.misses += 1
            return None
        obj = fleet_plan_objective(
            tenants, entry.plan, fleet, objective=objective
        )
        hit = self._admit(entry, obj, sum(t.rate for t in tenants))
        if hit is None:
            self.stats.rejects += 1
            return None
        self.stats.hits += 1
        return hit

    def store(
        self,
        tenants: Sequence[TenantSpec],
        fleet: Sequence,
        fleet_plan,
        value: float,
        *,
        k_max: int | None = None,
        discipline_space: Sequence[DisciplineSpec] | None = None,
        objective: Objective | None = None,
    ) -> None:
        tot_rate = sum(t.rate for t in tenants)
        if not tot_rate > 0:
            return
        norm = value / tot_rate
        if not math.isfinite(norm) or value >= _PENALTY_BASE:
            return
        self._put(
            self._key(tenants, fleet, k_max, discipline_space, objective),
            _Entry(fleet_plan, norm),
        )


__all__ = [
    "CacheStats",
    "FleetPlanCache",
    "PERSIST_FORMAT",
    "PlanCache",
    "mix_fingerprint",
    "quantize_rates",
]
