"""End-to-end latency prediction (Eq. 1-4) for a global configuration."""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import queueing, swap
from repro.core.planner import (
    ModelProfile,
    Plan,
    TenantSpec,
    load_time,
    prefix_service_time,
)
from repro.hw.specs import Platform


@dataclasses.dataclass(frozen=True)
class LatencyBreakdown:
    """Per-model expected latency components (all seconds)."""

    input_xfer: float
    tpu_wait: float
    tpu_swap: float          # expected inter-model swap: alpha * T_load
    tpu_service: float       # prefix compute + intra-model swap streaming
    boundary_xfer: float
    cpu_wait: float
    cpu_service: float

    @property
    def total(self) -> float:
        return (
            self.input_xfer
            + self.tpu_wait
            + self.tpu_swap
            + self.tpu_service
            + self.boundary_xfer
            + self.cpu_wait
            + self.cpu_service
        )


@dataclasses.dataclass(frozen=True)
class SystemPrediction:
    per_model: tuple[LatencyBreakdown, ...]
    tpu_utilization: float
    cpu_utilizations: tuple[float, ...]
    alphas: tuple[float, ...]

    @property
    def stable(self) -> bool:
        return self.tpu_utilization < 1.0 and all(
            u < 1.0 for u in self.cpu_utilizations
        )

    @property
    def overload(self) -> float:
        """Total excess utilization; 0 when all queues are stable."""
        return max(0.0, self.tpu_utilization - 1.0) + sum(
            max(0.0, u - 1.0) for u in self.cpu_utilizations
        )

    @property
    def latencies(self) -> tuple[float, ...]:
        return tuple(b.total for b in self.per_model)

    def weighted_latency(self, tenants: Sequence[TenantSpec]) -> float:
        """Objective of Eq. 5: sum_i lambda_i * T_e2e_i."""
        return sum(t.rate * b.total for t, b in zip(tenants, self.per_model))

    def mean_latency(self, tenants: Sequence[TenantSpec]) -> float:
        """Request-weighted mean latency (what the paper's figures report)."""
        tot = sum(t.rate for t in tenants)
        if tot <= 0:
            return 0.0
        return self.weighted_latency(tenants) / tot


def tpu_service_distribution(
    tenants: Sequence[TenantSpec],
    partition: Sequence[int],
    alphas: Sequence[float],
    platform: Platform,
) -> tuple[list[float], list[float]]:
    """The TPU service-time mixture of Eq. 2 as (weights, atoms).

    Each TPU-active model contributes two atoms: a hit (prob 1-alpha) with
    service ``s_tpu`` and a miss (prob alpha) with service ``T_load + s_tpu``.
    Using the full two-atom mixture gives the exact E[S^2] needed by
    Pollaczek-Khinchine (the paper states only the mean, Eq. 2; the second
    moment follows from the same distribution).
    """
    weights: list[float] = []
    atoms: list[float] = []
    for t, p, a in zip(tenants, partition, alphas):
        if p <= 0:
            continue
        s = prefix_service_time(t.profile, p, platform)
        tl = load_time(t.profile, p, platform)
        if a > 0.0:
            weights.extend([t.rate * (1.0 - a), t.rate * a])
            atoms.extend([s, s + tl])
        else:
            weights.append(t.rate)
            atoms.append(s)
    return weights, atoms


def predict(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    *,
    force_alpha_zero: bool = False,
) -> SystemPrediction:
    """Predict per-model end-to-end latency under (P, K)  --  Eq. 4.

    ``force_alpha_zero`` implements the paper's "SwapLess (alpha=0)" ablation
    baseline: the queueing terms are kept but inter-model swapping is ignored.
    """
    partition, cores = plan.partition, plan.cores
    if force_alpha_zero:
        alphas = [0.0] * len(tenants)
    else:
        alphas = swap.weight_miss_probs(tenants, partition, platform)

    lam_tpu = swap.tpu_arrival_rate(tenants, partition)
    weights, atoms = tpu_service_distribution(tenants, partition, alphas, platform)
    es, es2 = queueing.mixture_moments(weights, atoms)
    tpu_wait = queueing.mg1_wait(lam_tpu, es, es2)
    rho_tpu = lam_tpu * es

    per_model: list[LatencyBreakdown] = []
    cpu_utils: list[float] = []
    for t, p, k, a in zip(tenants, partition, cores, alphas):
        prof = t.profile
        P_i = prof.num_partition_points
        on_tpu = p > 0
        on_cpu = p < P_i

        input_xfer = prof.input_bytes / platform.swap_bw if on_tpu else 0.0
        t_wait = tpu_wait if on_tpu else 0.0
        t_swap = a * load_time(prof, p, platform) if on_tpu else 0.0
        t_serv = prefix_service_time(prof, p, platform) if on_tpu else 0.0
        b_xfer = prof.boundary_bytes(p) / platform.swap_bw if on_tpu and on_cpu else 0.0

        if on_cpu:
            # The paper's runtime executes each request's suffix on one
            # worker thread of a model-specific pool of size k_i (Sec. IV);
            # parallelism comes from serving k_i requests concurrently, so
            # the M/D/k pool has k servers of per-server rate 1/s_cpu(1 core).
            s_one = prof.suffix_cpu_time(p, 1)
            mu_one = 1.0 / s_one if s_one > 0 else math.inf
            c_wait = queueing.mdk_wait(t.rate, mu_one, k)
            c_serv = s_one
            cpu_utils.append(t.rate * s_one / max(k, 1))
        else:
            c_wait = 0.0
            c_serv = 0.0
            cpu_utils.append(0.0)

        per_model.append(
            LatencyBreakdown(
                input_xfer=input_xfer,
                tpu_wait=t_wait,
                tpu_swap=t_swap,
                tpu_service=t_serv,
                boundary_xfer=b_xfer,
                cpu_wait=c_wait,
                cpu_service=c_serv,
            )
        )
    return SystemPrediction(
        per_model=tuple(per_model),
        tpu_utilization=rho_tpu,
        cpu_utilizations=tuple(cpu_utils),
        alphas=tuple(alphas),
    )


def objective(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    *,
    force_alpha_zero: bool = False,
) -> float:
    """Eq. 5 objective; ``inf`` when any queue is unstable."""
    pred = predict(tenants, plan, platform, force_alpha_zero=force_alpha_zero)
    return pred.weighted_latency(tenants)


# Any finite objective is < _PENALTY_BASE; overload adds gradient on top so
# the hill-climb can walk *out* of infeasible regions (the all-CPU start is
# often unstable at the paper's moderate loads).
_PENALTY_BASE = 1e9


def penalized_objective(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    *,
    force_alpha_zero: bool = False,
) -> float:
    """Eq. 5 objective with a smooth infeasibility penalty.

    Stable configurations return their true weighted latency.  Unstable ones
    return ``_PENALTY_BASE * (1 + overload)`` so that moves reducing excess
    utilization still rank as improvements -- this is what lets Algorithm 1's
    all-CPU initialization climb into the feasible region.

    This is the allocator's hot path (hundreds of evaluations per
    re-planning); it computes the scalar objective without materializing the
    per-model breakdown dataclasses ``predict`` builds for reporting.
    """
    partition, cores = plan.partition, plan.cores
    if force_alpha_zero:
        alphas = [0.0] * len(tenants)
    else:
        alphas = swap.weight_miss_probs(tenants, partition, platform)

    lam_tpu = swap.tpu_arrival_rate(tenants, partition)
    weights, atoms = tpu_service_distribution(tenants, partition, alphas, platform)
    es, es2 = queueing.mixture_moments(weights, atoms)
    rho_tpu = lam_tpu * es
    tpu_wait = queueing.mg1_wait(lam_tpu, es, es2)

    total = 0.0
    overload = max(0.0, rho_tpu - 1.0)
    bw = platform.swap_bw
    for t, p, k, a in zip(tenants, partition, cores, alphas):
        prof = t.profile
        P_i = prof.num_partition_points
        lat = 0.0
        if p > 0:
            lat += (
                prof.input_bytes / bw
                + tpu_wait
                + a * load_time(prof, p, platform)
                + prefix_service_time(prof, p, platform)
            )
            if p < P_i:
                lat += prof.boundary_bytes(p) / bw
        if p < P_i:
            s_one = prof.suffix_cpu_time(p, 1)
            overload += max(0.0, t.rate * s_one / max(k, 1) - 1.0)
            mu_one = 1.0 / s_one if s_one > 0 else math.inf
            lat += queueing.mdk_wait(t.rate, mu_one, k) + s_one
        total += t.rate * lat
    if overload == 0.0 and math.isfinite(total):
        return total
    return _PENALTY_BASE * (1.0 + overload)
