"""End-to-end latency prediction (Eq. 1-4) for a global configuration."""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core import queueing, swap
from repro.core.objective import Objective, deadlines_of, is_default
from repro.core.plan_tables import (
    PCOL_ACTIVE,
    PCOL_LAM,
    PCOL_Q,
    PCOL_S1,
    PCOL_S2,
    PCOL_SL,
    PCOL_U,
    PCOL_V,
    PCOL_WEIGHT,
    PKCOL_OVERLOAD,
    PKCOL_STATIC,
    EvalTables,
    PlanTables,
)
from repro.core.planner import (
    FCFS,
    DisciplineSpec,
    ModelProfile,
    Plan,
    TenantSpec,
    load_time,
    prefix_service_time,
)
from repro.hw.specs import Platform


def _amortized_tpu_terms(
    tenants: Sequence[TenantSpec],
    partition: Sequence[int],
    alphas: Sequence[float],
    platform: Platform,
    batch_cap: int,
    staleness: float,
) -> tuple[float, float, np.ndarray]:
    """Scalar-path swap-batch aggregates: ``(tpu_wait, rho_tpu, alpha_eff)``.

    Assembles the per-tenant inputs of
    ``queueing.swap_batch_amortization`` from profile lookups -- the same
    formulas the batched evaluator runs on gathered tables, so the two
    paths agree to round-off (the PR-1 batch == scalar invariant extended
    to batching disciplines).
    """
    n = len(tenants)
    rates = np.zeros(n)
    svc = np.zeros(n)
    tl = np.zeros(n)
    for j, (t, p) in enumerate(zip(tenants, partition)):
        if p > 0:
            rates[j] = t.rate
            svc[j] = prefix_service_time(t.profile, p, platform)
            tl[j] = load_time(t.profile, p, platform)
    lam = float(rates.sum())
    s1 = float((rates * svc).sum())
    s2 = float((rates * svc * svc).sum())
    wait, rho, alpha_eff = queueing.swap_batch_amortization(
        lam, s1, s2, rates, np.asarray(alphas, dtype=np.float64), tl, svc,
        batch_cap, staleness=staleness,
    )
    return float(wait), float(rho), alpha_eff


@dataclasses.dataclass(frozen=True)
class LatencyBreakdown:
    """Per-model expected latency components (all seconds)."""

    input_xfer: float
    tpu_wait: float
    tpu_swap: float          # expected inter-model swap: alpha * T_load
    tpu_service: float       # prefix compute + intra-model swap streaming
    boundary_xfer: float
    cpu_wait: float
    cpu_service: float

    @property
    def total(self) -> float:
        return (
            self.input_xfer
            + self.tpu_wait
            + self.tpu_swap
            + self.tpu_service
            + self.boundary_xfer
            + self.cpu_wait
            + self.cpu_service
        )

    @property
    def static(self) -> float:
        """Closed-form deterministic path: transfers + service, no queueing
        and no expected swap.  A lone request in an otherwise idle, warm
        system takes exactly this long -- the round-off-exact reference the
        discrete-event simulator is validated against (tests/test_des.py).
        """
        return (
            self.input_xfer
            + self.tpu_service
            + self.boundary_xfer
            + self.cpu_service
        )

    @property
    def queueing(self) -> float:
        """Stochastic congestion terms (Eq. 1 + Eq. 3 waits): what remains
        of ``total`` beyond ``static`` and the expected swap penalty."""
        return self.tpu_wait + self.cpu_wait


@dataclasses.dataclass(frozen=True)
class SystemPrediction:
    per_model: tuple[LatencyBreakdown, ...]
    tpu_utilization: float
    cpu_utilizations: tuple[float, ...]
    alphas: tuple[float, ...]

    @property
    def stable(self) -> bool:
        return self.tpu_utilization < 1.0 and all(
            u < 1.0 for u in self.cpu_utilizations
        )

    @property
    def overload(self) -> float:
        """Total excess utilization; 0 when all queues are stable."""
        return max(0.0, self.tpu_utilization - 1.0) + sum(
            max(0.0, u - 1.0) for u in self.cpu_utilizations
        )

    @property
    def latencies(self) -> tuple[float, ...]:
        return tuple(b.total for b in self.per_model)

    @property
    def static_latencies(self) -> tuple[float, ...]:
        """Per-model closed-form static latency (no queueing, no swap)."""
        return tuple(b.static for b in self.per_model)

    @property
    def queueing_latencies(self) -> tuple[float, ...]:
        """Per-model predicted wait (TPU + CPU queueing only)."""
        return tuple(b.queueing for b in self.per_model)

    def weighted_latency(self, tenants: Sequence[TenantSpec]) -> float:
        """Objective of Eq. 5: sum_i lambda_i * T_e2e_i."""
        return sum(t.rate * b.total for t, b in zip(tenants, self.per_model))

    def mean_latency(self, tenants: Sequence[TenantSpec]) -> float:
        """Request-weighted mean latency (what the paper's figures report)."""
        tot = sum(t.rate for t in tenants)
        if tot <= 0:
            return 0.0
        return self.weighted_latency(tenants) / tot


def tpu_service_distribution(
    tenants: Sequence[TenantSpec],
    partition: Sequence[int],
    alphas: Sequence[float],
    platform: Platform,
) -> tuple[list[float], list[float]]:
    """The TPU service-time mixture of Eq. 2 as (weights, atoms).

    Each TPU-active model contributes two atoms: a hit (prob 1-alpha) with
    service ``s_tpu`` and a miss (prob alpha) with service ``T_load + s_tpu``.
    Using the full two-atom mixture gives the exact E[S^2] needed by
    Pollaczek-Khinchine (the paper states only the mean, Eq. 2; the second
    moment follows from the same distribution).
    """
    weights: list[float] = []
    atoms: list[float] = []
    for t, p, a in zip(tenants, partition, alphas):
        if p <= 0:
            continue
        s = prefix_service_time(t.profile, p, platform)
        tl = load_time(t.profile, p, platform)
        if a > 0.0:
            weights.extend([t.rate * (1.0 - a), t.rate * a])
            atoms.extend([s, s + tl])
        else:
            weights.append(t.rate)
            atoms.append(s)
    return weights, atoms


def predict(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    *,
    force_alpha_zero: bool = False,
) -> SystemPrediction:
    """Predict per-model end-to-end latency under (P, K)  --  Eq. 4.

    ``force_alpha_zero`` implements the paper's "SwapLess (alpha=0)" ablation
    baseline: the queueing terms are kept but inter-model swapping is ignored.

    A batching ``plan.discipline`` (swap_batch with cap > 1) swaps the Eq. 2
    mixture for the batch-amortized model
    (``queueing.swap_batch_amortization``); the reported ``alphas`` are then
    the amortized effective switch-in probabilities.  The ``priority`` /
    ``weighted_fair`` disciplines keep the FCFS aggregate prediction: they
    redistribute waiting between tenants but are work-conserving and
    service-blind, so the mean terms the Eq. 5 objective sums are conserved
    and they batch nothing.
    """
    partition, cores = plan.partition, plan.cores
    if force_alpha_zero:
        alphas = [0.0] * len(tenants)
    else:
        alphas = swap.weight_miss_probs(tenants, partition, platform)

    lam_tpu = swap.tpu_arrival_rate(tenants, partition)
    if plan.discipline.batches and not force_alpha_zero:
        tpu_wait, rho_tpu, alphas = _amortized_tpu_terms(
            tenants, partition, alphas, platform,
            plan.discipline.batch_cap, plan.discipline.staleness,
        )
        alphas = [float(a) for a in alphas]
    else:
        weights, atoms = tpu_service_distribution(
            tenants, partition, alphas, platform
        )
        es, es2 = queueing.mixture_moments(weights, atoms)
        tpu_wait = queueing.mg1_wait(lam_tpu, es, es2)
        rho_tpu = lam_tpu * es

    per_model: list[LatencyBreakdown] = []
    cpu_utils: list[float] = []
    for t, p, k, a in zip(tenants, partition, cores, alphas):
        prof = t.profile
        P_i = prof.num_partition_points
        on_tpu = p > 0
        on_cpu = p < P_i

        input_xfer = prof.input_bytes / platform.swap_bw if on_tpu else 0.0
        t_wait = tpu_wait if on_tpu else 0.0
        t_swap = a * load_time(prof, p, platform) if on_tpu else 0.0
        t_serv = prefix_service_time(prof, p, platform) if on_tpu else 0.0
        b_xfer = prof.boundary_bytes(p) / platform.swap_bw if on_tpu and on_cpu else 0.0

        if on_cpu:
            # The paper's runtime executes each request's suffix on one
            # worker thread of a model-specific pool of size k_i (Sec. IV);
            # parallelism comes from serving k_i requests concurrently, so
            # the M/D/k pool has k servers of per-server rate 1/s_cpu(1 core).
            s_one = prof.suffix_cpu_time(p, 1)
            mu_one = 1.0 / s_one if s_one > 0 else math.inf
            c_wait = queueing.mdk_wait(t.rate, mu_one, k)
            c_serv = s_one
            cpu_utils.append(t.rate * s_one / max(k, 1))
        else:
            c_wait = 0.0
            c_serv = 0.0
            cpu_utils.append(0.0)

        per_model.append(
            LatencyBreakdown(
                input_xfer=input_xfer,
                tpu_wait=t_wait,
                tpu_swap=t_swap,
                tpu_service=t_serv,
                boundary_xfer=b_xfer,
                cpu_wait=c_wait,
                cpu_service=c_serv,
            )
        )
    return SystemPrediction(
        per_model=tuple(per_model),
        tpu_utilization=rho_tpu,
        cpu_utilizations=tuple(cpu_utils),
        alphas=tuple(alphas),
    )


def objective(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    *,
    force_alpha_zero: bool = False,
    objective: Objective | None = None,
) -> float:
    """Eq. 5 objective; ``inf`` when any queue is unstable.

    ``objective`` selects the opt-in SLO objectives of
    ``repro.core.objective``; ``None`` (or an explicit mean spec) is the
    pinned Eq. 5 path above.
    """
    pred = predict(tenants, plan, platform, force_alpha_zero=force_alpha_zero)
    if not is_default(objective):
        return _slo_value(tenants, pred, objective)
    return pred.weighted_latency(tenants)


def _miss_prob(wt, rho_t, wc, rho_c, slack):
    """P(W_tpu + W_cpu > slack) under the exponential-tail wait model.

    The two waits are treated as independent, with the slack split between
    them proportionally to their means (all the slack goes to the only
    nonzero wait when one is zero).  Monotone non-increasing in ``slack``;
    1 when ``slack < 0`` (the static path already blew the budget) and when
    either queue is unstable (``wait_exceed_prob`` maps infinite waits to
    1).  Element-wise over any broadcastable shapes -- the scalar
    reference, the batched evaluator, and ``benchmarks/model_vs_sim`` all
    run this exact function.
    """
    wt = np.asarray(wt, dtype=np.float64)
    wc = np.asarray(wc, dtype=np.float64)
    slack = np.asarray(slack, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        wsum = wt + wc
        ft = np.where(wsum > 0.0, wt / wsum, 0.0)
        fc = np.where(wsum > 0.0, wc / wsum, 0.0)
        # inf * 0 guards: an all-slack-to-one-side split stays exact and an
        # infinite budget never produces NaN shares.
        sa = np.where(ft > 0.0, slack * ft, 0.0)
        sb = np.where(fc > 0.0, slack * fc, 0.0)
        pt = queueing.wait_exceed_prob(wt, rho_t, sa)
        pc = queueing.wait_exceed_prob(wc, rho_c, sb)
        miss = 1.0 - (1.0 - pt) * (1.0 - pc)
    return np.where(slack < 0.0, 1.0, miss)


def predict_tail_latencies(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    q: float = 0.99,
    *,
    force_alpha_zero: bool = False,
    pred: SystemPrediction | None = None,
) -> np.ndarray:
    """Per-tenant analytic q-quantile latency ``T_i(q)``.

    ``T_i(q)`` replaces each mean queueing delay of the Eq. 4 breakdown
    with its q-quantile under the M/G/1 exponential-tail model
    (``queueing.wait_tail_quantile``); statics and the expected swap stay
    at their means.  Summing the marginal TPU and CPU quantiles is
    conservative -- ``benchmarks/model_vs_sim.py`` maps the error against
    the DES p99.  Off-TPU tenants get a zero TPU term; unstable queues
    produce ``inf``.
    """
    if pred is None:
        pred = predict(tenants, plan, platform, force_alpha_zero=force_alpha_zero)
    rho_t = pred.tpu_utilization
    out = np.empty(len(tenants), dtype=np.float64)
    for i, b in enumerate(pred.per_model):
        tail_t = float(queueing.wait_tail_quantile(b.tpu_wait, rho_t, q))
        tail_c = float(
            queueing.wait_tail_quantile(b.cpu_wait, pred.cpu_utilizations[i], q)
        )
        out[i] = b.static + b.tpu_swap + tail_t + tail_c
    return out


def predict_miss_probs(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    deadlines: np.ndarray | None = None,
    *,
    force_alpha_zero: bool = False,
    pred: SystemPrediction | None = None,
) -> np.ndarray:
    """Per-tenant analytic deadline-miss probability ``P(T_i > d_i)``.

    ``deadlines`` defaults to the budgets carried on the mix
    (``TenantSpec.deadline``; tenants without one never miss).  The miss
    splits each tenant's slack ``d_i - static_i - swap_i`` across the TPU
    and CPU waits -- see ``_miss_prob`` for the model and its conventions.
    Monotone non-increasing in every deadline.
    """
    if pred is None:
        pred = predict(tenants, plan, platform, force_alpha_zero=force_alpha_zero)
    if deadlines is None:
        deadlines = deadlines_of(tenants)
    d = np.asarray(deadlines, dtype=np.float64)
    rho_t = pred.tpu_utilization
    out = np.empty(len(tenants), dtype=np.float64)
    for i, b in enumerate(pred.per_model):
        slack = d[i] - b.static - b.tpu_swap
        out[i] = float(
            _miss_prob(
                b.tpu_wait, rho_t, b.cpu_wait, pred.cpu_utilizations[i], slack
            )
        )
    return out


def _slo_value(
    tenants: Sequence[TenantSpec],
    pred: SystemPrediction,
    objective: Objective,
) -> float:
    """Scalar-path SLO objective value from a computed prediction.

    ``p_tail``: ``sum_i lambda_i * T_i(q)`` -- Eq. 5 with quantile
    latencies.  ``deadline_miss``: ``sum_i lambda_i * P(T_i > d_i)``, the
    rate of deadline misses per second.  Zero-rate tenants on unstable
    queues contribute ``0 * inf = NaN`` exactly as the mean path does.
    """
    rates = np.array([t.rate for t in tenants], dtype=np.float64)
    if objective.kind == "p_tail":
        vals = predict_tail_latencies(tenants, None, None, objective.q, pred=pred)
    else:
        vals = predict_miss_probs(tenants, None, None, pred=pred)
    return float(np.sum(rates * vals))


# Any finite objective is < _PENALTY_BASE; overload adds gradient on top so
# the hill-climb can walk *out* of infeasible regions (the all-CPU start is
# often unstable at the paper's moderate loads).
_PENALTY_BASE = 1e9


def penalized_objective(
    tenants: Sequence[TenantSpec],
    plan: Plan,
    platform: Platform,
    *,
    force_alpha_zero: bool = False,
    objective: Objective | None = None,
) -> float:
    """Eq. 5 objective with a smooth infeasibility penalty.

    Stable configurations return their true weighted latency.  Unstable ones
    return ``_PENALTY_BASE * (1 + overload)`` so that moves reducing excess
    utilization still rank as improvements -- this is what lets Algorithm 1's
    all-CPU initialization climb into the feasible region.

    This is the allocator's hot path (hundreds of evaluations per
    re-planning); it computes the scalar objective without materializing the
    per-model breakdown dataclasses ``predict`` builds for reporting.

    ``objective`` selects the opt-in SLO objectives (same penalty and
    feasibility semantics, SLO value instead of the weighted mean); the
    ``None`` default is the pinned pre-refactor mean path below.
    """
    if not is_default(objective):
        pred = predict(
            tenants, plan, platform, force_alpha_zero=force_alpha_zero
        )
        total = _slo_value(tenants, pred, objective)
        over = pred.overload
        if over == 0.0 and math.isfinite(total):
            return total
        return _PENALTY_BASE * (1.0 + over)
    partition, cores = plan.partition, plan.cores
    if force_alpha_zero:
        alphas = [0.0] * len(tenants)
    else:
        alphas = swap.weight_miss_probs(tenants, partition, platform)

    lam_tpu = swap.tpu_arrival_rate(tenants, partition)
    if plan.discipline.batches and not force_alpha_zero:
        tpu_wait, rho_tpu, alphas = _amortized_tpu_terms(
            tenants, partition, alphas, platform,
            plan.discipline.batch_cap, plan.discipline.staleness,
        )
    else:
        weights, atoms = tpu_service_distribution(
            tenants, partition, alphas, platform
        )
        es, es2 = queueing.mixture_moments(weights, atoms)
        rho_tpu = lam_tpu * es
        tpu_wait = queueing.mg1_wait(lam_tpu, es, es2)

    total = 0.0
    overload = max(0.0, rho_tpu - 1.0)
    bw = platform.swap_bw
    for t, p, k, a in zip(tenants, partition, cores, alphas):
        prof = t.profile
        P_i = prof.num_partition_points
        lat = 0.0
        if p > 0:
            lat += (
                prof.input_bytes / bw
                + tpu_wait
                + a * load_time(prof, p, platform)
                + prefix_service_time(prof, p, platform)
            )
            if p < P_i:
                lat += prof.boundary_bytes(p) / bw
        if p < P_i:
            s_one = prof.suffix_cpu_time(p, 1)
            overload += max(0.0, t.rate * s_one / max(k, 1) - 1.0)
            mu_one = 1.0 / s_one if s_one > 0 else math.inf
            lat += queueing.mdk_wait(t.rate, mu_one, k) + s_one
        total += t.rate * lat
    if overload == 0.0 and math.isfinite(total):
        return total
    return _PENALTY_BASE * (1.0 + overload)


# --------------------------------------------------------------------------
# Vectorized plan-space evaluation engine
# --------------------------------------------------------------------------
#
# The scalar objective above walks Python loops per candidate; Algorithm 1
# needs hundreds of candidate evaluations per re-plan and the paper budgets
# <2 ms for the whole invocation.  The batch evaluator below scores B plans
# at once with NumPy gathers over precomputed PlanTables.
#
# Invariant (enforced by tests/test_batch_eval.py): for every plan,
# penalized_objective_batch == penalized_objective and objective_batch ==
# objective up to float round-off (~1e-12 relative).  Any future change to
# the analytic model must land in both paths.

def _resolve_tables(
    tenants: Sequence[TenantSpec],
    platform: Platform,
    cores: np.ndarray,
    tables: PlanTables | EvalTables | None,
) -> EvalTables:
    """Rate-aware tables for this mix, reusing whatever half of ``tables``
    is still valid (EvalTables -> as-is; stale rates -> rebuild on the same
    PlanTables; profile/platform mismatch -> full rebuild)."""
    if isinstance(tables, EvalTables) and tables.matches(tenants, platform):
        et = tables
    else:
        # Reuse the rate-free half when only the rates went stale; build
        # discards it if the profiles or platform do not match.
        base = tables.base if isinstance(tables, EvalTables) else tables
        et = EvalTables.build(
            tenants,
            platform,
            int(max(np.max(cores, initial=1), base.k_max if base else 1)),
            base=base,
        )
    if cores.size and int(cores.max()) > et.k_max:
        # Core counts beyond the prebuilt k-axis: extend once.
        et = EvalTables.build(tenants, platform, int(cores.max()), base=et.base)
    return et


def _batch_eval(
    tenants: Sequence[TenantSpec],
    partitions: np.ndarray,
    cores: np.ndarray,
    platform: Platform,
    *,
    force_alpha_zero: bool,
    tables: PlanTables | EvalTables | None,
    discipline: DisciplineSpec = FCFS,
    objective: Objective | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Shared core: per-plan (weighted_latency_total, overload) arrays.

    ``partitions``/``cores`` are int arrays of shape [B, n].  Every row must
    already satisfy the box constraints 0 <= p_i <= P_i (out-of-range gathers
    hit NaN poison in the tables and fail loudly in tests).

    The Eq. 1-5 objective is evaluated through the ``EvalTables``
    decomposition (see plan_tables.py): two gathers + two row-sums recover
    every per-tenant aggregate, and the remaining work is O(1) vector math
    on [B]-shaped arrays -- the per-candidate cost no longer scales with the
    per-tenant Python loop of the scalar path.
    """
    P = np.asarray(partitions, dtype=np.intp)
    K = np.asarray(cores, dtype=np.intp)
    if P.ndim != 2 or P.shape != K.shape:
        raise ValueError(f"expected [B, n] partitions/cores, got {P.shape}/{K.shape}")
    et = _resolve_tables(tenants, platform, K, tables)

    if not is_default(objective):
        return _batch_eval_slo(
            tenants,
            et,
            P,
            K,
            force_alpha_zero=force_alpha_zero,
            discipline=discipline,
            objective=objective,
        )
    ti = et.tenant_idx
    A = et.pstack[ti, P].sum(axis=1)       # [B, 9] per-tenant aggregates
    F = et.pkstack[ti, P, K].sum(axis=1)   # [B, 2] static latency + overload
    return _aggregate_objective(
        et, A, F, P, force_alpha_zero=force_alpha_zero, discipline=discipline
    )


def _aggregate_objective(
    et: EvalTables,
    A: np.ndarray,
    F: np.ndarray,
    P: np.ndarray,
    *,
    force_alpha_zero: bool,
    discipline: DisciplineSpec = FCFS,
) -> tuple[np.ndarray, np.ndarray]:
    """O(1)-per-plan tail of the decomposed objective: [B, 9] / [B, 2]
    per-tenant aggregates -> (weighted_latency_total, overload).

    A batching ``discipline`` routes through the batch-amortized swap model
    instead of the Eq. 10 collapse; the per-tenant amortization weights
    depend on the plan's own fixed-point wait, so this branch pays two
    extra per-tenant gathers from the rate-free tables ([B, n] instead of
    the aggregate [B, 9]) -- still one NumPy pass, and exactly the formulas
    the scalar ``_amortized_tpu_terms`` runs.
    """
    lam = A[:, PCOL_LAM]
    S1 = A[:, PCOL_S1]
    S2 = A[:, PCOL_S2]

    if discipline.batches and not force_alpha_zero:
        return _aggregate_objective_batched_swap(et, A, F, P, discipline)

    with np.errstate(divide="ignore", invalid="ignore"):
        if force_alpha_zero:
            swap_term = 0.0
            rho_tpu = S1
            es2_num = S2
        else:
            # Eq. 10 shared-occupancy regime: alphas_i = 1 - r_i/lam for
            # every TPU-active tenant, which collapses the swap and moment
            # sums to (SL - Q/lam) and (U - V/lam).
            shared = (
                (A[:, PCOL_WEIGHT] > et.sram_bytes)
                & (A[:, PCOL_ACTIVE] > 1.0)
                & (lam > 0.0)
            )
            inv_lam = np.divide(
                1.0, lam, out=np.zeros_like(lam), where=shared
            )
            swap_term = (A[:, PCOL_SL] - A[:, PCOL_Q] * inv_lam) * shared
            rho_tpu = S1 + swap_term
            es2_num = S2 + (A[:, PCOL_U] - A[:, PCOL_V] * inv_lam) * shared

        # Pollaczek-Khinchine (Eq. 1): lam * E[S^2] == es2_num and
        # lam * E[S] == rho, so the idle-queue case (lam == 0) falls out
        # naturally: es2_num == 0 -> wait == 0, as in scalar mg1_wait.
        tpu_wait = np.where(
            rho_tpu >= 1.0, np.inf, es2_num / (2.0 * (1.0 - rho_tpu))
        )
        total = F[:, PKCOL_STATIC] + lam * tpu_wait + swap_term
        if (et.rates <= 0.0).any():
            # The scalar objective multiplies rate * latency per tenant, so a
            # zero-rate tenant sitting on an unstable TPU queue contributes
            # 0 * inf = NaN to the scalar total; reproduce that here instead
            # of the inf the decomposed sum would otherwise give.
            zr_on_tpu = ((et.rates <= 0.0)[None, :] & (P > 0)).any(axis=1)
            total = np.where(zr_on_tpu & np.isinf(tpu_wait), np.nan, total)
        overload = np.maximum(0.0, rho_tpu - 1.0) + F[:, PKCOL_OVERLOAD]
    return total, overload


def _aggregate_objective_batched_swap(
    et: EvalTables,
    A: np.ndarray,
    F: np.ndarray,
    P: np.ndarray,
    discipline: DisciplineSpec,
) -> tuple[np.ndarray, np.ndarray]:
    """Swap-batch tail of the decomposed objective (see
    ``queueing.swap_batch_amortization`` for the model)."""
    lam = A[:, PCOL_LAM]
    ti = et.tenant_idx
    on = P > 0
    r = np.where(on, et.rates[None, :], 0.0)            # [B, n]
    svc = np.where(on, et.base.prefix_service[ti, P], 0.0)
    tl = np.where(on, et.base.load[ti, P], 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        shared = (
            (A[:, PCOL_WEIGHT] > et.sram_bytes)
            & (A[:, PCOL_ACTIVE] > 1.0)
            & (lam > 0.0)
        )
        # Eq. 10 shared-occupancy alphas, per tenant (the collapse to the
        # (SL - Q/lam) aggregates is FCFS-only: amortization reweights each
        # tenant's summand individually).
        alphas = np.where(
            shared[:, None] & on,
            np.maximum(0.0, 1.0 - r / np.where(lam > 0, lam, 1.0)[:, None]),
            0.0,
        )
        wait, rho, alpha_eff = queueing.swap_batch_amortization(
            lam, A[:, PCOL_S1], A[:, PCOL_S2], r, alphas, tl, svc,
            discipline.batch_cap, staleness=discipline.staleness,
        )
        swap_latency = (r * alpha_eff * tl).sum(axis=-1)
        total = F[:, PKCOL_STATIC] + lam * wait + swap_latency
        if (et.rates <= 0.0).any():
            # Same zero-rate NaN convention as the FCFS tail: a zero-rate
            # tenant on an unstable TPU queue contributes 0 * inf = NaN in
            # the scalar sum.
            zr_on_tpu = ((et.rates <= 0.0)[None, :] & (P > 0)).any(axis=1)
            total = np.where(zr_on_tpu & np.isinf(wait), np.nan, total)
        overload = np.maximum(0.0, rho - 1.0) + F[:, PKCOL_OVERLOAD]
    return total, overload


def _batch_eval_slo(
    tenants: Sequence[TenantSpec],
    et: EvalTables,
    P: np.ndarray,
    K: np.ndarray,
    *,
    force_alpha_zero: bool,
    discipline: DisciplineSpec,
    objective: Objective,
) -> tuple[np.ndarray, np.ndarray]:
    """SLO (non-mean) tail of the batched evaluator: (value, overload).

    The mean objective's linear decomposition (``F_STATIC + lam * W +
    swap``) cannot price nonlinear per-tenant objectives, so this path
    gathers the per-tenant static pieces from the rate-free tables ([B, n]
    instead of the aggregate [B, 9]) and runs exactly the formulas the
    scalar ``predict_tail_latencies`` / ``predict_miss_probs`` reference
    runs -- the batch == scalar invariant extends to every objective at
    <= 1e-9 relative (tests/test_slo.py).
    """
    ti = et.tenant_idx
    A = et.pstack[ti, P].sum(axis=1)       # [B, 9] per-tenant aggregates
    F = et.pkstack[ti, P, K].sum(axis=1)   # [B, 2] static latency + overload
    lam = A[:, PCOL_LAM]
    on = P > 0
    on_cpu = P < et.num_points[None, :]
    r_full = np.broadcast_to(et.rates[None, :], P.shape)
    r = np.where(on, r_full, 0.0)
    svc = np.where(on, et.base.prefix_service[ti, P], 0.0)
    tl = np.where(on, et.base.load[ti, P], 0.0)

    with np.errstate(divide="ignore", invalid="ignore"):
        if force_alpha_zero:
            alphas = np.zeros_like(r)
        else:
            shared = (
                (A[:, PCOL_WEIGHT] > et.sram_bytes)
                & (A[:, PCOL_ACTIVE] > 1.0)
                & (lam > 0.0)
            )
            # Eq. 10 shared-occupancy alphas, per tenant (the scalar path's
            # swap.weight_miss_probs; the mean path's (SL - Q/lam) collapse
            # is the aggregate of exactly these).
            alphas = np.where(
                shared[:, None] & on,
                np.maximum(0.0, 1.0 - r / np.where(lam > 0, lam, 1.0)[:, None]),
                0.0,
            )
        if discipline.batches and not force_alpha_zero:
            tpu_wait, rho_tpu, alpha_eff = queueing.swap_batch_amortization(
                lam, A[:, PCOL_S1], A[:, PCOL_S2], r, alphas, tl, svc,
                discipline.batch_cap, staleness=discipline.staleness,
            )
        else:
            alpha_eff = alphas
            sl = (r * alpha_eff * tl).sum(axis=-1)
            u = (r * alpha_eff * tl * (2.0 * svc + tl)).sum(axis=-1)
            rho_tpu = A[:, PCOL_S1] + sl
            es2_num = A[:, PCOL_S2] + u
            tpu_wait = np.where(
                rho_tpu >= 1.0, np.inf, es2_num / (2.0 * (1.0 - rho_tpu))
            )

        swap_i = alpha_eff * tl                                   # [B, n]
        # Per-tenant CPU pool: the PKCOL_STATIC fold buries the mdk wait, so
        # recompute it from the one-core suffix table (same scalar formula).
        s1c = np.where(on_cpu, et.base.suffix1[ti, P], 0.0)
        mu_one = np.where(s1c > 0.0, 1.0 / np.where(s1c > 0.0, s1c, 1.0), np.inf)
        cpu_wait = queueing.mdk_wait_batch(r_full, mu_one, K)
        cpu_wait = np.where(on_cpu, cpu_wait, 0.0)
        rho_cpu = r_full * s1c / np.maximum(K, 1)
        # Per-tenant static pieces (input transfer, prefix service, boundary
        # transfer on genuinely split plans, one-core suffix service).
        bnd = np.where(on & on_cpu, et.base.boundary[ti, P], 0.0)
        static = (
            np.where(on, et.base.input_xfer[None, :], 0.0) + svc + bnd + s1c
        )

        wt = np.where(on, tpu_wait[:, None], 0.0)
        if objective.kind == "p_tail":
            tail_t = queueing.wait_tail_quantile(
                wt, rho_tpu[:, None], objective.q
            )
            tail_c = queueing.wait_tail_quantile(cpu_wait, rho_cpu, objective.q)
            vals = static + swap_i + tail_t + tail_c
        else:
            d = deadlines_of(tenants)[None, :]
            slack = d - static - swap_i
            vals = _miss_prob(wt, rho_tpu[:, None], cpu_wait, rho_cpu, slack)
        value = (r_full * vals).sum(axis=1)
        overload = np.maximum(0.0, rho_tpu - 1.0) + F[:, PKCOL_OVERLOAD]
    return value, overload


def objective_batch(
    tenants: Sequence[TenantSpec],
    partitions: np.ndarray,
    cores: np.ndarray,
    platform: Platform,
    *,
    force_alpha_zero: bool = False,
    tables: PlanTables | EvalTables | None = None,
    discipline: DisciplineSpec = FCFS,
    objective: Objective | None = None,
) -> np.ndarray:
    """Eq. 5 objective for B candidate plans at once; ``inf`` where unstable.

    Batched equivalent of ``objective``: element b equals
    ``objective(tenants, Plan(partitions[b], cores[b], discipline),
    platform)``.
    """
    total, _ = _batch_eval(
        tenants,
        partitions,
        cores,
        platform,
        force_alpha_zero=force_alpha_zero,
        tables=tables,
        discipline=discipline,
        objective=objective,
    )
    return total


def penalized_objective_batch(
    tenants: Sequence[TenantSpec],
    partitions: np.ndarray,
    cores: np.ndarray,
    platform: Platform,
    *,
    force_alpha_zero: bool = False,
    tables: PlanTables | EvalTables | None = None,
    discipline: DisciplineSpec = FCFS,
    objective: Objective | None = None,
) -> np.ndarray:
    """Batched ``penalized_objective``: one pass of array ops over B plans.

    Element b equals ``penalized_objective(tenants, Plan(partitions[b],
    cores[b], discipline), platform)`` up to float round-off; pass
    precomputed ``tables`` (see ``PlanTables.for_tenants``) to skip table
    construction on repeated calls -- the allocator's hot path does.
    """
    total, overload = _batch_eval(
        tenants,
        partitions,
        cores,
        platform,
        force_alpha_zero=force_alpha_zero,
        tables=tables,
        discipline=discipline,
        objective=objective,
    )
    feasible = (overload == 0.0) & np.isfinite(total)
    return np.where(feasible, total, _PENALTY_BASE * (1.0 + overload))


def penalized_objective_delta_batch(
    tenants: Sequence[TenantSpec],
    base_partition: np.ndarray,
    base_cores: np.ndarray,
    partitions: np.ndarray,
    cores: np.ndarray,
    platform: Platform,
    *,
    force_alpha_zero: bool = False,
    tables: PlanTables | EvalTables | None = None,
    discipline: DisciplineSpec = FCFS,
    objective: Objective | None = None,
) -> np.ndarray:
    """``penalized_objective_batch`` for neighbors of one base plan.

    Candidate b's per-tenant aggregates are recovered as
    ``base_aggregate + (new - old)`` over only the (tenant, p/k) entries
    where row b differs from ``(base_partition, base_cores)`` -- the
    hill-climb's neighbor moves change one tenant's partition and a handful
    of core counts, so each candidate costs O(changed) gathered table rows
    instead of the full O(n) re-gather of ``penalized_objective_batch``.
    The base aggregates themselves are re-summed fresh on every call (one
    O(n) pass), so the delta rounding never compounds across hill-climb
    iterations: each value differs from the full re-gather by at most the
    one add/subtract round-off (~1 ulp), which is inside the plan-identity
    tie tolerance recorded in ROADMAP.md.
    """
    P = np.asarray(partitions, dtype=np.intp)
    K = np.asarray(cores, dtype=np.intp)
    if P.ndim != 2 or P.shape != K.shape:
        raise ValueError(f"expected [B, n] partitions/cores, got {P.shape}/{K.shape}")
    P0 = np.asarray(base_partition, dtype=np.intp)
    K0 = np.asarray(base_cores, dtype=np.intp)
    if P0.shape != (P.shape[1],) or K0.shape != P0.shape:
        raise ValueError(
            f"expected [n] base partition/cores, got {P0.shape}/{K0.shape}"
        )
    et = _resolve_tables(
        tenants, platform, np.concatenate([K.ravel(), K0]), tables
    )
    if not is_default(objective):
        # The delta decomposition is mean-only (it reconstructs the linear
        # aggregate sums); SLO objectives are nonlinear per tenant, so score
        # the neighbors with the full batched evaluator instead.  Mean keeps
        # the O(changed) fast path below untouched.
        return penalized_objective_batch(
            tenants,
            partitions,
            cores,
            platform,
            force_alpha_zero=force_alpha_zero,
            tables=et,
            discipline=discipline,
            objective=objective,
        )
    ti = et.tenant_idx
    B = P.shape[0]
    F0 = et.pkstack[ti, P0, K0].sum(axis=0)                  # [2]
    if not np.isfinite(F0).all():
        # An infeasible base (e.g. the unstable all-CPU start of Algorithm 1)
        # has inf static latency, and inf-base deltas would turn genuinely
        # feasible neighbors into NaN.  Every per-tenant summand is >= 0, so
        # a finite row-sum certifies every old cell is finite and the deltas
        # below are exact; otherwise score the neighbors from scratch.
        return penalized_objective_batch(
            tenants,
            partitions,
            cores,
            platform,
            force_alpha_zero=force_alpha_zero,
            tables=et,
            discipline=discipline,
        )
    A = np.tile(et.pstack[ti, P0].sum(axis=0), (B, 1))       # [B, 9]
    F = np.tile(F0, (B, 1))                                  # [B, 2]

    b_idx, i_idx = np.nonzero((P != P0[None, :]) | (K != K0[None, :]))
    if b_idx.size:
        pi = ti[i_idx]
        p_new, k_new = P[b_idx, i_idx], K[b_idx, i_idx]
        np.add.at(A, b_idx, et.pstack[pi, p_new] - et.pstack[pi, P0[i_idx]])
        np.add.at(
            F,
            b_idx,
            et.pkstack[pi, p_new, k_new] - et.pkstack[pi, P0[i_idx], K0[i_idx]],
        )
    total, overload = _aggregate_objective(
        et, A, F, P, force_alpha_zero=force_alpha_zero, discipline=discipline
    )
    feasible = (overload == 0.0) & np.isfinite(total)
    return np.where(feasible, total, _PENALTY_BASE * (1.0 + overload))
