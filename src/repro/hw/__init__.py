from repro.hw.specs import (
    AcceleratorSpec,
    CORAL_EDGE_TPU,
    CORTEX_A76_QUAD,
    EDGE_TPU_PLATFORM,
    HostCPUSpec,
    Platform,
    TPU_V5E,
    TPU_V5E_SERVING_PLATFORM,
    TPUChipSpec,
)

__all__ = [
    "AcceleratorSpec",
    "CORAL_EDGE_TPU",
    "CORTEX_A76_QUAD",
    "EDGE_TPU_PLATFORM",
    "HostCPUSpec",
    "Platform",
    "TPU_V5E",
    "TPU_V5E_SERVING_PLATFORM",
    "TPUChipSpec",
]
