"""Hardware models for both the paper-faithful edge platform and the
datacenter TPU target.

Two tiers are modeled:

* ``EDGE_TPU_PLATFORM`` — the paper's testbed: Google Coral USB Edge TPU
  (4 TOPS int8, 8 MB on-chip SRAM) attached over USB 3.0 to a Raspberry Pi 5
  (quad-core Cortex-A76 @ 2.4 GHz).  Used by the paper-faithful benchmarks
  (Figs. 1-8).
* ``TPU_V5E`` — the datacenter target for the generalized framework: roofline
  constants used by the dry-run analysis (197 TFLOP/s bf16 per chip, 819 GB/s
  HBM, ~50 GB/s per ICI link).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AcceleratorSpec:
    """A bounded-fast-memory accelerator attached to a host."""

    name: str
    peak_ops: float          # ops/s at native precision (int8 for EdgeTPU)
    sram_bytes: int          # bounded fast-memory tier (SRAM / HBM)
    host_bw: float           # host <-> accelerator bandwidth, bytes/s (swap channel)
    # Effective-utilization envelope across a model's depth.  Early (wide,
    # highly parallel) segments run near ``eff_front``; trailing (narrow,
    # pointwise) segments degrade toward ``eff_back`` -- this reproduces the
    # paper's Fig. 3 observation that CPU and TPU converge in later stages.
    eff_front: float = 0.10
    eff_back: float = 0.004


@dataclasses.dataclass(frozen=True)
class HostCPUSpec:
    name: str
    n_cores: int
    ops_per_core: float      # effective ops/s per core (NEON int8 ~ 4 GOPS)
    parallel_frac: float     # Amdahl parallelizable fraction for suffix blocks


@dataclasses.dataclass(frozen=True)
class Platform:
    accelerator: AcceleratorSpec
    cpu: HostCPUSpec

    @property
    def sram_bytes(self) -> int:
        return self.accelerator.sram_bytes

    @property
    def swap_bw(self) -> float:
        return self.accelerator.host_bw


# --- Paper testbed -----------------------------------------------------------
CORAL_EDGE_TPU = AcceleratorSpec(
    name="coral-usb-edgetpu",
    peak_ops=4.0e12,               # 4 TOPS int8
    sram_bytes=8 * 1024 * 1024,    # 8 MB on-chip SRAM
    host_bw=400e6,                 # effective USB 3.0 weight-streaming bandwidth
)

CORTEX_A76_QUAD = HostCPUSpec(
    name="rpi5-cortex-a76",
    n_cores=4,
    ops_per_core=4.0e9,            # effective int8 GOPS/core via NEON
    parallel_frac=0.90,
)

EDGE_TPU_PLATFORM = Platform(accelerator=CORAL_EDGE_TPU, cpu=CORTEX_A76_QUAD)


# --- Datacenter target (roofline constants for the dry-run) ------------------
@dataclasses.dataclass(frozen=True)
class TPUChipSpec:
    name: str
    peak_flops_bf16: float
    hbm_bytes: int
    hbm_bw: float
    ici_link_bw: float


TPU_V5E = TPUChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    ici_link_bw=50e9,
)

# A v5e-like serving platform viewed through the SwapLess abstraction:
# HBM is the bounded tier, host DRAM the backing store, PCIe the swap channel.
TPU_V5E_SERVING_PLATFORM = Platform(
    accelerator=AcceleratorSpec(
        name="tpu-v5e-serving",
        peak_ops=197e12,
        sram_bytes=16 * 1024**3,
        host_bw=32e9,              # PCIe gen4 x16-ish host link
        eff_front=0.55,
        eff_back=0.08,
    ),
    cpu=HostCPUSpec(name="dc-host", n_cores=112, ops_per_core=50e9, parallel_frac=0.95),
)
