"""Flash-attention Pallas kernel: causal (optionally sliding-window)
online-softmax attention.

Grid: (batch*heads, n_q_blocks, n_kv_blocks); the KV dimension iterates
sequentially carrying (m, l, acc) running statistics in VMEM scratch, so the
(Sq, Sk) score matrix never exists.  Block shapes are MXU/VPU aligned
(q/kv blocks multiples of 128 lanes where possible).

The TPU adaptation of the GPU flash algorithm: instead of warp-level
shuffles for the rescaling reductions, the row statistics live in VMEM
scratch across sequential grid steps (TPU grids execute in order on a core),
and all inner products are MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    n_kv: int,
    window: int,          # 0 = global
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (block_q, hd)
    k = k_ref[0]                       # (block_k, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                          # (block_q, block_k)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos <= q_pos
    if window > 0:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,     # (BH, Sq, hd)
    k: jax.Array,     # (BH, Sk, hd)
    v: jax.Array,     # (BH, Sk, hd)
    *,
    scale: float,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_kv = Sq // block_q, Sk // block_k
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        n_kv=n_kv,
        window=window,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
