"""MXU-tiled blocked matmul Pallas kernel.

Block sizes default to 128x128x128 (MXU-native 128-lane tiles); the K grid
dimension iterates sequentially with a float32 VMEM accumulator, so inputs
can be bf16 while accumulation stays full precision.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K) @ y: (K, N) -> (M, N).  M, N, K must divide the blocks
    (the ops.py wrapper pads otherwise)."""
    M, K = x.shape
    K2, N = y.shape
    assert K == K2, (x.shape, y.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    n_k = K // block_k
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(M // block_m, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, y)
