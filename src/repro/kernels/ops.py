"""Jit'd public wrappers around the Pallas kernels.

On CPU backends (this container) the kernels execute via ``interpret=True``
-- the kernel body runs in Python for correctness validation; on TPU they
compile to Mosaic.  Wrappers handle padding to block multiples and GQA
head-repeat plumbing so callers keep natural shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_matmul import block_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.wkv6 import wkv6_chunked


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "out_dtype")
)
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
) -> jax.Array:
    """Padded tiled matmul: (M, K) @ (K, N) for arbitrary M, N, K."""
    M, K = x.shape
    _, N = y.shape
    bm = min(block_m, max(8, M))
    bn = min(block_n, max(8, N))
    bk = min(block_k, max(8, K))
    x, _ = _pad_to(x, 0, bm)
    x, _ = _pad_to(x, 1, bk)
    y, _ = _pad_to(y, 0, bk)
    y, _ = _pad_to(y, 1, bn)
    out = block_matmul(
        x, y,
        block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype,
        interpret=_use_interpret(),
    )
    return out[:M, :N]


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_q", "block_k")
)
def causal_attention(
    q: jax.Array,    # (B, S, H, hd)
    k: jax.Array,    # (B, S, KV, hd)
    v: jax.Array,
    *,
    scale: float,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """GQA flash attention over natural (B, S, H, hd) layouts."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    bq = min(block_q, S)
    bk = min(block_k, S)
    while S % bq:
        bq //= 2
    while S % bk:
        bk //= 2
    out = flash_attention(
        qf, kf, vf,
        scale=scale, window=window,
        block_q=max(bq, 1), block_k=max(bk, 1),
        interpret=_use_interpret(),
    )
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(
    r: jax.Array,    # (B, T, H, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,    # (H, hd)
    *,
    chunk: int = 32,
) -> jax.Array:
    """RWKV6 WKV over natural (B, T, H, hd) layouts; float32 output."""
    B, T, H, hd = r.shape

    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)

    u_flat = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    c = min(chunk, T)
    while T % c:
        c //= 2
    out = wkv6_chunked(
        flat(r), flat(k), flat(v), flat(w), u_flat,
        chunk=max(c, 1),
        interpret=_use_interpret(),
    )
    return out.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
