"""Chunked WKV6 Pallas kernel -- the RWKV6 recurrence re-thought for TPU.

The reference CUDA WKV kernel is a per-timestep sequential loop with warp
parallelism over channels.  The TPU-native formulation processes the
sequence in chunks of L tokens: within a chunk, the recurrence closed form

    out_t = r~_t S_0 + sum_{s<t} (r~_t . k~_s) v_s + (r_t . (u*k_t)) v_t
    S_L   = diag(c_L) (S_0 + k~^T v)

with c_t = prod_{j<t} w_j (inclusive cumulative decay), r~_t = r_t * c_t,
k~_s = k_s / c_{s+1} turns all inner work into (L x hd) x (hd x hd) MXU
matmuls and one (L x L) strictly-lower-triangular combine -- within-chunk
parallel, cross-chunk sequential carry in VMEM scratch.

Numerics: decays are accumulated in log space within the chunk; chunk
length bounds the dynamic range of 1/c (documented constraint: chunk_len *
|log w| must stay within float32 range; RWKV6's w = exp(-exp(...)) < 1 and
typically > 0.5, so chunks of 16-64 are safe).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, o_ref,
    state_ref,
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)        # (L, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)        # decays in (0, 1)
    u = u_ref[0].astype(jnp.float32)        # (1, hd) bonus

    logw = jnp.log(w)
    # c_incl[t] = prod_{j<=t} w_j ; c_excl[t] = prod_{j<t} w_j.
    lc_incl = jnp.cumsum(logw, axis=0)
    lc_excl = lc_incl - logw
    r_t = r * jnp.exp(lc_excl)              # r~
    k_t = k * jnp.exp(-lc_incl)             # k~

    # Intra-chunk pairwise term, strictly lower triangular.
    a = jax.lax.dot_general(
        r_t, k_t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(cols < rows, a, 0.0)
    # Diagonal bonus term: (r_t . (u * k_t)) v_t.
    diag = jnp.sum(r * u * k, axis=-1)       # (L,)

    out = (
        jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + diag[:, None] * v
        + jax.lax.dot_general(r_t, state_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    )
    o_ref[0] = out.astype(o_ref.dtype)

    # Carry: S_L = diag(c_L) (S_0 + k~^T v).
    c_last = jnp.exp(lc_incl[-1])            # (hd,)
    kv = jax.lax.dot_general(
        k_t, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (hd, hd)
    state_ref[...] = c_last[:, None] * (state_ref[...] + kv)


def wkv6_chunked(
    r: jax.Array,   # (BH, T, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,   # (BH, T, hd) decays in (0, 1)
    u: jax.Array,   # (BH, 1, hd)
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> jax.Array:
    BH, T, hd = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
