"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array, out_dtype=None) -> jax.Array:
    out = jnp.dot(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(out_dtype or x.dtype)


def attention_ref(
    q: jax.Array,    # (BH, Sq, hd)
    k: jax.Array,    # (BH, Sk, hd)
    v: jax.Array,
    *,
    scale: float,
    window: int = 0,
) -> jax.Array:
    Sq, Sk = q.shape[1], k.shape[1]
    s = jnp.einsum(
        "bqd,bkd->bqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = k_pos <= q_pos
    if window > 0:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def wkv6_ref(
    r: jax.Array,    # (BH, T, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,    # (BH, 1, hd)
) -> jax.Array:
    """Step-by-step WKV6 recurrence (float32)."""
    BH, T, hd = r.shape

    def per_head(r_h, k_h, v_h, w_h, u_h):
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp
            kv = k_t[:, None] * v_t[None, :]                 # (hd, hd)
            out = (S + u_h[0][:, None] * kv).T @ r_t          # (hd,)
            S = w_t[:, None] * S + kv
            return S, out

        S0 = jnp.zeros((hd, hd), jnp.float32)
        _, outs = jax.lax.scan(
            step,
            S0,
            (
                r_h.astype(jnp.float32),
                k_h.astype(jnp.float32),
                v_h.astype(jnp.float32),
                w_h.astype(jnp.float32),
            ),
        )
        return outs

    return jax.vmap(per_head)(r, k, v, w, u.astype(jnp.float32))
