# Developer entry points wrapping the tier-1 verify command (see ROADMAP.md).
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export JAX_PLATFORMS ?= cpu

.PHONY: test test-fast test-slow bench-smoke bench-sched bench-jax bench-fleet bench-predictive bench-faults bench-slo

# Full tier-1 suite (includes the multi-minute 512-device dry-run compiles).
test:
	$(PYTHON) -m pytest -x -q

# Everything except tests marked `slow` -- the fast CI gate.
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

# Only the `slow` tests (DES convergence, 512-device dry-run compiles);
# the second job of the CI matrix.
test-slow:
	$(PYTHON) -m pytest -x -q -m "slow"

# Fast benchmark sanity: allocator overhead + plan-space engine scaling
# (including the incremental re-planner on the large 32/64-tenant mixes)
# + the analytic-model-vs-DES error sweep on short traces
# + the simulation-core throughput smoke (also self-checks that every fast
#   path still matches its reference before timing it)
# + the scheduling-discipline sweep smoke (self-checks fcfs == the frozen
#   DES baseline before timing)
# + the fleet-scaling smoke (self-checks the N=1 fleet degenerate case is
#   bitwise the single-device API before timing)
# + the predictive re-planning smoke (self-checks the no-forecaster/no-cache
#   path is bitwise the reactive controller before timing)
# + the fault-injection smoke (self-checks the faults=None path is bitwise
#   the pre-fault simulators and controllers before timing)
# + the SLO-objective smoke (self-checks the objective=None path is bitwise
#   the pre-refactor Eq. 5 mean on every layer before timing).
bench-smoke:
	$(PYTHON) -m benchmarks.run alg_overhead alg_scaling
	$(PYTHON) -m benchmarks.alg_scaling --tenants 32,64
	$(PYTHON) -m benchmarks.model_vs_sim --smoke
	$(PYTHON) -m benchmarks.sim_throughput --smoke --out BENCH_sim_throughput.smoke.json
	$(PYTHON) -m benchmarks.scheduling --smoke --out BENCH_scheduling.smoke.json
	$(PYTHON) -m benchmarks.fleet_scaling --smoke --out BENCH_fleet_scaling.smoke.json
	$(PYTHON) -m benchmarks.predictive --smoke --out BENCH_predictive.smoke.json
	$(PYTHON) -m benchmarks.faults --smoke --out BENCH_faults.smoke.json
	$(PYTHON) -m benchmarks.slo --smoke --out BENCH_slo.smoke.json

# Full scheduling-discipline sweep (swap-amortization vs FCFS on the
# swap2/thrash16/collab8 mixes); records BENCH_scheduling.json.
bench-sched:
	$(PYTHON) -m benchmarks.scheduling --out BENCH_scheduling.json

# Full JAX replica-engine throughput sweep (self-checks statistical
# equivalence vs the NumPy stepper before timing); records
# BENCH_jax_throughput.json. CPU-jax fallback numbers unless an
# accelerator-backed jax is installed -- the JSON says which.
bench-jax:
	$(PYTHON) -m benchmarks.jax_throughput --out BENCH_jax_throughput.json

# Full fleet-scaling sweep: fleet planner vs round-robin placement on the
# 4-device heterogeneous mix + the 64-device x 64-tenant re-plan timing
# (self-checks the bitwise N=1 degenerate pin first); records
# BENCH_fleet_scaling.json.
bench-fleet:
	$(PYTHON) -m benchmarks.fleet_scaling --out BENCH_fleet_scaling.json

# Full predictive re-planning sweep: reactive vs forecaster-driven
# controllers on MMPP/diurnal drift + plan-memoization hit economics
# (self-checks the bitwise opt-in pin first); records BENCH_predictive.json.
bench-predictive:
	$(PYTHON) -m benchmarks.predictive --out BENCH_predictive.json

# Full SLO-objective sweep: mean vs p_tail(0.99) vs deadline_miss planners
# on the tail-sensitive mix (one bursty heavy tenant + latency-critical
# lights), DES ground truth; self-checks the bitwise objective=None pin on
# every layer first; records BENCH_slo.json.
bench-slo:
	$(PYTHON) -m benchmarks.slo --out BENCH_slo.json

# Full fault-injection sweep: fault-aware vs fault-oblivious adaptive
# serving under device dropout / thermal throttling / swap-bandwidth
# collapse, with recovery metrics (TTR, lost/requeued, degraded-window
# means); self-checks the bitwise faults=None pin first; records
# BENCH_faults.json.
bench-faults:
	$(PYTHON) -m benchmarks.faults --out BENCH_faults.json
