"""Tests for PropAlloc, Algorithm 1 hill-climbing, baselines, and the NLIP
constraints -- including optimality checks against a brute-force oracle."""
import math

import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import latency
from repro.core.allocator import (
    brute_force_oracle,
    edge_tpu_compiler_plan,
    hill_climb,
    prop_alloc,
    threshold_plan,
)
from repro.core.planner import Plan, TenantSpec, validate_plan
from repro.configs.paper_models import paper_profile
from repro.hw.specs import EDGE_TPU_PLATFORM

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


# --------------------------------------------------------------------------
# PropAlloc
# --------------------------------------------------------------------------
class TestPropAlloc:
    def test_full_tpu_gets_zero_cores(self):
        ts = tenants_for(("inceptionv4", 1.0), ("mnasnet", 1.0))
        partition = [t.profile.num_partition_points for t in ts]
        assert prop_alloc(ts, partition, K_MAX) == (0, 0)

    def test_suffix_models_get_at_least_one_core(self):
        ts = tenants_for(("inceptionv4", 1.0), ("mnasnet", 1.0))
        cores = prop_alloc(ts, [5, 3], K_MAX)
        assert all(c >= 1 for c in cores)
        assert sum(cores) <= K_MAX

    def test_proportionality(self):
        # Two identical models, one with 3x the rate -> more cores.
        ts = tenants_for(("inceptionv4", 3.0), ("inceptionv4", 1.0))
        cores = prop_alloc(ts, [5, 5], 8)
        assert cores[0] > cores[1]

    def test_overflow_raises(self):
        ts = tenants_for(("mnasnet", 1.0), ("mnasnet", 1.0), ("mnasnet", 1.0))
        with pytest.raises(ValueError):
            prop_alloc(ts, [0, 0, 0], 2)

    @given(
        rates=st.lists(st.floats(0.1, 5.0), min_size=1, max_size=4),
        k_max=st.integers(4, 16),
        data=st.data(),
    )
    @settings(max_examples=50, deadline=None)
    def test_never_exceeds_kmax_and_uses_all_when_needed(self, rates, k_max, data):
        names = ["inceptionv4", "xception", "densenet201", "mnasnet"]
        ts = tenants_for(*[(names[i % 4], r) for i, r in enumerate(rates)])
        partition = [
            data.draw(st.integers(0, t.profile.num_partition_points)) for t in ts
        ]
        cores = prop_alloc(ts, partition, k_max)
        assert sum(cores) <= k_max
        for t, p, c in zip(ts, partition, cores):
            if p < t.profile.num_partition_points:
                assert c >= 1
            else:
                assert c == 0
        # If anything needs CPU and there is spare capacity + load, all cores
        # are handed out (work-conserving).
        needs = [p < t.profile.num_partition_points for t, p in zip(ts, partition)]
        loads = [
            t.rate * t.profile.suffix_cpu_time_1core(p)
            for t, p in zip(ts, partition)
        ]
        if any(needs) and sum(loads) > 0:
            assert sum(cores) == k_max


class TestPropAllocEdgeCases:
    """Largest-remainder corner cases around allocator's fallback branch."""

    def test_n_need_equals_k_max(self):
        # Exactly one core per suffix model: the floor allocation IS the
        # final allocation, no spare to distribute.
        ts = tenants_for(("inceptionv4", 3.0), ("xception", 1.0), ("mnasnet", 0.5))
        cores = prop_alloc(ts, [5, 4, 3], 3)
        assert cores == (1, 1, 1)

    def test_n_need_exceeding_k_max_raises(self):
        ts = tenants_for(("inceptionv4", 3.0), ("xception", 1.0), ("mnasnet", 0.5))
        with pytest.raises(ValueError):
            prop_alloc(ts, [5, 4, 3], 2)

    def test_zero_total_load_keeps_floor_allocation(self):
        # Suffix models whose CPU suffix costs exactly 0 (or zero-rate
        # tenants): no load signal to divide by, so the spare cores stay
        # unassigned and every suffix model keeps its constraint floor of 1.
        from repro.core.planner import ModelProfile, Segment

        seg = Segment(
            name="free",
            flops=0.0,
            weight_bytes=1024,
            out_bytes=64,
            tpu_time=1e-3,
            cpu_time_1core=0.0,
            cpu_parallel_frac=0.9,
        )
        prof = ModelProfile(name="zero-cpu", segments=(seg, seg), input_bytes=64)
        ts = [TenantSpec(prof, 1.0), TenantSpec(prof, 2.0)]
        cores = prop_alloc(ts, [0, 1], K_MAX)
        assert cores == (1, 1)

    def test_zero_rate_tenants_zero_total_load(self):
        ts = tenants_for(("inceptionv4", 0.0), ("xception", 0.0))
        cores = prop_alloc(ts, [5, 4], K_MAX)
        assert cores == (1, 1)

    def test_full_tpu_tenant_never_receives_leftover(self):
        # The largest-remainder walk must hand every spare core to a
        # suffix-bearing tenant even when a no-suffix tenant ties at zero
        # remainder with a lower index (the fallback branch's concern).
        for k_max in range(2, 12):
            ts = tenants_for(
                ("mnasnet", 1.0),       # full TPU below -> no suffix
                ("inceptionv4", 1.0),
                ("xception", 1.0),
            )
            partition = [ts[0].profile.num_partition_points, 5, 4]
            cores = prop_alloc(ts, partition, k_max)
            assert cores[0] == 0
            assert sum(cores) == k_max  # work-conserving
            assert all(c >= 1 for c in cores[1:])

    @given(
        rates=st.lists(st.floats(0.01, 10.0), min_size=2, max_size=5),
        k_max=st.integers(2, 16),
        data=st.data(),
    )
    @settings(max_examples=60, deadline=None)
    def test_leftover_always_lands_on_needy(self, rates, k_max, data):
        # Invariant behind allocator's fallback branch: remainders sum to the
        # leftover and each is < 1, so at least `leftover` suffix-bearing
        # tenants have a positive remainder and the no-suffix fallback can
        # only fire on float pathologies.  Whatever path is taken, no-suffix
        # tenants end with 0 cores and the result is work-conserving.
        names = ["inceptionv4", "xception", "densenet201", "mnasnet", "gpunet"]
        ts = tenants_for(*[(names[i % 5], r) for i, r in enumerate(rates)])
        partition = [
            data.draw(st.integers(0, t.profile.num_partition_points)) for t in ts
        ]
        needs = [p < t.profile.num_partition_points for t, p in zip(ts, partition)]
        if sum(needs) > k_max:
            with pytest.raises(ValueError):
                prop_alloc(ts, partition, k_max)
            return
        cores = prop_alloc(ts, partition, k_max)
        for need, c in zip(needs, cores):
            if need:
                assert c >= 1
            else:
                assert c == 0
        loads = [
            t.rate * t.profile.suffix_cpu_time_1core(p)
            for t, p in zip(ts, partition)
        ]
        if any(needs) and sum(loads) > 0:
            assert sum(cores) == k_max


# --------------------------------------------------------------------------
# Algorithm 1
# --------------------------------------------------------------------------
class TestHillClimb:
    def test_single_tenant_improves_over_full_tpu(self):
        # InceptionV4 exceeds SRAM: collaborative partitioning must beat
        # full-TPU execution (the paper's central claim).
        ts = tenants_for(("inceptionv4", 3.0))
        plan, obj = hill_climb(ts, HW, K_MAX)
        validate_plan(plan, ts, K_MAX)
        full = edge_tpu_compiler_plan(ts)
        obj_full = latency.objective(ts, full, HW)
        assert obj < obj_full
        # And it should keep a TPU prefix (not dump everything to 4 ARM cores).
        assert plan.partition[0] > 0

    def test_small_model_stays_on_tpu(self):
        # MobileNetV2 fits in SRAM and the TPU is much faster everywhere
        # except the tail; at trivial load, full-TPU should be (near) optimal.
        ts = tenants_for(("mobilenetv2", 0.5))
        plan, obj = hill_climb(ts, HW, K_MAX)
        oracle_plan, oracle_obj = brute_force_oracle(ts, HW, K_MAX)
        assert obj <= oracle_obj * 1.05

    def test_matches_oracle_single_tenant(self):
        for name, rate in [("inceptionv4", 2.0), ("xception", 3.0), ("gpunet", 5.0)]:
            ts = tenants_for((name, rate))
            plan, obj = hill_climb(ts, HW, K_MAX)
            _, oracle_obj = brute_force_oracle(ts, HW, K_MAX)
            assert obj <= oracle_obj * 1.10, (name, obj, oracle_obj)

    def test_two_tenant_near_oracle(self):
        ts = tenants_for(("gpunet", 2.0), ("efficientnet", 2.0))
        plan, obj = hill_climb(ts, HW, K_MAX)
        _, oracle_obj = brute_force_oracle(ts, HW, K_MAX)
        assert obj <= oracle_obj * 1.15

    def test_terminates_and_valid_on_many_tenants(self):
        ts = tenants_for(
            ("inceptionv4", 1.0),
            ("xception", 1.0),
            ("densenet201", 1.0),
            ("mnasnet", 2.0),
        )
        plan, obj = hill_climb(ts, HW, K_MAX)
        validate_plan(plan, ts, K_MAX)
        assert math.isfinite(obj)

    @given(
        rate=st.floats(0.5, 6.0),
        name=st.sampled_from(
            ["inceptionv4", "xception", "resnet50v2", "densenet201", "gpunet"]
        ),
    )
    @settings(max_examples=20, deadline=None)
    def test_never_worse_than_all_cpu_or_all_tpu(self, rate, name):
        ts = tenants_for((name, rate))
        plan, obj = hill_climb(ts, HW, K_MAX)
        P = ts[0].profile.num_partition_points
        all_cpu = latency.objective(
            ts, Plan((0,), (prop_alloc(ts, [0], K_MAX)[0],)), HW
        )
        all_tpu = latency.objective(ts, Plan((P,), (0,)), HW)
        assert obj <= min(all_cpu, all_tpu) + 1e-12


# --------------------------------------------------------------------------
# Baselines
# --------------------------------------------------------------------------
class TestBaselines:
    def test_edge_tpu_compiler_full_tpu(self):
        ts = tenants_for(("inceptionv4", 1.0), ("mnasnet", 1.0))
        plan = edge_tpu_compiler_plan(ts)
        assert plan.partition == (11, 7)
        assert plan.cores == (0, 0)

    def test_threshold_offloads_tail(self):
        ts = tenants_for(("inceptionv4", 1.0))
        plan = threshold_plan(ts, HW, K_MAX)
        P = ts[0].profile.num_partition_points
        # inceptionv4's tail speedup is ~4x, i.e. CPU not within 10% of TPU:
        # threshold keeps everything on TPU here -- exactly the failure mode
        # the paper describes (threshold ignores swap + queueing).
        validate_plan(plan, ts, K_MAX)
        assert 0 <= plan.partition[0] <= P

    def test_threshold_offloads_when_tail_comparable(self):
        ts = tenants_for(("mobilenetv2", 1.0))
        # mobilenetv2 tail speedups: last segment CPU/TPU = 1.5 > 1.1 -> stays.
        plan = threshold_plan(ts, HW, K_MAX, threshold=0.6)
        assert plan.partition[0] < ts[0].profile.num_partition_points
