"""Unit tests for mesh construction and sharding rules (no 512-device
requirement -- specs are validated structurally)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.launch import sharding as shd
from repro.launch.mesh import batch_axes, make_host_mesh
from repro.models.transformer import init_params


class FakeMesh:
    """Duck-typed mesh exposing shape/axis_names for spec computation."""

    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


MESH = FakeMesh({"data": 16, "model": 16})


class TestSpecRules:
    def test_embed_vocab_sharded(self):
        leaf = jax.ShapeDtypeStruct((262144, 1152), jnp.bfloat16)
        spec = shd._spec_for_param("['embed']", leaf, ARCHS["gemma3-1b"], MESH)
        assert spec == P("model", None)

    def test_attn_projections(self):
        cfg = ARCHS["qwen1.5-0.5b"]
        wq = jax.ShapeDtypeStruct((24, 1024, 1024), jnp.bfloat16)
        spec = shd._spec_for_param("['groups'][0]['attn']['wq']", wq, cfg, MESH)
        assert spec == P(None, None, "model")
        wo = jax.ShapeDtypeStruct((24, 1024, 1024), jnp.bfloat16)
        spec = shd._spec_for_param("['groups'][0]['attn']['wo']", wo, cfg, MESH)
        assert spec == P(None, "model", None)

    def test_moe_expert_parallel_when_divisible(self):
        cfg = ARCHS["llama4-maverick-400b-a17b"]  # 128 experts % 16 == 0
        w = jax.ShapeDtypeStruct((24, 128, 5120, 8192), jnp.bfloat16)
        spec = shd._spec_for_param("['groups'][1]['moe']['w_in']", w, cfg, MESH)
        assert spec == P(None, "data", None, "model")

    def test_moe_tensor_parallel_when_not_divisible(self):
        cfg = ARCHS["grok-1-314b"]  # 8 experts % 16 != 0
        w = jax.ShapeDtypeStruct((64, 8, 6144, 32768), jnp.bfloat16)
        spec = shd._spec_for_param("['groups'][0]['moe']['w_in']", w, cfg, MESH)
        assert spec == P(None, None, "data", "model")

    def test_sanitize_drops_nondivisible(self):
        spec = shd._sanitize(P("model", None), (32001, 1600), MESH)
        assert spec == P(None, None)
        spec = shd._sanitize(P("model", None), (32000, 1600), MESH)
        assert spec == P("model", None)

    def test_sanitize_tuple_axes(self):
        mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
        spec = shd._sanitize(P(("pod", "data"), None), (256, 4), mesh)
        assert spec == P(("pod", "data"), None)
        spec = shd._sanitize(P(("pod", "data"), None), (100, 4), mesh)
        assert spec == P(None, None)


class TestBatchAxes:
    def test_single_pod(self):
        assert batch_axes(FakeMesh({"data": 16, "model": 16})) == ("data",)

    def test_multi_pod(self):
        assert batch_axes(FakeMesh({"pod": 2, "data": 16, "model": 16})) == (
            "pod",
            "data",
        )


class TestRealShardedExecution:
    """End-to-end sharded forward on the real (single-device) mesh."""

    def test_param_shardings_cover_tree(self):
        cfg = ARCHS["gemma3-1b"].reduced()
        params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        mesh = make_host_mesh(1, 1)
        shards = shd.param_shardings(cfg, mesh, params)
        assert jax.tree.structure(shards) == jax.tree.structure(params)

    @pytest.mark.parametrize("name", ["qwen1.5-0.5b", "grok-1-314b", "rwkv6-7b"])
    def test_forward_under_mesh(self, name):
        from repro.models.frontend import make_train_batch
        from repro.models.transformer import forward_loss

        cfg = ARCHS[name].reduced()
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        batch = make_train_batch(cfg, 2, 32)
        mesh = make_host_mesh(1, 1)
        with mesh:
            loss, _ = jax.jit(
                lambda p, b: forward_loss(cfg, p, b, remat=False)
            )(params, batch)
        assert np.isfinite(float(loss))
