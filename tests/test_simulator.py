"""Tests for the discrete-event simulator, SRAM cache, and workloads --
including cross-validation of the analytic model against the DES (the
in-silico analogue of the paper's Figs. 5-6 validation)."""
import math

import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import latency
from repro.core.allocator import prop_alloc
from repro.core.planner import Plan, TenantSpec
from repro.configs.paper_models import paper_profile
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.cache import SramCache
from repro.serving.simulator import SimResult, simulate
from repro.serving.workload import RatePhase, dynamic_trace, poisson_trace

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


class TestWorkload:
    def test_poisson_rate(self):
        reqs = poisson_trace([5.0], duration=2000.0, seed=1)
        rate = len(reqs) / 2000.0
        assert rate == pytest.approx(5.0, rel=0.05)

    def test_merged_sorted(self):
        reqs = poisson_trace([2.0, 3.0], duration=100.0, seed=2)
        times = [r.arrival for r in reqs]
        assert times == sorted(times)
        assert {r.model_idx for r in reqs} == {0, 1}

    def test_dynamic_phases(self):
        phases = [
            RatePhase(0.0, 100.0, (1.0, 0.0)),
            RatePhase(100.0, 200.0, (0.0, 5.0)),
        ]
        reqs = dynamic_trace(phases, seed=3)
        for r in reqs:
            if r.model_idx == 0:
                assert r.arrival < 100.0
            else:
                assert r.arrival >= 100.0


class TestCache:
    def test_cold_miss_then_hit(self):
        c = SramCache(100)
        assert c.access(0, 50, 0.0) is True
        assert c.access(0, 50, 1.0) is False

    def test_lru_eviction(self):
        c = SramCache(100)
        c.access(0, 60, 0.0)
        c.access(1, 60, 1.0)     # evicts 0
        assert not c.resident(0)
        assert c.access(0, 60, 2.0) is True  # miss again

    def test_both_fit_no_eviction(self):
        c = SramCache(100)
        c.access(0, 40, 0.0)
        c.access(1, 40, 1.0)
        assert c.access(0, 40, 2.0) is False
        assert c.access(1, 40, 3.0) is False

    def test_oversized_capped(self):
        c = SramCache(100)
        assert c.access(0, 500, 0.0) is True
        assert c.access(0, 500, 1.0) is False  # resident share = capacity

    @given(
        caps=st.integers(10, 200),
        ops=st.lists(
            st.tuples(st.integers(0, 3), st.integers(1, 120)),
            min_size=1,
            max_size=40,
        ),
    )
    def test_used_never_exceeds_capacity(self, caps, ops):
        c = SramCache(caps)
        for t, (m, b) in enumerate(ops):
            c.access(m, b, float(t))
            assert c.used <= caps


def _result_with(latencies):
    return SimResult(
        latencies=latencies,
        arrivals=[[0.0] * len(ls) for ls in latencies],
        tpu_busy=0.0,
        duration=1.0,
        misses=[0] * len(latencies),
        tpu_requests=[0] * len(latencies),
    )


class TestSimResultMetrics:
    def test_p99_nearest_rank_100_samples(self):
        # Nearest-rank p99 of 1..100 is the 99th order statistic, not the
        # max (the pre-fix int(0.99n) index overshot by one rank).
        res = _result_with([[float(i) for i in range(1, 101)]])
        assert res.p99(0) == 99.0

    def test_p99_nearest_rank_200_samples(self):
        res = _result_with([[float(i) for i in range(1, 201)]])
        assert res.p99(0) == 198.0

    def test_p99_small_and_empty(self):
        assert _result_with([[5.0]]).p99(0) == 5.0
        res = _result_with([[3.0, 1.0, 2.0]])
        assert res.p99(0) == 3.0  # ceil(2.97)-1 = idx 2 of sorted

    def test_p99_nearest_rank_boundaries(self):
        # Nearest-rank boundary pins around the n=100 grid, where a float
        # 0.99*n index is one rounding error away from an off-by-one.  The
        # exact-integer rank is ceil(99n/100) = (99n+99)//100:
        #   n=1   -> rank 1   (the only sample)
        #   n=2   -> rank 2   (the max: covering 99% of 2 needs both)
        #   n=99  -> rank 99  (still the max: ceil(98.01) = 99)
        #   n=100 -> rank 99  (index 98 -- the FIRST n where p99 < max)
        #   n=101 -> rank 100 (index 99: ceil(99.99), again below max)
        for n, expected in [(1, 1.0), (2, 2.0), (99, 99.0),
                            (100, 99.0), (101, 100.0)]:
            res = _result_with([[float(i) for i in range(1, n + 1)]])
            assert res.p99(0) == expected, f"n={n}"
        # Single-request model on the array (vectorized-stepper) path too.
        res = _result_with([np.asarray([7.0])])
        assert res.p99(0) == 7.0

    def test_p99_integer_rank_matches_float_ceil_definition(self):
        # The integer rank must agree with the scalar nearest-rank
        # reference (math.ceil on the float product) on every small n and
        # on rounding-hostile larger counts.
        for n in list(range(1, 512)) + [9_999, 10_000, 10_001, 999_881]:
            assert (99 * n + 99) // 100 - 1 == math.ceil(0.99 * n) - 1, n

    def test_zero_completed_requests_is_nan_not_zero(self):
        # A model with no completed requests has an *unknown* latency, not a
        # zero one: 0.0 silently wins every comparison and poisons means.
        res = _result_with([[], [4.0]])
        assert math.isnan(res.p99(0))
        assert math.isnan(res.mean_latency(0))
        # The observed model is unaffected...
        assert res.p99(1) == 4.0
        assert res.mean_latency(1) == 4.0
        # ...and the aggregate metrics still skip the unobserved model
        # rather than propagating the nan.
        assert res.overall_mean() == 4.0
        assert res.request_weighted_mean([1.0, 1.0]) == 4.0
        # With *nothing* completed anywhere the aggregates are unknown too.
        empty = _result_with([[], []])
        assert math.isnan(empty.overall_mean())
        assert math.isnan(empty.request_weighted_mean([1.0, 1.0]))

    def test_request_weighted_mean_uses_rates(self):
        # Model 0: mean 2.0 over 2 requests; model 1: mean 8.0 over 1.
        res = _result_with([[2.0, 2.0], [8.0]])
        # Eq. 5 weighting by offered rates, not by observed counts.
        assert res.request_weighted_mean([3.0, 1.0]) == pytest.approx(3.5)
        assert res.request_weighted_mean([1.0, 3.0]) == pytest.approx(6.5)
        # Without rates the observed counts recover the overall mean.
        assert res.request_weighted_mean() == pytest.approx(res.overall_mean())
        assert res.request_weighted_mean() == pytest.approx(4.0)

    def test_request_weighted_mean_validates_length(self):
        res = _result_with([[1.0], [2.0]])
        with pytest.raises(ValueError):
            res.request_weighted_mean([1.0])

    def test_request_weighted_mean_zero_rates_is_nan(self):
        # All-zero weights leave the rate-weighted mean undefined -- nan per
        # the unknown-not-zero convention (the pre-fix 0.0 silently ranked
        # below every real latency).
        res = _result_with([[1.0], [2.0]])
        assert math.isnan(res.request_weighted_mean([0.0, 0.0]))

    def test_observed_miss_rate_no_tpu_visits_is_nan(self):
        # "No TPU visits" is unknown (nan); "visited, never missed" is 0.0.
        res = _result_with([[1.0], [2.0]])
        assert res.tpu_requests == [0, 0]
        assert math.isnan(res.observed_miss_rate(0))
        visited = SimResult(
            latencies=[[1.0]],
            arrivals=[[0.0]],
            tpu_busy=0.0,
            duration=1.0,
            misses=[0],
            tpu_requests=[5],
        )
        assert visited.observed_miss_rate(0) == 0.0

    def test_request_weighted_mean_skips_unobserved_models(self):
        # A tenant with no recorded samples (all arrivals in warmup) has an
        # unknown mean; it must be excluded, not priced as zero latency.
        res = _result_with([[5.0, 5.0], []])
        assert res.request_weighted_mean([1.0, 1.0]) == pytest.approx(5.0)
        assert res.request_weighted_mean() == pytest.approx(5.0)


class TestSimulatorVsAnalytic:
    """The heart of the reproduction: DES observations vs Eq. 1-4 predictions."""

    def _compare(self, tenants, plan, duration=4000.0, tol=0.12, seed=0):
        reqs = poisson_trace([t.rate for t in tenants], duration, seed=seed)
        sim = simulate(tenants, plan, HW, reqs)
        pred = latency.predict(tenants, plan, HW)
        for i, t in enumerate(tenants):
            obs = sim.mean_latency(i)
            exp = pred.latencies[i]
            assert obs == pytest.approx(exp, rel=tol), (
                t.profile.name,
                obs,
                exp,
            )
        return sim, pred

    def test_single_tenant_full_tpu_low_load(self):
        ts = tenants_for(("inceptionv4", 1.0))
        plan = Plan((11,), (0,))
        self._compare(ts, plan)

    def test_single_tenant_full_tpu_moderate_load(self):
        ts = tenants_for(("inceptionv4", 3.0))
        plan = Plan((11,), (0,))
        self._compare(ts, plan)

    def test_single_tenant_partitioned(self):
        ts = tenants_for(("inceptionv4", 2.0))
        plan = Plan((9,), (4,))
        self._compare(ts, plan)

    def test_single_tenant_full_cpu(self):
        ts = tenants_for(("mnasnet", 2.0))
        plan = Plan((0,), (4,))
        self._compare(ts, plan)

    def test_multi_tenant_fits_no_misses(self):
        ts = tenants_for(("mobilenetv2", 3.0), ("squeezenet", 3.0))
        plan = Plan((5, 2), (0, 0))
        sim, pred = self._compare(ts, plan)
        # Both tenants visited the TPU and never missed -- a true 0.0, which
        # the nan convention distinguishes from "never visited".
        assert sim.tpu_requests[0] > 0 and sim.tpu_requests[1] > 0
        assert sim.observed_miss_rate(0) == 0.0
        assert sim.observed_miss_rate(1) == 0.0
        assert pred.alphas == (0.0, 0.0)

    def test_multi_tenant_5050_alpha_validation(self):
        # EfficientNet+GPUNet exceed SRAM; 50:50 mix -> alpha ~ 0.5 (Fig. 6a).
        ts = tenants_for(("efficientnet", 2.0), ("gpunet", 2.0))
        plan = Plan((6, 5), (0, 0))
        reqs = poisson_trace([2.0, 2.0], 4000.0, seed=11)
        sim = simulate(ts, plan, HW, reqs)
        pred = latency.predict(ts, plan, HW)
        assert pred.alphas == pytest.approx((0.5, 0.5))
        # Observed miss rate should be <= the conservative alpha and within
        # a sane band of it (alpha is an upper bound by construction).
        for i in range(2):
            obs = sim.observed_miss_rate(i)
            assert obs <= pred.alphas[i] + 0.05
            assert obs >= 0.25

    def test_multi_tenant_9010_skew(self):
        ts = tenants_for(("efficientnet", 3.6), ("gpunet", 0.4))
        plan = Plan((6, 5), (0, 0))
        reqs = poisson_trace([3.6, 0.4], 4000.0, seed=12)
        sim = simulate(ts, plan, HW, reqs)
        pred = latency.predict(ts, plan, HW)
        assert pred.alphas == pytest.approx((0.1, 0.9))
        # The rare model's weights are almost always evicted.
        assert sim.observed_miss_rate(1) > 0.6
        # The frequent model mostly hits.
        assert sim.observed_miss_rate(0) < 0.25

    def test_mixed_collaborative_multi_tenant(self):
        ts = tenants_for(("inceptionv4", 1.0), ("mnasnet", 2.0))
        cores = prop_alloc(ts, [9, 7], K_MAX)
        plan = Plan((9, 7), cores)
        self._compare(ts, plan, tol=0.15)

    def test_utilization_matches(self):
        ts = tenants_for(("inceptionv4", 3.0))
        plan = Plan((11,), (0,))
        reqs = poisson_trace([3.0], 4000.0, seed=4)
        sim = simulate(ts, plan, HW, reqs)
        pred = latency.predict(ts, plan, HW)
        assert sim.tpu_utilization == pytest.approx(pred.tpu_utilization, rel=0.08)

    def test_utilization_never_exceeds_one_under_backlog(self):
        # Offered load far above capacity: the queue drains long after the
        # last arrival.  Duration must extend to the last completion, or
        # busy/duration overshoots 1.0 (the pre-fix bug).
        ts = tenants_for(("inceptionv4", 60.0))
        plan = Plan((11,), (0,))
        reqs = poisson_trace([60.0], 20.0, seed=7)
        sim = simulate(ts, plan, HW, reqs, warmup_frac=0.0)
        assert sim.tpu_utilization <= 1.0
        assert sim.duration >= max(r.arrival for r in reqs)

    @given(seed=st.integers(0, 4), rate=st.floats(5.0, 80.0))
    @settings(max_examples=10, deadline=None)
    def test_utilization_bounded_any_load(self, seed, rate):
        ts = tenants_for(("xception", rate))
        plan = Plan((11,), (0,))
        reqs = poisson_trace([rate], 30.0, seed=seed)
        sim = simulate(ts, plan, HW, reqs)
        assert 0.0 <= sim.tpu_utilization <= 1.0

    @given(seed=st.integers(0, 5))
    @settings(max_examples=6, deadline=None)
    def test_seed_robustness(self, seed):
        ts = tenants_for(("xception", 2.0))
        plan = Plan((11,), (0,))
        reqs = poisson_trace([2.0], 3000.0, seed=seed)
        sim = simulate(ts, plan, HW, reqs)
        pred = latency.predict(ts, plan, HW)
        assert sim.mean_latency(0) == pytest.approx(pred.latencies[0], rel=0.2)
