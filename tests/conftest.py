import os
import sys

# Make src/ importable regardless of how pytest is invoked.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Keep XLA single-device and quiet for tests (the dry-run sets its own flags
# in a subprocess; see tests/test_dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
