"""Tests for the roofline machinery: HLO cost parser (loop-aware flops,
bytes, collectives) and model-flops accounting."""
import textwrap

import pytest

from repro.configs import ARCHS, INPUT_SHAPES
from repro.roofline.analysis import model_flops
from repro.roofline.hlo_parse import parse_hlo_costs

SIMPLE_HLO = textwrap.dedent(
    """
    HloModule test

    %body (param: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
      %param = (s32[], f32[128,128]) parameter(0)
      %gte0 = s32[] get-tuple-element(%param), index=0
      %gte1 = f32[128,128]{1,0} get-tuple-element(%param), index=1
      %dot.1 = f32[128,128]{1,0} dot(%gte1, %gte1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[128,128]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
      ROOT %tuple.1 = (s32[], f32[128,128]) tuple(%gte0, %ar)
    }

    %cond (param.1: (s32[], f32[128,128])) -> pred[] {
      %param.1 = (s32[], f32[128,128]) parameter(0)
      %gtec = s32[] get-tuple-element(%param.1), index=0
      %c = s32[] constant(10)
      ROOT %lt = pred[] compare(%gtec, %c), direction=LT
    }

    ENTRY %main (x: f32[128,128]) -> f32[128,128] {
      %x = f32[128,128]{1,0} parameter(0)
      %c0 = s32[] constant(0)
      %t = (s32[], f32[128,128]) tuple(%c0, %x)
      %w = (s32[], f32[128,128]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out = f32[128,128]{1,0} get-tuple-element(%w), index=1
    }
    """
)


class TestHloParser:
    def test_loop_aware_dot_flops(self):
        c = parse_hlo_costs(SIMPLE_HLO)
        # one 128x128x128 dot per iteration, 10 iterations
        assert c.flops == pytest.approx(2 * 128**3 * 10)

    def test_loop_aware_collectives(self):
        c = parse_hlo_costs(SIMPLE_HLO)
        assert c.collective_bytes["all-reduce"] == pytest.approx(
            128 * 128 * 4 * 10
        )
        assert c.collective_ops["all-reduce"] == 1

    def test_no_loop(self):
        hlo = textwrap.dedent(
            """
            HloModule t
            ENTRY %main (a: f32[64,32], b: f32[32,16]) -> f32[64,16] {
              %a = f32[64,32]{1,0} parameter(0)
              %b = f32[32,16]{1,0} parameter(1)
              ROOT %dot.0 = f32[64,16]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
            }
            """
        )
        c = parse_hlo_costs(hlo)
        assert c.flops == pytest.approx(2 * 64 * 32 * 16)
        assert c.collective_bytes["total"] == 0.0


class TestModelFlops:
    def test_train_6nd(self):
        cfg = ARCHS["qwen1.5-0.5b"]
        shape = INPUT_SHAPES["train_4k"]
        expect = 6.0 * cfg.active_param_count() * 256 * 4096
        assert model_flops(cfg, shape) == pytest.approx(expect)

    def test_decode_2nd_per_token(self):
        cfg = ARCHS["gemma3-1b"]
        shape = INPUT_SHAPES["decode_32k"]
        expect = 2.0 * cfg.active_param_count() * 128
        assert model_flops(cfg, shape) == pytest.approx(expect)

    def test_moe_uses_active_params(self):
        cfg = ARCHS["llama4-maverick-400b-a17b"]
        dense_equiv = 6.0 * cfg.param_count() * 256 * 4096
        assert model_flops(cfg, INPUT_SHAPES["train_4k"]) < 0.1 * dense_equiv


class TestShapeSupport:
    def test_long_context_gate(self):
        assert ARCHS["rwkv6-7b"].supports_shape("long_500k")
        assert ARCHS["gemma3-1b"].supports_shape("long_500k")
        assert ARCHS["hymba-1.5b"].supports_shape("long_500k")
        assert ARCHS["llama4-maverick-400b-a17b"].supports_shape("long_500k")
        assert not ARCHS["qwen1.5-0.5b"].supports_shape("long_500k")
        assert not ARCHS["grok-1-314b"].supports_shape("long_500k")
        assert not ARCHS["nemotron-4-15b"].supports_shape("long_500k")

    def test_all_support_other_shapes(self):
        for cfg in ARCHS.values():
            for s in ("train_4k", "prefill_32k", "decode_32k"):
                assert cfg.supports_shape(s)
