"""Unit + property tests for the queueing primitives (Eq. 1, 3)."""
import math

import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core.queueing import (
    mdk_wait,
    mg1_metrics,
    mg1_wait,
    mixture_moments,
    swap_batch_amortization,
)


class TestMg1Metrics:
    def test_terms_consistent_with_mg1_wait(self):
        lam, s = 0.5, 1.0
        m = mg1_metrics(lam, s, s * s)
        assert m.wait == mg1_wait(lam, s, s * s)
        assert m.rho == pytest.approx(lam * s)
        assert m.sojourn == pytest.approx(m.wait + s)
        # Little's law: L = lam * T.
        assert m.queue_len == pytest.approx(lam * m.sojourn)

    def test_idle_queue(self):
        m = mg1_metrics(0.0, 2.0, 4.0)
        assert m.wait == 0.0
        assert m.rho == 0.0
        assert m.sojourn == 2.0
        assert m.queue_len == 0.0

    def test_unstable_reports_rho_and_inf_wait(self):
        m = mg1_metrics(2.0, 1.0, 1.0)
        assert m.rho == 2.0
        assert m.wait == math.inf
        assert m.sojourn == math.inf


class TestMG1:
    def test_zero_arrivals(self):
        assert mg1_wait(0.0, 1.0, 1.0) == 0.0

    def test_md1_closed_form(self):
        # Deterministic service: E[S^2] = E[S]^2; P-K reduces to
        # rho*E[S] / (2(1-rho)).
        lam, s = 0.5, 1.0
        rho = lam * s
        expected = rho * s / (2 * (1 - rho))
        assert mg1_wait(lam, s, s * s) == pytest.approx(expected)

    def test_mm1_closed_form(self):
        # Exponential service: E[S^2] = 2 E[S]^2; P-K gives rho/(mu - lam).
        lam, mu = 0.3, 1.0
        es = 1.0 / mu
        es2 = 2.0 / mu**2
        expected = (lam / mu) / (mu - lam)
        assert mg1_wait(lam, es, es2) == pytest.approx(expected)

    def test_unstable_queue(self):
        assert mg1_wait(2.0, 1.0, 1.0) == math.inf
        assert mg1_wait(1.0, 1.0, 1.0) == math.inf

    @given(
        lam=st.floats(0.01, 0.99),
        es=st.floats(0.01, 1.0),
        cv2=st.floats(0.0, 4.0),
    )
    def test_wait_nonnegative_and_monotone_in_variance(self, lam, es, cv2):
        lam = min(lam, 0.95 / es)  # keep stable
        es2_det = es * es
        es2_var = es * es * (1.0 + cv2)
        w_det = mg1_wait(lam, es, es2_det)
        w_var = mg1_wait(lam, es, es2_var)
        assert w_det >= 0.0
        assert w_var >= w_det  # variance only hurts

    @given(lam1=st.floats(0.01, 0.4), lam2=st.floats(0.01, 0.4))
    def test_wait_monotone_in_load(self, lam1, lam2):
        es, es2 = 1.0, 1.0
        lo, hi = sorted([lam1, lam2])
        assert mg1_wait(lo, es, es2) <= mg1_wait(hi, es, es2)


class TestMDk:
    def test_zero_arrivals(self):
        assert mdk_wait(0.0, 1.0, 1) == 0.0

    def test_formula(self):
        lam, mu, k = 1.0, 1.0, 2
        expected = 0.5 * (1.0 / (k * mu - lam) - 1.0 / (k * mu))
        assert mdk_wait(lam, mu, k) == pytest.approx(expected)

    def test_unstable(self):
        assert mdk_wait(2.0, 1.0, 2) == math.inf
        assert mdk_wait(1.0, 1.0, 0) == math.inf

    @given(
        lam=st.floats(0.05, 0.95),
        mu=st.floats(0.5, 5.0),
        k=st.integers(1, 8),
    )
    def test_more_cores_never_hurt(self, lam, mu, k):
        lam = min(lam, 0.9 * k * mu)
        assert mdk_wait(lam, mu, k + 1) <= mdk_wait(lam, mu, k) + 1e-12

    @given(lam=st.floats(0.01, 0.9), mu=st.floats(1.0, 5.0))
    def test_half_of_mm1_style_wait(self, lam, mu):
        # Deterministic service halves the wait of the pooled M/M/1 analogue.
        k = 1
        if lam >= k * mu:
            return
        w = mdk_wait(lam, mu, k)
        mm1_style = 1.0 / (k * mu - lam) - 1.0 / (k * mu)
        assert w == pytest.approx(0.5 * mm1_style)


class TestMixture:
    def test_single_atom(self):
        m1, m2 = mixture_moments([2.0], [3.0])
        assert m1 == 3.0 and m2 == 9.0

    def test_two_atoms(self):
        m1, m2 = mixture_moments([1.0, 1.0], [2.0, 4.0])
        assert m1 == pytest.approx(3.0)
        assert m2 == pytest.approx((4.0 + 16.0) / 2)

    def test_empty(self):
        assert mixture_moments([], []) == (0.0, 0.0)

    @given(
        st.lists(
            st.tuples(st.floats(0.01, 10.0), st.floats(0.0, 10.0)),
            min_size=1,
            max_size=8,
        )
    )
    def test_jensen(self, pairs):
        ws = [p[0] for p in pairs]
        vs = [p[1] for p in pairs]
        m1, m2 = mixture_moments(ws, vs)
        assert m2 >= m1 * m1 - 1e-9  # E[X^2] >= E[X]^2
        assert min(vs) - 1e-9 <= m1 <= max(vs) + 1e-9


class TestSwapBatchConvergence:
    """Pinned regressions for the ``swap_batch_amortization`` damped fixed
    point: near saturation the damped sweep ``wq <- (wq + f(wq)) / 2`` can
    settle into a period-2 orbit instead of converging (``f`` is decreasing
    and steeper than ``-3`` when the amortized rho crosses 1), and the
    pre-fix code silently returned whichever orbit point iteration 60
    landed on -- a finite, iteration-count-dependent artifact on an
    unstable queue.  The fix adds an explicit residual check, a masked
    extension budget, and a safe fallback to the unamortized (FCFS,
    ``alpha_eff == alphas``) swap term.
    """

    # First failing input found by random sweep (trial 850 of the hunt):
    # amortized sweep oscillates between ~0.47 and ~0.84 forever while the
    # unamortized queue is plainly unstable (rho ~ 1.40).  Pre-fix:
    # iters=60 -> 0.5825..., iters=400 -> 1.0486... (both finite, both
    # wrong, and mutually inconsistent).
    LAM = 176.9585475992824
    RATES = [11.31098336620574, 114.22011537545326,
             23.329313473017407, 28.09813538460596]
    SVC = [0.006091372276501745, 0.005070261443390194,
           0.0043662468016955145, 0.007511505197715382]
    ALPHAS = [0.9360811697447973, 0.3545374500128614,
              0.8681650940883286, 0.8412162861540128]
    TLOAD = [0.007437672736638669, 0.002672193386986607,
             0.0028641736152308856, 0.008035388247537939]
    BATCH_CAP = 64

    def _pinned_args(self):
        rates = np.asarray(self.RATES)
        svc = np.asarray(self.SVC)
        s1 = float((rates * svc).sum())
        s2 = float((rates * svc * svc).sum())
        return (self.LAM, s1, s2, rates, np.asarray(self.ALPHAS),
                np.asarray(self.TLOAD), svc, self.BATCH_CAP)

    def test_oscillating_input_falls_back_to_unamortized(self):
        wait, rho, alpha_eff = swap_batch_amortization(*self._pinned_args())
        # The unamortized queue has rho ~ 1.396: the only safe answer is
        # the FCFS one -- infinite wait, no amortization credit.
        assert math.isinf(wait)
        assert rho == pytest.approx(1.395846882946971)
        np.testing.assert_array_equal(alpha_eff, np.asarray(self.ALPHAS))

    def test_result_is_iteration_count_independent(self):
        # Pre-fix the answer depended on where in the 2-cycle the loop
        # stopped; post-fix the residual check fires for any budget and
        # every budget agrees bitwise.
        args = self._pinned_args()
        w60, rho60, g60 = swap_batch_amortization(*args, iters=60)
        w400, rho400, g400 = swap_batch_amortization(*args, iters=400)
        assert w60 == w400 and rho60 == rho400
        np.testing.assert_array_equal(g60, g400)

    def test_batch_matches_scalar_through_fallback(self):
        # A batch mixing a diverging row with a benign converging row must
        # reproduce each scalar call bitwise: the fallback is a masked
        # per-element write, not a whole-batch branch.
        lam0, s1_0, s2_0, rates0, alphas0, tl0, svc0, cap = self._pinned_args()
        rates1 = np.array([2.0, 3.0, 4.0, 1.0])
        svc1 = np.array([0.01, 0.02, 0.005, 0.008])
        alphas1 = np.array([0.5, 0.4, 0.3, 0.2])
        tl1 = np.array([0.001, 0.002, 0.003, 0.004])
        lam1 = 10.0
        s1_1 = float((rates1 * svc1).sum())
        s2_1 = float((rates1 * svc1 * svc1).sum())

        wb, rhob, gb = swap_batch_amortization(
            np.array([lam0, lam1]),
            np.array([s1_0, s1_1]),
            np.array([s2_0, s2_1]),
            np.stack([rates0, rates1]),
            np.stack([alphas0, alphas1]),
            np.stack([tl0, tl1]),
            np.stack([svc0, svc1]),
            cap,
        )
        w0, rho0, g0 = swap_batch_amortization(
            lam0, s1_0, s2_0, rates0, alphas0, tl0, svc0, cap)
        w1, rho1, g1 = swap_batch_amortization(
            lam1, s1_1, s2_1, rates1, alphas1, tl1, svc1, cap)
        assert wb[0] == w0 and rhob[0] == rho0
        assert wb[1] == w1 and rhob[1] == rho1
        np.testing.assert_array_equal(gb[0], g0)
        np.testing.assert_array_equal(gb[1], g1)
        # The benign row still converges to its finite amortized wait --
        # the fallback never leaks onto lanes that converged.
        assert math.isfinite(wb[1]) and wb[1] > 0.0

    def test_benign_inputs_bitwise_unchanged(self):
        # Sanity: a comfortably-stable input takes the original 60-iter
        # path (residual check passes, no extension, no fallback) and the
        # amortized wait beats the plain FCFS wait it amortizes.
        rates = np.array([3.0, 2.0])
        svc = np.array([0.01, 0.02])
        lam = 5.0
        s1 = float((rates * svc).sum())
        s2 = float((rates * svc * svc).sum())
        alphas = np.array([0.4, 0.6])
        tl = np.array([0.05, 0.05])
        wait, rho, alpha_eff = swap_batch_amortization(
            lam, s1, s2, rates, alphas, tl, svc, 8)
        assert math.isfinite(wait) and wait > 0.0
        assert rho < 1.0
        # Amortization can only shed swap work, never add it.
        assert np.all(alpha_eff <= alphas + 1e-12)
