"""Unit + property tests for the queueing primitives (Eq. 1, 3)."""
import math

import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core.queueing import mdk_wait, mg1_metrics, mg1_wait, mixture_moments


class TestMg1Metrics:
    def test_terms_consistent_with_mg1_wait(self):
        lam, s = 0.5, 1.0
        m = mg1_metrics(lam, s, s * s)
        assert m.wait == mg1_wait(lam, s, s * s)
        assert m.rho == pytest.approx(lam * s)
        assert m.sojourn == pytest.approx(m.wait + s)
        # Little's law: L = lam * T.
        assert m.queue_len == pytest.approx(lam * m.sojourn)

    def test_idle_queue(self):
        m = mg1_metrics(0.0, 2.0, 4.0)
        assert m.wait == 0.0
        assert m.rho == 0.0
        assert m.sojourn == 2.0
        assert m.queue_len == 0.0

    def test_unstable_reports_rho_and_inf_wait(self):
        m = mg1_metrics(2.0, 1.0, 1.0)
        assert m.rho == 2.0
        assert m.wait == math.inf
        assert m.sojourn == math.inf


class TestMG1:
    def test_zero_arrivals(self):
        assert mg1_wait(0.0, 1.0, 1.0) == 0.0

    def test_md1_closed_form(self):
        # Deterministic service: E[S^2] = E[S]^2; P-K reduces to
        # rho*E[S] / (2(1-rho)).
        lam, s = 0.5, 1.0
        rho = lam * s
        expected = rho * s / (2 * (1 - rho))
        assert mg1_wait(lam, s, s * s) == pytest.approx(expected)

    def test_mm1_closed_form(self):
        # Exponential service: E[S^2] = 2 E[S]^2; P-K gives rho/(mu - lam).
        lam, mu = 0.3, 1.0
        es = 1.0 / mu
        es2 = 2.0 / mu**2
        expected = (lam / mu) / (mu - lam)
        assert mg1_wait(lam, es, es2) == pytest.approx(expected)

    def test_unstable_queue(self):
        assert mg1_wait(2.0, 1.0, 1.0) == math.inf
        assert mg1_wait(1.0, 1.0, 1.0) == math.inf

    @given(
        lam=st.floats(0.01, 0.99),
        es=st.floats(0.01, 1.0),
        cv2=st.floats(0.0, 4.0),
    )
    def test_wait_nonnegative_and_monotone_in_variance(self, lam, es, cv2):
        lam = min(lam, 0.95 / es)  # keep stable
        es2_det = es * es
        es2_var = es * es * (1.0 + cv2)
        w_det = mg1_wait(lam, es, es2_det)
        w_var = mg1_wait(lam, es, es2_var)
        assert w_det >= 0.0
        assert w_var >= w_det  # variance only hurts

    @given(lam1=st.floats(0.01, 0.4), lam2=st.floats(0.01, 0.4))
    def test_wait_monotone_in_load(self, lam1, lam2):
        es, es2 = 1.0, 1.0
        lo, hi = sorted([lam1, lam2])
        assert mg1_wait(lo, es, es2) <= mg1_wait(hi, es, es2)


class TestMDk:
    def test_zero_arrivals(self):
        assert mdk_wait(0.0, 1.0, 1) == 0.0

    def test_formula(self):
        lam, mu, k = 1.0, 1.0, 2
        expected = 0.5 * (1.0 / (k * mu - lam) - 1.0 / (k * mu))
        assert mdk_wait(lam, mu, k) == pytest.approx(expected)

    def test_unstable(self):
        assert mdk_wait(2.0, 1.0, 2) == math.inf
        assert mdk_wait(1.0, 1.0, 0) == math.inf

    @given(
        lam=st.floats(0.05, 0.95),
        mu=st.floats(0.5, 5.0),
        k=st.integers(1, 8),
    )
    def test_more_cores_never_hurt(self, lam, mu, k):
        lam = min(lam, 0.9 * k * mu)
        assert mdk_wait(lam, mu, k + 1) <= mdk_wait(lam, mu, k) + 1e-12

    @given(lam=st.floats(0.01, 0.9), mu=st.floats(1.0, 5.0))
    def test_half_of_mm1_style_wait(self, lam, mu):
        # Deterministic service halves the wait of the pooled M/M/1 analogue.
        k = 1
        if lam >= k * mu:
            return
        w = mdk_wait(lam, mu, k)
        mm1_style = 1.0 / (k * mu - lam) - 1.0 / (k * mu)
        assert w == pytest.approx(0.5 * mm1_style)


class TestMixture:
    def test_single_atom(self):
        m1, m2 = mixture_moments([2.0], [3.0])
        assert m1 == 3.0 and m2 == 9.0

    def test_two_atoms(self):
        m1, m2 = mixture_moments([1.0, 1.0], [2.0, 4.0])
        assert m1 == pytest.approx(3.0)
        assert m2 == pytest.approx((4.0 + 16.0) / 2)

    def test_empty(self):
        assert mixture_moments([], []) == (0.0, 0.0)

    @given(
        st.lists(
            st.tuples(st.floats(0.01, 10.0), st.floats(0.0, 10.0)),
            min_size=1,
            max_size=8,
        )
    )
    def test_jensen(self, pairs):
        ws = [p[0] for p in pairs]
        vs = [p[1] for p in pairs]
        m1, m2 = mixture_moments(ws, vs)
        assert m2 >= m1 * m1 - 1e-9  # E[X^2] >= E[X]^2
        assert min(vs) - 1e-9 <= m1 <= max(vs) + 1e-9
