"""Regression anchors for the paper's headline claims (EXPERIMENTS.md
§Paper-validation).  These pin the reproduction: if calibration or the
queueing model drifts, these fail."""
import pytest

from benchmarks.common import HW, K_MAX, full_tpu_rates_for_utilization, tenants
from repro.configs.paper_models import all_paper_profiles, paper_profile
from repro.core import latency
from repro.core.allocator import (
    edge_tpu_compiler_plan,
    swapless_alpha0_plan,
    swapless_plan,
    threshold_plan,
)
from repro.core.planner import intra_swap_bytes
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace


def swap_fraction(name: str) -> float:
    p = paper_profile(name)
    P = p.num_partition_points
    c = p.prefix_tpu_time(P)
    s = intra_swap_bytes(p, P, HW) / HW.swap_bw
    return 100.0 * s / (s + c)


class TestFig1Calibration:
    def test_densenet_bracket(self):
        # Paper: 20.2%
        assert swap_fraction("densenet201") == pytest.approx(20.2, abs=1.5)

    def test_inceptionv4_bracket(self):
        # Paper: 62.4%
        assert swap_fraction("inceptionv4") == pytest.approx(62.4, abs=3.0)

    def test_fitting_models_no_swap(self):
        for n in ("squeezenet", "mobilenetv2", "efficientnet", "mnasnet"):
            assert swap_fraction(n) == 0.0

    def test_range_ordering(self):
        fr = {n: swap_fraction(n) for n in all_paper_profiles()}
        assert fr["inceptionv4"] == max(fr.values())
        big = [n for n, f in fr.items() if f > 0]
        assert set(big) == {
            "gpunet", "densenet201", "resnet50v2", "xception", "inceptionv4"
        }


class TestFig3Shape:
    def test_speedup_monotone_decreasing(self):
        p = paper_profile("inceptionv4")
        sp = [s.cpu_time_1core / s.tpu_time for s in p.segments]
        assert all(a >= b for a, b in zip(sp, sp[1:]))
        assert sp[0] > 100      # early segments: strong TPU advantage
        assert sp[-1] < 2.0     # tail: CPU-comparable (the paper's lever)


class TestFig7Ordering:
    """SwapLess >= alpha0 >= {threshold, compiler} on memory-pressured
    multi-tenant mixes (simulated, not just predicted)."""

    @pytest.mark.parametrize("rho", [0.2, 0.5])
    def test_policy_ordering_efficient_gpunet(self, rho):
        profs = [paper_profile("efficientnet"), paper_profile("gpunet")]
        rates = full_tpu_rates_for_utilization(profs, rho)
        ts = tenants(profs, rates)
        reqs = poisson_trace(rates, 1500.0, seed=3)
        lat = {}
        for name, plan in [
            ("compiler", edge_tpu_compiler_plan(ts)),
            ("threshold", threshold_plan(ts, HW, K_MAX)),
            ("alpha0", swapless_alpha0_plan(ts, HW, K_MAX)),
            ("swapless", swapless_plan(ts, HW, K_MAX)),
        ]:
            lat[name] = simulate(ts, plan, HW, reqs).overall_mean()
        assert lat["swapless"] <= lat["alpha0"] * 1.02
        assert lat["swapless"] < lat["compiler"]
        assert lat["swapless"] <= lat["threshold"] * 1.02

    def test_single_tenant_reduction_bracket(self):
        # Paper: up to 63.8% single-tenant reduction at rho=0.5.
        profs = [paper_profile("inceptionv4")]
        rates = full_tpu_rates_for_utilization(profs, 0.5)
        ts = tenants(profs, rates)
        reqs = poisson_trace(rates, 2000.0, seed=4)
        base = simulate(ts, edge_tpu_compiler_plan(ts), HW, reqs).overall_mean()
        sl = simulate(ts, swapless_plan(ts, HW, K_MAX), HW, reqs).overall_mean()
        red = 100.0 * (base - sl) / base
        assert red > 45.0, red    # deep in the paper's reported regime


class TestAllocatorOverhead:
    def test_two_model_replan_under_2ms(self):
        """The paper's dynamic scenario (2 models) re-plans in <2 ms."""
        import time

        from repro.core.allocator import hill_climb

        profs = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        ts = tenants(profs, [5.0, 3.0])
        hill_climb(ts, HW, K_MAX)  # warm
        t0 = time.perf_counter()
        n = 30
        for _ in range(n):
            hill_climb(ts, HW, K_MAX)
        dt = (time.perf_counter() - t0) / n
        assert dt < 0.004, f"{dt*1e3:.2f} ms"  # <2ms target, 2x CI slack
