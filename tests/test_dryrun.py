"""Dry-run smoke test: one (arch x shape) pair lowered + compiled on the
512-device production mesh, in a subprocess (the XLA flag must be set before
jax initializes, so it cannot run in the main pytest process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_one
rec = run_one("{arch}", "{shape}", multi_pod={multi_pod}, out_dir="{out}", verbose=False)
print("RESULT:" + json.dumps({{"status": rec["status"],
                               "bottleneck": rec.get("roofline", {{}}).get("bottleneck"),
                               "peak": rec.get("memory", {{}}).get("peak_bytes")}}))
"""


def _run(arch, shape, multi_pod, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = SCRIPT.format(arch=arch, shape=shape, multi_pod=multi_pod, out=tmp_path)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.slow
def test_single_pod_gemma_train(tmp_path):
    rec = _run("gemma3-1b", "train_4k", False, tmp_path)
    assert rec["status"] == "ok"
    assert rec["peak"] is not None


@pytest.mark.slow
def test_multi_pod_gemma_decode(tmp_path):
    rec = _run("gemma3-1b", "decode_32k", True, tmp_path)
    assert rec["status"] == "ok"


@pytest.mark.slow
def test_long_context_skip_is_recorded(tmp_path):
    rec = _run("qwen1.5-0.5b", "long_500k", False, tmp_path)
    assert rec["status"] == "skipped"
