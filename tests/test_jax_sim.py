"""Statistical-equivalence harness for the JAX simulation/plan-search paths.

ROADMAP standing invariant: *JAX paths are statistically equivalent,
NumPy paths stay bitwise-pinned*.  The ``backend="jax"`` stepper and the
``JaxPlanEvaluator`` run their float recurrences in float32 (no global
``jax_enable_x64`` -- flipping it would silently widen every jnp array in
the process and mask precision bugs), while the NumPy reference is
float64.  A float32 mantissa carries ~7 significant digits, so observed
per-request delays agree to ~1e-7 *absolute seconds* (the kernels work in
delay space exactly so that no absolute clock ever enters a float32
register) and aggregate statistics (means, p99, objectives) to ~1e-5
relative; order- and integer-valued observables (routing, SRAM misses,
counts, committed hill-climb plans) have no rounding channel at all and
must match exactly -- except where two hill-climb candidates tie within
float32 round-off, which the paper's mixes never produce (pinned here).
"""
import math

import numpy as np
import pytest

from benchmarks.sim_throughput import _mixes
from repro.configs.paper_models import paper_profile
from repro.core import latency
from repro.core.allocator import hill_climb, prop_alloc
from repro.core.plan_tables import EvalTables
from repro.core.planner import FCFS, DisciplineSpec, Plan, TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM as HW
from repro.serving.controller import run_adaptive
from repro.serving.jax_stepper import JaxStepper, lindley_ends
from repro.serving.simulator import RuntimeSimulator, _server_ends, make_backend, simulate
from repro.serving.workload import Trace

SWAP_BATCH = DisciplineSpec(kind="swap_batch", batch_cap=64)


def _mix(name):
    ts, plan, _ = _mixes()[name]
    return ts, plan


def _poisson_mix_trace(rates, n_req, seed):
    """Merged-Poisson trace with per-model rates (sorted, unit scale)."""
    rng = np.random.default_rng(seed)
    lam = float(sum(rates))
    arr = np.cumsum(rng.exponential(1.0 / lam, n_req))
    mi = rng.choice(
        len(rates), size=n_req, p=np.asarray(rates) / lam
    ).astype(np.int64)
    return Trace(mi, arr)


# ---------------------------------------------------------------------------
# lindley_ends: the drop-in FCFS kernel
# ---------------------------------------------------------------------------
class TestLindleyEnds:
    def test_empty(self):
        got = lindley_ends(np.empty(0), np.empty(0), 0.5)
        assert got.shape == (0,)

    @pytest.mark.parametrize("n", [1, 2, 7, 1000, 4097])
    def test_matches_server_ends_in_delay_space(self, n):
        rng = np.random.default_rng(n)
        enq = np.cumsum(rng.exponential(0.01, n))
        svc = rng.exponential(0.008, n)
        ref = _server_ends(enq, svc, 0.005)
        got = lindley_ends(enq, svc, 0.005)
        assert got.shape == ref.shape
        # Absolute tolerance on the *delays*: float32 resolves the small
        # delay-space quantities to ~1e-7 s regardless of how large the
        # absolute clock has grown.
        np.testing.assert_allclose(got - enq, ref - enq, atol=2e-6, rtol=0)

    def test_saturated_queue(self):
        # rho > 1: delays grow linearly; still small relative error.
        rng = np.random.default_rng(3)
        n = 5000
        enq = np.cumsum(rng.exponential(0.005, n))
        svc = rng.exponential(0.008, n)
        ref = _server_ends(enq, svc, 0.0)
        got = lindley_ends(enq, svc, 0.0)
        np.testing.assert_allclose(
            got - enq, ref - enq, rtol=1e-5, atol=2e-6
        )


# ---------------------------------------------------------------------------
# backend="jax": full simulate() path
# ---------------------------------------------------------------------------
class TestJaxBackend:
    def test_make_backend_dispatch(self):
        ts, plan = _mix("collab8")
        profs = [t.profile for t in ts]
        sim = make_backend("jax", profs, plan, HW)
        assert isinstance(sim, JaxStepper)
        assert isinstance(sim, RuntimeSimulator)
        with pytest.raises(ValueError, match="'jax'"):
            make_backend("nope", profs, plan, HW)

    @pytest.mark.parametrize("mix", ["collab8", "swap2", "thrash16"])
    def test_statistical_equivalence_vs_stepper(self, mix):
        ts, plan = _mix(mix)
        trace = _poisson_mix_trace([2.0] * len(ts), 6000, seed=11)
        ref = simulate(ts, plan, HW, trace, warmup_frac=0.0)
        got = simulate(ts, plan, HW, trace, warmup_frac=0.0, backend="jax")
        # Integer observables: no rounding channel, must be exact.
        assert got.misses == ref.misses
        assert got.tpu_requests == ref.tpu_requests
        for m in range(len(ts)):
            assert len(got.latencies[m]) == len(ref.latencies[m])
            np.testing.assert_array_equal(got.arrivals[m], ref.arrivals[m])
            # Float observables: statistical tolerance.
            assert got.mean_latency(m) == pytest.approx(
                ref.mean_latency(m), rel=1e-4, abs=1e-6
            )
            assert got.p99(m) == pytest.approx(
                ref.p99(m), rel=1e-4, abs=1e-6
            )

    def test_run_adaptive_jax_backend_matches_replans(self):
        # Re-plan boundaries and committed plans depend only on arrival
        # timestamps (rate estimation), never on simulated latencies: the
        # jax backend must reproduce them identically.
        profiles = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        rng = np.random.default_rng(5)
        arr = np.sort(rng.uniform(0.0, 90.0, 300))
        mi = rng.integers(0, 2, size=300).astype(np.int64)
        trace = Trace(mi, arr)
        ref = run_adaptive(
            profiles, trace, HW, 4, replan_period=30.0,
            initial_rates=(2.0, 2.0),
        )
        got = run_adaptive(
            profiles, trace, HW, 4, replan_period=30.0,
            initial_rates=(2.0, 2.0), backend="jax",
        )
        assert got.replan_times == ref.replan_times
        assert got.plans == ref.plans
        for m in range(2):
            a = np.asarray(ref.sim.latencies[m])
            b = np.asarray(got.sim.latencies[m])
            np.testing.assert_allclose(b, a, atol=2e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# Monte-Carlo replica engine
# ---------------------------------------------------------------------------
class TestReplicaEngine:
    def _setup(self, n_req=5000, n_rep=3, seed=21):
        ts, plan = _mix("collab8")
        profs = [t.profile for t in ts]
        trace = _poisson_mix_trace([2.4] * 4 + [15.0] * 4, n_req, seed)
        rng = np.random.default_rng(seed + 1)
        scales = rng.uniform(0.8, 1.25, size=(n_rep, len(profs)))
        return ts, profs, plan, trace, scales

    def test_matches_per_replica_numpy_simulate(self):
        ts, profs, plan, trace, scales = self._setup()
        sim = make_backend("jax", profs, plan, HW)
        stats = sim.run_trace_replicas(trace, scales)
        for r in range(scales.shape[0]):
            tr = Trace(
                trace.model_idx,
                trace.arrival,
                scales[r][trace.model_idx],
            )
            ref = simulate(ts, plan, HW, tr, warmup_frac=0.0)
            for m in range(len(profs)):
                assert stats.mean_latency[r, m] == pytest.approx(
                    ref.mean_latency(m), rel=2e-4
                )
                assert stats.counts[m] == len(ref.latencies[m])
            assert list(stats.misses) == ref.misses
            assert stats.tpu_busy[r] == pytest.approx(
                ref.tpu_busy, rel=1e-4
            )

    def test_replica_engine_is_read_only(self):
        _, profs, plan, trace, scales = self._setup(n_req=1000)
        sim = make_backend("jax", profs, plan, HW)
        sim.run_trace_replicas(trace, scales)
        assert sim.tpu_free == 0.0 and sim.tpu_busy == 0.0
        assert all(len(ls) == 0 for ls in sim.latencies)
        # A fresh-state engine can therefore rerun identically.
        a = sim.run_trace_replicas(trace, scales)
        b = sim.run_trace_replicas(trace, scales)
        np.testing.assert_array_equal(a.mean_latency, b.mean_latency)

    def test_guards(self):
        ts, profs, plan, trace, scales = self._setup(n_req=200)
        sim = make_backend("jax", profs, plan, HW)
        with pytest.raises(ValueError, match="n_replicas"):
            sim.run_trace_replicas(trace, scales[0])
        jitter = Trace(
            trace.model_idx, trace.arrival,
            np.full(len(trace), 1.0 + 1e-9),
        )
        with pytest.raises(ValueError, match="unit-scale"):
            sim.run_trace_replicas(jitter, scales)
        dirty = make_backend("jax", profs, plan, HW)
        dirty.run_trace(trace)
        with pytest.raises(ValueError, match="fresh"):
            dirty.run_trace_replicas(trace, scales)
        sb_plan = Plan(plan.partition, plan.cores, SWAP_BATCH)
        disc_sim = make_backend("jax", profs, sb_plan, HW)
        with pytest.raises(ValueError, match="FCFS"):
            disc_sim.run_trace_replicas(trace, scales)

    def test_empty_trace(self):
        _, profs, plan, _, scales = self._setup(n_req=200)
        sim = make_backend("jax", profs, plan, HW)
        stats = sim.run_trace_replicas(
            Trace(np.empty(0, np.int64), np.empty(0)), scales
        )
        assert stats.mean_latency.shape == (3, len(profs))
        assert stats.counts.sum() == 0 and stats.misses.sum() == 0


# ---------------------------------------------------------------------------
# JaxPlanEvaluator
# ---------------------------------------------------------------------------
class TestJaxPlanEvaluator:
    def _tenants(self, name, seed=1):
        ts, _ = _mix(name)
        rng = np.random.default_rng(seed)
        return [
            TenantSpec(t.profile, float(r))
            for t, r in zip(ts, rng.uniform(0.5, 4.0, len(ts)))
        ]

    def _feasible_plans(self, ts, k_max, n_plans=48, seed=2):
        rng = np.random.default_rng(seed)
        n = len(ts)
        p_max = np.array([t.profile.num_partition_points for t in ts])
        P = rng.integers(0, p_max + 1, size=(n_plans, n))
        K = np.zeros((n_plans, n), dtype=np.int64)
        keep = np.ones(n_plans, dtype=bool)
        for b in range(n_plans):
            try:
                K[b] = prop_alloc(ts, P[b], k_max)
            except ValueError:
                keep[b] = False
        return P[keep], K[keep]

    @pytest.mark.parametrize("mix", ["collab8", "swap2", "thrash16"])
    @pytest.mark.parametrize(
        "disc", [FCFS, SWAP_BATCH], ids=["fcfs", "swap_batch"]
    )
    def test_objective_matches_numpy_batch(self, mix, disc):
        ts = self._tenants(mix)
        k_max = max(4, len(ts))
        et = EvalTables.build(ts, HW, k_max)
        ev = et.to_jax()
        P, K = self._feasible_plans(ts, k_max)
        ref = latency.objective_batch(ts, P, K, HW, tables=et, discipline=disc)
        got = ev.objective_batch(P, K, discipline=disc)
        assert np.array_equal(np.isinf(ref), np.isinf(got))
        finite = np.isfinite(ref)
        assert finite.any()
        np.testing.assert_allclose(got[finite], ref[finite], rtol=5e-5)

    def test_alpha_zero_and_penalized(self):
        ts = self._tenants("collab8")
        k_max = max(4, len(ts))
        et = EvalTables.build(ts, HW, k_max)
        ev = et.to_jax()
        P, K = self._feasible_plans(ts, k_max)
        ref = latency.objective_batch(
            ts, P, K, HW, tables=et, force_alpha_zero=True
        )
        got = ev.objective_batch(P, K, force_alpha_zero=True)
        finite = np.isfinite(ref)
        np.testing.assert_allclose(got[finite], ref[finite], rtol=5e-5)
        refp = latency.penalized_objective_batch(ts, P, K, HW, tables=et)
        gotp = ev.penalized_objective_batch(P, K)
        # Penalized values are finite by construction; the penalty band
        # (1e9 * (1 + overload)) must agree on which plans it prices.
        assert np.array_equal(refp >= 1e9, gotp >= 1e9)
        ok = refp < 1e9
        np.testing.assert_allclose(gotp[ok], refp[ok], rtol=5e-5)

    @pytest.mark.parametrize("mix", ["collab8", "swap2", "thrash16"])
    def test_hill_climb_plans_identical(self, mix):
        # The ISSUE acceptance pin: committed plans identical on the
        # benchmark mixes (float32 ties would be the only legal divergence
        # channel, and these mixes have none).
        ts = self._tenants(mix)
        k_max = max(4, len(ts))
        et = EvalTables.build(ts, HW, k_max)
        ev = et.to_jax()
        p_ref, o_ref = hill_climb(ts, HW, k_max, tables=et, batch=True)
        p_jax, o_jax = hill_climb(ts, HW, k_max, evaluator=ev)
        assert p_ref == p_jax
        assert o_jax == pytest.approx(o_ref, rel=1e-4)
        # Warm start through the evaluator too.
        pw_ref, _ = hill_climb(
            ts, HW, k_max, tables=et, batch=True, init_plan=p_ref
        )
        pw_jax, _ = hill_climb(ts, HW, k_max, evaluator=ev, init_plan=p_ref)
        assert pw_ref == pw_jax

    def test_hill_climb_discipline_space_with_evaluator(self):
        ts = self._tenants("swap2")
        k_max = 4
        et = EvalTables.build(ts, HW, k_max)
        ev = et.to_jax()
        space = (FCFS, SWAP_BATCH)
        p_ref, _ = hill_climb(
            ts, HW, k_max, tables=et, batch=True, discipline_space=space
        )
        p_jax, _ = hill_climb(
            ts, HW, k_max, evaluator=ev, discipline_space=space
        )
        assert p_ref == p_jax

    def test_evaluator_mismatch_raises(self):
        ts = self._tenants("swap2")
        other = [TenantSpec(t.profile, t.rate * 2.0) for t in ts]
        ev = EvalTables.build(other, HW, 4).to_jax()
        with pytest.raises(ValueError, match="evaluator"):
            hill_climb(ts, HW, 4, evaluator=ev)
