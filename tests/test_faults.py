"""Fault injection: schedule validation, simulator parity, self-healing.

Tiers:

* *validation* -- property-style checks that ``FaultSchedule`` rejects
  malformed inputs (overlapping same-kind windows, unknown devices,
  out-of-range factors) and that the JSON round trip is bit-identical;
* *parity* -- the DES and the stepper must agree **elementwise** under
  every fault kind and both dropout policies (the standing DES==stepper
  invariant extends to faulted runs), and the empty schedule must be
  bitwise the ``faults=None`` path on both backends;
* *self-healing* -- the fault-aware adaptive controllers detect dropout /
  throttling from observed signals, evacuate/degrade, and beat the
  fault-oblivious controller; the ``faults=None`` controller path stays
  bitwise the pre-fault controller.
"""
import json
import math

import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs.paper_models import paper_profile
from repro.core.fleet import DeviceSpec, evacuate_device
from repro.core.planner import Plan, TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.controller import run_adaptive
from repro.serving.des import DiscreteEventSimulator
from repro.serving.faults import (
    DeviceFaultView,
    FaultEvent,
    FaultSchedule,
    LatencyWindowTracker,
    as_view,
)
from repro.serving.fleet import run_adaptive_fleet, simulate_fleet
from repro.serving.simulator import RuntimeSimulator, simulate
from repro.serving.workload import Trace, poisson_trace, route_trace

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


def _mix_plan():
    """A two-tenant collaborative mix whose base DES==stepper parity is
    elementwise-exact (required so fault parity diffs are attributable)."""
    ts = tenants_for(("mnasnet", 6.0), ("inceptionv4", 4.0))
    from repro.core.allocator import hill_climb

    plan, _ = hill_climb(ts, HW, K_MAX)
    return ts, plan


def _full_schedule(policy="requeue"):
    return FaultSchedule(
        events=(
            FaultEvent(kind="dropout", device=0, start=30.0, end=45.0),
            FaultEvent(
                kind="throttle",
                device=0,
                start=60.0,
                end=80.0,
                tpu_factor=0.4,
                cpu_factor=0.5,
            ),
            FaultEvent(
                kind="swap_degrade",
                device=0,
                start=85.0,
                end=100.0,
                swap_factor=0.3,
            ),
        ),
        dropout_policy=policy,
    )


class TestValidation:
    def test_overlapping_same_kind_windows_rejected(self):
        with pytest.raises(ValueError, match="overlap"):
            FaultSchedule(
                events=(
                    FaultEvent(kind="dropout", device=0, start=0.0, end=10.0),
                    FaultEvent(kind="dropout", device=0, start=5.0, end=15.0),
                )
            )

    def test_adjacent_windows_allowed(self):
        s = FaultSchedule(
            events=(
                FaultEvent(kind="dropout", device=0, start=0.0, end=10.0),
                FaultEvent(kind="dropout", device=0, start=10.0, end=15.0),
            )
        )
        # Chained adjacent outages defer to the end of the chain.
        assert s.view(0).down_until(5.0) == 15.0

    def test_different_kind_or_device_overlap_allowed(self):
        FaultSchedule(
            events=(
                FaultEvent(kind="dropout", device=0, start=0.0, end=10.0),
                FaultEvent(
                    kind="throttle",
                    device=0,
                    start=5.0,
                    end=15.0,
                    tpu_factor=0.5,
                ),
                FaultEvent(kind="dropout", device=1, start=5.0, end=15.0),
            )
        )

    def test_unknown_device_rejected_by_validate(self):
        s = FaultSchedule(
            events=(FaultEvent(kind="dropout", device=3, start=0.0, end=1.0),)
        )
        with pytest.raises(ValueError, match="device"):
            s.validate(2)
        assert s.validate(4) is s

    @pytest.mark.parametrize(
        "kwargs",
        [
            dict(kind="throttle", tpu_factor=0.0),
            dict(kind="throttle", tpu_factor=-0.5),
            dict(kind="throttle", tpu_factor=1.5),
            dict(kind="swap_degrade", swap_factor=0.0),
            dict(kind="swap_degrade", swap_factor=2.0),
        ],
    )
    def test_out_of_range_factors_rejected(self, kwargs):
        with pytest.raises(ValueError):
            FaultEvent(device=0, start=0.0, end=1.0, **kwargs)

    def test_bad_window_rejected(self):
        with pytest.raises(ValueError):
            FaultEvent(kind="dropout", device=0, start=5.0, end=5.0)
        with pytest.raises(ValueError):
            FaultEvent(kind="dropout", device=0, start=-1.0, end=5.0)

    def test_bad_kind_and_policy_rejected(self):
        with pytest.raises(ValueError):
            FaultEvent(kind="meteor", device=0, start=0.0, end=1.0)
        with pytest.raises(ValueError):
            FaultSchedule(events=(), dropout_policy="retry")

    def test_as_view_passthrough_and_typeerror(self):
        assert as_view(None) is None
        v = _full_schedule().view(0)
        assert as_view(v) is v
        assert isinstance(as_view(_full_schedule()), DeviceFaultView)
        with pytest.raises(TypeError):
            as_view(42)


class TestJsonRoundTrip:
    @given(
        starts=st.lists(
            st.floats(min_value=0.0, max_value=500.0), min_size=1, max_size=6
        ),
        widths=st.lists(
            st.floats(min_value=0.5, max_value=40.0), min_size=6, max_size=6
        ),
        kinds=st.lists(
            st.sampled_from(["dropout", "throttle", "swap_degrade"]),
            min_size=6,
            max_size=6,
        ),
        devices=st.lists(
            st.integers(min_value=0, max_value=3), min_size=6, max_size=6
        ),
        policy=st.sampled_from(["requeue", "lost"]),
    )
    @settings(max_examples=25, deadline=None)
    def test_round_trip_bit_identical(
        self, starts, widths, kinds, devices, policy
    ):
        # Build non-overlapping windows per (device, kind) by stacking each
        # group's windows end to end.
        cursor = {}
        events = []
        for i, s0 in enumerate(starts):
            kind, dev = kinds[i], devices[i]
            lo = cursor.get((dev, kind), 0.0)
            start = max(lo, s0)
            end = start + widths[i]
            cursor[(dev, kind)] = end
            kw = {}
            if kind == "throttle":
                kw = dict(tpu_factor=0.25, cpu_factor=0.75)
            elif kind == "swap_degrade":
                kw = dict(swap_factor=0.5)
            events.append(
                FaultEvent(kind=kind, device=dev, start=start, end=end, **kw)
            )
        sched = FaultSchedule(events=tuple(events), dropout_policy=policy)
        payload = sched.to_json()
        back = FaultSchedule.from_json(payload)
        assert back == sched
        # Bit-identical: a second serialization is the same byte string.
        assert back.to_json() == payload

    def test_from_json_rejects_foreign_payload(self):
        with pytest.raises(ValueError):
            FaultSchedule.from_json(json.dumps({"format": "nope"}))


class TestSimulatorParity:
    """DES == stepper elementwise under every fault kind; the empty
    schedule is bitwise the faults=None path."""

    @pytest.mark.parametrize("policy", ["requeue", "lost"])
    def test_des_equals_stepper_under_faults(self, policy):
        ts, plan = _mix_plan()
        trace = poisson_trace([t.rate for t in ts], duration=120.0, seed=5)
        sched = _full_schedule(policy)
        des = simulate(ts, plan, HW, trace, backend="des", faults=sched)
        stp = simulate(ts, plan, HW, trace, backend="stepper", faults=sched)
        for i in range(len(ts)):
            a = np.asarray(des.latencies[i], dtype=np.float64)
            b = np.asarray(stp.latencies[i], dtype=np.float64)
            assert np.array_equal(a, b), f"model {i} ({policy}) diverged"
        assert des.misses == stp.misses
        assert des.requests_lost == stp.requests_lost
        assert des.requests_requeued == stp.requests_requeued

    def test_lost_policy_drops_requeue_defers(self):
        ts, plan = _mix_plan()
        trace = poisson_trace([t.rate for t in ts], duration=120.0, seed=5)
        lost = simulate(
            ts, plan, HW, trace, backend="des", faults=_full_schedule("lost")
        )
        req = simulate(
            ts,
            plan,
            HW,
            trace,
            backend="des",
            faults=_full_schedule("requeue"),
        )
        assert lost.requests_lost > 0 and lost.requests_requeued == 0
        assert req.requests_requeued > 0 and req.requests_lost == 0
        # Lost requests vanish: fewer recorded completions than deferred.
        n_lost = sum(len(ls) for ls in lost.latencies)
        n_req = sum(len(ls) for ls in req.latencies)
        assert n_lost < n_req

    def test_empty_schedule_is_bitwise_no_fault(self):
        ts, plan = _mix_plan()
        trace = poisson_trace([t.rate for t in ts], duration=60.0, seed=3)
        empty = FaultSchedule(events=())
        for backend in ("des", "stepper"):
            ref = simulate(ts, plan, HW, trace, backend=backend)
            none = simulate(
                ts, plan, HW, trace, backend=backend, faults=None
            )
            emp = simulate(
                ts, plan, HW, trace, backend=backend, faults=empty
            )
            for i in range(len(ts)):
                a = np.asarray(ref.latencies[i])
                assert np.array_equal(a, np.asarray(none.latencies[i]))
                assert np.array_equal(a, np.asarray(emp.latencies[i]))

    def test_faults_reject_non_fcfs_discipline(self):
        from repro.core.planner import DisciplineSpec

        ts, plan = _mix_plan()
        batched = Plan(
            plan.partition,
            plan.cores,
            DisciplineSpec(kind="swap_batch", batch_cap=4),
        )
        profs = [t.profile for t in ts]
        sched = _full_schedule()
        for cls in (RuntimeSimulator, DiscreteEventSimulator):
            with pytest.raises(ValueError, match="FCFS"):
                cls(profs, batched, HW, faults=sched.view(0))

    def test_recovery_metrics_and_stats(self):
        ts, plan = _mix_plan()
        trace = poisson_trace([t.rate for t in ts], duration=120.0, seed=5)
        res = simulate(
            ts, plan, HW, trace, backend="des", faults=_full_schedule()
        )
        ttrs = res.recovery_times()
        assert len(ttrs) == 1  # one dropout window
        assert ttrs[0] >= 0.0
        dm = res.degraded_window_mean()
        assert math.isfinite(dm) and dm > 0
        # Fault-free runs report inert metrics.
        base = simulate(ts, plan, HW, trace, backend="des")
        assert base.fault is None
        assert base.requests_lost == 0 and base.requests_requeued == 0
        assert base.recovery_times() == []
        assert math.isnan(base.degraded_window_mean())


class TestRouteTraceFaults:
    def test_down_device_redirects_split_tenants(self):
        n = 200
        arr = np.sort(np.random.default_rng(0).uniform(0, 100.0, n))
        trace = Trace(
            arrival=arr,
            model_idx=np.zeros(n, dtype=np.int64),
            service_scale=np.ones(n),
        )
        sched = FaultSchedule(
            events=(
                FaultEvent(kind="dropout", device=0, start=0.0, end=200.0),
            )
        )
        subs = route_trace(
            trace, [(0, 1)], [(0.5, 0.5)], 2, seed=1, faults=sched
        )
        assert len(subs[0]) == 0 and len(subs[1]) == n

    def test_single_placement_tenant_keeps_requests(self):
        n = 50
        arr = np.linspace(0.0, 49.0, n)
        trace = Trace(
            arrival=arr,
            model_idx=np.zeros(n, dtype=np.int64),
            service_scale=np.ones(n),
        )
        sched = FaultSchedule(
            events=(
                FaultEvent(kind="dropout", device=0, start=0.0, end=100.0),
            )
        )
        subs = route_trace(trace, [(0,)], [(1.0,)], 2, seed=1, faults=sched)
        assert len(subs[0]) == n

    def test_faults_none_routes_bitwise(self):
        n = 300
        rng = np.random.default_rng(2)
        trace = Trace(
            arrival=np.sort(rng.uniform(0, 100.0, n)),
            model_idx=rng.integers(0, 2, n),
            service_scale=np.ones(n),
        )
        placement, routing = [(0, 1), (1,)], [(0.3, 0.7), (1.0,)]
        a = route_trace(trace, placement, routing, 2, seed=4)
        b = route_trace(trace, placement, routing, 2, seed=4, faults=None)
        for x, y in zip(a, b):
            assert np.array_equal(x.arrival, y.arrival)
            assert np.array_equal(x.model_idx, y.model_idx)


class TestLatencyWindowTracker:
    def test_incremental_polling(self):
        tr = LatencyWindowTracker(2)
        lat = [[1.0, 2.0], []]
        cnt, mean = tr.poll_mean(lat)
        assert cnt == 2 and mean == pytest.approx(1.5)
        lat[0].append(4.0)
        lat[1].append(6.0)
        cnt, mean = tr.poll_mean(lat)
        assert cnt == 2 and mean == pytest.approx(5.0)
        cnt, mean = tr.poll_mean(lat)
        assert cnt == 0 and math.isnan(mean)


class _Devices:
    @staticmethod
    def fleet(n=3):
        return [DeviceSpec.from_platform(HW, name=f"d{i}") for i in range(n)]


class TestEvacuateDevice:
    def test_evacuation_moves_all_tenants_off(self):
        ts = tenants_for(
            ("mnasnet", 4.0), ("inceptionv4", 2.0), ("mobilenetv2", 3.0)
        )
        fleet = _Devices.fleet(3)
        plan, obj = evacuate_device(ts, fleet, [1], k_max=K_MAX)
        assert math.isfinite(obj)
        assert plan.n_devices == 3
        for devs in plan.placement:
            assert 1 not in devs
        # The down device's plan row is inert: full-TPU pin, zero cores.
        inert = plan.device_plans[1]
        assert all(k == 0 for k in inert.cores)

    def test_empty_surviving_fleet_raises(self):
        ts = tenants_for(("mnasnet", 1.0))
        with pytest.raises(ValueError):
            evacuate_device(ts, _Devices.fleet(1), [0], k_max=K_MAX)


class TestSelfHealingControllers:
    def _dropout_setup(self):
        profiles = [
            paper_profile(n)
            for n in ("mnasnet", "inceptionv4", "mobilenetv2")
        ]
        rates = [6.0, 4.0, 5.0]
        trace = poisson_trace(rates, duration=300.0, seed=7)
        sched = FaultSchedule(
            events=(
                FaultEvent(kind="dropout", device=1, start=60.0, end=180.0),
            ),
            dropout_policy="requeue",
        )
        return profiles, rates, trace, sched

    def test_fault_aware_fleet_beats_oblivious_on_dropout(self):
        profiles, rates, trace, sched = self._dropout_setup()
        kw = dict(replan_period=15.0, window=30.0, backend="des")
        obl = run_adaptive_fleet(
            profiles, trace, _Devices.fleet(), faults=sched, **kw
        )
        aware = run_adaptive_fleet(
            profiles,
            trace,
            _Devices.fleet(),
            faults=sched,
            fault_aware=True,
            **kw,
        )
        m_obl = obl.sim.request_weighted_mean(rates)
        m_aw = aware.sim.request_weighted_mean(rates)
        assert m_aw < 0.8 * m_obl  # the benchmark bar, conservatively
        assert aware.failover_times, "dropout was never detected"
        assert aware.restore_times, "recovery was never detected"
        assert aware.failover_times[0] >= 60.0
        assert aware.sim.requests_requeued < obl.sim.requests_requeued
        # Time-to-recover collapses once the backlog is rerouted.
        assert max(aware.sim.recovery_times()) < max(
            obl.sim.recovery_times()
        )

    def test_health_probe_detects_at_boundary(self):
        profiles, rates, trace, sched = self._dropout_setup()
        kw = dict(replan_period=15.0, window=30.0, backend="des")
        probe = run_adaptive_fleet(
            profiles,
            trace,
            _Devices.fleet(),
            faults=sched,
            fault_aware=True,
            health_probe=True,
            **kw,
        )
        # The heartbeat sees the outage at the first boundary inside it.
        assert probe.failover_times == [75.0] or probe.failover_times == [
            60.0
        ]
        assert probe.restore_times and probe.restore_times[0] >= 180.0

    def test_controller_no_fault_path_is_bitwise_pre_fault(self):
        profiles, rates, trace, _ = self._dropout_setup()
        kw = dict(replan_period=15.0, window=30.0, backend="des")
        ref = run_adaptive_fleet(profiles, trace, _Devices.fleet(), **kw)
        exp = run_adaptive_fleet(
            profiles,
            trace,
            _Devices.fleet(),
            faults=None,
            fault_aware=False,
            **kw,
        )
        assert ref.fleet_plans == exp.fleet_plans
        for i in range(len(profiles)):
            assert np.array_equal(
                np.asarray(ref.sim.latencies[i]),
                np.asarray(exp.sim.latencies[i]),
            )
        assert exp.failover_times == []
        assert exp.restore_times == []
        assert exp.degraded_replan_times == []

    def test_single_device_throttle_awareness(self):
        profiles = [paper_profile(n) for n in ("mnasnet", "inceptionv4")]
        rates = [4.0, 3.0]
        trace = poisson_trace(rates, duration=240.0, seed=11)
        sched = FaultSchedule(
            events=(
                FaultEvent(
                    kind="throttle",
                    device=0,
                    start=60.0,
                    end=180.0,
                    tpu_factor=0.3,
                    cpu_factor=0.3,
                ),
            )
        )
        kw = dict(replan_period=15.0, window=30.0, backend="des")
        obl = run_adaptive(profiles, trace, HW, K_MAX, faults=sched, **kw)
        aware = run_adaptive(
            profiles, trace, HW, K_MAX, faults=sched, fault_aware=True, **kw
        )
        assert aware.degraded_replan_times, "throttle was never detected"
        assert all(60.0 < t <= 195.0 for t in aware.degraded_replan_times)
        m_obl = obl.sim.request_weighted_mean(rates)
        m_aw = aware.sim.request_weighted_mean(rates)
        assert m_aw <= m_obl * 1.02  # never materially worse
        # And the no-fault path stays bitwise pre-fault.
        ref = run_adaptive(profiles, trace, HW, K_MAX, **kw)
        exp = run_adaptive(
            profiles, trace, HW, K_MAX, faults=None, fault_aware=False, **kw
        )
        assert ref.plans == exp.plans
        for i in range(len(profiles)):
            assert np.array_equal(
                np.asarray(ref.sim.latencies[i]),
                np.asarray(exp.sim.latencies[i]),
            )

    def test_simulate_fleet_fault_injection_and_reroute(self):
        profiles, rates, trace, sched = self._dropout_setup()
        ts = [TenantSpec(p, r) for p, r in zip(profiles, rates)]
        from repro.core.fleet import fleet_hill_climb

        fleet = _Devices.fleet()
        plan, _ = fleet_hill_climb(ts, fleet, k_max=K_MAX)
        base = simulate_fleet(ts, plan, fleet, trace)
        faulted = simulate_fleet(ts, plan, fleet, trace, faults=sched)
        assert base.fault is None
        assert faulted.requests_requeued > 0
        # The outage stretches latencies fleet-wide.
        assert faulted.overall_mean() > base.overall_mean()
