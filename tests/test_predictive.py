"""Tests for predictive re-planning: rate forecasters, the plan-memoization
cache, and their opt-in wiring into the adaptive controller (PR 8).

The load-bearing contract throughout: forecasting and memoization are
opt-in, and the default path (``forecaster=None, plan_cache=None``) is
bitwise the reactive controller.
"""
import json
import math

import numpy as np
import pytest

from repro.configs.paper_models import paper_profile
from repro.core.allocator import hill_climb
from repro.core.plan_cache import (
    FleetPlanCache,
    PlanCache,
    mix_fingerprint,
    quantize_rates,
)
from repro.core.planner import TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.controller import _should_cold_fallback, run_adaptive
from repro.serving.forecast import (
    EwmaTrendForecaster,
    NeverForecaster,
    OracleForecaster,
    PeriodicForecaster,
    RateForecaster,
    piecewise_rate_fn,
)
from repro.serving.workload import RatePhase, dynamic_trace, poisson_trace
from tests._hypothesis_compat import given, settings, st

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores


class TestEwmaTrendForecaster:
    def test_none_until_two_observations(self):
        fc = EwmaTrendForecaster(2)
        assert fc.forecast(0.0, 30.0) is None
        fc.observe(0.0, [1.0, 2.0])
        assert fc.forecast(0.0, 30.0) is None
        fc.observe(30.0, [1.0, 2.0])
        assert fc.forecast(30.0, 30.0) is not None

    def test_linear_ramp_slope_convergence(self):
        # On a noiseless ramp x(t) = 2 + 0.5 t the trend must converge to
        # the true slope and the forecast to the true future value.
        fc = EwmaTrendForecaster(1)
        for t in np.arange(0.0, 630.0, 30.0):
            fc.observe(float(t), [2.0 + 0.5 * float(t)])
        assert fc.trend[0] == pytest.approx(0.5, rel=0.05)
        pred = fc.forecast(600.0, 30.0)
        assert pred[0] == pytest.approx(2.0 + 0.5 * 630.0, rel=0.05)

    def test_declining_ramp_clamps_at_zero(self):
        fc = EwmaTrendForecaster(1)
        for t in (0.0, 30.0, 60.0, 90.0):
            fc.observe(t, [max(0.0, 3.0 - 0.03 * t)])
        # Far enough out the linear extrapolation goes negative: clamped.
        pred = fc.forecast(90.0, 500.0)
        assert pred[0] == 0.0

    def test_same_instant_reobservation_refreshes_level_only(self):
        fc = EwmaTrendForecaster(1)
        fc.observe(0.0, [1.0])
        fc.observe(30.0, [1.0])
        trend_before = fc.trend[0]
        fc.observe(30.0, [5.0])  # dt == 0: no trend attribution
        assert fc.trend[0] == trend_before
        assert fc.level[0] == pytest.approx(0.5 * 5.0 + 0.5 * 1.0)

    def test_shape_mismatch_raises(self):
        fc = EwmaTrendForecaster(2)
        with pytest.raises(ValueError):
            fc.observe(0.0, [1.0])

    @given(
        level=st.floats(min_value=0.1, max_value=50.0),
        horizon=st.floats(min_value=1.0, max_value=300.0),
    )
    @settings(max_examples=15)
    def test_constant_series_is_fixed_point(self, level, horizon):
        # A constant rate stream must forecast itself at any horizon: the
        # trend stays exactly zero and the level exactly the constant.
        fc = EwmaTrendForecaster(1)
        for t in (0.0, 30.0, 60.0, 90.0, 120.0):
            fc.observe(t, [level])
        pred = fc.forecast(120.0, horizon)
        assert pred[0] == pytest.approx(level, rel=1e-9)


class TestPeriodicForecaster:
    def test_none_until_target_bin_seen(self):
        fc = PeriodicForecaster(1, period=100.0, n_bins=4)
        fc.observe(10.0, [1.0])  # bin 0
        assert fc.forecast(10.0, 25.0) is None  # target bin 1: unseen
        assert fc.forecast(80.0, 25.0) is not None  # target wraps to bin 0

    def test_noiseless_profile_recovery(self):
        # Deterministic per-bin rates sampled over 3 cycles recover the
        # profile exactly (running mean of identical values).
        period, n_bins = 120.0, 4
        bin_rates = {0: 1.0, 1: 4.0, 2: 2.5, 3: 0.5}
        fc = PeriodicForecaster(1, period, n_bins=n_bins)
        for cycle in range(3):
            for b in range(n_bins):
                t = cycle * period + (b + 0.5) * period / n_bins
                fc.observe(t, [bin_rates[b]])
        for b in range(n_bins):
            assert fc.profile(b) == [bin_rates[b]]
        # forecast(now, horizon) answers with the *target* time's bin.
        t_now = 3 * period + 15.0  # bin 0 of cycle 4
        assert fc.forecast(t_now, 30.0) == [bin_rates[1]]
        assert fc.forecast(t_now, 60.0) == [bin_rates[2]]

    def test_profile_averages_across_cycles(self):
        fc = PeriodicForecaster(1, period=100.0, n_bins=1)
        fc.observe(50.0, [1.0])
        fc.observe(150.0, [3.0])
        assert fc.profile(0) == [2.0]

    def test_shape_mismatch_raises(self):
        fc = PeriodicForecaster(2, period=100.0)
        with pytest.raises(ValueError):
            fc.observe(0.0, [1.0, 2.0, 3.0])

    def test_bad_construction_raises(self):
        with pytest.raises(ValueError):
            PeriodicForecaster(1, period=0.0)
        with pytest.raises(ValueError):
            PeriodicForecaster(1, period=10.0, n_bins=0)


class TestOracleAndProtocol:
    def test_all_forecasters_satisfy_protocol(self):
        for fc in (
            EwmaTrendForecaster(1),
            PeriodicForecaster(1, period=10.0),
            OracleForecaster(lambda t: (1.0,)),
            NeverForecaster(),
        ):
            assert isinstance(fc, RateForecaster)

    def test_piecewise_rate_fn_boundaries(self):
        phases = [
            RatePhase(0.0, 10.0, (1.0, 2.0)),
            RatePhase(10.0, 20.0, (3.0, 4.0)),
        ]
        fn = piecewise_rate_fn(phases)
        assert fn(-5.0) == (1.0, 2.0)  # before the first phase
        assert fn(5.0) == (1.0, 2.0)
        assert fn(10.0) == (3.0, 4.0)  # phase end is exclusive
        assert fn(99.0) == (3.0, 4.0)  # past the last phase
        with pytest.raises(ValueError):
            piecewise_rate_fn([])

    def test_oracle_clamps_negative_rates(self):
        fc = OracleForecaster(lambda t: (-1.0, 2.0))
        assert fc.forecast(0.0, 1.0) == [0.0, 2.0]


class TestQuantization:
    def test_nearby_rates_share_a_cell(self):
        # A grid-point rate and small perturbations of it share a cell
        # (cells are ~10% wide; a cell-center rate tolerates ~+-4%).
        r = 1e-3 * 1.1**50  # exactly on the default grid
        assert quantize_rates([r, 5.0]) == quantize_rates([1.02 * r, 5.0])
        assert quantize_rates([r, 5.0]) == quantize_rates([0.98 * r, 5.0])

    def test_distant_rates_differ(self):
        assert quantize_rates([1.0]) != quantize_rates([2.0])

    def test_idle_sentinel(self):
        assert quantize_rates([0.0]) == (-1,)
        assert quantize_rates([1e-4]) == (-1,)
        assert quantize_rates([1.0]) != (-1,)

    def test_bad_rel_raises(self):
        with pytest.raises(ValueError):
            quantize_rates([1.0], rel=0.0)

    def test_mix_fingerprint_distinguishes_models(self):
        a = [TenantSpec(paper_profile("mobilenetv2"), 1.0)]
        b = [TenantSpec(paper_profile("squeezenet"), 1.0)]
        assert mix_fingerprint(a) != mix_fingerprint(b)
        assert mix_fingerprint(a) == mix_fingerprint(
            [TenantSpec(paper_profile("mobilenetv2"), 9.9)]
        )  # rates are not part of the structural fingerprint


def _tenants(rates):
    profs = [paper_profile("mobilenetv2"), paper_profile("squeezenet")]
    return [TenantSpec(p, r) for p, r in zip(profs, rates)]


class TestPlanCache:
    def test_hit_roundtrip(self):
        tenants = _tenants([2.0, 3.0])
        plan, obj = hill_climb(tenants, HW, K_MAX)
        cache = PlanCache()
        cache.store(tenants, HW, K_MAX, plan, obj)
        hit = cache.lookup(tenants, HW, K_MAX)
        assert hit is not None
        got_plan, got_obj = hit
        assert got_plan == plan
        assert math.isfinite(got_obj)
        assert cache.stats.hits == 1 and cache.stats.misses == 0

    def test_nearby_rates_hit_distant_rates_miss(self):
        tenants = _tenants([2.0, 3.0])
        plan, obj = hill_climb(tenants, HW, K_MAX)
        cache = PlanCache()
        cache.store(tenants, HW, K_MAX, plan, obj)
        assert cache.lookup(_tenants([2.02, 3.0]), HW, K_MAX) is not None
        assert cache.lookup(_tenants([4.0, 3.0]), HW, K_MAX) is None
        assert cache.stats.misses == 1

    def test_key_includes_k_max(self):
        tenants = _tenants([2.0, 3.0])
        plan, obj = hill_climb(tenants, HW, K_MAX)
        cache = PlanCache()
        cache.store(tenants, HW, K_MAX, plan, obj)
        assert cache.lookup(tenants, HW, K_MAX - 1) is None

    def test_lru_eviction(self):
        cache = PlanCache(capacity=2)
        states = [[1.0, 1.0], [2.0, 2.0], [4.0, 4.0]]
        for rates in states:
            tenants = _tenants(rates)
            plan, obj = hill_climb(tenants, HW, K_MAX)
            cache.store(tenants, HW, K_MAX, plan, obj)
        assert len(cache) == 2
        assert cache.lookup(_tenants(states[0]), HW, K_MAX) is None  # evicted
        assert cache.lookup(_tenants(states[1]), HW, K_MAX) is not None
        assert cache.lookup(_tenants(states[2]), HW, K_MAX) is not None

    def test_verify_rejects_quality_regression(self):
        # A hit is only reusable while its fresh re-score stays within
        # margin of the stored quality.  Tampering the stored norm down
        # simulates a cached plan that has gone stale for this cell.
        tenants = _tenants([2.0, 3.0])
        plan, obj = hill_climb(tenants, HW, K_MAX)
        cache = PlanCache(margin=0.10)
        cache.store(tenants, HW, K_MAX, plan, obj)
        (entry,) = cache._entries.values()
        entry.norm_objective /= 10.0  # fresh norm now >> (1+margin)*stored
        assert cache.lookup(tenants, HW, K_MAX) is None
        assert cache.stats.rejects == 1 and cache.stats.hits == 0

    def test_store_skips_idle_and_infeasible(self):
        cache = PlanCache()
        tenants = _tenants([2.0, 3.0])
        plan, obj = hill_climb(tenants, HW, K_MAX)
        cache.store(_tenants([0.0, 0.0]), HW, K_MAX, plan, obj)
        cache.store(tenants, HW, K_MAX, plan, float("inf"))
        cache.store(tenants, HW, K_MAX, plan, float("nan"))
        assert len(cache) == 0

    def test_stats_hit_rate(self):
        cache = PlanCache()
        assert cache.stats.hit_rate == 0.0  # no lookups yet
        tenants = _tenants([2.0, 3.0])
        plan, obj = hill_climb(tenants, HW, K_MAX)
        cache.store(tenants, HW, K_MAX, plan, obj)
        cache.lookup(tenants, HW, K_MAX)
        cache.lookup(_tenants([9.0, 9.0]), HW, K_MAX)
        assert cache.stats.hit_rate == pytest.approx(0.5)
        d = cache.stats.as_dict()
        assert d["hits"] == 1 and d["misses"] == 1 and d["rejects"] == 0

    def test_bad_construction_raises(self):
        with pytest.raises(ValueError):
            PlanCache(capacity=0)
        with pytest.raises(ValueError):
            PlanCache(margin=-0.1)


class TestPlanCachePersistence:
    """persist()/restore(): JSON round trip for both caches, fingerprint
    rejection, and the never-restored hot path staying untouched."""

    def _warm_cache(self, rate_states):
        cache = PlanCache()
        for rates in rate_states:
            tenants = _tenants(rates)
            plan, obj = hill_climb(tenants, HW, K_MAX)
            cache.store(tenants, HW, K_MAX, plan, obj)
        return cache

    def test_round_trip_hits_and_promotes(self):
        states = [[2.0, 3.0], [4.0, 1.0]]
        cache = self._warm_cache(states)
        payload = cache.persist()
        fresh = PlanCache()
        assert fresh.restore(payload) == 2
        assert len(fresh) == 2
        for rates in states:
            tenants = _tenants(rates)
            want = cache.lookup(tenants, HW, K_MAX)
            got = fresh.lookup(tenants, HW, K_MAX)
            assert got is not None and got == want
        # Every hit promoted its entry back under a live key.
        assert len(fresh._restored) == 0 and len(fresh._entries) == 2
        assert fresh.stats.hits == 2

    def test_repersist_is_bit_identical(self):
        cache = self._warm_cache([[2.0, 3.0], [4.0, 1.0]])
        payload = cache.persist()
        fresh = PlanCache()
        fresh.restore(payload)
        assert fresh.persist() == payload
        # Round-trip again after promotion: same entries, just reordered
        # into the live table -- the digests and plans survive unchanged.
        fresh.lookup(_tenants([2.0, 3.0]), HW, K_MAX)
        again = PlanCache()
        assert again.restore(fresh.persist()) == 2

    def test_restore_rejects_wrong_kind(self):
        payload = self._warm_cache([[2.0, 3.0]]).persist()
        with pytest.raises(ValueError, match="kind"):
            FleetPlanCache().restore(payload)

    def test_restore_rejects_grid_mismatch(self):
        payload = self._warm_cache([[2.0, 3.0]]).persist()
        with pytest.raises(ValueError, match="grid"):
            PlanCache(rel=0.2).restore(payload)

    def test_restore_rejects_foreign_payload(self):
        with pytest.raises(ValueError):
            PlanCache().restore("not json at all {")
        with pytest.raises(ValueError, match="format"):
            PlanCache().restore(json.dumps({"format": "something-else"}))
        with pytest.raises(ValueError):
            PlanCache().restore(json.dumps([1, 2, 3]))

    def test_restore_trims_to_capacity_keeping_newest(self):
        states = [[1.0, 1.0], [2.0, 2.0], [4.0, 4.0]]
        payload = self._warm_cache(states).persist()
        small = PlanCache(capacity=2)
        assert small.restore(payload) == 2
        assert small.lookup(_tenants(states[0]), HW, K_MAX) is None
        assert small.lookup(_tenants(states[1]), HW, K_MAX) is not None
        assert small.lookup(_tenants(states[2]), HW, K_MAX) is not None

    def test_fleet_cache_round_trip(self):
        from repro.core.fleet import DeviceSpec, fleet_hill_climb

        fleet = [DeviceSpec.from_platform(HW, name=f"d{i}") for i in range(2)]
        tenants = _tenants([2.0, 3.0])
        plan, obj = fleet_hill_climb(tenants, fleet, k_max=K_MAX)
        cache = FleetPlanCache()
        cache.store(tenants, fleet, plan, obj)
        fresh = FleetPlanCache()
        assert fresh.restore(cache.persist()) == 1
        got = fresh.lookup(tenants, fleet)
        assert got is not None
        assert got[0] == plan

    def test_never_restored_cache_has_no_restored_entries(self):
        cache = self._warm_cache([[2.0, 3.0]])
        assert len(cache._restored) == 0
        cache.lookup(_tenants([2.0, 3.0]), HW, K_MAX)
        cache.lookup(_tenants([9.0, 9.0]), HW, K_MAX)
        assert len(cache._restored) == 0


DRIFT_PROFILES = ("mobilenetv2", "squeezenet")


def _step_trace(r0, r1, duration=180.0, seed=0):
    half = duration / 2.0
    phases = [RatePhase(0.0, half, r0), RatePhase(half, duration, r1)]
    return phases, dynamic_trace(phases, seed=seed)


class TestControllerWiring:
    def test_never_forecaster_is_bitwise_reactive(self):
        profiles = [paper_profile(m) for m in DRIFT_PROFILES]
        _, trace = _step_trace((1.0, 2.0), (5.0, 2.0), seed=2)
        kw = dict(replan_period=30.0, window=30.0, initial_rates=(1.0, 2.0))
        ref = run_adaptive(profiles, trace, HW, K_MAX, **kw)
        got = run_adaptive(
            profiles, trace, HW, K_MAX, forecaster=NeverForecaster(), **kw
        )
        assert got.plans == ref.plans
        assert got.replan_times == ref.replan_times
        for i in range(len(profiles)):
            assert np.array_equal(
                np.asarray(ref.sim.latencies[i]),
                np.asarray(got.sim.latencies[i]),
            )

    def test_oracle_forecaster_anticipates_step(self):
        # With perfect knowledge the plan for the post-step rates commits
        # at the boundary *before* the step enters the sliding window.
        profiles = [paper_profile(m) for m in DRIFT_PROFILES]
        phases, trace = _step_trace((1.0, 2.0), (8.0, 2.0), seed=3)
        kw = dict(replan_period=30.0, window=30.0, initial_rates=(1.0, 2.0))
        reactive = run_adaptive(profiles, trace, HW, K_MAX, **kw)
        oracle = run_adaptive(
            profiles,
            trace,
            HW,
            K_MAX,
            forecaster=OracleForecaster(piecewise_rate_fn(phases)),
            **kw,
        )
        assert oracle.plans != reactive.plans

    @given(seed=st.integers(min_value=0, max_value=4))
    @settings(max_examples=5, deadline=None)
    def test_oracle_never_worse_than_reactive(self, seed):
        # Property (small tolerance for simulation noise): planning against
        # the true future rates never meaningfully loses to chasing the
        # trailing estimate on a forecastable step drift.
        profiles = [paper_profile(m) for m in DRIFT_PROFILES]
        phases, trace = _step_trace((1.0, 2.0), (6.0, 2.0), seed=seed)
        kw = dict(replan_period=30.0, window=30.0, initial_rates=(1.0, 2.0))
        reactive = run_adaptive(profiles, trace, HW, K_MAX, **kw)
        oracle = run_adaptive(
            profiles,
            trace,
            HW,
            K_MAX,
            forecaster=OracleForecaster(piecewise_rate_fn(phases)),
            **kw,
        )
        assert oracle.sim.overall_mean() <= (
            1.10 * reactive.sim.overall_mean() + 5e-3
        )

    def test_plan_cache_hits_on_recurring_state(self):
        # A constant-rate oracle forecast makes every re-plan boundary the
        # same quantized state: all but the first resolve as cache hits.
        profiles = [paper_profile(m) for m in DRIFT_PROFILES]
        rates = (2.0, 3.0)
        trace = poisson_trace(rates, 160.0, seed=5)
        cache = PlanCache()
        res = run_adaptive(
            profiles,
            trace,
            HW,
            K_MAX,
            replan_period=30.0,
            window=30.0,
            initial_rates=rates,
            forecaster=OracleForecaster(lambda t: rates),
            plan_cache=cache,
        )
        assert cache.stats.hits >= 2
        assert cache.stats.rejects == 0
        assert len(set(res.plans)) == 1  # the memoized plan every time

    def test_plan_cache_alone_never_degrades_plans(self):
        # Reactive keys rarely repeat, but when they do the verified hit
        # must commit a plan at least as good as margin allows; the run
        # must complete and the no-cache comparison stays within margin.
        profiles = [paper_profile(m) for m in DRIFT_PROFILES]
        rates = (2.0, 3.0)
        trace = poisson_trace(rates, 160.0, seed=6)
        kw = dict(replan_period=30.0, window=30.0, initial_rates=rates)
        ref = run_adaptive(profiles, trace, HW, K_MAX, **kw)
        cached = run_adaptive(
            profiles, trace, HW, K_MAX, plan_cache=PlanCache(), **kw
        )
        assert cached.sim.overall_mean() <= 1.15 * ref.sim.overall_mean()


class TestZeroTrafficHardening:
    """S3: idle boundaries and degenerate objectives must not fire the
    cold-fallback guard or crash the re-plan loop."""

    def test_guard_false_on_empty_history(self):
        assert not _should_cold_fallback(5.0, [], 0.05)

    def test_guard_false_on_non_finite_objective(self):
        history = [1.0, 1.1, 0.9]
        assert not _should_cold_fallback(float("nan"), history, 0.05)
        assert not _should_cold_fallback(float("inf"), history, 0.05)
        # The finite regression case still fires.
        assert _should_cold_fallback(2.0, history, 0.05)

    def test_zero_traffic_replan_with_guard_and_cache(self):
        # Arrivals only in a leading burst, then silence: every later
        # boundary sees an all-zero estimate and must be skipped -- no
        # division by zero, no guard firing, no cache pollution, even with
        # min_rate=0 (no artificial rate floor) and zero initial rates.
        profiles = [paper_profile(m) for m in DRIFT_PROFILES]
        phases = [
            RatePhase(0.0, 20.0, (3.0, 3.0)),
            RatePhase(20.0, 200.0, (0.0, 0.0)),
        ]
        trace = list(dynamic_trace(phases, seed=7))
        # A single trailing arrival so boundaries keep firing through the
        # silent span (the loop only fires boundaries up to arrivals).
        from repro.serving.workload import Request

        trace.append(Request(arrival=199.0, model_idx=0))
        cache = PlanCache()
        res = run_adaptive(
            profiles,
            trace,
            HW,
            K_MAX,
            replan_period=30.0,
            window=30.0,
            initial_rates=(0.0, 0.0),
            min_rate=0.0,
            cold_fallback_margin=0.05,
            plan_cache=cache,
        )
        assert res.cold_fallback_times == []
        assert all(math.isfinite(t) for t in res.replan_times)
        # Idle boundaries were skipped, not planned: far fewer plans than
        # the 6 boundaries the trace horizon spans.
        assert len(res.plans) <= 4
        # The all-idle initial state never entered the cache.
        for key in cache._entries:
            assert key[0] != (-1, -1)
