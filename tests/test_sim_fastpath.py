"""Differential tests for the high-throughput simulation core.

ROADMAP invariant: *fast paths are replays, not semantics*.  Every fast
path introduced by the throughput PR -- the vectorized stepper
(``RuntimeSimulator.run_trace`` + ``_server_ends``), the columnar DES
driver (``offer_trace``), the optimized DES hot loop, and the O(1)
``SramCache`` -- must reproduce its scalar/pre-optimization reference
exactly:

* vectorized stepper == scalar stepper **bitwise** on every recorded
  observable (the busy-period-exact ``_server_ends`` keeps even the float
  association of the scalar recurrence; only the aggregate ``tpu_busy``
  may differ at round-off, from pairwise vs sequential summation);
* optimized DES == the frozen PR-3 snapshot in
  ``benchmarks/des_baseline.py`` **bitwise** (same float ops in the same
  event order);
* O(1) ``SramCache`` == the scan-based reference on any access sequence
  with increasing stamps (the only regime simulators produce).

Plus regression coverage for the workload over-draw fix and the
verify-then-skip trace sorting.
"""
import math

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from benchmarks.des_baseline import (
    BaselineDiscreteEventSimulator,
    BaselineSramCache,
    baseline_simulate,
)
from repro.configs.paper_models import paper_profile
from repro.core.planner import Plan, TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.cache import SramCache
from repro.serving.controller import run_adaptive
from repro.serving.des import DiscreteEventSimulator
from repro.serving.simulator import (
    _server_ends,
    ensure_sorted,
    simulate,
)
from repro.serving.workload import (
    Request,
    Trace,
    _poisson_arrival_times,
    mmpp_trace,
    poisson_trace,
    tenant_churn_trace,
    with_service_jitter,
)

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


def assert_bitwise_equal(a, b, *, busy_exact=False):
    """Recorded observables of two SimResults are identical."""
    for x, y in zip(a.latencies, b.latencies):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(a.arrivals, b.arrivals):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert a.misses == b.misses
    assert a.tpu_requests == b.tpu_requests
    if busy_exact:
        assert a.tpu_busy == b.tpu_busy
    else:
        assert a.tpu_busy == pytest.approx(b.tpu_busy, rel=1e-12)
    assert a.duration == pytest.approx(b.duration, rel=1e-12)


# -- vectorized stepper == scalar stepper ------------------------------------

def _scenarios():
    sq, mb = paper_profile("squeezenet"), paper_profile("mobilenetv2")
    collab_ts = [TenantSpec(p, 5.0) for p in [sq] * 2 + [mb] * 2]
    collab_plan = Plan(
        (sq.num_partition_points, sq.num_partition_points, 1, 1),
        (0, 0, 1, 1),
    )
    yield (
        "collab_poisson",
        collab_ts,
        collab_plan,
        poisson_trace([5.0] * 4, 300.0, seed=1),
    )
    swap_ts = tenants_for(("efficientnet", 2.0), ("gpunet", 2.0))
    yield (
        "swap_pair_poisson",
        swap_ts,
        Plan((6, 5), (0, 0)),
        poisson_trace([2.0, 2.0], 400.0, seed=2),
    )
    yield (
        "swap_pair_mmpp",
        swap_ts,
        Plan((6, 5), (0, 0)),
        mmpp_trace([2.0, 2.0], 400.0, burst_factor=3.0, seed=3),
    )
    iv = tenants_for(("inceptionv4", 2.0))
    yield (
        "jitter_split_k1",
        iv,
        Plan((9,), (1,)),
        with_service_jitter(poisson_trace([2.0], 300.0, seed=4), sigma=0.8, seed=5),
    )
    yield (
        "jitter_split_k4",
        iv,
        Plan((9,), (4,)),
        with_service_jitter(poisson_trace([2.0], 300.0, seed=6), sigma=0.8, seed=7),
    )
    churn_ts = tenants_for(("mnasnet", 4.0), ("inceptionv4", 1.0))
    yield (
        "churn_split",
        churn_ts,
        Plan((5, 9), (2, 2)),
        tenant_churn_trace(
            [4.0, 1.0], 400.0, mean_session=80.0, mean_absence=40.0, seed=8
        ).requests,
    )
    yield (
        "full_cpu",
        tenants_for(("mnasnet", 3.0)),
        Plan((0,), (4,)),
        poisson_trace([3.0], 300.0, seed=9),
    )


class TestVectorizedStepperIsAReplay:
    @pytest.mark.parametrize(
        "name,ts,plan,trace",
        list(_scenarios()),
        ids=[s[0] for s in _scenarios()],
    )
    def test_bitwise_equal_to_scalar(self, name, ts, plan, trace):
        assert isinstance(trace, Trace)
        assert len(trace) > 100, "scenario too small to exercise the paths"
        fast = simulate(ts, plan, HW, trace, vectorize=True)
        slow = simulate(ts, plan, HW, trace, vectorize=False)
        assert_bitwise_equal(fast, slow)

    def test_warmup_recording_matches(self):
        ts = tenants_for(("squeezenet", 5.0))
        plan = Plan((2,), (0,))
        trace = poisson_trace([5.0], 200.0, seed=10)
        for frac in (0.0, 0.3, 0.99):
            fast = simulate(ts, plan, HW, trace, warmup_frac=frac)
            slow = simulate(ts, plan, HW, trace, warmup_frac=frac, vectorize=False)
            assert_bitwise_equal(fast, slow)

    def test_adaptive_midflight_plan_changes_match(self):
        # run_adaptive's columnar fast path must commit the same plans at
        # the same times and record bitwise-equal observations.
        profiles = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        trace = poisson_trace([5.0, 1.5], 240.0, seed=11)
        common = dict(replan_period=30.0, window=30.0, initial_rates=(5.0, 1.5))
        fast = run_adaptive(profiles, trace, HW, K_MAX, vectorize=True, **common)
        slow = run_adaptive(profiles, trace, HW, K_MAX, vectorize=False, **common)
        assert fast.plans == slow.plans
        assert fast.replan_times == slow.replan_times
        assert fast.plan_objectives == slow.plan_objectives
        assert fast.cold_fallback_times == slow.cold_fallback_times
        assert_bitwise_equal(fast.sim, slow.sim)

    @given(seed=st.integers(0, 20), rate=st.floats(5.0, 60.0))
    @settings(max_examples=10, deadline=None)
    def test_backlog_regimes_match(self, seed, rate):
        # From idle to heavy overload: the busy-period classification in
        # _server_ends must stay exact everywhere.
        ts = tenants_for(("xception", rate))
        plan = Plan((11,), (0,))
        trace = poisson_trace([rate], 40.0, seed=seed)
        fast = simulate(ts, plan, HW, trace)
        slow = simulate(ts, plan, HW, trace, vectorize=False)
        assert_bitwise_equal(fast, slow)


class TestServerEnds:
    @given(seed=st.integers(0, 100), load=st.floats(0.2, 3.0))
    @settings(max_examples=20, deadline=None)
    def test_matches_scalar_recurrence_bitwise(self, seed, load):
        rng = np.random.default_rng(seed)
        n = 400
        enq = np.cumsum(rng.exponential(1.0, size=n))
        svc = rng.exponential(load, size=n)
        free0 = float(rng.uniform(0.0, 5.0))
        got = _server_ends(enq, svc, free0)
        free = free0
        for j, (e, s) in enumerate(zip(enq.tolist(), svc.tolist())):
            free = max(e, free) + s
            assert got[j] == free, (j, got[j], free)


# -- optimized DES == frozen PR-3 snapshot -----------------------------------

class TestDesBitIdenticalToBaseline:
    def _pair(self, profiles, plan):
        return (
            DiscreteEventSimulator(profiles, plan, HW),
            BaselineDiscreteEventSimulator(profiles, plan, HW),
        )

    def _assert_state_equal(self, a, b):
        assert a.latencies == b.latencies
        assert a.arrivals == b.arrivals
        assert a.misses == b.misses
        assert a.tpu_requests == b.tpu_requests
        assert a.tpu_busy == b.tpu_busy
        assert a.last_completion == b.last_completion

    @pytest.mark.parametrize(
        "names,plan,rates",
        [
            (("squeezenet",), Plan((2,), (0,)), [20.0]),
            (("efficientnet", "gpunet"), Plan((6, 5), (0, 0)), [3.0, 3.0]),
            (("inceptionv4",), Plan((9,), (2,)), [2.5]),
            (("mnasnet", "inceptionv4"), Plan((5, 9), (2, 2)), [5.0, 1.0]),
        ],
    )
    def test_static_traces(self, names, plan, rates):
        profiles = [paper_profile(n) for n in names]
        trace = with_service_jitter(
            poisson_trace(rates, 200.0, seed=13), sigma=0.5, seed=14
        )
        new = simulate(
            [TenantSpec(p, r) for p, r in zip(profiles, rates)],
            plan,
            HW,
            trace,
            backend="des",
        )
        old = baseline_simulate(
            [TenantSpec(p, r) for p, r in zip(profiles, rates)],
            plan,
            HW,
            trace.to_requests(),
            backend="des",
        )
        assert new.latencies == old.latencies
        assert new.arrivals == old.arrivals
        assert new.misses == old.misses
        assert new.tpu_requests == old.tpu_requests
        assert new.tpu_busy == old.tpu_busy

    def test_columnar_driver_equals_scalar_offers(self):
        profiles = [paper_profile("efficientnet"), paper_profile("gpunet")]
        ts = [TenantSpec(p, 2.0) for p in profiles]
        trace = poisson_trace([2.0, 2.0], 300.0, seed=15)
        fast = simulate(ts, Plan((6, 5), (0, 0)), HW, trace, backend="des")
        slow = simulate(
            ts, Plan((6, 5), (0, 0)), HW, trace, backend="des", vectorize=False
        )
        assert_bitwise_equal(fast, slow, busy_exact=True)

    def test_midflight_plan_changes(self):
        # The full driver surface under random re-plans: submit, advance_to,
        # set_plan, drain -- event-for-event identical.
        profiles = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        plans = [
            Plan((7, 11), (0, 0)),
            Plan((0, 11), (4, 0)),
            Plan((5, 9), (2, 2)),
            Plan((7, 0), (0, 4)),
        ]
        reqs = poisson_trace([4.0, 2.0], 60.0, seed=16).to_requests()
        new, old = self._pair(profiles, plans[0])
        for sim in (new, old):
            next_switch, pi = 10.0, 1
            for r in reqs:
                while r.arrival >= next_switch:
                    sim.advance_to(next_switch)
                    sim.set_plan(plans[pi % len(plans)], now=next_switch)
                    pi += 1
                    next_switch += 10.0
                sim.offer(r)
            sim.drain()
        self._assert_state_equal(new, old)

    def test_submit_out_of_order_future(self):
        profiles = [paper_profile("mnasnet")]
        new, old = self._pair(profiles, Plan((7,), (0,)))
        for sim in (new, old):
            for j in (5, 1, 3, 2, 4):
                sim.submit(Request(0, 0.01 * j))
            sim.drain()
        self._assert_state_equal(new, old)


# -- O(1) SramCache == scan-based reference ----------------------------------

class TestSramCacheEquivalence:
    @given(
        cap=st.integers(10, 200),
        seed=st.integers(0, 200),
    )
    @settings(max_examples=25, deadline=None)
    def test_random_access_sequences(self, cap, seed):
        rng = np.random.default_rng(seed)
        fast, ref = SramCache(cap), BaselineSramCache(cap)
        now = 0.0
        for _ in range(120):
            m = int(rng.integers(0, 5))
            b = int(rng.integers(1, 150))
            now += float(rng.uniform(0.01, 1.0))  # stamps strictly increase
            assert fast.access(m, b, now) == ref.access(m, b, now)
            assert fast.used == ref.used
            for g in range(5):
                assert fast.resident(g) == ref.resident(g)

    def test_used_is_constant_time_counter(self):
        c = SramCache(100)
        c.access(0, 40, 0.0)
        c.access(1, 50, 1.0)
        assert c.used == 90
        c.access(2, 30, 2.0)  # evicts 0
        assert c.used == 80
        c.reset()
        assert c.used == 0

    def test_state_restore_round_trip(self):
        c = SramCache(100)
        c.access(0, 40, 0.0)
        c.access(1, 50, 1.0)
        c.access(0, 40, 2.0)  # 1 is now LRU
        snap = c.state()
        assert [m for m, _, _ in snap] == [1, 0]
        c2 = SramCache(100)
        c2.restore(snap)
        assert c2.used == 90
        c2.access(2, 30, 3.0)  # must evict 1 (the LRU), not 0
        assert not c2.resident(1) and c2.resident(0)

    def test_restore_rejects_overflow(self):
        c = SramCache(50)
        with pytest.raises(ValueError):
            c.restore([(0, 40, 0.0), (1, 40, 1.0)])


# -- workload over-draw fix ---------------------------------------------------

class TestPoissonCoverage:
    @given(seed=st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_tiny_draw_blocks_still_cover_the_horizon(self, seed):
        # _chunk=7 forces the extension loop dozens of times; the realized
        # count must still match the rate (a silent truncation would cap it
        # near the block size).
        lam, duration = 50.0, 100.0
        rng = np.random.default_rng(seed)
        times = _poisson_arrival_times(rng, lam, duration, _chunk=7)
        mean = lam * duration
        assert abs(times.size - mean) < 6.0 * math.sqrt(mean)
        assert times.size and times[-1] < duration
        # The tail of the horizon is populated, not truncated.
        assert times[-1] > duration * 0.95

    def test_chunked_trace_well_formed(self):
        trace = poisson_trace([20.0, 10.0], 50.0, seed=3, _chunk=5)
        arr = trace.arrival
        assert np.all(arr[1:] >= arr[:-1])
        assert arr[-1] < 50.0
        counts = np.bincount(trace.model_idx, minlength=2)
        assert abs(counts[0] - 1000) < 6 * math.sqrt(1000)
        assert abs(counts[1] - 500) < 6 * math.sqrt(500)

    def test_high_rate_long_duration_hits_rate(self):
        trace = poisson_trace([200.0], 500.0, seed=4)
        assert len(trace) / 500.0 == pytest.approx(200.0, rel=0.02)


# -- verify-then-skip sorting -------------------------------------------------

class TestSortedSkip:
    def test_sorted_inputs_pass_through_unchanged(self):
        trace = poisson_trace([3.0], 50.0, seed=5)
        assert ensure_sorted(trace) is trace
        reqs = trace.to_requests()
        assert ensure_sorted(reqs) is reqs

    def test_unsorted_inputs_still_sorted(self):
        reqs = [Request(0, 3.0), Request(0, 1.0), Request(0, 2.0)]
        out = ensure_sorted(reqs)
        assert [r.arrival for r in out] == [1.0, 2.0, 3.0]
        tr = Trace(np.array([0, 0]), np.array([2.0, 1.0]))
        out_t = ensure_sorted(tr)
        assert out_t.arrival.tolist() == [1.0, 2.0]

    def test_fast_drivers_reject_unsorted_traces(self):
        # The scalar offer() raises per request on a clock rewind; the bulk
        # drivers must surface the same misuse instead of silently
        # corrupting the service order / warmup boundary.
        from repro.serving.simulator import RuntimeSimulator

        prof = [paper_profile("mnasnet")]
        plan = Plan((7,), (0,))
        bad = Trace(np.array([0, 0, 0]), np.array([5.0, 1.0, 3.0]))
        with pytest.raises(ValueError):
            RuntimeSimulator(prof, plan, HW).run_trace(bad)
        with pytest.raises(ValueError):
            DiscreteEventSimulator(prof, plan, HW).offer_trace(bad)

    def test_trace_does_not_freeze_caller_arrays(self):
        # Trace copies caller-owned writable arrays before marking its
        # columns read-only -- wrapping a buffer must not make later writes
        # to that buffer crash.
        mi = np.array([0, 0], dtype=np.int64)
        ar = np.array([1.0, 2.0])
        tr = Trace(mi, ar)
        ar[0] = 5.0  # caller's buffer stays writable...
        mi[0] = 1
        assert tr.arrival[0] == 1.0  # ...and the trace kept the old values
        assert tr.model_idx[0] == 0

    def test_unsorted_trace_simulates_like_sorted(self):
        base = poisson_trace([4.0], 60.0, seed=6)
        perm = np.random.default_rng(0).permutation(len(base))
        shuffled = Trace(
            base.model_idx[perm], base.arrival[perm], base.service_scale[perm]
        )
        ts = tenants_for(("squeezenet", 4.0))
        plan = Plan((2,), (0,))
        a = simulate(ts, plan, HW, base)
        b = simulate(ts, plan, HW, shuffled)
        assert_bitwise_equal(a, b)
