"""Per-architecture smoke tests: a REDUCED variant of each assigned arch
(2 layers, d_model <= 512, <= 4 experts) runs one forward/loss and one
decode step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.frontend import make_decode_token, make_train_batch
from repro.models.transformer import (
    count_params,
    decode_step,
    forward_loss,
    init_decode_caches,
    init_params,
)

ARCH_NAMES = sorted(ARCHS)
SEQ = 32
BATCH = 2


@pytest.fixture(scope="module")
def reduced_setups():
    out = {}
    for name in ARCH_NAMES:
        cfg = ARCHS[name].reduced()
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        out[name] = (cfg, params)
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_config_bounds(name):
    cfg = ARCHS[name].reduced()
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.vocab_size <= 1024


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_loss_finite(name, reduced_setups):
    cfg, params = reduced_setups[name]
    batch = make_train_batch(cfg, BATCH, SEQ, seed=1)
    loss, metrics = forward_loss(cfg, params, batch, remat=False)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: loss={loss}"
    assert float(metrics["ce"]) > 0.0
    # Random init => CE should be near log(vocab).
    assert float(metrics["ce"]) < 2.0 * np.log(cfg.vocab_size) + 1.0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_grads_finite(name, reduced_setups):
    cfg, params = reduced_setups[name]
    batch = make_train_batch(cfg, BATCH, SEQ, seed=2)

    def loss_fn(p):
        return forward_loss(cfg, p, batch, remat=True)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves
    for leaf in leaves:
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_shapes(name, reduced_setups):
    cfg, params = reduced_setups[name]
    max_len = 64
    caches = init_decode_caches(cfg, BATCH, max_len, dtype=jnp.float32)
    tok = make_decode_token(cfg, BATCH, seed=3)
    if cfg.frontend == "audio":
        tok = tok.astype(jnp.float32)
    logits, new_caches = decode_step(cfg, params, caches, tok, jnp.int32(0))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert len(new_caches) == cfg.n_layers


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_multiple_steps_stable(name, reduced_setups):
    cfg, params = reduced_setups[name]
    max_len = 64
    caches = init_decode_caches(cfg, 1, max_len, dtype=jnp.float32)
    step = jax.jit(lambda c, t, l: decode_step(cfg, params, c, t, l))
    for i in range(4):
        tok = make_decode_token(cfg, 1, seed=10 + i)
        if cfg.frontend == "audio":
            tok = tok.astype(jnp.float32)
        logits, caches = step(caches, tok, jnp.int32(i))
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_positive_and_moe_active_smaller(name):
    cfg = ARCHS[name]
    total = count_params(cfg)
    active = count_params(cfg, active_only=True)
    assert total > 0
    if cfg.is_moe:
        assert active < total
    else:
        assert active == total


def test_decode_prefix_consistency_dense():
    """Decoding token-by-token must match the full forward pass logits."""
    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    T = 8
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, T), 0, cfg.vocab_size)
    # Full forward.
    from repro.models.transformer import backbone, embed_inputs, unembed

    h, _ = embed_inputs(cfg, params, {"tokens": tokens})
    h, _ = backbone(cfg, params, h, remat=False)
    full_logits = unembed(cfg, params, h)  # (1, T, V)
    # Token-by-token decode.
    caches = init_decode_caches(cfg, 1, T, dtype=jnp.float32)
    outs = []
    for t in range(T):
        logits, caches = decode_step(
            cfg, params, caches, tokens[:, t : t + 1], jnp.int32(t)
        )
        outs.append(np.asarray(logits[0, 0]))
    dec_logits = np.stack(outs)
    np.testing.assert_allclose(
        dec_logits, np.asarray(full_logits[0]), rtol=2e-3, atol=2e-3
    )


def test_decode_prefix_consistency_rwkv():
    """RWKV recurrent decode must match the scan forward pass."""
    cfg = ARCHS["rwkv6-7b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
    T = 6
    tokens = jax.random.randint(jax.random.PRNGKey(6), (1, T), 0, cfg.vocab_size)
    from repro.models.transformer import backbone, embed_inputs, unembed

    h, _ = embed_inputs(cfg, params, {"tokens": tokens})
    h, _ = backbone(cfg, params, h, remat=False)
    full_logits = unembed(cfg, params, h)
    caches = init_decode_caches(cfg, 1, T, dtype=jnp.float32)
    outs = []
    for t in range(T):
        logits, caches = decode_step(
            cfg, params, caches, tokens[:, t : t + 1], jnp.int32(t)
        )
        outs.append(np.asarray(logits[0, 0]))
    np.testing.assert_allclose(
        np.stack(outs), np.asarray(full_logits[0]), rtol=2e-3, atol=2e-3
    )
