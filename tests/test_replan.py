"""Incremental re-planning engine: dominance pruning, warm-started
hill-climb, and delta evaluation.

Invariants enforced here (recorded in ROADMAP.md):

* Pruning exactness: the Pareto frontier of ``ModelProfile.pareto_points``
  never removes every optimum of the NLIP, so the pruned brute-force oracle
  returns the scalar oracle's objective exactly (plans may differ only when
  an exact-tie duplicate was pruned).
* Delta evaluation: ``penalized_objective_delta_batch`` equals
  ``penalized_objective_batch`` to ~1 ulp for any valid base plan, including
  the infeasible-base fallback.
* Warm start: ``hill_climb(init_plan=...)`` is a monotone descent -- its
  result never scores worse than the (snapped) incumbent under the new
  rates -- and it terminates at a plan stable under every +-{1,2} frontier
  move.  It is *not* guaranteed bit-identical to the cold climb (the greedy
  endpoint is path-dependent); across random drifted mixes it ties or beats
  the cold objective in the overwhelming majority of cases, which the
  deterministic benchmark mixes assert.
"""
import math

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs.paper_models import PAPER_MODEL_NAMES, paper_profile
from repro.core import latency
from repro.core.allocator import (
    _brute_force_scalar,
    brute_force_oracle,
    hill_climb,
    prop_alloc,
    prop_alloc_batch,
)
from repro.core.plan_tables import EvalTables, PlanTables
from repro.core.planner import ModelProfile, Plan, Segment, TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores
REL_TOL = 1e-12
# Delta evaluation re-bases aggregates with one add/subtract, so allow a few
# ulps beyond the PR-1 scalar-vs-batch tolerance.
DELTA_TOL = 1e-9


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


def _seg(name, *, w, out, tpu, cpu, frac=0.8):
    return Segment(
        name=name,
        flops=1e6,
        weight_bytes=w,
        out_bytes=out,
        tpu_time=tpu,
        cpu_time_1core=cpu,
        cpu_parallel_frac=frac,
    )


def dominated_profile() -> ModelProfile:
    """4-segment profile where the cut after the zero-CPU seg2 (p=3) is
    dominated by p=2: equal CPU suffix, strictly less weight/TPU time, and a
    no-larger boundary tensor."""
    return ModelProfile(
        name="crafted",
        segments=(
            _seg("s0", w=2_000_000, out=100_000, tpu=1e-3, cpu=10e-3),
            _seg("s1", w=1_000_000, out=60_000, tpu=0.5e-3, cpu=8e-3),
            _seg("s2", w=500_000, out=80_000, tpu=0.3e-3, cpu=0.0),
            _seg("s3", w=3_000_000, out=4_000, tpu=0.8e-3, cpu=20e-3),
        ),
        input_bytes=150_000,
    )


class TestParetoFrontier:
    def test_paper_profiles_frontier_is_valid(self):
        for name in PAPER_MODEL_NAMES:
            prof = paper_profile(name)
            f = prof.pareto_points
            P = prof.num_partition_points
            assert f[0] == 0 and f[-1] == P
            assert np.all(np.diff(f) > 0)
            assert set(f.tolist()) <= set(range(P + 1))

    def test_paper_profiles_are_smooth_no_pruning(self):
        # The synthetic paper profiles have strictly positive per-segment
        # costs, so no point is dominated and the pruned search is
        # bit-identical to the unpruned one (covered below).
        for name in PAPER_MODEL_NAMES:
            prof = paper_profile(name)
            assert len(prof.pareto_points) == prof.num_partition_points + 1

    def test_crafted_dominated_point_is_pruned(self):
        prof = dominated_profile()
        f = prof.pareto_points.tolist()
        assert 3 not in f          # dominated by p=2 (zero-CPU seg2)
        assert {0, 1, 2, 4} <= set(f)

    def test_plan_tables_carry_frontiers(self):
        ts = [TenantSpec(dominated_profile(), 1.0)] + tenants_for(
            ("mnasnet", 2.0)
        )
        tab = PlanTables.for_tenants(ts, HW, K_MAX)
        assert len(tab.frontiers) == 2
        np.testing.assert_array_equal(
            tab.frontiers[0], ts[0].profile.pareto_points
        )
        assert tab.frontier_sizes.tolist() == [4, 8]

    def test_endpoints_never_pruned_degenerate_profile(self):
        # All-zero CPU suffix: everything ties; 0 and P must survive.
        prof = ModelProfile(
            name="zeros",
            segments=tuple(
                _seg(f"z{i}", w=1000, out=1000, tpu=1e-4, cpu=0.0)
                for i in range(4)
            ),
            input_bytes=1000,
        )
        f = prof.pareto_points.tolist()
        assert f[0] == 0 and f[-1] == 4


class TestOraclePruning:
    def test_single_tenant_exact(self):
        # Exactness theorem, single-tenant case: the pruned optimum equals
        # the full optimum exactly (alpha = 0 throughout, every objective
        # term monotone in the dominance quadruple).
        ts = [TenantSpec(dominated_profile(), 2.0)]
        plan_p, obj_p = brute_force_oracle(ts, HW, K_MAX, prune=True)
        plan_s, obj_s = _brute_force_scalar(ts, HW, K_MAX)
        assert obj_p == pytest.approx(obj_s, rel=REL_TOL)
        assert plan_p.partition[0] in ts[0].profile.pareto_points

    def test_multi_tenant_pruned_optimum_matches(self):
        ts = [TenantSpec(dominated_profile(), 1.5)] + tenants_for(
            ("mobilenetv2", 1.0)
        )
        plan_p, obj_p = brute_force_oracle(ts, HW, K_MAX, prune=True)
        plan_f, obj_f = brute_force_oracle(ts, HW, K_MAX, prune=False)
        assert obj_p == pytest.approx(obj_f, rel=REL_TOL)

    def test_paper_mix_pruning_noop(self):
        ts = tenants_for(("mnasnet", 3.0), ("mobilenetv2", 1.0))
        plan_p, obj_p = brute_force_oracle(ts, HW, K_MAX, prune=True)
        plan_f, obj_f = brute_force_oracle(ts, HW, K_MAX, prune=False)
        assert plan_p == plan_f
        assert obj_p == obj_f


class TestDeltaEval:
    @given(
        rates=st.lists(st.floats(0.2, 5.0), min_size=2, max_size=5),
        k_max=st.integers(4, 12),
        faz=st.sampled_from([False, True]),
        data=st.data(),
    )
    @settings(max_examples=30, deadline=None)
    def test_delta_matches_full_batch(self, rates, k_max, faz, data):
        names = ["inceptionv4", "xception", "densenet201", "mnasnet", "gpunet"]
        ts = tenants_for(*[(names[i % 5], r) for i, r in enumerate(rates)])
        n = len(ts)
        n_points = [t.profile.num_partition_points for t in ts]
        base_p = np.array(
            [data.draw(st.integers(0, P)) for P in n_points], dtype=np.intp
        )
        base_k = np.array(
            [
                data.draw(st.integers(1, k_max)) if p < P else 0
                for p, P in zip(base_p, n_points)
            ],
            dtype=np.intp,
        )
        # Neighbor candidates: one partition entry changed, cores re-drawn
        # for a couple of tenants (as PropAlloc reallocation would).
        cands_p, cands_k = [], []
        for _ in range(6):
            p = base_p.copy()
            k = base_k.copy()
            m = data.draw(st.integers(0, n - 1))
            p[m] = data.draw(st.integers(0, n_points[m]))
            k[m] = (
                data.draw(st.integers(1, k_max)) if p[m] < n_points[m] else 0
            )
            j = data.draw(st.integers(0, n - 1))
            if base_p[j] < n_points[j]:
                k[j] = data.draw(st.integers(1, k_max))
            cands_p.append(p)
            cands_k.append(k)
        P = np.array(cands_p)
        K = np.array(cands_k)
        full = latency.penalized_objective_batch(
            ts, P, K, HW, force_alpha_zero=faz
        )
        delta = latency.penalized_objective_delta_batch(
            ts, base_p, base_k, P, K, HW, force_alpha_zero=faz
        )
        for b in range(P.shape[0]):
            f, d = float(full[b]), float(delta[b])
            if math.isnan(f) or math.isnan(d):
                assert math.isnan(f) and math.isnan(d)
            elif math.isinf(f) or math.isinf(d):
                assert f == d
            else:
                assert d == pytest.approx(f, rel=DELTA_TOL, abs=1e-300)

    def test_infeasible_base_falls_back_to_full(self):
        # The unstable all-CPU start has inf static latency; the delta path
        # must re-score neighbors from scratch, not propagate inf - inf.
        ts = tenants_for(("inceptionv4", 50.0), ("xception", 50.0))
        base_p = np.zeros(2, dtype=np.intp)
        base_k = np.array(prop_alloc(ts, [0, 0], K_MAX), dtype=np.intp)
        P = np.array([[2, 0], [0, 2], [5, 3]], dtype=np.intp)
        K = np.array([[2, 2], [2, 2], [2, 2]], dtype=np.intp)
        full = latency.penalized_objective_batch(ts, P, K, HW)
        delta = latency.penalized_objective_delta_batch(
            ts, base_p, base_k, P, K, HW
        )
        np.testing.assert_array_equal(full, delta)

    def test_tables_reuse(self):
        ts = tenants_for(("inceptionv4", 2.0), ("mnasnet", 1.0))
        etab = EvalTables.build(ts, HW, K_MAX)
        base_p = np.array([5, 3], dtype=np.intp)
        base_k = np.array([2, 2], dtype=np.intp)
        P = np.array([[6, 3], [5, 7]], dtype=np.intp)
        K = np.array([[2, 2], [3, 0]], dtype=np.intp)
        via_tables = latency.penalized_objective_delta_batch(
            ts, base_p, base_k, P, K, HW, tables=etab
        )
        fresh = latency.penalized_objective_delta_batch(
            ts, base_p, base_k, P, K, HW
        )
        np.testing.assert_array_equal(via_tables, fresh)


def _stable_under_neighbor_moves(ts, plan, k_max, tol=DELTA_TOL):
    """True when no single-tenant +-1/2 frontier move (with PropAlloc cores)
    improves on ``plan`` beyond round-off: the warm climb's termination
    criterion, re-checked from scratch."""
    tabs = PlanTables.for_tenants(ts, HW, k_max)
    base = np.array(plan.partition, dtype=np.intp)
    l_curr = latency.penalized_objective(ts, plan, HW)
    cands = []
    for m, f in enumerate(tabs.frontiers):
        pos = int(np.searchsorted(f, base[m]))
        for h in (1, 2, -1, -2):
            q = pos + h
            if 0 <= q < len(f):
                cand = base.copy()
                cand[m] = f[q]
                cands.append(cand)
    parts = np.array(cands)
    cores, feasible = prop_alloc_batch(ts, parts, k_max)
    parts, cores = parts[feasible], cores[feasible]
    objs = latency.penalized_objective_batch(ts, parts, cores, HW, tables=tabs)
    return bool(np.all(objs >= l_curr * (1.0 - tol)))


class TestWarmStart:
    def _mix(self, n, rates):
        names = [PAPER_MODEL_NAMES[i % len(PAPER_MODEL_NAMES)] for i in range(n)]
        return tenants_for(*zip(names, rates))

    @given(
        rates=st.lists(st.floats(0.2, 3.0), min_size=2, max_size=6),
        data=st.data(),
    )
    @settings(max_examples=20, deadline=None)
    def test_warm_descends_from_incumbent_and_is_stable(self, rates, data):
        n = len(rates)
        ts = self._mix(n, rates)
        k_max = max(K_MAX, n)
        tabs = PlanTables.for_tenants(ts, HW, k_max)
        incumbent, _ = hill_climb(ts, HW, k_max, batch=True, tables=tabs)
        drift = [data.draw(st.floats(0.7, 1.4)) for _ in range(n)]
        ts2 = self._mix(n, [r * d for r, d in zip(rates, drift)])
        warm_plan, warm_obj = hill_climb(
            ts2, HW, k_max, batch=True, tables=tabs, init_plan=incumbent
        )
        # Monotone descent: never worse than the incumbent re-priced at the
        # new rates (the warm climb's starting point).
        inc_cores = prop_alloc(ts2, list(incumbent.partition), k_max)
        inc_obj = latency.penalized_objective(
            ts2, Plan(incumbent.partition, inc_cores), HW
        )
        assert warm_obj <= inc_obj * (1.0 + DELTA_TOL)
        # Returned objective is the true objective of the returned plan.
        assert warm_obj == pytest.approx(
            latency.penalized_objective(ts2, warm_plan, HW), rel=DELTA_TOL
        )
        assert _stable_under_neighbor_moves(ts2, warm_plan, k_max)

    def test_zero_drift_warm_from_cold_never_worse(self):
        for n in (2, 4, 6, 8):
            rates = [0.4 + 0.3 * i for i in range(n)]
            ts = self._mix(n, rates)
            k_max = max(K_MAX, n)
            cold_plan, cold_obj = hill_climb(ts, HW, k_max, batch=True)
            warm_plan, warm_obj = hill_climb(
                ts, HW, k_max, batch=True, init_plan=cold_plan
            )
            assert warm_obj <= cold_obj * (1.0 + DELTA_TOL)

    def test_benchmark_drift_warm_ties_or_beats_cold(self):
        # The deterministic alg_scaling drift scenario: one controller
        # period of +-20% drift.  The warm bidirectional descent must tie or
        # beat the cold up-only climb (it usually escapes the cold path's
        # local traps; see the module docstring for why bit-identity is not
        # guaranteed).
        from benchmarks.common import full_tpu_rates_for_utilization

        for n in (6, 10, 16):
            profs = [
                paper_profile(PAPER_MODEL_NAMES[i % len(PAPER_MODEL_NAMES)])
                for i in range(n)
            ]
            rates = full_tpu_rates_for_utilization(profs, 0.5)
            ts = [TenantSpec(p, r) for p, r in zip(profs, rates)]
            k_max = max(K_MAX, n)
            tabs = PlanTables.for_tenants(ts, HW, k_max)
            incumbent, _ = hill_climb(ts, HW, k_max, batch=True, tables=tabs)
            ts2 = [
                TenantSpec(p, r * (1.2 if i % 2 else 0.85))
                for i, (p, r) in enumerate(zip(profs, rates))
            ]
            cold_plan, cold_obj = hill_climb(
                ts2, HW, k_max, batch=True, tables=tabs
            )
            warm_plan, warm_obj = hill_climb(
                ts2, HW, k_max, batch=True, tables=tabs, init_plan=incumbent
            )
            assert (
                warm_plan == cold_plan
                or warm_obj <= cold_obj * (1.0 + DELTA_TOL)
            )

    def test_warm_start_snaps_off_frontier_incumbent(self):
        # An incumbent holding a dominated point must snap down to the
        # nearest frontier point and still return a valid plan.
        ts = [TenantSpec(dominated_profile(), 1.0)] + tenants_for(
            ("mnasnet", 2.0)
        )
        incumbent = Plan((3, 4), prop_alloc(ts, [3, 4], K_MAX))
        plan, obj = hill_climb(
            ts, HW, K_MAX, batch=True, init_plan=incumbent
        )
        assert plan.partition[0] in ts[0].profile.pareto_points
        assert obj == pytest.approx(
            latency.penalized_objective(ts, plan, HW), rel=DELTA_TOL
        )

    def test_init_plan_requires_batch(self):
        ts = tenants_for(("mnasnet", 1.0))
        incumbent = Plan((7,), (0,))
        with pytest.raises(ValueError):
            hill_climb(ts, HW, K_MAX, batch=False, init_plan=incumbent)

    def test_init_plan_forces_batch_dispatch(self):
        # Below the size crossover, init_plan must still route to the
        # batched path (the scalar loop cannot warm-start) and return a
        # valid plan.
        ts = tenants_for(("mnasnet", 2.0), ("mobilenetv2", 1.0))
        cold, _ = hill_climb(ts, HW, K_MAX)
        plan, _ = hill_climb(ts, HW, K_MAX, init_plan=cold)
        assert len(plan.partition) == 2


class TestPrunedHillClimb:
    def test_paper_mixes_prune_noop_identical(self):
        # Paper profiles have full frontiers, so pruning must not change
        # the batched climb at all.
        for n in (2, 5, 8):
            rates = [0.3 + 0.25 * i for i in range(n)]
            names = [PAPER_MODEL_NAMES[i % len(PAPER_MODEL_NAMES)] for i in range(n)]
            ts = tenants_for(*zip(names, rates))
            k_max = max(K_MAX, n)
            p1, o1 = hill_climb(ts, HW, k_max, batch=True, prune=True)
            p2, o2 = hill_climb(ts, HW, k_max, batch=True, prune=False)
            assert p1 == p2
            assert o1 == o2

    def test_crafted_mix_pruned_plan_on_frontier(self):
        ts = [TenantSpec(dominated_profile(), 1.5)] + tenants_for(
            ("mobilenetv2", 1.0)
        )
        plan, obj = hill_climb(ts, HW, K_MAX, batch=True, prune=True)
        assert plan.partition[0] in ts[0].profile.pareto_points
        assert obj == pytest.approx(
            latency.penalized_objective(ts, plan, HW), rel=DELTA_TOL
        )

    def test_opt_out_spans_full_axis(self):
        ts = [TenantSpec(dominated_profile(), 1.5)] + tenants_for(
            ("mobilenetv2", 1.0)
        )
        plan, obj = hill_climb(ts, HW, K_MAX, batch=True, prune=False)
        assert obj == pytest.approx(
            latency.penalized_objective(ts, plan, HW), rel=DELTA_TOL
        )
