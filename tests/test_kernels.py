"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests
against the pure-jnp oracles in repro/kernels/ref.py (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# --------------------------------------------------------------------------
# block_matmul
# --------------------------------------------------------------------------
class TestMatmul:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "shape", [(128, 128, 128), (256, 128, 64), (64, 256, 128), (512, 64, 256)]
    )
    def test_aligned_shapes(self, shape, dtype):
        M, K, N = shape
        x = _rand(jax.random.PRNGKey(0), (M, K), dtype)
        y = _rand(jax.random.PRNGKey(1), (K, N), dtype)
        out = ops.matmul(x, y, block_m=64, block_n=64, block_k=64)
        expect = ref.matmul_ref(x, y)
        tol = 1e-3 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(expect, np.float32),
            rtol=tol,
            atol=tol,
        )

    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 200),
        n=st.integers(1, 200),
    )
    @settings(max_examples=15, deadline=None)
    def test_unaligned_shapes_padded(self, m, k, n):
        x = _rand(jax.random.PRNGKey(2), (m, k), jnp.float32)
        y = _rand(jax.random.PRNGKey(3), (k, n), jnp.float32)
        out = ops.matmul(x, y, block_m=32, block_n=32, block_k=32)
        expect = ref.matmul_ref(x, y)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=1e-3, atol=1e-3
        )

    def test_identity(self):
        x = _rand(jax.random.PRNGKey(4), (96, 96), jnp.float32)
        eye = jnp.eye(96)
        out = ops.matmul(x, eye, block_m=32, block_n=32, block_k=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
def _attn_expect(q, k, v, scale, window):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = kk.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = vv.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = ref.attention_ref(qf, kf, vf, scale=scale, window=window)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("window", [0, 64, 17])
    def test_causal_and_window(self, window, dtype):
        B, S, H, KV, hd = 2, 128, 4, 2, 32
        q = _rand(jax.random.PRNGKey(0), (B, S, H, hd), dtype)
        k = _rand(jax.random.PRNGKey(1), (B, S, KV, hd), dtype)
        v = _rand(jax.random.PRNGKey(2), (B, S, KV, hd), dtype)
        scale = 1.0 / np.sqrt(hd)
        out = ops.causal_attention(
            q, k, v, scale=scale, window=window, block_q=32, block_k=32
        )
        expect = _attn_expect(q, k, v, scale, window)
        tol = 5e-4 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(expect, np.float32),
            rtol=tol,
            atol=tol,
        )

    @given(
        s_pow=st.integers(5, 8),
        h=st.sampled_from([1, 2, 4]),
        kv_div=st.sampled_from([1, 2]),
        hd=st.sampled_from([16, 32, 64]),
        window=st.sampled_from([0, 16, 100]),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_sweep(self, s_pow, h, kv_div, hd, window):
        if h % kv_div:
            return
        S = 2**s_pow
        kv = h // kv_div
        q = _rand(jax.random.PRNGKey(10), (1, S, h, hd), jnp.float32)
        k = _rand(jax.random.PRNGKey(11), (1, S, kv, hd), jnp.float32)
        v = _rand(jax.random.PRNGKey(12), (1, S, kv, hd), jnp.float32)
        scale = 1.0 / np.sqrt(hd)
        out = ops.causal_attention(
            q, k, v, scale=scale, window=window, block_q=64, block_k=64
        )
        expect = _attn_expect(q, k, v, scale, window)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=1e-3, atol=1e-3
        )

    def test_first_token_attends_to_itself_only(self):
        B, S, H, hd = 1, 64, 2, 16
        q = _rand(jax.random.PRNGKey(20), (B, S, H, hd), jnp.float32)
        k = _rand(jax.random.PRNGKey(21), (B, S, H, hd), jnp.float32)
        v = _rand(jax.random.PRNGKey(22), (B, S, H, hd), jnp.float32)
        out = ops.causal_attention(
            q, k, v, scale=0.25, window=0, block_q=32, block_k=32
        )
        np.testing.assert_allclose(
            np.asarray(out[:, 0]), np.asarray(v[:, 0]), rtol=1e-4, atol=1e-4
        )


# --------------------------------------------------------------------------
# WKV6
# --------------------------------------------------------------------------
def _wkv_expect(r, k, v, w, u):
    B, T, H, hd = r.shape

    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)

    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    out = ref.wkv6_ref(flat(r), flat(k), flat(v), flat(w), uf)
    return out.reshape(B, H, T, hd).transpose(0, 2, 1, 3)


class TestWKV6:
    @pytest.mark.parametrize("chunk", [8, 16, 32, 64])
    def test_chunk_invariance(self, chunk):
        B, T, H, hd = 1, 64, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r = _rand(ks[0], (B, T, H, hd), jnp.float32)
        k = _rand(ks[1], (B, T, H, hd), jnp.float32)
        v = _rand(ks[2], (B, T, H, hd), jnp.float32)
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.3 + 0.69
        u = _rand(ks[4], (H, hd), jnp.float32) * 0.1
        out = ops.wkv6(r, k, v, w, u, chunk=chunk)
        expect = _wkv_expect(r, k, v, w, u)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=2e-3, atol=2e-3
        )

    @given(
        t_pow=st.integers(4, 7),
        h=st.sampled_from([1, 2, 4]),
        hd=st.sampled_from([8, 16, 32]),
        w_lo=st.floats(0.55, 0.9),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_sweep(self, t_pow, h, hd, w_lo, seed):
        T = 2**t_pow
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        r = _rand(ks[0], (1, T, h, hd), jnp.float32)
        k = _rand(ks[1], (1, T, h, hd), jnp.float32)
        v = _rand(ks[2], (1, T, h, hd), jnp.float32)
        w = (
            jax.nn.sigmoid(jax.random.normal(ks[3], (1, T, h, hd)))
            * (0.98 - w_lo)
            + w_lo
        )
        u = _rand(ks[4], (h, hd), jnp.float32) * 0.1
        out = ops.wkv6(r, k, v, w, u, chunk=16)
        expect = _wkv_expect(r, k, v, w, u)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=3e-3, atol=3e-3
        )

    def test_matches_model_reference(self):
        """Kernel agrees with the model-layer wkv_scan (repro.models.rwkv)."""
        from repro.models.rwkv import wkv_scan

        B, T, H, hd = 2, 32, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(9), 5)
        r = _rand(ks[0], (B, T, H, hd), jnp.float32)
        k = _rand(ks[1], (B, T, H, hd), jnp.float32)
        v = _rand(ks[2], (B, T, H, hd), jnp.float32)
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.3 + 0.69
        u = _rand(ks[4], (H, hd), jnp.float32) * 0.1
        out = ops.wkv6(r, k, v, w, u, chunk=8)
        state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        expect, _ = wkv_scan(r, k, v, w, u, state0)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=2e-3, atol=2e-3
        )

    def test_decay_zero_input_isolation(self):
        """With w ~ 1 and k = 0 everywhere except t0, out_t = (r_t . k0) v0."""
        B, T, H, hd = 1, 16, 1, 8
        r = _rand(jax.random.PRNGKey(30), (B, T, H, hd), jnp.float32)
        k = jnp.zeros((B, T, H, hd)).at[:, 0].set(1.0)
        v = jnp.zeros((B, T, H, hd)).at[:, 0].set(2.0)
        w = jnp.ones((B, T, H, hd)) * 0.9999
        u = jnp.zeros((H, hd))
        out = np.asarray(ops.wkv6(r, k, v, w, u, chunk=8))
        for t in range(1, T):
            expect = float(r[0, t, 0].sum()) * 2.0 * (0.9999 ** t)
            np.testing.assert_allclose(out[0, t, 0], expect, rtol=2e-2)
