"""Differential tests for the discrete-event serving simulator.

The DES (``repro.serving.des``) is the repo's ground truth for serving
latency (ROADMAP "DES is ground truth" invariant).  Three tiers of checks:

* *round-off exact*: with deterministic spaced arrivals and a single tenant
  the DES must equal the closed-form static latency (Eq. 4 without waits)
  to float round-off, and the DES must agree with the sequential stepper
  elementwise whenever both see the same FCFS order;
* *statistical*: seeded Poisson single-tenant waits must converge to the
  Pollaczek-Khinchine ``mg1_wait`` (slow-marked);
* *mechanical*: mid-flight plan changes bind routing at arrival, conserve
  requests, and never deadlock.
"""
import math

import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import latency, queueing
from repro.core.planner import Plan, TenantSpec, prefix_service_time
from repro.configs.paper_models import paper_profile
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.des import DiscreteEventSimulator
from repro.serving.simulator import RuntimeSimulator, make_backend, simulate
from repro.serving.workload import (
    Request,
    deterministic_trace,
    poisson_trace,
    with_service_jitter,
)

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


class TestBackendFactory:
    def test_known_backends(self):
        profs = [paper_profile("mnasnet")]
        plan = Plan((7,), (0,))
        assert isinstance(
            make_backend("stepper", profs, plan, HW), RuntimeSimulator
        )
        assert isinstance(
            make_backend("des", profs, plan, HW), DiscreteEventSimulator
        )

    def test_unknown_backend_raises(self):
        profs = [paper_profile("mnasnet")]
        with pytest.raises(ValueError):
            make_backend("quantum", profs, Plan((7,), (0,)), HW)


class TestDeterministicExact:
    """Spaced deterministic arrivals: zero queueing, warm cache -- every
    recorded latency must equal LatencyBreakdown.static to round-off."""

    def _assert_static_exact(self, name, plan, rate=0.05):
        ts = tenants_for((name, rate))
        # Gaps of 1/rate = 20 s dwarf any service time: no queueing at all.
        reqs = deterministic_trace([rate], 2000.0)
        res = simulate(ts, plan, HW, reqs, backend="des")
        static = latency.predict(ts, plan, HW).static_latencies[0]
        assert res.latencies[0], "trace produced no recorded requests"
        for lat in res.latencies[0]:
            assert lat == pytest.approx(static, rel=1e-9)
        if plan.partition[0] > 0:
            # Visited the TPU, never missed post-warmup (single tenant).
            assert res.tpu_requests[0] > 0
            assert res.observed_miss_rate(0) == 0.0
        else:
            # Full-CPU route: no TPU visits, so the miss rate is unknown
            # (nan), not a perfect 0.0 hit rate.
            assert res.tpu_requests[0] == 0
            assert math.isnan(res.observed_miss_rate(0))

    def test_full_tpu(self):
        self._assert_static_exact("inceptionv4", Plan((11,), (0,)))

    def test_partitioned(self):
        self._assert_static_exact("inceptionv4", Plan((9,), (4,)))

    def test_full_cpu(self):
        self._assert_static_exact("mnasnet", Plan((0,), (4,)))

    def test_multi_tenant_static_when_fits(self):
        # Two models that fit SRAM together, arrivals far apart: still the
        # zero-queueing closed form, per model.  Unequal-rate deterministic
        # streams can still collide for unlucky rate ratios, so the
        # zero-queueing premise (every gap dwarfs every service time) is
        # asserted explicitly.
        ts = tenants_for(("mobilenetv2", 0.05), ("squeezenet", 0.03))
        plan = Plan((5, 2), (0, 0))
        reqs = deterministic_trace([0.05, 0.03], 2000.0)
        gaps = [
            b.arrival - a.arrival for a, b in zip(reqs, reqs[1:])
        ]
        assert min(gaps) > 1.0
        res = simulate(ts, plan, HW, reqs, backend="des")
        pred = latency.predict(ts, plan, HW)
        for i in range(2):
            assert res.latencies[i]
            for lat in res.latencies[i]:
                assert lat == pytest.approx(pred.static_latencies[i], rel=1e-9)


def _by_arrival(res, model_idx):
    """(arrival, latency) pairs sorted by arrival: the DES records in
    completion order, the stepper in arrival order, and multi-core CPU
    suffixes with jittered service times legitimately complete out of
    order -- pairing by arrival stamp compares like with like."""
    return sorted(zip(res.arrivals[model_idx], res.latencies[model_idx]))


class TestDesMatchesStepper:
    """Where both backends see the same FCFS order they are two independent
    implementations of the same system and must agree elementwise."""

    def _assert_elementwise(self, des, step, model_idx=0):
        d, s = _by_arrival(des, model_idx), _by_arrival(step, model_idx)
        assert len(d) == len(s)
        for (at_d, a), (at_s, b) in zip(d, s):
            assert at_d == at_s
            assert a == pytest.approx(b, rel=1e-12, abs=1e-15)

    def test_single_tenant_poisson_elementwise(self):
        ts = tenants_for(("inceptionv4", 3.0))
        plan = Plan((11,), (0,))
        reqs = poisson_trace([3.0], 500.0, seed=1)
        des = simulate(ts, plan, HW, reqs, backend="des")
        step = simulate(ts, plan, HW, reqs, backend="stepper")
        self._assert_elementwise(des, step)
        assert des.tpu_busy == pytest.approx(step.tpu_busy, rel=1e-12)

    def test_single_tenant_partitioned_elementwise(self):
        ts = tenants_for(("inceptionv4", 2.0))
        plan = Plan((9,), (4,))
        reqs = poisson_trace([2.0], 500.0, seed=2)
        des = simulate(ts, plan, HW, reqs, backend="des")
        step = simulate(ts, plan, HW, reqs, backend="stepper")
        self._assert_elementwise(des, step)

    def test_single_tenant_jittered_elementwise(self):
        ts = tenants_for(("inceptionv4", 2.0))
        plan = Plan((9,), (4,))
        reqs = with_service_jitter(
            poisson_trace([2.0], 500.0, seed=3), sigma=0.8, seed=4
        )
        des = simulate(ts, plan, HW, reqs, backend="des")
        step = simulate(ts, plan, HW, reqs, backend="stepper")
        self._assert_elementwise(des, step)

    @given(seed=st.integers(0, 5))
    @settings(max_examples=6, deadline=None)
    def test_multi_tenant_statistical(self, seed):
        # Multi-tenant order can differ at ties, so compare statistics.
        ts = tenants_for(("efficientnet", 2.0), ("gpunet", 2.0))
        plan = Plan((6, 5), (0, 0))
        reqs = poisson_trace([2.0, 2.0], 1500.0, seed=seed)
        des = simulate(ts, plan, HW, reqs, backend="des")
        step = simulate(ts, plan, HW, reqs, backend="stepper")
        for i in range(2):
            assert des.mean_latency(i) == pytest.approx(
                step.mean_latency(i), rel=0.05
            )
            assert des.observed_miss_rate(i) == pytest.approx(
                step.observed_miss_rate(i), abs=0.05
            )


class TestDesVsAnalytic:
    """DES observations against Eq. 1-4 predictions (the in-silico
    analogue of the paper's Figs. 5-6 validation, on the independent
    backend)."""

    def test_mean_latency_tracks_prediction(self):
        ts = tenants_for(("inceptionv4", 3.0))
        plan = Plan((11,), (0,))
        reqs = poisson_trace([3.0], 4000.0, seed=5)
        res = simulate(ts, plan, HW, reqs, backend="des")
        pred = latency.predict(ts, plan, HW)
        assert res.mean_latency(0) == pytest.approx(pred.latencies[0], rel=0.12)

    def test_utilization_tracks_rho(self):
        ts = tenants_for(("inceptionv4", 3.0))
        plan = Plan((11,), (0,))
        reqs = poisson_trace([3.0], 4000.0, seed=6)
        res = simulate(ts, plan, HW, reqs, backend="des")
        pred = latency.predict(ts, plan, HW)
        assert res.tpu_utilization == pytest.approx(pred.tpu_utilization, rel=0.08)

    @pytest.mark.slow
    @pytest.mark.parametrize("rho", [0.5, 0.8])
    @pytest.mark.parametrize("seed", [0, 3])
    def test_poisson_wait_converges_to_mg1(self, rho, seed):
        """Acceptance: seeded Poisson DES mean wait within 5% of mg1_wait at
        utilization <= 0.8 (M/D/1: es2 = es^2 for the deterministic prefix)."""
        prof = paper_profile("inceptionv4")
        P = prof.num_partition_points
        s = prefix_service_time(prof, P, HW)
        lam = rho / s
        expected = queueing.mg1_wait(lam, s, s * s)
        ts = [TenantSpec(prof, lam)]
        reqs = poisson_trace([lam], 6000.0, seed=seed)
        res = simulate(ts, Plan((P,), (0,)), HW, reqs, backend="des")
        in_xfer = prof.input_bytes / HW.swap_bw
        waits = [l - in_xfer - s for l in res.latencies[0]]
        obs = sum(waits) / len(waits)
        assert obs == pytest.approx(expected, rel=0.05)
        # Cross-check the packaged per-term metrics helper.
        m = queueing.mg1_metrics(lam, s, s * s)
        assert m.wait == expected
        assert m.rho == pytest.approx(rho)


class TestMidFlightPlanChange:
    def test_routing_binds_at_arrival(self):
        # A backlog bound to the TPU keeps draining through the TPU after
        # the plan moves the tenant to full-CPU; only post-switch arrivals
        # skip the TPU stage.
        prof = paper_profile("mnasnet")
        des = DiscreteEventSimulator([prof], Plan((7,), (0,)), HW)
        for j in range(20):
            des.submit(Request(0, 0.001 * j))
        des.advance_to(0.02)  # mid-backlog
        des.set_plan(Plan((0,), (4,)), now=0.02)
        for j in range(10):
            des.submit(Request(0, 0.03 + 0.001 * j))
        des.drain()
        assert sum(len(l) for l in des.latencies) == 30
        # Every pre-switch request ran a TPU prefix; no post-switch one did.
        assert des.tpu_requests[0] == 20

    def test_grown_cpu_pool_admits_queued_work(self):
        # One core, a pile of suffix work queued; growing the pool to 4
        # must immediately start queued jobs (no deadlock, faster drain).
        prof = paper_profile("mnasnet")
        reqs = [Request(0, 0.0005 * j) for j in range(40)]

        des_static = DiscreteEventSimulator([prof], Plan((0,), (1,)), HW)
        for r in reqs:
            des_static.submit(r)
        t_static = des_static.drain()

        des_grow = DiscreteEventSimulator([prof], Plan((0,), (1,)), HW)
        for r in reqs:
            des_grow.submit(r)
        des_grow.advance_to(0.05)
        des_grow.set_plan(Plan((0,), (4,)), now=0.05)
        t_grow = des_grow.drain()

        assert sum(len(l) for l in des_grow.latencies) == 40
        assert t_grow < t_static

    def test_shrunk_pool_drains_bound_suffixes(self):
        # Bound CPU work survives a switch to a 0-core full-TPU plan: the
        # pool keeps one effective server until the backlog drains.
        prof = paper_profile("mnasnet")
        des = DiscreteEventSimulator([prof], Plan((0,), (4,)), HW)
        for j in range(20):
            des.submit(Request(0, 0.0005 * j))
        des.advance_to(0.02)
        des.set_plan(Plan((7,), (0,)), now=0.02)
        des.submit(Request(0, 0.05))
        des.drain()
        assert sum(len(l) for l in des.latencies) == 21

    def test_conservation_under_random_replans(self):
        profs = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        plans = [
            Plan((7, 11), (0, 0)),
            Plan((0, 11), (4, 0)),
            Plan((5, 9), (2, 2)),
            Plan((7, 0), (0, 4)),
        ]
        reqs = poisson_trace([4.0, 2.0], 60.0, seed=7)
        des = DiscreteEventSimulator(profs, plans[0], HW)
        switch_every = 10.0
        next_switch, pi = switch_every, 1
        for r in reqs:
            while r.arrival >= next_switch:
                des.advance_to(next_switch)
                des.set_plan(plans[pi % len(plans)], now=next_switch)
                pi += 1
                next_switch += switch_every
            des.offer(r)
        des.drain()
        assert sum(len(l) for l in des.latencies) == len(reqs)
        assert all(l >= 0.0 for ls in des.latencies for l in ls)


class TestDesGuards:
    def test_submit_in_past_raises(self):
        des = DiscreteEventSimulator(
            [paper_profile("mnasnet")], Plan((7,), (0,)), HW
        )
        des.advance_to(10.0)
        with pytest.raises(ValueError):
            des.submit(Request(0, 5.0))

    def test_clock_rewind_raises(self):
        des = DiscreteEventSimulator(
            [paper_profile("mnasnet")], Plan((7,), (0,)), HW
        )
        des.advance_to(10.0)
        with pytest.raises(ValueError):
            des.advance_to(5.0)

    def test_bad_model_idx_raises(self):
        des = DiscreteEventSimulator(
            [paper_profile("mnasnet")], Plan((7,), (0,)), HW
        )
        with pytest.raises(ValueError):
            des.submit(Request(3, 0.0))

    def test_plan_size_mismatch_raises(self):
        des = DiscreteEventSimulator(
            [paper_profile("mnasnet")], Plan((7,), (0,)), HW
        )
        with pytest.raises(ValueError):
            des.set_plan(Plan((7, 7), (0, 0)), now=0.0)


class TestServiceJitter:
    def test_jitter_inflates_wait_beyond_deterministic_model(self):
        # Mean-1 lognormal jitter keeps utilization but grows E[S^2]: the
        # observed wait must exceed the deterministic-service prediction.
        prof = paper_profile("inceptionv4")
        P = prof.num_partition_points
        s = prefix_service_time(prof, P, HW)
        lam = 0.7 / s
        base = poisson_trace([lam], 3000.0, seed=8)
        jittered = with_service_jitter(base, sigma=1.0, seed=9)
        ts = [TenantSpec(prof, lam)]
        plain = simulate(ts, Plan((P,), (0,)), HW, base, backend="des")
        noisy = simulate(ts, Plan((P,), (0,)), HW, jittered, backend="des")
        # Utilization is mean-preserved (within sampling noise)...
        assert noisy.tpu_utilization == pytest.approx(
            plain.tpu_utilization, rel=0.1
        )
        # ...but congestion is not: heavy-tailed service queues much harder.
        assert noisy.mean_latency(0) > 1.15 * plain.mean_latency(0)


class TestDesUtilization:
    @given(seed=st.integers(0, 4), rate=st.floats(5.0, 80.0))
    @settings(max_examples=8, deadline=None)
    def test_utilization_bounded_any_load(self, seed, rate):
        ts = tenants_for(("xception", rate))
        plan = Plan((11,), (0,))
        reqs = poisson_trace([rate], 30.0, seed=seed)
        res = simulate(ts, plan, HW, reqs, backend="des")
        assert 0.0 <= res.tpu_utilization <= 1.0
        assert res.duration >= max(r.arrival for r in reqs)
