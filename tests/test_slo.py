"""Tests for the pluggable objective layer (PR 10): mean / p_tail /
deadline_miss across the scalar, batched, delta, JAX, fleet, cache, and
controller paths.

The load-bearing contract throughout: objectives are opt-in, and
``objective=None`` is bitwise the pre-refactor Eq. 5 mean on every layer
(ROADMAP standing invariant "objectives are opt-in; mean stays pinned").
"""
import math

import numpy as np
import pytest

from repro.configs.paper_models import paper_profile
from repro.core import latency, queueing
from repro.core.allocator import hill_climb
from repro.core.fleet import DeviceSpec, fleet_hill_climb, fleet_plan_objective
from repro.core.objective import (
    MEAN,
    Objective,
    deadline_miss,
    deadlines_of,
    is_default,
    objective_key,
    p_tail,
)
from repro.core.plan_cache import FleetPlanCache, PlanCache
from repro.core.plan_tables import EvalTables
from repro.core.planner import Plan, TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.controller import run_adaptive
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace
from tests._hypothesis_compat import given, settings, st

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores

MODELS = ("inceptionv4", "squeezenet", "mobilenetv2")


def _tenants(rates=(0.3, 5.0, 3.75), deadlines=(0.25, 0.10, None)):
    return [
        TenantSpec(paper_profile(m), r, deadline=d)
        for m, r, d in zip(MODELS, rates, deadlines)
    ]


def _random_plans(ts, n_plans, seed):
    rng = np.random.default_rng(seed)
    npts = np.asarray([t.profile.num_partition_points for t in ts])
    P = np.stack(
        [rng.integers(0, npts + 1) for _ in range(n_plans)]
    ).astype(np.intp)
    K = rng.integers(0, K_MAX + 1, size=(n_plans, len(ts))).astype(np.intp)
    return P, K


OBJECTIVES = [None, MEAN, p_tail(0.99), p_tail(0.9), deadline_miss()]


class TestObjectiveSpec:
    def test_kind_validation(self):
        with pytest.raises(ValueError, match="unknown objective kind"):
            Objective("p50")
        with pytest.raises(ValueError, match="quantile"):
            p_tail(1.0)
        with pytest.raises(ValueError, match="quantile"):
            p_tail(0.0)

    def test_is_default(self):
        assert is_default(None) and is_default(MEAN)
        assert not is_default(p_tail(0.99))
        assert not is_default(deadline_miss())

    def test_deadlines_of(self):
        d = deadlines_of(_tenants())
        assert d[0] == 0.25 and d[1] == 0.10 and math.isinf(d[2])

    def test_objective_key_identity(self):
        ts = _tenants()
        assert objective_key(None, ts) is None
        assert objective_key(MEAN, ts) is None
        assert objective_key(p_tail(0.99), ts) == ("p_tail", 0.99)
        assert objective_key(p_tail(0.99), ts) != objective_key(
            p_tail(0.9), ts
        )
        k1 = objective_key(deadline_miss(), ts)
        k2 = objective_key(
            deadline_miss(), _tenants(deadlines=(0.5, 0.10, None))
        )
        # The deadline vector must enter the key: mixes differing only in
        # budgets must not collide.
        assert k1 != k2
        assert k1 != objective_key(p_tail(0.99), ts)


class TestTailFunctions:
    @settings(max_examples=20)
    @given(
        wq=st.floats(min_value=1e-4, max_value=10.0),
        rho=st.floats(min_value=0.01, max_value=0.99),
        t=st.floats(min_value=0.0, max_value=50.0),
    )
    def test_exceed_prob_in_unit_interval(self, wq, rho, t):
        p = queueing.wait_exceed_prob(wq, rho, t)
        assert 0.0 <= p <= 1.0
        # Monotone non-increasing in t.
        assert queueing.wait_exceed_prob(wq, rho, t + 1.0) <= p + 1e-15

    def test_exceed_prob_conventions(self):
        assert queueing.wait_exceed_prob(1.0, 0.0, 1.0) == 0.0
        assert queueing.wait_exceed_prob(1.0, 1.0, 1.0) == 1.0
        assert queueing.wait_exceed_prob(math.inf, 0.5, 1.0) == 1.0
        assert queueing.wait_exceed_prob(0.0, 0.5, 1.0) == 0.0

    @settings(max_examples=20)
    @given(
        wq=st.floats(min_value=1e-4, max_value=10.0),
        rho=st.floats(min_value=0.01, max_value=0.99),
    )
    def test_quantile_inverts_exceed(self, wq, rho):
        q = 0.99
        t = queueing.wait_tail_quantile(wq, rho, q)
        if t > 0.0:
            p = queueing.wait_exceed_prob(wq, rho, t)
            assert p == pytest.approx(1.0 - q, rel=1e-9)
        else:
            # Mass at zero already covers the quantile.
            assert rho <= 1.0 - q + 1e-12

    def test_quantile_conventions(self):
        assert queueing.wait_tail_quantile(1.0, 1.0, 0.99) == math.inf
        assert queueing.wait_tail_quantile(1.0, 0.0, 0.99) == 0.0
        # Below the atom at zero: quantile is 0.
        assert queueing.wait_tail_quantile(1.0, 0.005, 0.99) == 0.0


class TestBatchMatchesScalar:
    @pytest.mark.parametrize("objective", OBJECTIVES)
    def test_batch_matches_scalar(self, objective):
        ts = _tenants()
        P, K = _random_plans(ts, 24, seed=5)
        et = EvalTables.build(ts, HW, K_MAX)
        got = latency.penalized_objective_batch(
            ts, P, K, HW, tables=et, objective=objective
        )
        for b in range(P.shape[0]):
            plan = Plan(tuple(int(x) for x in P[b]), tuple(int(x) for x in K[b]))
            ref = latency.penalized_objective(
                ts, plan, HW, objective=objective
            )
            assert got[b] == pytest.approx(ref, rel=1e-9, abs=1e-12), (
                f"objective={objective} plan={plan}"
            )

    @pytest.mark.parametrize("objective", OBJECTIVES)
    def test_delta_matches_full_batch(self, objective):
        ts = _tenants()
        base, _ = hill_climb(ts, HW, K_MAX)
        P, K = _random_plans(ts, 24, seed=6)
        et = EvalTables.build(ts, HW, K_MAX)
        full = latency.penalized_objective_batch(
            ts, P, K, HW, tables=et, objective=objective
        )
        delta = latency.penalized_objective_delta_batch(
            ts,
            np.asarray(base.partition, dtype=np.intp),
            np.asarray(base.cores, dtype=np.intp),
            P,
            K,
            HW,
            tables=et,
            objective=objective,
        )
        np.testing.assert_allclose(delta, full, rtol=1e-9)

    def test_default_is_bitwise(self):
        ts = _tenants()
        P, K = _random_plans(ts, 24, seed=7)
        et = EvalTables.build(ts, HW, K_MAX)
        ref = latency.penalized_objective_batch(ts, P, K, HW, tables=et)
        for o in (None, MEAN):
            got = latency.penalized_objective_batch(
                ts, P, K, HW, tables=et, objective=o
            )
            assert np.array_equal(ref, got)


class TestJaxPlanIdentity:
    @pytest.mark.parametrize(
        "objective", [p_tail(0.99), p_tail(0.9), deadline_miss()]
    )
    def test_hill_climb_plans_identical(self, objective):
        ts = _tenants()
        et = EvalTables.build(ts, HW, K_MAX)
        ev = et.to_jax()
        p_ref, o_ref = hill_climb(
            ts, HW, K_MAX, tables=et, batch=True, objective=objective
        )
        p_jax, o_jax = hill_climb(
            ts, HW, K_MAX, evaluator=ev, objective=objective
        )
        assert p_ref == p_jax
        assert o_jax == pytest.approx(o_ref, rel=1e-4)

    def test_jax_default_bitwise(self):
        ts = _tenants()
        et = EvalTables.build(ts, HW, K_MAX)
        ev = et.to_jax()
        P, K = _random_plans(ts, 16, seed=8)
        ref = ev.penalized_objective_batch(P, K)
        got = ev.penalized_objective_batch(P, K, objective=None)
        assert np.array_equal(ref, got)


class TestDeadlineMiss:
    @settings(max_examples=15)
    @given(
        d0=st.floats(min_value=0.01, max_value=1.0),
        bump=st.floats(min_value=0.0, max_value=1.0),
    )
    def test_miss_prob_monotone_in_budget(self, d0, bump):
        ts = _tenants(deadlines=(None, None, None))
        plan, _ = hill_climb(ts, HW, K_MAX)
        lo = latency.predict_miss_probs(
            ts, plan, HW, np.array([d0, d0, d0])
        )
        hi = latency.predict_miss_probs(
            ts, plan, HW, np.array([d0 + bump, d0 + bump, d0 + bump])
        )
        assert np.all(hi <= lo + 1e-12)
        assert np.all((lo >= 0.0) & (lo <= 1.0))

    def test_no_deadline_never_misses(self):
        ts = _tenants(deadlines=(None, None, None))
        plan, _ = hill_climb(ts, HW, K_MAX)
        probs = latency.predict_miss_probs(ts, plan, HW)
        np.testing.assert_array_equal(probs, np.zeros(len(ts)))
        assert latency.penalized_objective(
            ts, plan, HW, objective=deadline_miss()
        ) == pytest.approx(0.0)

    def test_static_over_budget_misses_surely(self):
        ts = _tenants(deadlines=(1e-9, 1e-9, 1e-9))
        plan, _ = hill_climb(ts, HW, K_MAX)
        probs = latency.predict_miss_probs(ts, plan, HW)
        np.testing.assert_array_equal(probs, np.ones(len(ts)))

    def test_tail_latencies_dominate_means(self):
        ts = _tenants()
        plan, _ = hill_climb(ts, HW, K_MAX)
        pred = latency.predict(ts, plan, HW)
        tails = latency.predict_tail_latencies(ts, plan, HW, 0.99)
        # q=0.99 quantile latency can never fall below the static floor
        # and is >= the q=0.5 quantile.
        mid = latency.predict_tail_latencies(ts, plan, HW, 0.5)
        assert np.all(tails >= mid - 1e-12)
        statics = np.array([b.static for b in pred.per_model])
        assert np.all(tails >= statics - 1e-12)


class TestPlannerPins:
    def test_hill_climb_default_bitwise(self):
        ts = _tenants()
        p_ref, o_ref = hill_climb(ts, HW, K_MAX)
        for o in (None, MEAN):
            p_got, o_got = hill_climb(ts, HW, K_MAX, objective=o)
            assert p_got == p_ref and o_got == o_ref

    def test_slo_objectives_change_search_metric(self):
        ts = _tenants()
        for o in (p_tail(0.99), deadline_miss()):
            plan, value = hill_climb(ts, HW, K_MAX, objective=o)
            # The returned value is the SLO metric of the returned plan.
            assert value == pytest.approx(
                latency.penalized_objective(ts, plan, HW, objective=o),
                rel=1e-9,
            )

    def test_fleet_degenerate_matches_single_device(self):
        ts = _tenants()
        fleet = [DeviceSpec.from_platform(HW, name="d0")]
        for o in (None, p_tail(0.99), deadline_miss()):
            fp, fo = fleet_hill_climb(ts, fleet, objective=o)
            sp, so = hill_climb(
                ts,
                HW,
                K_MAX,
                tables=EvalTables.build(ts, HW, K_MAX),
                batch=True,
                objective=o,
            )
            assert fp.device_plans[0].partition == sp.partition
            assert fp.device_plans[0].cores == sp.cores
            assert fo == pytest.approx(so, rel=1e-9)
            rescored = fleet_plan_objective(ts, fp, fleet, objective=o)
            assert rescored == pytest.approx(fo, rel=1e-9)


class TestCacheKeys:
    def test_default_keyspace_pinned(self):
        ts = _tenants()
        cache = PlanCache()
        assert cache._key(ts, HW, K_MAX, None) == cache._key(
            ts, HW, K_MAX, None, objective=None
        )
        assert len(cache._key(ts, HW, K_MAX, None)) == 5

    def test_objective_enters_key(self):
        ts = _tenants()
        cache = PlanCache()
        base = cache._key(ts, HW, K_MAX, None)
        kt = cache._key(ts, HW, K_MAX, None, objective=p_tail(0.99))
        kd = cache._key(ts, HW, K_MAX, None, objective=deadline_miss())
        assert kt != base and kd != base and kt != kd
        assert kt[:5] == base and kd[:5] == base

    def test_no_cross_objective_hits(self):
        ts = _tenants()
        cache = PlanCache()
        plan, obj = hill_climb(ts, HW, K_MAX)
        cache.store(ts, HW, K_MAX, plan, obj)
        assert cache.lookup(ts, HW, K_MAX) is not None
        # A tail-objective lookup must not reuse the mean-keyed entry:
        # verify-then-reuse would silently compare different metrics.
        assert cache.lookup(ts, HW, K_MAX, objective=p_tail(0.99)) is None
        o = p_tail(0.99)
        plan_t, obj_t = hill_climb(ts, HW, K_MAX, objective=o)
        cache.store(ts, HW, K_MAX, plan_t, obj_t, objective=o)
        hit = cache.lookup(ts, HW, K_MAX, objective=o)
        assert hit is not None and hit[0] == plan_t

    def test_fleet_cache_objective_keyed(self):
        ts = _tenants()
        fleet = [DeviceSpec.from_platform(HW, name="d0")]
        cache = FleetPlanCache()
        fp, fo = fleet_hill_climb(ts, fleet)
        cache.store(ts, fleet, fp, fo)
        assert cache.lookup(ts, fleet) is not None
        assert cache.lookup(ts, fleet, objective=deadline_miss()) is None


class TestControllerPins:
    def _run(self, **kw):
        ts = _tenants()
        profs = [t.profile for t in ts]
        rates = [t.rate for t in ts]
        trace = poisson_trace(rates, 120.0, seed=11)
        return run_adaptive(
            profs,
            trace,
            HW,
            K_MAX,
            replan_period=30.0,
            window=30.0,
            initial_rates=rates,
            **kw,
        )

    def test_explicit_none_bitwise(self):
        ref = self._run()
        got = self._run(objective=None, rate_margin=None, deadlines=None)
        assert got.plans == ref.plans
        assert got.replan_times == ref.replan_times
        for i in range(len(MODELS)):
            assert np.array_equal(
                np.asarray(ref.sim.latencies[i]),
                np.asarray(got.sim.latencies[i]),
            )

    def test_slo_objective_accepted(self):
        got = self._run(
            objective=p_tail(0.99), deadlines=[0.25, 0.10, None]
        )
        assert got.plans  # committed at least the initial plan

    def test_rate_margin_plans_for_inflated_rates(self):
        ref = self._run()
        got = self._run(rate_margin=0.5)
        assert got.plans  # runs; plans may legitimately differ
        with pytest.raises(ValueError, match="rate_margin"):
            self._run(rate_margin=-0.1)

    def test_deadline_validation(self):
        with pytest.raises(ValueError, match="deadlines"):
            self._run(deadlines=[0.25, 0.10])


class TestSimObservables:
    def _sim(self):
        ts = _tenants()
        plan, _ = hill_climb(ts, HW, K_MAX)
        trace = poisson_trace([t.rate for t in ts], 200.0, seed=13)
        return simulate(ts, plan, HW, trace, backend="des")

    def test_per_model_p99(self):
        sim = self._sim()
        p99s = sim.per_model_p99()
        assert len(p99s) == len(MODELS)
        for i, p in enumerate(p99s):
            assert p == sim.p99(i)

    def test_deadline_miss_observables(self):
        sim = self._sim()
        dls = [0.25, 0.10, None]
        misses = sim.deadline_misses(dls)
        rates = sim.per_model_deadline_miss_rate(dls)
        assert misses[2] == 0  # no deadline -> never a miss
        for i in (0, 1):
            expect = sum(
                1 for x in sim.latencies[i] if float(x) > dls[i]
            )
            assert misses[i] == expect
            assert rates[i] == pytest.approx(
                expect / len(sim.latencies[i])
            )
        pooled = sim.deadline_miss_rate(dls)
        n0, n1 = len(sim.latencies[0]), len(sim.latencies[1])
        assert pooled == pytest.approx(
            (misses[0] + misses[1]) / (n0 + n1)
        )
        with pytest.raises(ValueError):
            sim.deadline_misses([0.1])

    def test_miss_rate_monotone_in_budget(self):
        sim = self._sim()
        loose = sim.deadline_miss_rate([0.5, 0.5, 0.5])
        tight = sim.deadline_miss_rate([0.05, 0.05, 0.05])
        assert loose <= tight
