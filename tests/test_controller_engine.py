"""Tests for the online controller (adaptive re-planning) and the
real-execution serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.allocator import edge_tpu_compiler_plan, hill_climb
from repro.core.planner import Plan, TenantSpec
from repro.configs.paper_models import paper_profile
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.controller import (
    SlidingRateEstimator,
    _should_cold_fallback,
    run_adaptive,
)
from repro.serving.engine import ExecutableModel, ServingEngine
from repro.serving.simulator import simulate
from repro.serving.workload import (
    RatePhase,
    Trace,
    dynamic_trace,
    poisson_trace,
)

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores


class TestRateEstimator:
    def test_basic_rate(self):
        est = SlidingRateEstimator(1, window=10.0)
        for t in np.arange(0.0, 10.0, 0.5):
            est.observe(0, float(t))
        assert est.rates(10.0)[0] == pytest.approx(2.0)

    def test_window_expiry(self):
        est = SlidingRateEstimator(1, window=5.0)
        est.observe(0, 0.0)
        est.observe(0, 8.0)
        assert est.rates(10.0)[0] == pytest.approx(1 / 5.0)

    def test_partial_window_divides_by_elapsed_time(self):
        # 3 arrivals in the first second with a 30 s window: lambda-hat is
        # 3/s, not 0.1/s (the pre-fix bug divided by the full window before
        # one window had elapsed).
        est = SlidingRateEstimator(1, window=30.0)
        for t in (0.1, 0.5, 0.9):
            est.observe(0, t)
        assert est.rates(1.0)[0] == pytest.approx(3.0)

    def test_full_window_unchanged(self):
        est = SlidingRateEstimator(1, window=10.0)
        for t in np.arange(0.0, 40.0, 0.5):
            est.observe(0, float(t))
        assert est.rates(40.0)[0] == pytest.approx(2.0)

    def test_time_zero_no_division_by_zero(self):
        est = SlidingRateEstimator(2, window=30.0)
        est.observe(0, 0.0)
        assert est.rates(0.0) == [0.0, 0.0]

    def test_backdated_probe_is_monotone_safe(self):
        # rates(t1) evicts stamps older than t1 - window; a later probe at
        # t0 < t1 used to answer from the already-evicted window (an
        # eviction-order-dependent estimate).  The clock now clamps to its
        # high-water mark: the backdated probe answers at t1, and probing
        # forward again is unchanged.
        est = SlidingRateEstimator(1, window=10.0)
        for t in (1.0, 2.0, 14.0, 15.0):
            est.observe(0, t)
        at_t1 = est.rates(16.0)  # evicts the 1.0/2.0 stamps
        assert at_t1[0] == pytest.approx(2 / 10.0)
        assert est.rates(8.0) == at_t1  # backdated probe: clamped, stable
        assert est.rates(16.0) == at_t1

    def test_boundary_stamp_is_idempotent(self):
        # A stamp sitting exactly on the window edge (dq[0] == now - window)
        # is kept by the strict < eviction; repeated evaluation at the same
        # instant must count it every time, not evict it on the first pass
        # and lose it on the second.
        est = SlidingRateEstimator(1, window=10.0)
        est.observe(0, 5.0)
        est.observe(0, 12.0)
        first = est.rates(15.0)  # 5.0 == 15.0 - 10.0: on the boundary
        assert first[0] == pytest.approx(2 / 10.0)
        assert est.rates(15.0) == first
        assert est.rates(15.0) == first

    # -- exponential-decay weighting (opt-in, PR 8) --

    def test_decay_requires_positive(self):
        with pytest.raises(ValueError):
            SlidingRateEstimator(1, window=10.0, decay=0.0)
        with pytest.raises(ValueError):
            SlidingRateEstimator(1, window=10.0, decay=-1.0)

    def test_decay_matches_closed_form(self):
        # Pins the estimator's exact semantics: each stamp at age ``a``
        # weighs exp(-a/tau) and the normalizer is the kernel's integral
        # over the observed horizon, tau * (1 - exp(-horizon/tau)).
        tau, now, window = 5.0, 10.0, 30.0
        stamps = (1.0, 2.0, 3.0, 7.5)
        est = SlidingRateEstimator(1, window=window, decay=tau)
        for t in stamps:
            est.observe(0, t)
        horizon = min(window, now)
        expected = sum(np.exp((t - now) / tau) for t in stamps) / (
            tau * (1.0 - np.exp(-horizon / tau))
        )
        assert est.rates(now)[0] == pytest.approx(expected)

    def test_decay_unbiased_for_stationary_arrivals(self):
        # A steady 2/s stream over a full window estimates ~2/s regardless
        # of tau (the normalizer makes the weighted count unbiased).
        for tau in (3.0, 10.0, 100.0):
            est = SlidingRateEstimator(1, window=30.0, decay=tau)
            for t in np.arange(0.0, 30.0, 0.5):
                est.observe(0, float(t))
            assert est.rates(30.0)[0] == pytest.approx(2.0, rel=0.1)

    def test_decay_steps_down_faster_than_uniform(self):
        # Regression (the burst-decay bias): after a 10/s burst ends and
        # traffic settles at 1/s, the uniform window stays inflated until
        # the burst stamps age out, while the decayed estimate has already
        # relaxed close to the true post-step rate.
        def feed(est):
            for t in np.arange(0.0, 10.0, 0.1):  # 10/s burst in [0, 10)
                est.observe(0, float(t))
            for t in np.arange(10.0, 30.0, 1.0):  # 1/s tail in [10, 30)
                est.observe(0, float(t))
            return est.rates(30.0)[0]

        plain = feed(SlidingRateEstimator(1, window=30.0))
        decayed = feed(SlidingRateEstimator(1, window=30.0, decay=5.0))
        assert plain == pytest.approx(120 / 30.0)  # still burst-inflated
        assert decayed < plain
        assert abs(decayed - 1.0) < abs(plain - 1.0)
        assert decayed == pytest.approx(1.0, rel=0.5)

    def test_decay_none_is_bitwise_default(self):
        a = SlidingRateEstimator(1, window=10.0)
        b = SlidingRateEstimator(1, window=10.0, decay=None)
        for t in (0.5, 1.0, 4.0, 9.0):
            a.observe(0, t)
            b.observe(0, t)
        assert a.rates(9.5) == b.rates(9.5)


class TestAdaptiveController:
    def test_adapts_and_beats_static_full_tpu(self):
        # MnasNet + InceptionV4 with rate step-ups, as in Fig. 8.
        profiles = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        phases = [
            RatePhase(0.0, 300.0, (5.0, 1.0)),
            RatePhase(300.0, 600.0, (5.0, 3.0)),
            RatePhase(600.0, 900.0, (5.0, 5.0)),
        ]
        trace = dynamic_trace(phases, seed=0)
        res = run_adaptive(
            profiles,
            trace,
            HW,
            K_MAX,
            replan_period=30.0,
            window=30.0,
            initial_rates=(5.0, 1.0),
        )
        assert len(res.plans) > 1
        # Planner stays cheap (paper: <2ms; allow slack for CI noise).
        assert max(res.plan_compute_seconds) < 0.05
        # Compare with the static default-compiler plan on the same trace.
        tenants = [TenantSpec(p, 3.0) for p in profiles]
        static = simulate(tenants, edge_tpu_compiler_plan(tenants), HW, trace)
        assert res.sim.overall_mean() < static.overall_mean()

    def test_replans_on_schedule(self):
        profiles = [paper_profile("mnasnet")]
        phases = [RatePhase(0.0, 120.0, (2.0,))]
        trace = dynamic_trace(phases, seed=1)
        res = run_adaptive(
            profiles, trace, HW, K_MAX, replan_period=30.0, initial_rates=(2.0,)
        )
        assert len(res.replan_times) >= 3

    def test_warmup_frac_excludes_leading_requests(self):
        profiles = [paper_profile("mnasnet")]
        phases = [RatePhase(0.0, 120.0, (2.0,))]
        trace = dynamic_trace(phases, seed=2)
        full = run_adaptive(
            profiles, trace, HW, K_MAX, initial_rates=(2.0,), warmup_frac=0.0
        )
        trimmed = run_adaptive(
            profiles, trace, HW, K_MAX, initial_rates=(2.0,), warmup_frac=0.5
        )
        n_full = len(full.sim.latencies[0])
        n_trim = len(trimmed.sim.latencies[0])
        assert n_full == len(trace)
        assert 0 < n_trim < n_full
        # Only requests arriving past the warmup horizon are recorded.
        horizon = max(r.arrival for r in trace)
        assert min(trimmed.sim.arrivals[0]) >= 0.5 * horizon

    def test_replan_tick_tie_timestamp_determinism(self):
        # Regression pin: an arrival landing *exactly* on a re-plan tick
        # must be observed on a fixed side of the plan switch in both
        # drivers.  Both resolve the boundary with a strict `<` cut
        # (scalar: `fire_due_replans` fires before any arrival with
        # `t >= next_replan` is observed; columnar: `searchsorted(...,
        # side="left")` ends the span before the tying arrival), so the
        # tying request is always served under the NEW plan and counted
        # toward the NEW window.  Identical plans and a bitwise-identical
        # SimResult across the two paths is the contract.
        profiles = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        rng = np.random.default_rng(7)
        n = 400
        arr = np.sort(rng.uniform(0.0, 120.0, n))
        # Plant exact tie timestamps on the 30s re-plan grid.  Replacing
        # the first arrival at or after each tick keeps the column sorted.
        for tick in (30.0, 60.0, 90.0):
            arr[np.searchsorted(arr, tick)] = tick
        mi = rng.integers(0, 2, n)
        trace = Trace(mi, arr)
        assert {30.0, 60.0, 90.0} <= set(arr.tolist())

        common = dict(
            replan_period=30.0, window=30.0, initial_rates=(2.0, 2.0)
        )
        col = run_adaptive(profiles, trace, HW, K_MAX, vectorize=True,
                           **common)
        seq = run_adaptive(profiles, trace, HW, K_MAX, vectorize=False,
                           **common)

        assert col.replan_times == seq.replan_times
        assert col.plans == seq.plans
        assert len(col.plans) > 1  # the ticks actually re-planned
        # Bitwise-identical observations: the columnar driver hands the
        # estimator and simulator the same requests on the same side of
        # every boundary as the scalar loop.  Sole documented exception
        # (run_trace docstring, test_sim_fastpath.assert_bitwise_equal):
        # the aggregate ``tpu_busy`` sums pairwise instead of
        # sequentially, equal to round-off only.
        assert col.sim.tpu_busy == pytest.approx(seq.sim.tpu_busy,
                                                 rel=1e-12)
        assert col.sim.duration == seq.sim.duration
        assert col.sim.misses == seq.sim.misses
        assert col.sim.tpu_requests == seq.sim.tpu_requests
        for m in range(len(profiles)):
            np.testing.assert_array_equal(
                np.asarray(col.sim.latencies[m]),
                np.asarray(seq.sim.latencies[m]))
            np.testing.assert_array_equal(
                np.asarray(col.sim.arrivals[m]),
                np.asarray(seq.sim.arrivals[m]))

    def test_adaptive_utilization_never_exceeds_one(self):
        # Overload phase: the backlog drains past the last arrival; the
        # duration fix keeps observed utilization physical.
        profiles = [paper_profile("inceptionv4")]
        phases = [RatePhase(0.0, 60.0, (60.0,))]
        trace = dynamic_trace(phases, seed=3)
        res = run_adaptive(profiles, trace, HW, K_MAX, initial_rates=(60.0,))
        assert res.sim.tpu_utilization <= 1.0
        assert res.sim.duration >= max(r.arrival for r in trace)

    def test_replans_warm_start_from_incumbent(self):
        # The controller passes the incumbent plan to warm-capable planners.
        profiles = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        seen: list[Plan | None] = []

        def spy_planner(tenants, platform, k_max, *, tables=None, init_plan=None):
            seen.append(init_plan)
            return hill_climb(
                tenants, platform, k_max, tables=tables, init_plan=init_plan
            )

        phases = [RatePhase(0.0, 120.0, (5.0, 1.0))]
        trace = dynamic_trace(phases, seed=4)
        res = run_adaptive(
            profiles,
            trace,
            HW,
            K_MAX,
            replan_period=30.0,
            initial_rates=(5.0, 1.0),
            planner=spy_planner,
            # Guard off: a fallback would add cold planner invocations and
            # this test pins the *warm-start threading* one-call-per-replan
            # contract (the guard has its own tests below).
            cold_fallback_margin=None,
        )
        assert seen[0] is None                      # cold initial plan
        assert len(seen) == len(res.plans)
        assert all(p is not None for p in seen[1:])  # re-plans warm-started
        for incumbent, prev in zip(seen[1:], res.plans):
            assert incumbent == prev


# The warm-start quality tail (ROADMAP): cold-planning this mix at DRIFT_R0,
# then warm-descending after the rates drift to DRIFT_R1, lands in a basin
# >5% worse than a cold re-climb.  Found by random search over paper-model
# mixes; robust to +-5% rate perturbation.
DRIFT_MODELS = ("densenet201", "mobilenetv2", "squeezenet")
DRIFT_R0 = (2.2, 1.0, 3.2)
DRIFT_R1 = (11.4, 1.3, 2.9)


class TestColdFallbackGuard:
    def test_warm_tail_reproduction(self):
        # Regression for the quality tail itself: warm descent from the
        # stale incumbent lands >5% worse than the cold climb.
        profs = [paper_profile(n) for n in DRIFT_MODELS]
        t0 = [TenantSpec(p, r) for p, r in zip(profs, DRIFT_R0)]
        t1 = [TenantSpec(p, r) for p, r in zip(profs, DRIFT_R1)]
        plan0, obj0 = hill_climb(t0, HW, K_MAX)
        _, warm = hill_climb(t1, HW, K_MAX, init_plan=plan0)
        _, cold = hill_climb(t1, HW, K_MAX)
        assert warm > 1.05 * cold
        # The guard detects the regression from the incumbent's trend and
        # taking the better of warm/cold recovers the cold optimum.
        norm_hist = [obj0 / sum(DRIFT_R0)]
        assert _should_cold_fallback(warm / sum(DRIFT_R1), norm_hist, 0.05)
        assert min(warm, cold) == cold

    def test_should_cold_fallback_edge_cases(self):
        assert not _should_cold_fallback(10.0, [], 0.05)      # no trend yet
        assert not _should_cold_fallback(1.04, [1.0], 0.05)   # within margin
        assert _should_cold_fallback(1.06, [1.0], 0.05)
        # The trend is the *median* of the recent re-plans: one lucky low
        # estimate must not make ordinary noise look like a regression.
        assert not _should_cold_fallback(1.2, [2.0, 1.0, 1.5], 0.05)
        assert _should_cold_fallback(1.6, [2.0, 1.0, 1.5], 0.05)

    def test_run_adaptive_guard_recovers_drift_regression(self):
        # Integration: the trace runs at the drifted rates while the initial
        # plan is the stale cold plan for the old rates; every re-plan's
        # warm descent lands in the bad basin and the guard's cold fallback
        # recovers >5% of predicted objective (deterministic: seeded trace,
        # deterministic planner).
        profs = [paper_profile(n) for n in DRIFT_MODELS]
        trace = poisson_trace(list(DRIFT_R1), 100.0, seed=3)
        common = dict(
            replan_period=30.0, window=30.0, initial_rates=DRIFT_R0
        )
        guarded = run_adaptive(
            profs, trace, HW, K_MAX, cold_fallback_margin=0.05, **common
        )
        plain = run_adaptive(
            profs, trace, HW, K_MAX, cold_fallback_margin=None, **common
        )
        assert guarded.cold_fallback_times == [30.0, 60.0, 90.0]
        assert not plain.cold_fallback_times
        # Identical rate estimates in both runs (the estimator only sees the
        # trace), so per-replan objectives are directly comparable.
        assert len(guarded.plan_objectives) == len(plain.plan_objectives)
        for g, p in zip(guarded.plan_objectives[1:], plain.plan_objectives[1:]):
            assert g <= p * (1 + 1e-12)
        best_recovery = max(
            (p - g) / p
            for g, p in zip(guarded.plan_objectives[1:], plain.plan_objectives[1:])
        )
        assert best_recovery > 0.05

    def test_guard_quiet_on_stationary_load(self):
        # No drift: warm re-plans track the incumbent trend (the median of
        # recent normalized objectives) and a margin above the estimator
        # noise keeps the guard silent.
        profiles = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        phases = [RatePhase(0.0, 300.0, (5.0, 1.0))]
        for seed in (11, 12, 13):
            trace = dynamic_trace(phases, seed=seed)
            res = run_adaptive(
                profiles, trace, HW, K_MAX,
                replan_period=30.0, window=30.0, initial_rates=(5.0, 1.0),
                cold_fallback_margin=0.25,
            )
            assert res.cold_fallback_times == []
            assert len(res.plan_objectives) == len(res.plans)


class TestAdaptiveDesBackend:
    def test_des_backend_adapts_and_matches_stepper_stats(self):
        profiles = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
        phases = [
            RatePhase(0.0, 200.0, (5.0, 1.0)),
            RatePhase(200.0, 400.0, (5.0, 4.0)),
        ]
        trace = dynamic_trace(phases, seed=21)
        common = dict(
            replan_period=30.0, window=30.0, initial_rates=(5.0, 1.0)
        )
        des = run_adaptive(profiles, trace, HW, K_MAX, backend="des", **common)
        step = run_adaptive(
            profiles, trace, HW, K_MAX, backend="stepper", **common
        )
        assert len(des.plans) > 1
        assert des.sim.tpu_utilization <= 1.0
        assert sum(len(l) for l in des.sim.latencies) == sum(
            len(l) for l in step.sim.latencies
        )
        # Two independent runtimes under the same controller: statistics
        # agree even though event mechanics differ.
        assert des.sim.overall_mean() == pytest.approx(
            step.sim.overall_mean(), rel=0.1
        )
        # Same rate estimates -> same re-plans on both backends.
        assert des.plans == step.plans


def _make_mlp_model(name: str, n_segments: int, dim: int, seed: int) -> ExecutableModel:
    key = jax.random.PRNGKey(seed)
    weights = []
    for i in range(n_segments):
        key, sub = jax.random.split(key)
        weights.append(jax.random.normal(sub, (dim, dim), jnp.float32) / jnp.sqrt(dim))

    def make_seg(w):
        @jax.jit
        def seg(x):
            return jnp.tanh(x @ w)
        return seg

    return ExecutableModel(
        name=name,
        segments=tuple(make_seg(w) for w in weights),
        make_input=lambda s: jax.random.normal(jax.random.PRNGKey(s), (1, dim)),
    )


class TestServingEngine:
    def test_end_to_end_execution_matches_sequential(self):
        models = [_make_mlp_model("a", 4, 32, 0), _make_mlp_model("b", 3, 32, 1)]
        plan = Plan((2, 1), (1, 1))
        eng = ServingEngine(models, plan, k_max=4)
        try:
            inputs = []
            for i, m in enumerate(models):
                for s in range(5):
                    x = m.make_input(s)
                    inputs.append((i, x))
                    eng.submit(i, x)
            done = eng.drain(timeout=30.0)
            assert len(done) == len(inputs)
            # Outputs must equal the plain sequential forward pass.
            by_model = {}
            for c in done:
                by_model.setdefault(c.model_idx, []).append(c)
            for i, m in enumerate(models):
                outs = {np.asarray(c.output).tobytes() for c in by_model[i]}
                expect = set()
                for s in range(5):
                    x = m.make_input(s)
                    for seg in m.segments:
                        x = seg(x)
                    expect.add(np.asarray(x).tobytes())
                assert outs == expect
        finally:
            eng.shutdown()

    def test_full_cpu_and_full_tpu_paths(self):
        models = [_make_mlp_model("a", 3, 16, 0), _make_mlp_model("b", 3, 16, 1)]
        plan = Plan((0, 3), (2, 0))  # model 0 all-CPU, model 1 all-TPU
        eng = ServingEngine(models, plan, k_max=4)
        try:
            for i in range(2):
                eng.submit(i, models[i].make_input(0))
            done = eng.drain(timeout=30.0)
            assert len(done) == 2
        finally:
            eng.shutdown()

    def test_plan_switch_live(self):
        models = [_make_mlp_model("a", 4, 16, 0)]
        eng = ServingEngine(models, Plan((4,), (0,)), k_max=4)
        try:
            eng.submit(0, models[0].make_input(0))
            eng.drain(timeout=30.0)
            eng.set_plan(Plan((2,), (2,)))
            eng.submit(0, models[0].make_input(1))
            done = eng.drain(timeout=30.0)
            assert len(done) == 1
        finally:
            eng.shutdown()

    def test_rejects_bad_plan(self):
        models = [_make_mlp_model("a", 2, 8, 0)]
        with pytest.raises(ValueError):
            ServingEngine(models, Plan((1, 1), (1, 1)), k_max=4)

    def test_segment_exception_surfaces_and_engine_survives(self):
        # A segment that raises must become an errored CompletedRequest --
        # not a dead worker thread holding the in-flight count forever.
        base = _make_mlp_model("a", 2, 16, 0)

        def raise_on_nan(x):
            if bool(np.isnan(np.asarray(x)).any()):
                raise RuntimeError("poisoned input")
            return x

        model = ExecutableModel(
            name="poison",
            segments=(base.segments[0], raise_on_nan, base.segments[1]),
            make_input=base.make_input,
        )
        # (partition, cores): all-prefix exercises the TPU-worker except
        # path; split exercises the CPU suffix-pool except path (NaN rides
        # through the jitted first segment into the raising one).
        for part, cores in ((3, 0), (1, 1)):
            eng = ServingEngine([model], Plan((part,), (cores,)), k_max=4)
            try:
                good = model.make_input(0)
                bad = jnp.full((1, 16), jnp.nan)
                eng.submit(0, good)
                eng.submit(0, bad)
                eng.submit(0, good)
                done = eng.drain(timeout=30.0)
                assert len(done) == 3
                errs = [c for c in done if not c.ok]
                assert len(errs) == 1
                assert isinstance(errs[0].error, RuntimeError)
                assert errs[0].output is None
                assert all(c.error is None for c in done if c.ok)
                # The engine keeps serving after the failure.
                eng.submit(0, good)
                done2 = eng.drain(timeout=30.0)
                assert len(done2) == 1 and done2[0].ok
            finally:
                eng.shutdown()

    def test_sync_dispatch_failure_releases_inflight_slot(self):
        # Synchronous zero-prefix dispatch failures must both propagate to
        # the submitter and release the in-flight slot so drain() returns.
        models = [_make_mlp_model("a", 2, 8, 0)]
        eng = ServingEngine(models, Plan((0,), (1,)), k_max=4)
        try:
            pool = eng._pools[0]
            eng._pools[0] = None  # simulate a lost suffix pool
            with pytest.raises(RuntimeError):
                eng.submit(0, models[0].make_input(0))
            done = eng.drain(timeout=5.0)
            assert len(done) == 1 and not done[0].ok
            eng._pools[0] = pool
            eng.submit(0, models[0].make_input(1))
            done = eng.drain(timeout=30.0)
            assert len(done) == 1 and done[0].ok
        finally:
            eng.shutdown()
