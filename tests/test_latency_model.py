"""Tests for the end-to-end latency model (Eq. 2, 4, 10)."""
import math

import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import latency, swap
from repro.core.planner import (
    Plan,
    TenantSpec,
    intra_swap_bytes,
    load_time,
    prefix_service_time,
)
from repro.configs.paper_models import paper_profile
from repro.hw.specs import EDGE_TPU_PLATFORM

HW = EDGE_TPU_PLATFORM


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


class TestAlpha:
    def test_fits_in_sram_alpha_zero(self):
        # MobileNetV2 (4.1 MB) + SqueezeNet (1.4 MB) fit in 8 MB -> alpha = 0
        # (the paper's Fig. 6a first scenario).
        ts = tenants_for(("mobilenetv2", 1.0), ("squeezenet", 1.0))
        partition = [t.profile.num_partition_points for t in ts]
        assert swap.weight_miss_probs(ts, partition, HW) == [0.0, 0.0]

    def test_single_tenant_alpha_zero(self):
        # Driver keeps weights persistent for a single model of any size.
        ts = tenants_for(("inceptionv4", 1.0))
        partition = [ts[0].profile.num_partition_points]
        assert swap.weight_miss_probs(ts, partition, HW) == [0.0]

    def test_5050_mix_alpha_half(self):
        # EfficientNet + GPUNet exceed 8 MB; 50:50 mix -> alpha = 0.5 each
        # (the paper's Fig. 6a second scenario).
        ts = tenants_for(("efficientnet", 2.0), ("gpunet", 2.0))
        partition = [t.profile.num_partition_points for t in ts]
        alphas = swap.weight_miss_probs(ts, partition, HW)
        assert alphas == pytest.approx([0.5, 0.5])

    def test_9010_skew(self):
        # 90:10 skew -> infrequent model suffers alpha = 0.9
        # (the paper's Fig. 6a third scenario).
        ts = tenants_for(("efficientnet", 9.0), ("gpunet", 1.0))
        partition = [t.profile.num_partition_points for t in ts]
        alphas = swap.weight_miss_probs(ts, partition, HW)
        assert alphas == pytest.approx([0.1, 0.9])

    def test_cpu_only_model_alpha_zero(self):
        ts = tenants_for(("efficientnet", 1.0), ("gpunet", 1.0))
        alphas = swap.weight_miss_probs(
            ts, [0, ts[1].profile.num_partition_points], HW
        )
        assert alphas[0] == 0.0
        # Only one model left on TPU -> single-tenant regime, alpha = 0.
        assert alphas[1] == 0.0

    @given(
        r1=st.floats(0.1, 10.0),
        r2=st.floats(0.1, 10.0),
        p1=st.integers(1, 6),
        p2=st.integers(1, 5),
    )
    @settings(max_examples=50, deadline=None)
    def test_alpha_bounds_and_complement(self, r1, r2, p1, p2):
        ts = tenants_for(("densenet201", r1), ("gpunet", r2))
        alphas = swap.weight_miss_probs(ts, [p1, p2], HW)
        for a in alphas:
            assert 0.0 <= a <= 1.0
        if (
            swap.aggregate_footprint(ts, [p1, p2]) > HW.sram_bytes
        ):
            # alpha_i = 1 - lambda_i/lambda_tpu => alphas sum to n-1.
            assert sum(alphas) == pytest.approx(len(ts) - 1)


class TestServiceTimes:
    def test_prefix_service_includes_intra_swap(self):
        prof = paper_profile("inceptionv4")
        P = prof.num_partition_points
        t_no_swap = prof.prefix_tpu_time(P)
        t_with = prefix_service_time(prof, P, HW)
        assert t_with > t_no_swap
        overflow = prof.total_weight_bytes - HW.sram_bytes
        assert t_with - t_no_swap == pytest.approx(overflow / HW.swap_bw)

    def test_small_prefix_no_intra_swap(self):
        prof = paper_profile("inceptionv4")
        for p in range(1, prof.num_partition_points + 1):
            if prof.prefix_weight_bytes(p) <= HW.sram_bytes:
                assert intra_swap_bytes(prof, p, HW) == 0

    def test_load_time_caps_at_sram(self):
        prof = paper_profile("inceptionv4")
        P = prof.num_partition_points
        assert load_time(prof, P, HW) == pytest.approx(
            HW.sram_bytes / HW.swap_bw
        )


class TestPerTermDecomposition:
    def test_static_plus_queueing_plus_swap_is_total(self):
        ts = tenants_for(("inceptionv4", 1.0), ("mnasnet", 2.0))
        pred = latency.predict(ts, Plan((9, 5), (2, 2)), HW)
        for b in pred.per_model:
            assert b.static + b.queueing + b.tpu_swap == pytest.approx(b.total)
        assert pred.static_latencies == tuple(b.static for b in pred.per_model)
        assert pred.queueing_latencies == tuple(
            b.queueing for b in pred.per_model
        )

    def test_static_is_load_independent(self):
        # The closed-form static path must not move with the arrival rate
        # (only waits and the expected swap penalty do).
        plan = Plan((9,), (4,))
        lo = latency.predict(tenants_for(("inceptionv4", 0.2)), plan, HW)
        hi = latency.predict(tenants_for(("inceptionv4", 4.0)), plan, HW)
        assert lo.static_latencies == hi.static_latencies
        assert hi.queueing_latencies[0] > lo.queueing_latencies[0]


class TestEndToEnd:
    def test_full_cpu_has_no_tpu_terms(self):
        ts = tenants_for(("mnasnet", 1.0))
        pred = latency.predict(ts, Plan((0,), (4,)), HW)
        b = pred.per_model[0]
        assert b.input_xfer == 0 and b.tpu_wait == 0 and b.tpu_service == 0
        assert b.cpu_service > 0

    def test_full_tpu_has_no_cpu_terms(self):
        ts = tenants_for(("mnasnet", 1.0))
        P = ts[0].profile.num_partition_points
        pred = latency.predict(ts, Plan((P,), (0,)), HW)
        b = pred.per_model[0]
        assert b.cpu_wait == 0 and b.cpu_service == 0
        assert b.tpu_service > 0

    def test_alpha0_variant_predicts_lower_latency_when_swapping(self):
        ts = tenants_for(("efficientnet", 2.0), ("gpunet", 2.0))
        plan = Plan(
            tuple(t.profile.num_partition_points for t in ts), (0, 0)
        )
        full = latency.predict(ts, plan, HW)
        a0 = latency.predict(ts, plan, HW, force_alpha_zero=True)
        assert a0.mean_latency(ts) < full.mean_latency(ts)

    def test_unstable_overload_inf(self):
        ts = tenants_for(("inceptionv4", 100.0))
        P = ts[0].profile.num_partition_points
        assert latency.objective(ts, Plan((P,), (0,)), HW) == math.inf

    @given(rate=st.floats(0.2, 4.0), p=st.integers(0, 11))
    @settings(max_examples=40, deadline=None)
    def test_breakdown_components_nonnegative(self, rate, p):
        ts = tenants_for(("inceptionv4", rate))
        k = 4 if p < 11 else 0
        pred = latency.predict(ts, Plan((p,), (k,)), HW)
        b = pred.per_model[0]
        for field in (
            b.input_xfer,
            b.tpu_wait,
            b.tpu_swap,
            b.tpu_service,
            b.boundary_xfer,
            b.cpu_wait,
            b.cpu_service,
        ):
            assert field >= 0.0

    @given(r=st.floats(0.2, 2.0))
    @settings(max_examples=20, deadline=None)
    def test_latency_increases_with_load(self, r):
        ts_lo = tenants_for(("inceptionv4", r))
        ts_hi = tenants_for(("inceptionv4", r * 1.5))
        P = 11
        plan = Plan((P,), (0,))
        lo = latency.predict(ts_lo, plan, HW).latencies[0]
        hi = latency.predict(ts_hi, plan, HW).latencies[0]
        assert hi >= lo
