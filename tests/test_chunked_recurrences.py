"""Equality of the chunked closed-form recurrences (§Perf optimizations)
against their sequential-scan references, at kernel and model level."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.models.frontend import make_train_batch
from repro.models.rwkv import wkv_chunked, wkv_scan
from repro.models.ssm import selective_scan, selective_scan_chunked
from repro.models.transformer import forward_loss, init_params


class TestWKVChunked:
    @given(
        t_pow=st.integers(4, 8),
        chunk=st.sampled_from([8, 16, 32, 64]),
        seed=st.integers(0, 50),
    )
    @settings(max_examples=10, deadline=None)
    def test_matches_scan(self, t_pow, chunk, seed):
        B, T, H, hd = 2, 2**t_pow, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        r = jax.random.normal(ks[0], (B, T, H, hd))
        k = jax.random.normal(ks[1], (B, T, H, hd))
        v = jax.random.normal(ks[2], (B, T, H, hd))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.3 + 0.69
        u = jax.random.normal(ks[4], (H, hd)) * 0.1
        s0 = jnp.zeros((B, H, hd, hd))
        out1, st1 = wkv_scan(r, k, v, w, u, s0)
        out2, st2 = wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(out1), np.asarray(out2), rtol=3e-3, atol=3e-3
        )
        np.testing.assert_allclose(
            np.asarray(st1), np.asarray(st2), rtol=3e-3, atol=3e-3
        )

    def test_nonzero_initial_state(self):
        B, T, H, hd = 1, 32, 1, 8
        ks = jax.random.split(jax.random.PRNGKey(7), 6)
        r = jax.random.normal(ks[0], (B, T, H, hd))
        k = jax.random.normal(ks[1], (B, T, H, hd))
        v = jax.random.normal(ks[2], (B, T, H, hd))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.3 + 0.69
        u = jax.random.normal(ks[4], (H, hd)) * 0.1
        s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.3
        out1, st1 = wkv_scan(r, k, v, w, u, s0)
        out2, st2 = wkv_chunked(r, k, v, w, u, s0, chunk=8)
        np.testing.assert_allclose(
            np.asarray(out1), np.asarray(out2), rtol=3e-3, atol=3e-3
        )


class TestSSDChunked:
    @given(
        t_pow=st.integers(4, 7),
        chunk=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 50),
    )
    @settings(max_examples=10, deadline=None)
    def test_matches_scan(self, t_pow, chunk, seed):
        B, S, d, N = 2, 2**t_pow, 12, 8
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        x = jax.random.normal(ks[0], (B, S, d))
        Bt = jax.random.normal(ks[1], (B, S, N)) * 0.5
        Ct = jax.random.normal(ks[2], (B, S, N)) * 0.5
        dt = jax.random.normal(ks[3], (B, S, d)) * 0.5
        A = jnp.exp(jax.random.normal(ks[4], (d,)) * 0.2)
        h0 = jnp.zeros((B, d, N))
        y1, h1 = selective_scan(x, Bt, Ct, dt, A, h0)
        y2, h2 = selective_scan_chunked(x, Bt, Ct, dt, A, h0, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(y1), np.asarray(y2), rtol=1e-3, atol=1e-3
        )
        np.testing.assert_allclose(
            np.asarray(h1), np.asarray(h2), rtol=1e-3, atol=1e-3
        )

    def test_nonzero_initial_state(self):
        B, S, d, N = 1, 16, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        x = jax.random.normal(ks[0], (B, S, d))
        Bt = jax.random.normal(ks[1], (B, S, N)) * 0.5
        Ct = jax.random.normal(ks[2], (B, S, N)) * 0.5
        dt = jax.random.normal(ks[3], (B, S, d)) * 0.5
        A = jnp.exp(jax.random.normal(ks[4], (d,)) * 0.2)
        h0 = jax.random.normal(ks[5], (B, d, N)) * 0.5
        y1, h1 = selective_scan(x, Bt, Ct, dt, A, h0)
        y2, h2 = selective_scan_chunked(x, Bt, Ct, dt, A, h0, chunk=8)
        np.testing.assert_allclose(
            np.asarray(y1), np.asarray(y2), rtol=1e-3, atol=1e-3
        )


class TestModelLevelEquivalence:
    @pytest.mark.parametrize("name", ["rwkv6-7b", "hymba-1.5b"])
    def test_chunked_flag_preserves_loss(self, name):
        cfg = ARCHS[name].reduced()
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        batch = make_train_batch(cfg, 2, 64)
        l1, _ = forward_loss(cfg, params, batch, remat=False)
        cfg2 = dataclasses.replace(cfg, use_chunked_scan=True)
        l2, _ = forward_loss(cfg2, params, batch, remat=False)
        assert float(l1) == pytest.approx(float(l2), rel=1e-3)
