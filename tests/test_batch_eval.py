"""Vectorized plan-space engine: scalar-equivalence and search-identity tests.

The invariant these tests enforce (recorded in ROADMAP.md): for every plan,
``penalized_objective_batch`` / ``objective_batch`` match the scalar
``penalized_objective`` / ``objective`` to float round-off, and the batched
``hill_climb`` / ``brute_force_oracle`` return byte-identical plans to the
seed scalar implementations.  Any change to the analytic model must preserve
this or update both paths together.
"""
import math

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs.paper_models import PAPER_MODEL_NAMES, paper_profile
from repro.core import latency, queueing
from repro.core.allocator import (
    _brute_force_scalar,
    _hill_climb_scalar,
    brute_force_oracle,
    hill_climb,
    prop_alloc,
    prop_alloc_batch,
)
from repro.core.plan_tables import EvalTables, PlanTables
from repro.core.planner import Plan, TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores
REL_TOL = 1e-12


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


def assert_close_or_same_special(scalar: float, batched: float, ctx):
    """Equal-to-round-off for finite values; exact for inf; NaN matches NaN."""
    if math.isnan(scalar) or math.isnan(batched):
        assert math.isnan(scalar) and math.isnan(batched), ctx
    elif math.isinf(scalar) or math.isinf(batched):
        assert scalar == batched, ctx
    else:
        assert batched == pytest.approx(scalar, rel=REL_TOL, abs=1e-300), ctx


def check_plans(ts, plans, *, force_alpha_zero=False):
    parts = np.array([p.partition for p in plans])
    cores = np.array([p.cores for p in plans])
    pen = latency.penalized_objective_batch(
        ts, parts, cores, HW, force_alpha_zero=force_alpha_zero
    )
    obj = latency.objective_batch(
        ts, parts, cores, HW, force_alpha_zero=force_alpha_zero
    )
    for row, plan in enumerate(plans):
        s_pen = latency.penalized_objective(
            ts, plan, HW, force_alpha_zero=force_alpha_zero
        )
        s_obj = latency.objective(ts, plan, HW, force_alpha_zero=force_alpha_zero)
        assert_close_or_same_special(s_pen, float(pen[row]), (plan, "penalized"))
        assert_close_or_same_special(s_obj, float(obj[row]), (plan, "objective"))


# --------------------------------------------------------------------------
# Objective equivalence
# --------------------------------------------------------------------------
class TestObjectiveEquivalence:
    NAMES = ["inceptionv4", "xception", "densenet201", "mnasnet", "mobilenetv2"]

    @given(
        rates=st.lists(st.floats(0.1, 8.0), min_size=1, max_size=4),
        k_max=st.integers(2, 12),
        faz=st.sampled_from([False, True]),
        data=st.data(),
    )
    @settings(max_examples=40, deadline=None)
    def test_random_plans_match_scalar(self, rates, k_max, faz, data):
        ts = tenants_for(*[(self.NAMES[i % 5], r) for i, r in enumerate(rates)])
        plans = []
        for _ in range(6):
            part = tuple(
                data.draw(st.integers(0, t.profile.num_partition_points))
                for t in ts
            )
            cores = tuple(
                data.draw(st.integers(0, k_max)) if p < t.profile.num_partition_points
                else 0
                for t, p in zip(ts, part)
            )
            plans.append(Plan(part, cores))
        check_plans(ts, plans, force_alpha_zero=faz)

    def test_full_tpu_k0_rows(self):
        # k = 0 on full-TPU rows is the valid-plan shape (constraint 7).
        ts = tenants_for(("mobilenetv2", 1.0), ("mnasnet", 2.0))
        plans = [
            Plan((ts[0].profile.num_partition_points,
                  ts[1].profile.num_partition_points), (0, 0)),
            Plan((ts[0].profile.num_partition_points, 3), (0, 2)),
        ]
        check_plans(ts, plans)

    def test_k0_with_suffix_matches_scalar_penalty(self):
        # Invalid allocation (suffix but no core): scalar predicts inf
        # latency; the batch path must agree rather than crash or diverge.
        ts = tenants_for(("inceptionv4", 1.0))
        plans = [Plan((3,), (0,))]
        check_plans(ts, plans)

    def test_unstable_queue_inf_cases(self):
        # Absurd rates overload both the TPU M/G/1 and the CPU M/D/k.
        ts = tenants_for(("inceptionv4", 500.0), ("xception", 500.0))
        P0 = ts[0].profile.num_partition_points
        P1 = ts[1].profile.num_partition_points
        plans = [
            Plan((P0, P1), (0, 0)),     # all-TPU, rho_tpu >> 1
            Plan((0, 0), (2, 2)),       # all-CPU, both pools overloaded
            Plan((P0 // 2, P1 // 2), (2, 2)),
        ]
        check_plans(ts, plans)

    def test_zero_rate_tenant(self):
        ts = tenants_for(("inceptionv4", 0.0), ("mnasnet", 1.0))
        plans = [
            Plan((5, 3), (2, 2)),
            Plan((0, ts[1].profile.num_partition_points), (1, 0)),
        ]
        check_plans(ts, plans)

    def test_single_all_cpu_and_empty_tpu(self):
        ts = tenants_for(("gpunet", 2.0))
        plans = [Plan((0,), (4,)), Plan((0,), (1,))]
        check_plans(ts, plans)

    def test_zero_rate_tenant_on_unstable_tpu_is_nan_like_scalar(self):
        # 0 * inf: the scalar objective yields NaN when a zero-rate tenant
        # sits on an overloaded TPU queue; the batch path must agree.
        ts = tenants_for(("inceptionv4", 500.0), ("mnasnet", 0.0))
        P0 = ts[0].profile.num_partition_points
        P1 = ts[1].profile.num_partition_points
        check_plans(ts, [Plan((P0, P1), (0, 0))])

    def test_platform_mismatch_rebuilds_tables(self):
        # Tables carry baked-in hardware constants; passing them with a
        # different platform must re-price, not silently reuse.
        from repro.hw.specs import TPU_V5E_SERVING_PLATFORM as DC

        ts = tenants_for(("inceptionv4", 2.0))
        tabs = PlanTables.for_tenants(ts, HW, K_MAX)
        parts, cores = np.array([[5]]), np.array([[2]])
        got = float(latency.objective_batch(ts, parts, cores, DC, tables=tabs)[0])
        want = latency.objective(ts, Plan((5,), (2,)), DC)
        assert got == pytest.approx(want, rel=REL_TOL)

    def test_stale_rate_eval_tables_reuse_base(self):
        ts = tenants_for(("inceptionv4", 2.0), ("mnasnet", 1.0))
        etab = EvalTables.build(ts, HW, K_MAX)
        drifted = [TenantSpec(t.profile, t.rate * 1.7) for t in ts]
        rebuilt = EvalTables.build(drifted, HW, K_MAX, base=etab.base)
        assert rebuilt.base is etab.base
        parts, cores = np.array([[5, 3]]), np.array([[2, 2]])
        got = float(
            latency.penalized_objective_batch(drifted, parts, cores, HW, tables=etab)[0]
        )
        want = latency.penalized_objective(drifted, Plan((5, 3), (2, 2)), HW)
        assert got == pytest.approx(want, rel=REL_TOL)

    def test_tables_reuse_matches_fresh(self):
        ts = tenants_for(("inceptionv4", 2.0), ("mnasnet", 1.0))
        parts = np.array([[5, 3], [11, 0]])
        cores = np.array([[2, 2], [0, 4]])
        base = PlanTables.for_tenants(ts, HW, K_MAX)
        etab = EvalTables.build(ts, HW, K_MAX, base=base)
        fresh = latency.penalized_objective_batch(ts, parts, cores, HW)
        via_base = latency.penalized_objective_batch(ts, parts, cores, HW, tables=base)
        via_eval = latency.penalized_objective_batch(ts, parts, cores, HW, tables=etab)
        np.testing.assert_array_equal(fresh, via_base)
        np.testing.assert_array_equal(fresh, via_eval)


# --------------------------------------------------------------------------
# Batched queueing primitives
# --------------------------------------------------------------------------
class TestQueueingBatch:
    @given(
        lam=st.floats(0.0, 2.0),
        es=st.floats(0.0, 2.0),
        cv=st.floats(0.0, 3.0),
    )
    @settings(max_examples=30, deadline=None)
    def test_mg1_matches_scalar(self, lam, es, cv):
        es2 = es * es * (1.0 + cv)
        batched = float(queueing.mg1_wait_batch(np.array([lam]), np.array([es]),
                                                np.array([es2]))[0])
        assert_close_or_same_special(queueing.mg1_wait(lam, es, es2), batched,
                                     (lam, es, es2))

    @given(
        lam=st.floats(0.0, 5.0),
        mu=st.floats(0.0, 5.0),
        k=st.integers(0, 6),
    )
    @settings(max_examples=30, deadline=None)
    def test_mdk_matches_scalar(self, lam, mu, k):
        batched = float(queueing.mdk_wait_batch(np.array([lam]), np.array([mu]),
                                                np.array([k]))[0])
        assert_close_or_same_special(queueing.mdk_wait(lam, mu, k), batched,
                                     (lam, mu, k))

    def test_mdk_infinite_mu_empty_suffix(self):
        # mu = inf (zero service time) must give zero wait, not NaN.
        assert queueing.mdk_wait_batch(np.array([1.0]), np.array([np.inf]),
                                       np.array([2]))[0] == 0.0

    @given(data=st.data(), n=st.integers(1, 6))
    @settings(max_examples=30, deadline=None)
    def test_mixture_moments_match_scalar(self, data, n):
        w = [data.draw(st.floats(0.0, 3.0)) for _ in range(n)]
        v = [data.draw(st.floats(0.0, 3.0)) for _ in range(n)]
        m1, m2 = queueing.mixture_moments(w, v)
        bm1, bm2 = queueing.mixture_moments_batch(np.array([w]), np.array([v]))
        assert float(bm1[0]) == pytest.approx(m1, rel=REL_TOL, abs=1e-300)
        assert float(bm2[0]) == pytest.approx(m2, rel=REL_TOL, abs=1e-300)


# --------------------------------------------------------------------------
# Search identity: batched == seed scalar implementations
# --------------------------------------------------------------------------
class TestSearchIdentity:
    @given(
        rates=st.lists(st.floats(0.2, 6.0), min_size=1, max_size=4),
        k_max=st.integers(4, 12),
    )
    @settings(max_examples=25, deadline=None)
    def test_hill_climb_plans_identical(self, rates, k_max):
        names = ["inceptionv4", "xception", "gpunet", "efficientnet"]
        ts = tenants_for(*[(names[i % 4], r) for i, r in enumerate(rates)])
        plan_b, obj_b = hill_climb(ts, HW, k_max, batch=True)
        plan_s, obj_s = _hill_climb_scalar(ts, HW, k_max)
        assert plan_b == plan_s
        assert obj_b == pytest.approx(obj_s, rel=1e-9)

    def test_hill_climb_auto_mode_identical(self):
        # The size-based auto dispatch must not change results either side
        # of the crossover.
        for n in (2, 6):
            ts = tenants_for(
                *[(TestObjectiveEquivalence.NAMES[i % 5], 0.4 + 0.3 * i)
                  for i in range(n)]
            )
            k_max = max(K_MAX, n)
            assert hill_climb(ts, HW, k_max)[0] == _hill_climb_scalar(ts, HW, k_max)[0]

    def test_hill_climb_force_alpha_zero_identical(self):
        ts = tenants_for(("inceptionv4", 2.0), ("xception", 1.5), ("mnasnet", 1.0))
        plan_b, _ = hill_climb(ts, HW, K_MAX, batch=True, force_alpha_zero=True)
        plan_s, _ = _hill_climb_scalar(ts, HW, K_MAX, force_alpha_zero=True)
        assert plan_b == plan_s

    @pytest.mark.parametrize(
        "mix",
        [
            [("mobilenetv2", 0.5)],
            [("inceptionv4", 2.0)],
            [("gpunet", 2.0), ("efficientnet", 2.0)],
            [("mnasnet", 3.0), ("mobilenetv2", 1.0)],
        ],
    )
    def test_brute_force_identical(self, mix):
        ts = tenants_for(*mix)
        plan_b, obj_b = brute_force_oracle(ts, HW, K_MAX)
        plan_s, obj_s = _brute_force_scalar(ts, HW, K_MAX)
        assert plan_b == plan_s
        assert obj_b == pytest.approx(obj_s, rel=1e-9)

    def test_brute_force_chunk_boundary(self):
        # A chunk size smaller than the feasible set exercises the
        # cross-chunk argmin tracking.
        ts = tenants_for(("mnasnet", 3.0), ("mobilenetv2", 1.0))
        plan_small, obj_small = brute_force_oracle(ts, HW, K_MAX, chunk_size=7)
        plan_ref, obj_ref = _brute_force_scalar(ts, HW, K_MAX)
        assert plan_small == plan_ref
        assert obj_small == pytest.approx(obj_ref, rel=1e-12)

    @given(
        rates=st.lists(st.floats(0.05, 6.0), min_size=1, max_size=5),
        k_max=st.integers(1, 14),
        data=st.data(),
    )
    @settings(max_examples=40, deadline=None)
    def test_prop_alloc_batch_identical(self, rates, k_max, data):
        names = ["inceptionv4", "xception", "densenet201", "mnasnet", "squeezenet"]
        ts = tenants_for(*[(names[i % 5], r) for i, r in enumerate(rates)])
        parts = np.array(
            [
                [
                    data.draw(st.integers(0, t.profile.num_partition_points))
                    for t in ts
                ]
                for _ in range(5)
            ]
        )
        cores_b, feasible = prop_alloc_batch(ts, parts, k_max)
        for row in range(parts.shape[0]):
            try:
                cores_s = prop_alloc(ts, list(parts[row]), k_max)
            except ValueError:
                assert not feasible[row]
            else:
                assert feasible[row]
                assert tuple(cores_b[row]) == cores_s


# --------------------------------------------------------------------------
# Table construction details
# --------------------------------------------------------------------------
class TestTables:
    def test_suffix_cpu_matrix_matches_scalar(self):
        prof = paper_profile("inceptionv4")
        mat = prof.suffix_cpu_matrix(6)
        for p in range(prof.num_partition_points + 1):
            for k in range(7):
                assert_close_or_same_special(
                    prof.suffix_cpu_time(p, k), float(mat[p, k]), (p, k)
                )

    def test_plan_tables_match_profile_accessors(self):
        ts = tenants_for(("inceptionv4", 1.0), ("mnasnet", 2.0))
        tab = PlanTables.for_tenants(ts, HW, K_MAX)
        from repro.core.planner import load_time, prefix_service_time

        for i, t in enumerate(ts):
            prof = t.profile
            for p in range(prof.num_partition_points + 1):
                assert tab.prefix_service[i, p] == pytest.approx(
                    prefix_service_time(prof, p, HW), rel=REL_TOL
                )
                assert tab.load[i, p] == pytest.approx(
                    load_time(prof, p, HW), rel=REL_TOL
                )
                assert tab.suffix1[i, p] == pytest.approx(
                    prof.suffix_cpu_time_1core(p), rel=REL_TOL
                )
                assert tab.prefix_weight[i, p] == prof.prefix_weight_bytes(p)
                assert tab.boundary[i, p] == pytest.approx(
                    prof.boundary_bytes(p) / HW.swap_bw, rel=REL_TOL
                )

    def test_padding_is_nan_poisoned(self):
        # Tenants of different depths: the shorter tenant's padded cells
        # must be NaN so out-of-range gathers cannot go unnoticed.
        ts = tenants_for(("inceptionv4", 1.0), ("squeezenet", 1.0))
        tab = PlanTables.for_tenants(ts, HW, K_MAX)
        P_short = ts[1].profile.num_partition_points
        P_long = ts[0].profile.num_partition_points
        if P_short < P_long:
            assert np.isnan(tab.prefix_service[1, P_short + 1 :]).all()

    def test_eval_tables_matches_guard(self):
        ts = tenants_for(("inceptionv4", 1.0), ("mnasnet", 2.0))
        etab = EvalTables.build(ts, HW, K_MAX)
        assert etab.matches(ts)
        other_rate = [TenantSpec(t.profile, t.rate + 1.0) for t in ts]
        assert not etab.matches(other_rate)
        other_prof = tenants_for(("xception", 1.0), ("mnasnet", 2.0))
        assert not etab.matches(other_prof)
