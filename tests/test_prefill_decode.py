"""Prefill -> decode continuation: prefilling a prompt and then decoding
token-by-token must produce the same logits as running the full sequence
through the forward pass (the serving path's core correctness invariant,
including ring-buffer cache seeding for sliding-window layers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.transformer import (
    backbone,
    decode_step,
    embed_inputs,
    init_params,
    prefill_step,
    unembed,
)

# rwkv6 prefill returns exact recurrent state; transformer archs rebuild KV
# caches; hymba has both plus SSM state; gemma3 exercises the ring buffer.
CONT_ARCHS = ["qwen1.5-0.5b", "gemma3-1b", "rwkv6-7b", "hymba-1.5b", "grok-1-314b"]


def _full_logits(cfg, params, tokens):
    h, _ = embed_inputs(cfg, params, {"tokens": tokens})
    h, _ = backbone(cfg, params, h, remat=False)
    return unembed(cfg, params, h)


@pytest.mark.parametrize("name", CONT_ARCHS)
def test_prefill_then_decode_matches_full_forward(name):
    import dataclasses

    cfg = ARCHS[name].reduced()
    if cfg.is_moe:
        # GShard capacity can drop tokens in batched (prefill/train) groups
        # but never at single-token decode; raise capacity so the invariant
        # is exact (the capacity-drop semantics are tested in moe tests).
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    P, T = 8, 12   # prefill 8 tokens, decode 4 more
    max_len = 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0, cfg.vocab_size)

    full = np.asarray(_full_logits(cfg, params, tokens))         # (1, T, V)

    logits, caches = prefill_step(
        cfg, params, {"tokens": tokens[:, :P]}, max_len=max_len
    )
    # But prefill caches are sized to max_len for global layers only when
    # built through init-time paths; prefill_step sizes them itself.
    np.testing.assert_allclose(
        np.asarray(logits)[0, 0], full[0, P - 1], rtol=2e-3, atol=2e-3,
        err_msg=f"{name}: prefill last-token logits mismatch",
    )
    for t in range(P, T):
        logits, caches = decode_step(
            cfg, params, caches, tokens[:, t : t + 1], jnp.int32(t)
        )
        np.testing.assert_allclose(
            np.asarray(logits)[0, 0], full[0, t], rtol=5e-3, atol=5e-3,
            err_msg=f"{name}: decode logits mismatch at position {t}",
        )


def test_ring_buffer_prefill_longer_than_window():
    """Sliding-window arch with prompt > window: ring seeding must hold."""
    cfg = ARCHS["gemma3-1b"].reduced()   # window 16 after reduction
    assert cfg.window == 16
    params = init_params(cfg, jax.random.PRNGKey(2), dtype=jnp.float32)
    P, T = 24, 28                        # prompt exceeds the window
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, T), 0, cfg.vocab_size)
    full = np.asarray(_full_logits(cfg, params, tokens))
    logits, caches = prefill_step(
        cfg, params, {"tokens": tokens[:, :P]}, max_len=64
    )
    np.testing.assert_allclose(
        np.asarray(logits)[0, 0], full[0, P - 1], rtol=2e-3, atol=2e-3
    )
    for t in range(P, T):
        logits, caches = decode_step(
            cfg, params, caches, tokens[:, t : t + 1], jnp.int32(t)
        )
        np.testing.assert_allclose(
            np.asarray(logits)[0, 0], full[0, t], rtol=5e-3, atol=5e-3
        )
