"""Fleet-scale plan space: two-level planner, trace router, merged results.

Invariants enforced here (recorded in ROADMAP.md):

* **N=1 degenerate pin (bitwise)**: a single-device unit-speed fleet built
  from ``DeviceSpec.from_platform`` is the single-device API, exactly --
  ``fleet_hill_climb`` returns ``hill_climb``'s plan and objective,
  ``simulate_fleet`` returns ``simulate``'s latencies/counters bitwise on
  both the stepper and the DES, and ``run_adaptive_fleet`` replays
  ``run_adaptive(cold_fallback_margin=None)``'s plan history and merged
  latencies bitwise.
* ``route_trace`` partitions its input exactly (every request lands on
  exactly one device, global model indices and arrival stamps preserved),
  is deterministic in its seed, and commutes with the JSON replay contract.
* ``validate_fleet_plan`` rejects malformed fleet plans (bad partition
  index, cores over a device's budget, tenant placed on no device, routing
  weights off unity) with informative errors.
* ``merge_fleet_results`` pools per-device metrics on one clock and is the
  identity (same column objects) for a one-device fleet.
* Sustained offered-load imbalance -- and only *sustained* imbalance --
  triggers a placement re-plan in ``run_adaptive_fleet``.
"""
import json
import math

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs.paper_models import paper_profile
from repro.core.allocator import hill_climb
from repro.core.fleet import (
    DeviceSpec,
    FleetPlan,
    FleetTablesCache,
    fleet_hill_climb,
    round_robin_fleet_plan,
    validate_fleet_plan,
)
from repro.core.planner import Plan, TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.controller import run_adaptive
from repro.serving.fleet import (
    offered_device_loads,
    run_adaptive_fleet,
    simulate_fleet,
)
from repro.serving.result import SimResult, merge_fleet_results
from repro.serving.scheduling import FCFS
from repro.serving.simulator import make_backend, simulate
from repro.serving.workload import (
    RatePhase,
    dynamic_trace,
    poisson_trace,
    route_trace,
    trace_from_json,
    trace_to_json,
)

HW = EDGE_TPU_PLATFORM


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


def small_mix():
    return tenants_for(
        ("squeezenet", 4.0), ("mobilenetv2", 3.0), ("mnasnet", 2.0)
    )


def hetero_fleet():
    return [
        DeviceSpec("fast", 8 << 20, 400e6, 4, tpu_speed=1.2),
        DeviceSpec("ref", 8 << 20, 400e6, 4),
        DeviceSpec("small", 4 << 20, 200e6, 2, tpu_speed=0.6, cpu_speed=0.7),
        DeviceSpec("tiny", 2 << 20, 100e6, 2, tpu_speed=0.4, cpu_speed=0.5),
    ]


def eight_tenants():
    names = [
        "squeezenet", "mobilenetv2", "efficientnet", "mnasnet",
        "gpunet", "densenet201", "resnet50v2", "xception",
    ]
    return [
        TenantSpec(paper_profile(n), 2.0 + 0.5 * i)
        for i, n in enumerate(names)
    ]


def unit_device(n_cores: int) -> DeviceSpec:
    return DeviceSpec.from_platform(HW, cpu_cores=n_cores)


def assert_results_bitwise(ref: SimResult, got: SimResult):
    assert len(ref.latencies) == len(got.latencies)
    for i in range(len(ref.latencies)):
        a = np.asarray(ref.latencies[i], dtype=np.float64)
        b = np.asarray(got.latencies[i], dtype=np.float64)
        assert np.array_equal(a, b), f"model {i} latencies drifted"
        a = np.asarray(ref.arrivals[i], dtype=np.float64)
        b = np.asarray(got.arrivals[i], dtype=np.float64)
        assert np.array_equal(a, b), f"model {i} arrivals drifted"
    assert ref.misses == got.misses
    assert ref.tpu_requests == got.tpu_requests
    assert ref.tpu_busy == got.tpu_busy
    assert ref.duration == got.duration


# ---------------------------------------------------------------------------
# DeviceSpec


class TestDeviceSpec:
    def test_from_platform_preserves_platform_object(self):
        dev = unit_device(len(small_mix()))
        assert dev.platform is HW
        assert dev.sram_bytes == HW.sram_bytes
        assert dev.swap_bw == HW.swap_bw

    def test_synthesized_platform_matches_spec(self):
        dev = DeviceSpec("d", 4 << 20, 200e6, 2)
        assert dev.platform.sram_bytes == 4 << 20
        assert dev.platform.swap_bw == 200e6
        assert dev.platform.cpu.n_cores == 2

    def test_equal_class_devices_share_platform_equality(self):
        a = DeviceSpec("a", 4 << 20, 200e6, 2, tpu_speed=0.5)
        b = DeviceSpec("b", 4 << 20, 200e6, 2, tpu_speed=0.5)
        assert a.class_key == b.class_key
        assert a.platform == b.platform

    def test_scaled_profiles_identity_at_unit_speed(self):
        dev = DeviceSpec("d", 8 << 20, 400e6, 4)
        profiles = [t.profile for t in small_mix()]
        assert all(a is b for a, b in zip(dev.scaled_profiles(profiles), profiles))

    def test_scaled_identity_survives_equal_twin_cache_entries(self):
        # The scaled() LRU keys on profile *value*: an equal-but-distinct
        # twin that populated the cache first (e.g. a rebuilt paper
        # profile) must not shadow the unit-speed ``self`` identity.
        a, b = paper_profile("squeezenet"), paper_profile("squeezenet")
        assert a is not b and a == b
        assert b.scaled(2.0, 1.0) is not None  # warm the value-keyed cache
        assert a.scaled(1.0, 1.0) is a
        assert b.scaled(1.0, 1.0) is b
        # Non-unit factors may legitimately share one cached object.
        assert a.scaled(2.0, 1.0) == b.scaled(2.0, 1.0)

    def test_scaled_profile_retimes(self):
        dev = DeviceSpec("d", 8 << 20, 400e6, 4, tpu_speed=2.0, cpu_speed=0.5)
        base = paper_profile("mnasnet")
        scaled = dev.scaled_profiles([base])[0]
        for s0, s1 in zip(base.segments, scaled.segments):
            assert s1.tpu_time == s0.tpu_time / 2.0
            assert s1.cpu_time_1core == s0.cpu_time_1core / 0.5
            assert s1.weight_bytes == s0.weight_bytes

    def test_invalid_spec_rejected(self):
        with pytest.raises(ValueError):
            DeviceSpec("d", -1, 400e6, 4)
        with pytest.raises(ValueError):
            DeviceSpec("d", 8 << 20, -1.0, 4)
        with pytest.raises(ValueError):
            DeviceSpec("d", 8 << 20, 400e6, -1)
        with pytest.raises(ValueError):
            DeviceSpec("d", 8 << 20, 400e6, 4, tpu_speed=0.0)


# ---------------------------------------------------------------------------
# N=1 degenerate pins


class TestDegenerateFleet:
    def test_plan_and_objective_bitwise(self):
        ts = small_mix()
        fp, fobj = fleet_hill_climb(ts, [unit_device(len(ts))])
        plan, obj = hill_climb(ts, HW, len(ts))
        assert fp.device_plans[0] == plan
        assert fobj == obj
        assert fp.placement == tuple((0,) for _ in ts)
        assert fp.routing == tuple((1.0,) for _ in ts)

    @pytest.mark.parametrize("backend", ["stepper", "des"])
    def test_simulate_fleet_bitwise(self, backend):
        ts = small_mix()
        dev = unit_device(len(ts))
        fp, _ = fleet_hill_climb(ts, [dev])
        plan, _ = hill_climb(ts, HW, len(ts))
        trace = poisson_trace([t.rate for t in ts], 60.0, seed=3)
        ref = simulate(ts, plan, HW, trace, backend=backend)
        got = simulate_fleet(ts, fp, [dev], trace, backend=backend)
        assert_results_bitwise(ref, got)
        assert got.n_devices == 1
        assert got.tpu_utilization == ref.tpu_utilization

    def test_adaptive_fleet_replays_single_device_controller(self):
        ts = small_mix()
        profiles = [t.profile for t in ts]
        trace = dynamic_trace(
            [
                RatePhase(0.0, 60.0, (4.0, 1.0, 1.0)),
                RatePhase(60.0, 120.0, (1.0, 1.0, 4.0)),
            ],
            seed=11,
        )
        ref = run_adaptive(
            profiles,
            trace,
            HW,
            len(ts),
            replan_period=20.0,
            cold_fallback_margin=None,
        )
        got = run_adaptive_fleet(
            profiles, trace, [unit_device(len(ts))], replan_period=20.0
        )
        assert got.replan_times == ref.replan_times
        assert [fp.device_plans[0] for fp in got.fleet_plans] == ref.plans
        assert_results_bitwise(ref.sim, got.sim)


# ---------------------------------------------------------------------------
# Fleet planner


class TestFleetHillClimb:
    def test_placement_beats_round_robin_on_hetero_fleet(self):
        ts = eight_tenants()
        fleet = hetero_fleet()
        cache = FleetTablesCache()
        fp, fobj = fleet_hill_climb(ts, fleet, tables=cache)
        rr, robj = round_robin_fleet_plan(ts, fleet, tables=cache)
        validate_fleet_plan(fp, ts, fleet)
        validate_fleet_plan(rr, ts, fleet)
        assert fobj < robj

    def test_warm_replan_keeps_placement(self):
        ts = eight_tenants()
        fleet = hetero_fleet()
        cache = FleetTablesCache()
        cold, _ = fleet_hill_climb(ts, fleet, tables=cache)
        drifted = [TenantSpec(t.profile, t.rate * 1.3) for t in ts]
        warm, wobj = fleet_hill_climb(drifted, fleet, init=cold, tables=cache)
        assert warm.placement == cold.placement
        assert warm.routing == cold.routing
        assert math.isfinite(wobj)
        validate_fleet_plan(warm, drifted, fleet)

    def test_capacity_exhausted_raises(self):
        ts = eight_tenants()
        fleet = [DeviceSpec("a", 8 << 20, 400e6, 3), DeviceSpec("b", 8 << 20, 400e6, 3)]
        with pytest.raises(ValueError, match="cannot host"):
            fleet_hill_climb(ts, fleet)

    def test_empty_fleet_raises(self):
        with pytest.raises(ValueError):
            fleet_hill_climb(small_mix(), [])

    def test_unplaced_tenant_rows_are_inert(self):
        ts = eight_tenants()
        fleet = hetero_fleet()
        fp, _ = fleet_hill_climb(ts, fleet)
        for d, plan in enumerate(fp.device_plans):
            for i, t in enumerate(ts):
                if d not in fp.placement[i]:
                    assert plan.partition[i] == len(t.profile.segments)
                    assert plan.cores[i] == 0

    def test_tables_cache_reused_across_replans(self):
        ts = small_mix()
        fleet = [
            DeviceSpec("a", 4 << 20, 200e6, 4),
            DeviceSpec("b", 4 << 20, 200e6, 4),
        ]
        cache = FleetTablesCache()
        plan0, _ = fleet_hill_climb(ts, fleet, tables=cache)
        built = len(cache._tables)
        assert built >= 1
        # A warm re-plan over the same (class, mix) builds no new tables:
        # identity-keyed profiles hit the existing entries.
        drifted = [TenantSpec(t.profile, t.rate * 1.2) for t in ts]
        fleet_hill_climb(drifted, fleet, init=plan0, tables=cache)
        assert len(cache._tables) == built


# ---------------------------------------------------------------------------
# validate_fleet_plan rejection paths (property-tested)


def _valid_fleet_and_plan(n_tenants=3):
    ts = small_mix()[:n_tenants]
    fleet = [DeviceSpec("a", 8 << 20, 400e6, 4), DeviceSpec("b", 8 << 20, 400e6, 4)]
    fp, _ = fleet_hill_climb(ts, fleet)
    return ts, fleet, fp


class TestValidateFleetPlanRejections:
    def test_valid_plan_accepted(self):
        ts, fleet, fp = _valid_fleet_and_plan()
        validate_fleet_plan(fp, ts, fleet)

    @given(st.integers(min_value=0, max_value=2))
    @settings(max_examples=10)
    def test_bad_partition_index_rejected(self, tenant_idx):
        ts, fleet, fp = _valid_fleet_and_plan()
        dev = fp.placement[tenant_idx][0]
        plan = fp.device_plans[dev]
        bad_p = len(ts[tenant_idx].profile.segments) + 1
        partition = tuple(
            bad_p if i == tenant_idx else p for i, p in enumerate(plan.partition)
        )
        bad = FleetPlan(
            placement=fp.placement,
            routing=fp.routing,
            device_plans=tuple(
                Plan(partition, pl.cores, pl.discipline) if d == dev else pl
                for d, pl in enumerate(fp.device_plans)
            ),
        )
        with pytest.raises(ValueError):
            validate_fleet_plan(bad, ts, fleet)

    @given(st.integers(min_value=5, max_value=12))
    @settings(max_examples=10)
    def test_cores_over_device_budget_rejected(self, total_cores):
        ts, fleet, fp = _valid_fleet_and_plan()
        dev = fp.placement[0][0]
        plan = fp.device_plans[dev]
        # Inflate tenant 0's cores so the device total exceeds cpu_cores=4.
        cores = tuple(
            total_cores if i == 0 else c for i, c in enumerate(plan.cores)
        )
        partition = tuple(
            0 if i == 0 else p for i, p in enumerate(plan.partition)
        )
        bad = FleetPlan(
            placement=tuple(
                (dev,) if i == 0 else p for i, p in enumerate(fp.placement)
            ),
            routing=fp.routing,
            device_plans=tuple(
                Plan(partition, cores, pl.discipline) if d == dev else pl
                for d, pl in enumerate(fp.device_plans)
            ),
        )
        with pytest.raises(ValueError):
            validate_fleet_plan(bad, ts, fleet)

    @given(st.integers(min_value=0, max_value=2))
    @settings(max_examples=10)
    def test_tenant_placed_on_no_device_rejected(self, tenant_idx):
        ts, fleet, fp = _valid_fleet_and_plan()
        bad = FleetPlan(
            placement=tuple(
                () if i == tenant_idx else p for i, p in enumerate(fp.placement)
            ),
            routing=tuple(
                () if i == tenant_idx else r for i, r in enumerate(fp.routing)
            ),
            device_plans=fp.device_plans,
        )
        with pytest.raises(ValueError, match="no device"):
            validate_fleet_plan(bad, ts, fleet)

    @given(st.floats(min_value=0.05, max_value=0.9))
    @settings(max_examples=10)
    def test_routing_weights_off_unity_rejected(self, w):
        ts, fleet, fp = _valid_fleet_and_plan()
        bad = FleetPlan(
            placement=fp.placement,
            routing=tuple(
                (w,) if i == 0 else r for i, r in enumerate(fp.routing)
            ),
            device_plans=fp.device_plans,
        )
        with pytest.raises(ValueError, match="sum"):
            validate_fleet_plan(bad, ts, fleet)

    def test_out_of_range_device_rejected(self):
        ts, fleet, fp = _valid_fleet_and_plan()
        bad = FleetPlan(
            placement=tuple(
                (7,) if i == 0 else p for i, p in enumerate(fp.placement)
            ),
            routing=fp.routing,
            device_plans=fp.device_plans,
        )
        with pytest.raises(ValueError):
            validate_fleet_plan(bad, ts, fleet)


# ---------------------------------------------------------------------------
# route_trace


class TestRouteTrace:
    def _placed(self, n_tenants, n_devices):
        placement = tuple((i % n_devices,) for i in range(n_tenants))
        routing = tuple((1.0,) for _ in range(n_tenants))
        return placement, routing

    @given(st.integers(min_value=1, max_value=4), st.integers(min_value=0, max_value=99))
    @settings(max_examples=15)
    def test_partitions_exactly(self, n_devices, seed):
        rates = [3.0, 2.0, 1.0, 1.0, 0.5]
        trace = poisson_trace(rates, 40.0, seed=seed)
        placement, routing = self._placed(len(rates), n_devices)
        subs = route_trace(trace, placement, routing, n_devices)
        assert sum(len(s) for s in subs) == len(trace)
        for d, sub in enumerate(subs):
            for m in np.unique(np.asarray(sub.model_idx)):
                assert placement[int(m)] == (d,)
        merged = np.sort(np.concatenate([np.asarray(s.arrival) for s in subs]))
        assert np.array_equal(merged, np.sort(np.asarray(trace.arrival)))

    def test_single_device_identity(self):
        trace = poisson_trace([2.0, 1.0], 30.0, seed=0)
        subs = route_trace(trace, ((0,), (0,)), ((1.0,), (1.0,)), 1)
        assert subs[0] is trace

    def test_multi_placement_split_is_seed_deterministic(self):
        trace = poisson_trace([5.0], 60.0, seed=2)
        placement, routing = ((0, 1),), ((0.5, 0.5),)
        a = route_trace(trace, placement, routing, 2, seed=9)
        b = route_trace(trace, placement, routing, 2, seed=9)
        c = route_trace(trace, placement, routing, 2, seed=10)
        for s0, s1 in zip(a, b):
            assert np.array_equal(np.asarray(s0.arrival), np.asarray(s1.arrival))
        assert any(
            not np.array_equal(np.asarray(s0.arrival), np.asarray(s1.arrival))
            for s0, s1 in zip(a, c)
        )
        assert sum(len(s) for s in a) == len(trace)

    def test_json_replay_routes_bitwise(self):
        trace = poisson_trace([3.0, 2.0], 50.0, seed=4)
        replay = trace_from_json(trace_to_json(trace))
        placement, routing = ((0, 1), (1,)), ((0.3, 0.7), (1.0,))
        a = route_trace(trace, placement, routing, 2, seed=1)
        b = route_trace(replay, placement, routing, 2, seed=1)
        for s0, s1 in zip(a, b):
            assert np.array_equal(np.asarray(s0.model_idx), np.asarray(s1.model_idx))
            assert np.array_equal(np.asarray(s0.arrival), np.asarray(s1.arrival))

    def test_unplaced_model_in_trace_raises(self):
        trace = poisson_trace([1.0, 1.0], 30.0, seed=0)
        with pytest.raises(ValueError, match="unplaced"):
            route_trace(trace, ((0,),), ((1.0,),), 2)


# ---------------------------------------------------------------------------
# merge_fleet_results


def _sim_result(latencies, arrivals, duration=10.0, misses=None):
    n = len(latencies)
    return SimResult(
        latencies=[list(l) for l in latencies],
        arrivals=[list(a) for a in arrivals],
        tpu_busy=sum(float(np.sum(l)) for l in latencies),
        duration=duration,
        misses=misses or [0] * n,
        tpu_requests=[len(l) for l in latencies],
    )


class TestMergeFleetResults:
    def test_single_device_is_identity(self):
        r = _sim_result([[0.1, 0.2], [0.3]], [[1.0, 2.0], [1.5]])
        merged = merge_fleet_results([r])
        assert merged.latencies[0] is r.latencies[0]
        assert merged.duration == r.duration
        assert merged.n_devices == 1

    def test_pools_latencies_and_sums_counters(self):
        a = _sim_result([[0.1], []], [[1.0], []], duration=10.0, misses=[1, 0])
        b = _sim_result([[], [0.2, 0.4]], [[], [2.0, 3.0]], duration=12.0, misses=[0, 2])
        merged = merge_fleet_results([a, b])
        assert merged.n_devices == 2
        assert list(np.asarray(merged.latencies[0])) == [0.1]
        assert list(np.asarray(merged.latencies[1])) == [0.2, 0.4]
        assert merged.misses == [1, 2]
        assert merged.tpu_requests == [1, 2]
        assert merged.duration == 12.0
        assert merged.tpu_busy == pytest.approx(a.tpu_busy + b.tpu_busy)

    def test_fleet_utilization_normalizes_by_devices(self):
        a = _sim_result([[1.0]], [[0.0]], duration=10.0)
        b = _sim_result([[1.0]], [[0.0]], duration=10.0)
        merged = merge_fleet_results([a, b])
        assert merged.tpu_utilization == pytest.approx(2.0 / (10.0 * 2))

    def test_mismatched_model_counts_raise(self):
        a = _sim_result([[0.1]], [[1.0]])
        b = _sim_result([[0.1], [0.2]], [[1.0], [2.0]])
        with pytest.raises(ValueError):
            merge_fleet_results([a, b])

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            merge_fleet_results([])


# ---------------------------------------------------------------------------
# Adaptive fleet controller


class TestAdaptiveFleet:
    def test_imbalance_triggers_placement_replan(self):
        ts = eight_tenants()
        profiles = [t.profile for t in ts]
        fleet = hetero_fleet()
        base = tuple(1.0 for _ in ts)
        spike_late = tuple(
            8.0 if i >= 6 else 0.3 for i in range(len(ts))
        )
        trace = dynamic_trace(
            [RatePhase(0.0, 80.0, base), RatePhase(80.0, 240.0, spike_late)],
            seed=13,
        )
        res = run_adaptive_fleet(
            profiles,
            trace,
            fleet,
            replan_period=20.0,
            imbalance_threshold=0.15,
            imbalance_patience=2,
        )
        assert res.placement_replan_times, "sustained skew never re-placed"
        assert set(res.placement_replan_times) <= set(res.replan_times)
        # One plan per boundary (the initial plan's boundary is t=0).
        assert len(res.fleet_plans) == len(res.replan_times)

    def test_no_imbalance_no_placement_replan(self):
        ts = small_mix()
        profiles = [t.profile for t in ts]
        fleet = [
            DeviceSpec("a", 8 << 20, 400e6, 4),
            DeviceSpec("b", 8 << 20, 400e6, 4),
        ]
        trace = poisson_trace([t.rate for t in ts], 120.0, seed=7)
        res = run_adaptive_fleet(
            profiles, trace, fleet, replan_period=30.0, imbalance_threshold=10.0
        )
        assert res.placement_replan_times == []
        placements = {fp.placement for fp in res.fleet_plans}
        assert len(placements) == 1

    def test_controller_fleet_kwarg_delegates(self):
        ts = small_mix()
        profiles = [t.profile for t in ts]
        trace = poisson_trace([t.rate for t in ts], 60.0, seed=1)
        dev = unit_device(len(ts))
        via_controller = run_adaptive(
            profiles, trace, HW, len(ts), replan_period=30.0, fleet=[dev]
        )
        direct = run_adaptive_fleet(profiles, trace, [dev], replan_period=30.0)
        assert via_controller.replan_times == direct.replan_times
        assert_results_bitwise(via_controller.sim, direct.sim)

    def test_controller_fleet_rejects_custom_planner(self):
        ts = small_mix()
        profiles = [t.profile for t in ts]
        trace = poisson_trace([t.rate for t in ts], 10.0, seed=1)
        with pytest.raises(ValueError, match="fleet"):
            run_adaptive(
                profiles,
                trace,
                HW,
                len(ts),
                planner=lambda *a, **k: (None, 0.0),
                fleet=[unit_device(len(ts))],
            )

    def test_guard_history_cleared_on_placement_replan(self):
        # Regression (PR 8): the opt-in warm-tail guard's trend history is
        # normalized-objective samples of the *incumbent placement*.  A
        # committed placement re-plan changes that baseline, so the history
        # must restart -- without the clear, the first post-migration
        # boundary is judged against pre-migration (light-load) norms and
        # the guard mis-fires on every boundary after a migration under
        # heavier load (verified: removing the clear makes this scenario
        # cold-fallback at the boundary right after the migration).
        ts = eight_tenants()
        profiles = [t.profile for t in ts]
        fleet = hetero_fleet()
        base = tuple(1.0 for _ in ts)
        spike_late = tuple(8.0 if i >= 6 else 0.3 for i in range(len(ts)))
        trace = dynamic_trace(
            [RatePhase(0.0, 80.0, base), RatePhase(80.0, 240.0, spike_late)],
            seed=13,
        )
        period = 20.0
        res = run_adaptive_fleet(
            profiles,
            trace,
            fleet,
            replan_period=period,
            imbalance_threshold=0.15,
            imbalance_patience=2,
            cold_fallback_margin=0.05,
        )
        assert res.placement_replan_times, "scenario must migrate tenants"
        # The guard itself stays live (it fires on the pre-migration load
        # rise), but never inside the stale-history window right after a
        # committed migration.
        assert res.cold_fallback_times, "scenario must exercise the guard"
        window = 5 * period  # cold_fallback_window boundaries
        for pt in res.placement_replan_times:
            assert not any(
                pt < t <= pt + window for t in res.cold_fallback_times
            ), f"guard mis-fired against stale history after migration at {pt}"

    def test_guard_defaults_off_in_fleet_mode(self):
        # The fleet guard is opt-in: defaults never cold-fallback, and the
        # result field stays empty (the delegation pins in
        # TestDegenerateFleet rely on this default staying off).
        ts = small_mix()
        profiles = [t.profile for t in ts]
        trace = poisson_trace([t.rate for t in ts], 90.0, seed=11)
        fleet = [unit_device(len(ts))]
        res = run_adaptive_fleet(profiles, trace, fleet, replan_period=30.0)
        assert res.cold_fallback_times == []

    def test_offered_loads_shape_and_scaling(self):
        ts = small_mix()
        fleet = [
            DeviceSpec("a", 8 << 20, 400e6, 4),
            DeviceSpec("b", 8 << 20, 400e6, 4, tpu_speed=2.0),
        ]
        fp, _ = fleet_hill_climb(ts, fleet)
        loads = offered_device_loads(ts, fp, fleet, [t.rate for t in ts])
        assert len(loads) == 2
        assert all(l >= 0.0 for l in loads)


# ---------------------------------------------------------------------------
# make_backend registry (satellite regression)


class TestMakeBackendErrors:
    def test_unknown_backend_lists_valid_names(self):
        ts = small_mix()
        plan, _ = hill_climb(ts, HW, len(ts))
        profiles = [t.profile for t in ts]
        with pytest.raises(ValueError) as ei:
            make_backend("qpu", profiles, plan, HW)
        msg = str(ei.value)
        assert "'qpu'" in msg
        for name in ("stepper", "des", "jax"):
            assert f"'{name}'" in msg
